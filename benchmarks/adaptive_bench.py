"""Adaptive benchmark: goodput under SLO while the traffic mix flips.

Replays a compressed diurnal trace — two arrivals per slot whose
mbv1:squeezenet mix flips 3:1 -> 1:3 halfway through — against the same
overloaded single-pool fleet three ways:

  * ``static``   — weighted-fair shares frozen at the plan-time (phase-A)
    mix.  With ``co_dispatch=0`` the weights *are* the dispatch schedule,
    so after the flip the favored-but-idle member burns burst slots while
    the newly hot member's slot deadlines expire: stale weights shed.
  * ``adaptive`` — the same fleet plus a :class:`ControlLoop` (DESIGN.md
    §13) observing every ``INTERVAL`` slots and injecting
    ``SET_PARAM(weight)`` reweights when the arrival mix drifts past the
    deadband.  Gated hard in-bench: adaptive goodput >= static goodput
    and strictly fewer post-flip sheds.
  * ``replay``   — the adaptive run's recorded stream re-executed on a
    fresh fleet with **no controller attached**: stream signatures, shed
    sets and outputs must match bitwise, the decision log must verify
    against the replayed stream (``verify_decisions``), and the replayed
    SET_PARAMs must leave the fresh fleet at the flipped weights.

Writes ``BENCH_adaptive.json``; its ``goodput_fps`` leaves are gated
higher-is-better in ``benchmarks/compare_bench.py``.

    PYTHONPATH=src python -m benchmarks.adaptive_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# Two host platform devices unless the caller already configured XLA
# (must happen pre-import) — the pool leases a 2-device c/p split.
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

MODELS = ("mobilenet_v1", "squeezenet")
MIX_A = {"mobilenet_v1": 0.75, "squeezenet": 0.25}   # plan-time mix
RATE = 2            # arrivals per slot: sustained overload, not a spike
BURST = 2
SLACK = 4           # slot deadline = arrival slot + SLACK (+ rid jitter)
INTERVAL = 6        # controller observation period (fleet slots)


def _statuses(res):
    return {c.ticket.rid: c.metrics.status for c in res.completions}


def _drive(engine, reqs, arrivals):
    """Open-loop drive: submit each request at its arrival step, retry
    admission-refused (QueueFull) submissions next step, run to drain."""
    from repro.serving import QueueFull

    order = sorted(range(len(reqs)), key=lambda i: arrivals[i])
    nxt, step, refused = 0, 0, []
    while nxt < len(order) or refused or engine.has_work:
        due, refused = refused, []
        while nxt < len(order) and arrivals[order[nxt]] <= step:
            due.append(order[nxt])
            nxt += 1
        for i in due:
            try:
                engine.submit(reqs[i])
            except QueueFull:
                refused.append(i)
        if engine.has_work:
            engine.step()
        step += 1
    return engine.result()


def _diurnal_tags(requests: int) -> tuple[list[str], int]:
    """Mix 3:1 for the first half, 1:3 for the second: the compressed
    day/night flip.  Returns (tags, first post-flip request index)."""
    m1, m2 = MODELS
    day, night = [m1, m1, m1, m2], [m2, m2, m2, m1]
    half = requests // 2
    tags = [day[i % 4] for i in range(half)] + \
        [night[i % 4] for i in range(requests - half)]
    return tags, half


def bench_adaptive(report: dict, image_size: int, requests: int,
                   reps: int) -> None:
    import jax

    from repro.fleet import (ControlLoop, FleetEngine, WeightedFair,
                             build_cnn_fleet, decisions_from_json,
                             decisions_to_json, stream_from_json,
                             stream_signature, stream_to_json,
                             verify_decisions)
    from repro.fleet.instructions import SetParam
    from repro.serving import Request, ShedPolicy

    eng0, pool = build_cnn_fleet(list(MODELS), weights=MIX_A,
                                 use_pallas=True, fuse="group")
    runners = {m.name: m.engine.runner for m in eng0.members}

    def fresh_fleet():
        from repro.serving import DualCoreEngine

        members = {m: DualCoreEngine(r) for m, r in runners.items()}
        eng = FleetEngine(members, policy=WeightedFair(), weights=MIX_A,
                          burst=BURST, co_dispatch=0, pool=pool)
        for m in eng.members:   # slot-clock SLO shedding at admission
            m.engine.policy = ShedPolicy(inner=m.engine.policy)
        return eng

    def attach(eng):
        # reweight-only controller: retune needs the LM engine, and the
        # shed-rebalance path is exercised in tests — disarm both here
        return ControlLoop(eng, interval=INTERVAL, reweight_deadband=0.2,
                           shed_high=1.0, shed_low=0.0)

    tags, flip = _diurnal_tags(requests)
    arrivals = [i // RATE for i in range(requests)]
    keys = jax.random.split(jax.random.PRNGKey(0), requests)
    images = [jax.random.normal(k, (1, image_size, image_size, 3))
              for k in keys]
    by_model: dict[str, list] = {m: [] for m in MODELS}
    for x, t in zip(images, tags):
        by_model[t].append(x)
    for m, r in runners.items():        # warm every member's per-group jits
        r.run_sequential(by_model[m][:1])

    print(f"\n## adaptive serving ({'+'.join(MODELS)}, {image_size}px, "
          f"{requests} requests, mix flips "
          f"{MIX_A[MODELS[0]]:.2f}/{MIX_A[MODELS[1]]:.2f} -> "
          f"{MIX_A[MODELS[1]]:.2f}/{MIX_A[MODELS[0]]:.2f} at request "
          f"{flip}, {len(jax.devices())} local device(s))")

    def reqs():
        return [Request(x, model=t,
                        deadline=arrivals[i] + SLACK + i % 3)
                for i, (x, t) in enumerate(zip(images, tags))]

    def leg(adapt: bool):
        t0 = time.perf_counter()
        eng = fresh_fleet()
        ctl = attach(eng) if adapt else None
        res = _drive(eng, reqs(), arrivals)
        return time.perf_counter() - t0, res, eng, ctl

    def post_flip_sheds(res) -> int:
        return sum(1 for c in res.completions
                   if c.ticket.rid >= flip and c.metrics.status == "shed")

    # rep 0 is an untimed warm-in; best-of per leg after that
    leg(False), leg(True)
    best = {}
    for _ in range(max(2, reps)):
        for name, adapt in (("static", False), ("adaptive", True)):
            gc.collect()
            _w, res, eng, ctl = leg(adapt)
            g = res.metrics.goodput_fps()
            if name not in best or g > best[name][0]:
                best[name] = (g, res, eng, ctl)
    g_static, res_static, _, _ = best["static"]
    g_adapt, res_adapt, eng_adapt, ctl = best["adaptive"]

    # ---- invariants: accounting, adaptation, and the hard gates ------
    st_s, st_a = _statuses(res_static), _statuses(res_adapt)
    for st in (st_s, st_a):
        assert sorted(st) == list(range(requests)), \
            "lost or duplicated request ids"
        assert set(st.values()) <= {"ok", "shed"}
    rw = [d for d in ctl.decisions if d.action.kind == "reweight"]
    assert rw, "the mix flip must trigger at least one reweight"
    w_final = {m.name: round(m.weight, 6) for m in eng_adapt.members}
    assert w_final[MODELS[1]] > w_final[MODELS[0]], \
        f"weights never flipped toward the night mix: {w_final}"
    shed_s, shed_a = post_flip_sheds(res_static), post_flip_sheds(res_adapt)
    assert shed_a < shed_s, (
        f"adaptive must shed strictly less post-flip work than the stale "
        f"plan (adaptive {shed_a} vs static {shed_s})")
    assert g_adapt >= g_static, (
        f"adaptive goodput {g_adapt:.2f} fps fell below the static plan's "
        f"{g_static:.2f} fps")

    # ---- replay: the controlled run, bitwise, with no controller -----
    rt = stream_from_json(stream_to_json(eng_adapt.stream, pool="pool0"))
    assert any(isinstance(r.instr, SetParam) for r in rt), \
        "the recorded stream must carry the injected SET_PARAMs"
    log = decisions_from_json(decisions_to_json(ctl.decisions))
    fresh = fresh_fleet()
    assert fresh.controller is None
    res_rep = fresh.executor.replay(rt, reqs(), arrivals)
    assert stream_signature(fresh.stream) == \
        stream_signature(eng_adapt.stream), "replay diverged from recording"
    assert _statuses(res_rep) == st_a, "replayed shed set differs"
    verify_decisions(fresh.stream, log)
    assert {m.name: round(m.weight, 6) for m in fresh.members} == w_final, \
        "replayed SET_PARAMs must reproduce the final weights"

    sum_s = res_static.metrics.summary()
    sum_a = res_adapt.metrics.summary()
    report["slo"] = {"clock": "slot", "slack_slots": SLACK}
    report["mix"] = {"day": MIX_A,
                     "night": {m: MIX_A[n] for m, n in
                               zip(MODELS, reversed(MODELS))},
                     "flip_at_request": flip}
    report["static"] = {
        "goodput_fps": round(g_static, 2),
        "completed": res_static.metrics.completed,
        "shed": sum_s["shed"],
        "shed_post_flip": shed_s,
    }
    report["adaptive"] = {
        "goodput_fps": round(g_adapt, 2),
        "completed": res_adapt.metrics.completed,
        "shed": sum_a["shed"],
        "shed_post_flip": shed_a,
        "control": ctl.stats(),
        "final_weights": w_final,
    }
    report["replay"] = {
        "bitwise": True,
        "records": len(eng_adapt.stream),
        "decisions": len(ctl.decisions),
    }
    report["adaptive_vs_static"] = round(g_adapt / g_static, 3) \
        if g_static else None

    print(f"{'leg':<26}{'goodput fps':>12}{'shed':>6}{'post-flip':>10}")
    print(f"{'static (stale weights)':<26}{g_static:>12.2f}"
          f"{sum_s['shed']:>6}{shed_s:>10}")
    print(f"{'adaptive (ControlLoop)':<26}{g_adapt:>12.2f}"
          f"{sum_a['shed']:>6}{shed_a:>10}")
    print(f"adaptive vs static: {report['adaptive_vs_static']}x; "
          f"{len(rw)} reweight decision(s); replay bitwise over "
          f"{len(eng_adapt.stream)} records")


def main(argv=None) -> int:
    """CLI entry point: run the bench and write the report JSON."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small images, few requests")
    ap.add_argument("--out", default="BENCH_adaptive.json")
    ap.add_argument("--image-size", type=int, default=None,
                    help="input H=W (default: 48 smoke / 96 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests across the mix "
                         "(default: 24 smoke / 48 full)")
    ap.add_argument("--reps", type=int, default=4)
    args = ap.parse_args(argv)

    image_size = args.image_size or (48 if args.smoke else 96)
    requests = args.requests or (24 if args.smoke else 48)

    import jax

    report: dict = {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "image_size": image_size,
                    "requests": requests}
    bench_adaptive(report, image_size, requests, args.reps)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
