"""Chaos benchmark: goodput under SLO while a pool dies mid-run.

Replays the mbv1+squeezenet traffic mix as an *open-loop bursty Poisson*
trace (arrival rate well above capacity, so the SLO matters) three ways:

  * ``baseline`` — one fleet pool, no faults: the no-fault single-pool
    goodput reference.  Members run under a slot-clock :class:`ShedPolicy`
    with per-request slot deadlines, so late work is shed, not served.
  * ``chaos``    — two pools behind a :class:`MultiPoolRouter` with a
    seeded :class:`FaultPlan` that **kills pool1 mid-run**.  The router
    re-routes the dead pool's un-retired requests onto the survivor
    (status ``recovered``), re-leases the survivor's split (REBALANCE),
    and keeps shedding past-deadline work.  Invariants checked hard:
    every admitted request retires exactly once (none lost, none
    duplicated) and chaos goodput stays >= 0.9x the baseline's.
  * ``replay``   — the faulted run's recorded streams + placement log +
    recovery event log re-executed on fresh pools with **no injector
    attached**: stream signatures, shed set, recovered rids and the
    event log must all match bitwise.
  * ``process``  — a deterministic sim-member spike against real worker
    *processes* (``python -m repro.fleet.worker``) over SocketTransport,
    with one worker **SIGKILL'd** mid-drain (no injector — a real dead
    process): exactly-once retirement, slot-domain goodput
    (completions per router step) >= 0.9x the clean single-worker run,
    and bitwise replay of the killed run on fresh in-process pools.

Writes ``BENCH_chaos.json``; its ``goodput_fps`` leaves are gated
higher-is-better in ``benchmarks/compare_bench.py``.

    PYTHONPATH=src python -m benchmarks.chaos_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# Two host platform devices unless the caller already configured XLA
# (must happen pre-import) — each pool leases its own 2-device split.
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

MIX = {"mobilenet_v1": 0.5, "squeezenet": 0.5}
BURST = 4
CRASH_SLOT = 1      # pool1 dies with admitted + queued work on board
RATE = 50.0         # arrivals per slot — the mix lands as one spike
SLACK = 3           # slot deadline = arrival + SLACK (+ per-rid jitter)


def _statuses(res):
    return {c.ticket.rid: c.metrics.status for c in res.completions}


def _drive(engine, reqs, arrivals):
    """Open-loop drive: submit each request at its arrival step, retry
    admission-refused (QueueFull) submissions next step, run to drain."""
    from repro.serving import QueueFull

    order = sorted(range(len(reqs)), key=lambda i: arrivals[i])
    nxt, step, refused = 0, 0, []
    while nxt < len(order) or refused or engine.has_work:
        due, refused = refused, []
        while nxt < len(order) and arrivals[order[nxt]] <= step:
            due.append(order[nxt])
            nxt += 1
        for i in due:
            try:
                engine.submit(reqs[i])
            except QueueFull:
                refused.append(i)
        if engine.has_work:
            engine.step()
        step += 1
    return engine.result()


def bench_chaos(report: dict, image_size: int, requests: int,
                reps: int) -> None:
    import jax

    from repro.fleet import (Fault, FaultInjector, FaultPlan, FleetEngine,
                             MultiPoolRouter, WeightedFair, build_cnn_fleet,
                             mix_schedule, stream_from_json,
                             stream_signature, stream_to_json)
    from repro.serving import Request, ShedPolicy, poisson_arrivals

    def build():
        eng, pool = build_cnn_fleet(list(MIX), weights=MIX,
                                    use_pallas=True, fuse="group")
        return {m.name: m.engine.runner for m in eng.members}, pool

    def fresh_fleet(runners, pool):
        from repro.serving import DualCoreEngine

        members = {m: DualCoreEngine(r) for m, r in runners.items()}
        eng = FleetEngine(members, policy=WeightedFair(), weights=MIX,
                          burst=BURST, pool=pool)
        for m in eng.members:   # slot-clock SLO shedding at admission
            m.engine.policy = ShedPolicy(inner=m.engine.policy)
        return eng

    single_runners, single_pool = build()
    pool_sets = [build() for _ in range(2)]

    # bursty overload: Poisson arrivals at ~3x the per-slot admit rate,
    # slot deadlines a fixed slack past arrival — late work must shed
    tags = mix_schedule(MIX, requests)
    arrivals = poisson_arrivals(requests, rate=RATE, seed=0)
    keys = jax.random.split(jax.random.PRNGKey(0), requests)
    images = [jax.random.normal(k, (1, image_size, image_size, 3))
              for k in keys]
    by_model: dict[str, list] = {m: [] for m in MIX}
    for x, t in zip(images, tags):
        by_model[t].append(x)
    for runners in [single_runners] + [rs for rs, _ in pool_sets]:
        for m, r in runners.items():    # warm every member's per-group jits
            r.run_sequential(by_model[m][:1])

    plan = FaultPlan(faults=(
        Fault(kind="pool_crash", pool="pool1", slot=CRASH_SLOT),), seed=0)

    print(f"\n## chaos serving ({'+'.join(MIX)}, {image_size}px, "
          f"{requests} requests, pool1 killed at slot {CRASH_SLOT}, "
          f"{len(jax.devices())} local device(s))")

    def reqs():
        return [Request(x, model=t, deadline=arrivals[i] + SLACK + i % 3)
                for i, (x, t) in enumerate(zip(images, tags))]

    def leg_baseline():
        t0 = time.perf_counter()
        eng = fresh_fleet(single_runners, single_pool)
        res = _drive(eng, reqs(), arrivals)
        return time.perf_counter() - t0, res

    def fresh_router(injector=None):
        return MultiPoolRouter({
            f"pool{i}": fresh_fleet(rs, pool)
            for i, (rs, pool) in enumerate(pool_sets)},
            injector=injector, plan_evals=2)

    def leg_chaos():
        t0 = time.perf_counter()
        router = fresh_router(injector=FaultInjector(plan))
        res = _drive(router, reqs(), arrivals)
        return time.perf_counter() - t0, res, router

    # rep 0 is an untimed warm-in; best-of per leg after that
    leg_baseline(), leg_chaos()
    best_base = best_chaos = None
    g_base = g_chaos = -1.0
    for _ in range(max(2, reps)):
        gc.collect()
        _w, res = leg_baseline()
        if res.metrics.goodput_fps() > g_base:
            g_base, best_base = res.metrics.goodput_fps(), res
        gc.collect()
        _w, res, router = leg_chaos()
        if res.metrics.goodput_fps() > g_chaos:
            g_chaos, best_chaos = res.metrics.goodput_fps(), (res, router)
    res_chaos, router = best_chaos

    # ---- invariants: exactly-once retirement, explicit accounting ----
    st = _statuses(res_chaos)
    assert sorted(st) == list(range(requests)), \
        "lost or duplicated request ids"
    assert set(st.values()) <= {"ok", "shed", "recovered", "failed"}
    assert router.duplicates_dropped == 0, "a request retired twice"
    assert list(router.dead) == ["pool1"], "the injected crash must land"
    assert "failed" not in st.values(), \
        "pool0 serves every model: crash recovery must re-route, not fail"
    ratio = g_chaos / g_base if g_base else float("inf")
    assert ratio >= 0.9, (
        f"chaos goodput {g_chaos:.2f} fps fell below 0.9x the no-fault "
        f"single-pool baseline {g_base:.2f} fps")

    # ---- replay: the faulted run, bitwise, with no injector ----------
    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in router.streams().items()}
    fresh = fresh_router()
    res_rep = fresh.replay(rt, router.placements, reqs(),
                           events=router.events)
    assert stream_signature(fresh.stream()) == \
        stream_signature(router.stream()), "replay diverged from recording"
    assert fresh.events == router.events
    st_rep = _statuses(res_rep)
    assert st_rep == st, "replayed shed/recovered sets differ"
    shed_set = sorted(r for r, s in st.items() if s == "shed")
    recovered = sorted(r for r, s in st.items() if s == "recovered")

    base_sum = best_base.metrics.summary()
    chaos_sum = res_chaos.metrics.summary()
    report["slo"] = {"clock": "slot", "slack_slots": SLACK}
    report["fault_plan"] = plan.to_json()
    report["baseline"] = {
        "goodput_fps": round(g_base, 2),
        "completed": best_base.metrics.completed,
        "shed": base_sum["shed"],
    }
    report["chaos"] = {
        "goodput_fps": round(g_chaos, 2),
        "completed": res_chaos.metrics.completed,
        "shed": chaos_sum["shed"],
        "recovered": chaos_sum["recovered"],
        "failed": chaos_sum["failed"],
        "dead": sorted(router.dead),
        "duplicates_dropped": router.duplicates_dropped,
        "recovery_events": len(router.events),
    }
    report["replay"] = {
        "bitwise": True,
        "records": len(router.stream()),
        "shed_rids": shed_set,
        "recovered_rids": recovered,
    }
    report["chaos_vs_baseline"] = round(ratio, 3)

    print(f"{'leg':<28}{'goodput fps':>12}{'shed':>6}{'recov':>7}")
    print(f"{'baseline (1 pool, clean)':<28}{g_base:>12.2f}"
          f"{base_sum['shed']:>6}{0:>7}")
    print(f"{'chaos (2 pools, 1 dies)':<28}{g_chaos:>12.2f}"
          f"{chaos_sum['shed']:>6}{chaos_sum['recovered']:>7}")
    print(f"chaos vs baseline: {ratio:.2f}x; replay bitwise over "
          f"{len(router.stream())} records, {len(router.events)} "
          f"recovery events")


def bench_process(report: dict, requests: int, reps: int) -> None:
    """Real-process chaos (DESIGN.md §14): the same spike against worker
    *processes* over SocketTransport, with one worker **SIGKILL'd**
    mid-drain — no injector, a genuinely dead process detected by
    connection loss.  Members are deterministic sim stubs with a modeled
    per-slot compute cost, so outcomes and step counts are bitwise
    reproducible.  Gated hard: exactly-once retirement, and chaos
    goodput — measured in the *slot domain* (in-SLO completions per
    router step, which is deterministic; wall-clock fps over ~100 ms
    walls is scheduler noise) — >= 0.9x the clean single-worker run."""
    from repro.fleet import MultiPoolRouter, stream_signature
    from repro.fleet.net.coordinator import (connect, start_workers,
                                             stop_workers)
    from repro.fleet.net.worker import build_sim_fleet
    from repro.serving import QueueFull, Request, poisson_arrivals

    spec = "cnn:c:2,lm:p:3:opaque"
    cost_us = 200                   # modeled compute per occupied slot
    kill_step = max(2, requests // 5)   # mid-drain: victim holds work
    arrivals = poisson_arrivals(requests, rate=RATE, seed=0)

    def reqs():
        return [Request(payload=i, model=("cnn" if i % 2 == 0 else "lm"))
                for i in range(requests)]

    def run(n_workers, kill_at=None):
        procs = start_workers({
            f"pool{i}": ["--sim", spec, "--sim-cost-us", str(cost_us)]
            for i in range(n_workers)})
        fleets = {}
        try:
            fleets = connect(procs, heartbeat_s=30.0)
            router = MultiPoolRouter(fleets)
            rs = reqs()
            order = sorted(range(requests), key=lambda i: arrivals[i])
            nxt, step, refused = 0, 0, []
            t0 = time.perf_counter()
            while nxt < len(order) or refused or router.has_work:
                if kill_at is not None and step >= kill_at:
                    procs[f"pool{n_workers - 1}"].kill()
                    kill_at = None
                due, refused = refused, []
                while nxt < len(order) and arrivals[order[nxt]] <= step:
                    due.append(order[nxt])
                    nxt += 1
                for i in due:
                    try:
                        router.submit(rs[i])
                    except QueueFull:
                        refused.append(i)
                router.step()
                step += 1
            res = router.result()
            wall = time.perf_counter() - t0
        finally:
            stop_workers(fleets, procs)
        return wall, res, router, rs, step

    print(f"\n## real-process chaos (sim members {spec!r}, {requests} "
          f"requests, SIGKILL worker at router step {kill_step})")

    best = {}
    for name, leg in (("clean", lambda: run(1)),
                      ("chaos", lambda: run(2, kill_at=kill_step))):
        for _ in range(max(1, reps)):
            gc.collect()
            out = leg()
            if name not in best or out[1].metrics.goodput_fps() > \
                    best[name][1].metrics.goodput_fps():
                best[name] = out

    _w, res_chaos, router, rs, steps_chaos = best["chaos"]
    steps_clean = best["clean"][4]
    g_clean = best["clean"][1].metrics.goodput_fps()
    g_chaos = res_chaos.metrics.goodput_fps()

    # ---- invariants: exactly-once under a real SIGKILL ---------------
    st = _statuses(res_chaos)
    assert sorted(st) == list(range(requests)), \
        "lost or duplicated request ids"
    assert router.duplicates_dropped == 0, "a request retired twice"
    assert list(router.dead) == ["pool1"], "the SIGKILL must land"
    assert "failed" not in st.values(), \
        "the survivor serves every model: recovery must re-route"
    # slot-domain goodput: deterministic (same placements, same recovery
    # path every run), so this gate cannot flake on machine load
    gps_clean = best["clean"][1].metrics.completed / steps_clean
    gps_chaos = res_chaos.metrics.completed / steps_chaos
    ratio = gps_chaos / gps_clean if gps_clean else float("inf")
    assert ratio >= 0.9, (
        f"process-chaos goodput {gps_chaos:.3f}/step fell below 0.9x "
        f"the clean single-worker run {gps_clean:.3f}/step")

    # ---- the killed run replays bitwise on fresh in-process pools ----
    streams = router.streams()
    fresh = MultiPoolRouter({p: build_sim_fleet(spec) for p in streams})
    fresh.replay(streams, list(router.placements), rs,
                 list(router.events))
    for pool, recs in streams.items():
        assert stream_signature(recs) == stream_signature(
            fresh.executors[pool].records), f"replay diverged on {pool}"
    st_rep = {rid: fresh._metrics[rid].status for rid in range(requests)}
    assert st_rep == st, "replayed recovered sets differ"

    summ = res_chaos.metrics.summary()
    report["process"] = {
        "sim_cost_us": cost_us,
        "kill_step": kill_step,
        "clean": {"goodput_fps": round(g_clean, 2),
                  "goodput_per_step": round(gps_clean, 4),
                  "steps": steps_clean,
                  "completed": best["clean"][1].metrics.completed},
        "chaos": {"goodput_fps": round(g_chaos, 2),
                  "goodput_per_step": round(gps_chaos, 4),
                  "steps": steps_chaos,
                  "completed": res_chaos.metrics.completed,
                  "recovered": summ["recovered"],
                  "dead": sorted(router.dead),
                  "duplicates_dropped": router.duplicates_dropped},
        "chaos_vs_clean_per_step": round(ratio, 3),
        "replay_records": sum(len(r) for r in streams.values()),
    }
    print(f"{'leg':<28}{'good/step':>10}{'steps':>7}{'fps':>10}"
          f"{'recov':>7}")
    print(f"{'clean (1 worker)':<28}{gps_clean:>10.3f}{steps_clean:>7}"
          f"{g_clean:>10.1f}{0:>7}")
    print(f"{'chaos (2 workers, SIGKILL)':<28}{gps_chaos:>10.3f}"
          f"{steps_chaos:>7}{g_chaos:>10.1f}{summ['recovered']:>7}")
    print(f"process chaos vs clean: {ratio:.2f}x per-step; replay "
          f"bitwise over {report['process']['replay_records']} records")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small images, few requests")
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--image-size", type=int, default=None,
                    help="input H=W (default: 48 smoke / 96 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests across the mix "
                         "(default: 10 smoke / 24 full)")
    ap.add_argument("--reps", type=int, default=4)
    args = ap.parse_args(argv)

    image_size = args.image_size or (48 if args.smoke else 96)
    requests = args.requests or (10 if args.smoke else 24)

    import jax

    report: dict = {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "image_size": image_size,
                    "requests": requests}
    bench_chaos(report, image_size, requests, args.reps)
    bench_process(report, requests=200, reps=2)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
