"""Chaos benchmark: goodput under SLO while a pool dies mid-run.

Replays the mbv1+squeezenet traffic mix as an *open-loop bursty Poisson*
trace (arrival rate well above capacity, so the SLO matters) three ways:

  * ``baseline`` — one fleet pool, no faults: the no-fault single-pool
    goodput reference.  Members run under a slot-clock :class:`ShedPolicy`
    with per-request slot deadlines, so late work is shed, not served.
  * ``chaos``    — two pools behind a :class:`MultiPoolRouter` with a
    seeded :class:`FaultPlan` that **kills pool1 mid-run**.  The router
    re-routes the dead pool's un-retired requests onto the survivor
    (status ``recovered``), re-leases the survivor's split (REBALANCE),
    and keeps shedding past-deadline work.  Invariants checked hard:
    every admitted request retires exactly once (none lost, none
    duplicated) and chaos goodput stays >= 0.9x the baseline's.
  * ``replay``   — the faulted run's recorded streams + placement log +
    recovery event log re-executed on fresh pools with **no injector
    attached**: stream signatures, shed set, recovered rids and the
    event log must all match bitwise.

Writes ``BENCH_chaos.json``; its ``goodput_fps`` leaves are gated
higher-is-better in ``benchmarks/compare_bench.py``.

    PYTHONPATH=src python -m benchmarks.chaos_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# Two host platform devices unless the caller already configured XLA
# (must happen pre-import) — each pool leases its own 2-device split.
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

MIX = {"mobilenet_v1": 0.5, "squeezenet": 0.5}
BURST = 4
CRASH_SLOT = 1      # pool1 dies with admitted + queued work on board
RATE = 50.0         # arrivals per slot — the mix lands as one spike
SLACK = 3           # slot deadline = arrival + SLACK (+ per-rid jitter)


def _statuses(res):
    return {c.ticket.rid: c.metrics.status for c in res.completions}


def _drive(engine, reqs, arrivals):
    """Open-loop drive: submit each request at its arrival step, retry
    admission-refused (QueueFull) submissions next step, run to drain."""
    from repro.serving import QueueFull

    order = sorted(range(len(reqs)), key=lambda i: arrivals[i])
    nxt, step, refused = 0, 0, []
    while nxt < len(order) or refused or engine.has_work:
        due, refused = refused, []
        while nxt < len(order) and arrivals[order[nxt]] <= step:
            due.append(order[nxt])
            nxt += 1
        for i in due:
            try:
                engine.submit(reqs[i])
            except QueueFull:
                refused.append(i)
        if engine.has_work:
            engine.step()
        step += 1
    return engine.result()


def bench_chaos(report: dict, image_size: int, requests: int,
                reps: int) -> None:
    import jax

    from repro.fleet import (Fault, FaultInjector, FaultPlan, FleetEngine,
                             MultiPoolRouter, WeightedFair, build_cnn_fleet,
                             mix_schedule, stream_from_json,
                             stream_signature, stream_to_json)
    from repro.serving import Request, ShedPolicy, poisson_arrivals

    def build():
        eng, pool = build_cnn_fleet(list(MIX), weights=MIX,
                                    use_pallas=True, fuse="group")
        return {m.name: m.engine.runner for m in eng.members}, pool

    def fresh_fleet(runners, pool):
        from repro.serving import DualCoreEngine

        members = {m: DualCoreEngine(r) for m, r in runners.items()}
        eng = FleetEngine(members, policy=WeightedFair(), weights=MIX,
                          burst=BURST, pool=pool)
        for m in eng.members:   # slot-clock SLO shedding at admission
            m.engine.policy = ShedPolicy(inner=m.engine.policy)
        return eng

    single_runners, single_pool = build()
    pool_sets = [build() for _ in range(2)]

    # bursty overload: Poisson arrivals at ~3x the per-slot admit rate,
    # slot deadlines a fixed slack past arrival — late work must shed
    tags = mix_schedule(MIX, requests)
    arrivals = poisson_arrivals(requests, rate=RATE, seed=0)
    keys = jax.random.split(jax.random.PRNGKey(0), requests)
    images = [jax.random.normal(k, (1, image_size, image_size, 3))
              for k in keys]
    by_model: dict[str, list] = {m: [] for m in MIX}
    for x, t in zip(images, tags):
        by_model[t].append(x)
    for runners in [single_runners] + [rs for rs, _ in pool_sets]:
        for m, r in runners.items():    # warm every member's per-group jits
            r.run_sequential(by_model[m][:1])

    plan = FaultPlan(faults=(
        Fault(kind="pool_crash", pool="pool1", slot=CRASH_SLOT),), seed=0)

    print(f"\n## chaos serving ({'+'.join(MIX)}, {image_size}px, "
          f"{requests} requests, pool1 killed at slot {CRASH_SLOT}, "
          f"{len(jax.devices())} local device(s))")

    def reqs():
        return [Request(x, model=t, deadline=arrivals[i] + SLACK + i % 3)
                for i, (x, t) in enumerate(zip(images, tags))]

    def leg_baseline():
        t0 = time.perf_counter()
        eng = fresh_fleet(single_runners, single_pool)
        res = _drive(eng, reqs(), arrivals)
        return time.perf_counter() - t0, res

    def fresh_router(injector=None):
        return MultiPoolRouter({
            f"pool{i}": fresh_fleet(rs, pool)
            for i, (rs, pool) in enumerate(pool_sets)},
            injector=injector, plan_evals=2)

    def leg_chaos():
        t0 = time.perf_counter()
        router = fresh_router(injector=FaultInjector(plan))
        res = _drive(router, reqs(), arrivals)
        return time.perf_counter() - t0, res, router

    # rep 0 is an untimed warm-in; best-of per leg after that
    leg_baseline(), leg_chaos()
    best_base = best_chaos = None
    g_base = g_chaos = -1.0
    for _ in range(max(2, reps)):
        gc.collect()
        _w, res = leg_baseline()
        if res.metrics.goodput_fps() > g_base:
            g_base, best_base = res.metrics.goodput_fps(), res
        gc.collect()
        _w, res, router = leg_chaos()
        if res.metrics.goodput_fps() > g_chaos:
            g_chaos, best_chaos = res.metrics.goodput_fps(), (res, router)
    res_chaos, router = best_chaos

    # ---- invariants: exactly-once retirement, explicit accounting ----
    st = _statuses(res_chaos)
    assert sorted(st) == list(range(requests)), \
        "lost or duplicated request ids"
    assert set(st.values()) <= {"ok", "shed", "recovered", "failed"}
    assert router.duplicates_dropped == 0, "a request retired twice"
    assert list(router.dead) == ["pool1"], "the injected crash must land"
    assert "failed" not in st.values(), \
        "pool0 serves every model: crash recovery must re-route, not fail"
    ratio = g_chaos / g_base if g_base else float("inf")
    assert ratio >= 0.9, (
        f"chaos goodput {g_chaos:.2f} fps fell below 0.9x the no-fault "
        f"single-pool baseline {g_base:.2f} fps")

    # ---- replay: the faulted run, bitwise, with no injector ----------
    rt = {name: stream_from_json(stream_to_json(recs, pool=name))
          for name, recs in router.streams().items()}
    fresh = fresh_router()
    res_rep = fresh.replay(rt, router.placements, reqs(),
                           events=router.events)
    assert stream_signature(fresh.stream()) == \
        stream_signature(router.stream()), "replay diverged from recording"
    assert fresh.events == router.events
    st_rep = _statuses(res_rep)
    assert st_rep == st, "replayed shed/recovered sets differ"
    shed_set = sorted(r for r, s in st.items() if s == "shed")
    recovered = sorted(r for r, s in st.items() if s == "recovered")

    base_sum = best_base.metrics.summary()
    chaos_sum = res_chaos.metrics.summary()
    report["slo"] = {"clock": "slot", "slack_slots": SLACK}
    report["fault_plan"] = plan.to_json()
    report["baseline"] = {
        "goodput_fps": round(g_base, 2),
        "completed": best_base.metrics.completed,
        "shed": base_sum["shed"],
    }
    report["chaos"] = {
        "goodput_fps": round(g_chaos, 2),
        "completed": res_chaos.metrics.completed,
        "shed": chaos_sum["shed"],
        "recovered": chaos_sum["recovered"],
        "failed": chaos_sum["failed"],
        "dead": sorted(router.dead),
        "duplicates_dropped": router.duplicates_dropped,
        "recovery_events": len(router.events),
    }
    report["replay"] = {
        "bitwise": True,
        "records": len(router.stream()),
        "shed_rids": shed_set,
        "recovered_rids": recovered,
    }
    report["chaos_vs_baseline"] = round(ratio, 3)

    print(f"{'leg':<28}{'goodput fps':>12}{'shed':>6}{'recov':>7}")
    print(f"{'baseline (1 pool, clean)':<28}{g_base:>12.2f}"
          f"{base_sum['shed']:>6}{0:>7}")
    print(f"{'chaos (2 pools, 1 dies)':<28}{g_chaos:>12.2f}"
          f"{chaos_sum['shed']:>6}{chaos_sum['recovered']:>7}")
    print(f"chaos vs baseline: {ratio:.2f}x; replay bitwise over "
          f"{len(router.stream())} records, {len(router.events)} "
          f"recovery events")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small images, few requests")
    ap.add_argument("--out", default="BENCH_chaos.json")
    ap.add_argument("--image-size", type=int, default=None,
                    help="input H=W (default: 48 smoke / 96 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests across the mix "
                         "(default: 10 smoke / 24 full)")
    ap.add_argument("--reps", type=int, default=4)
    args = ap.parse_args(argv)

    image_size = args.image_size or (48 if args.smoke else 96)
    requests = args.requests or (10 if args.smoke else 24)

    import jax

    report: dict = {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "image_size": image_size,
                    "requests": requests}
    bench_chaos(report, image_size, requests, args.reps)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
