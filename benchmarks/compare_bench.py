"""CI perf-regression gate: diff a fresh --smoke benchmark report against
the committed baseline and fail on large per-entry slowdowns.

Gated metrics are the wall-clock fields this repo's perf story is built on
(``implicit_ms`` / ``fused_ms`` from ``BENCH_kernels.json``,
``pipelined_ms`` from ``BENCH_dualcore.json``, ``p50_ms`` / ``p95_ms``
request latencies from ``BENCH_serving.json`` / ``BENCH_fleet.json``),
plus two higher-is-better fields: ``aggregate_fps`` from
``BENCH_fleet.json`` (the multi-network throughput claim) and
``goodput_fps`` from ``BENCH_chaos.json`` (in-SLO throughput under
injected faults), which fail when fresh drops below baseline /
threshold.  Baseline-leg timings
(im2col, unfused, sequential) and the remaining throughput fields (fps,
tokens/s) are deliberately *not* gated — a slower baseline is not a
regression.  Entries present on only one side are
reported but never fail the gate (shapes come and go as benches evolve).

    python -m benchmarks.compare_bench \
        --baseline BENCH_kernels.json --fresh /tmp/fresh.json \
        [--threshold 2.0] [--min-ms 1.0]

Exit status 1 iff any entry slowed down by more than ``--threshold`` x
(entries whose baseline is below ``--min-ms`` are skipped: micro-timings
are dominated by dispatch noise).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

GATED_FIELDS = ("implicit_ms", "fused_ms", "pipelined_ms",
                "p50_ms", "p95_ms")
GATED_HIGHER_FIELDS = ("aggregate_fps",        # regression = fresh DROPS
                       "goodput_fps")


def _is_higher_better(key: str) -> bool:
    return key.rsplit("/", 1)[-1] in GATED_HIGHER_FIELDS


@dataclasses.dataclass
class Regression:
    key: str
    baseline: float
    fresh: float

    @property
    def ratio(self) -> float:
        return self.fresh / self.baseline if self.baseline else float("inf")


def extract_metrics(report: dict) -> dict[str, float]:
    """Flatten a benchmark report to ``path -> gated metric``.  List items
    are keyed by their ``shape`` field when present (stable under
    reordering), else by index."""
    out: dict[str, float] = {}

    def walk(node, path: list[str]):
        if isinstance(node, dict):
            for k, v in node.items():
                if (k in GATED_FIELDS or k in GATED_HIGHER_FIELDS) \
                        and isinstance(v, (int, float)):
                    out["/".join(path + [k])] = float(v)
                elif isinstance(v, (dict, list)):
                    walk(v, path + [k])
        elif isinstance(node, list):
            for i, v in enumerate(node):
                label = (v.get("shape") if isinstance(v, dict) else None)
                walk(v, path + [str(label) if label else str(i)])

    walk(report, [])
    return out


def compare(baseline: dict, fresh: dict, threshold: float = 2.0,
            min_ms: float = 1.0) -> tuple[list[Regression], list[str]]:
    """Return (regressions beyond ``threshold``x, informational notes)."""
    base_m = extract_metrics(baseline)
    fresh_m = extract_metrics(fresh)
    regressions: list[Regression] = []
    notes: list[str] = []
    for key in sorted(base_m.keys() | fresh_m.keys()):
        if key not in base_m:
            notes.append(f"new entry (not gated): {key}")
            continue
        if key not in fresh_m:
            notes.append(f"entry disappeared (not gated): {key}")
            continue
        b, f = base_m[key], fresh_m[key]
        if _is_higher_better(key):
            # throughput: fresh falling below baseline/threshold fails
            if b <= 0:
                notes.append(f"skipped (non-positive baseline): {key}")
            elif f * threshold < b:
                regressions.append(Regression(key, b, f))
            else:
                notes.append(f"ok ({f / b:5.2f}x, higher-better): {key} "
                             f"[{b:.2f} -> {f:.2f}]")
            continue
        if b < min_ms:
            notes.append(f"skipped (baseline {b:.3f} ms < {min_ms} ms "
                         f"noise floor): {key}")
            continue
        if f > threshold * b:
            regressions.append(Regression(key, b, f))
        else:
            notes.append(f"ok ({f / b:5.2f}x): {key} "
                         f"[{b:.2f} -> {f:.2f} ms]")
    return regressions, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True,
                    help="committed baseline JSON")
    ap.add_argument("--fresh", required=True,
                    help="freshly measured JSON from this run")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail on fresh > threshold x baseline (default 2)")
    ap.add_argument("--min-ms", type=float, default=1.0,
                    help="ignore entries whose baseline is below this")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.fresh) as f:
        fresh = json.load(f)

    regressions, notes = compare(baseline, fresh, args.threshold,
                                 args.min_ms)
    for n in notes:
        print(f"  {n}")
    if regressions:
        print(f"\nPERF GATE FAILED: {len(regressions)} entr"
              f"{'y' if len(regressions) == 1 else 'ies'} regressed "
              f"beyond {args.threshold}x vs baseline ({args.baseline}):")
        for r in regressions:
            if _is_higher_better(r.key):
                print(f"  {r.ratio:5.2f}x  {r.key}  "
                      f"[{r.baseline:.2f} -> {r.fresh:.2f}, "
                      f"higher-is-better: throughput DROPPED]")
            else:
                print(f"  {r.ratio:5.2f}x  {r.key}  "
                      f"[{r.baseline:.2f} -> {r.fresh:.2f} ms]")
        return 1
    print(f"\nperf gate OK: {len(extract_metrics(baseline))} baseline "
          f"entries within {args.threshold}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
