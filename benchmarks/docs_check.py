"""Docs drift check: every command the docs show must still answer.

Extracts each ``python -m <module>`` invocation from README.md and
docs/operations.md / docs/observability.md (fenced blocks, inline
code, prose — any mention must resolve) and runs the module with
``--help`` (PYTHONPATH=src, repo root as cwd), expecting exit 0 — so a
renamed module, a deleted bench, or a broken argparse surface fails CI
instead of rotting silently in the docs.  Only module *resolution and
CLI parsing* are checked; the benches' full runs are the perf job's.

    python -m benchmarks.docs_check [--verbose]
"""
from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DOCS = ("README.md", os.path.join("docs", "operations.md"),
        os.path.join("docs", "observability.md"))

_INVOKE = re.compile(r"python\s+-m\s+([A-Za-z_][\w.]*)")


def doc_modules(paths=DOCS) -> dict[str, list[str]]:
    """``{module: [doc files that invoke it]}`` across the whole docs."""
    out: dict[str, list[str]] = {}
    for rel in paths:
        with open(os.path.join(REPO, rel)) as f:
            text = f.read()
        for mod in _INVOKE.findall(text):
            out.setdefault(mod, [])
            if rel not in out[mod]:
                out[mod].append(rel)
    return out


def check_module(mod: str) -> tuple[bool, str]:
    """Run ``python -m mod --help``; (ok, trimmed output or error)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run([sys.executable, "-m", mod, "--help"],
                          cwd=REPO, env=env, capture_output=True,
                          text=True, timeout=120)
    ok = proc.returncode == 0
    tail = (proc.stdout + proc.stderr).strip().splitlines()
    return ok, tail[-1] if tail else ""


def main(argv=None) -> int:
    """Check every doc-referenced module; exit 1 on the first rot."""
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--verbose", action="store_true",
                    help="print each module as it is checked")
    args = ap.parse_args(argv)

    mods = doc_modules()
    if not mods:
        print("docs_check: no `python -m` invocations found — the "
              "extraction regex or the docs changed shape", file=sys.stderr)
        return 1
    failed = []
    for mod, sources in sorted(mods.items()):
        ok, tail = check_module(mod)
        status = "ok" if ok else "FAIL"
        if args.verbose or not ok:
            print(f"[docs_check] {status:<4} {mod}  "
                  f"(from {', '.join(sources)})"
                  + ("" if ok else f": {tail}"))
        if not ok:
            failed.append(mod)
    print(f"docs_check: {len(mods) - len(failed)}/{len(mods)} "
          f"doc-referenced modules answer --help")
    if failed:
        print(f"docs_check: rotted: {', '.join(failed)}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
