"""Dual-core CNN pipeline benchmark: measured execution vs. simulation.

Model side (always): for mbv1 / mbv2 / squeezenet under every allocation
scheme, the analytical two-batch latency T_b2 of the *executable* group
chain (the exec schedule the runtime actually runs), the instruction-level
simulator's prediction, and the pipeline speedup over serialized execution
(2 x sum of group latencies / T_b2) — the paper's Fig.4b claim.

Measured (``--smoke``): the balanced-scheme schedule is executed for real by
``repro.dualcore.runtime`` on a >=2-device host mesh (the module forces two
host platform devices when none are configured): two images pipelined
through the c/p submeshes vs. strictly sequential, wall-clock side by side
with the simulator's T_b2.  Writes ``BENCH_dualcore.json`` — the committed
baseline that ``benchmarks/compare_bench.py`` gates CI against.

    PYTHONPATH=src python -m benchmarks.dualcore_bench --smoke
"""
from __future__ import annotations

import argparse
import json
import os
import sys

# A >=2-device mesh is the point of the exercise: force two host platform
# devices unless the caller already configured XLA (must happen pre-import).
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

MODELS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")
SCHEMES = ("layer_type", "greedy", "round_robin", "balanced")


def bench_model_side(report: dict) -> None:
    """Analytic + simulated numbers for every model x scheme."""
    from repro.core.arch import DUAL_BASELINE, BoardModel
    from repro.core.scheduler import build_schedule
    from repro.core.simulator import simulate_dual_core
    from repro.dualcore.program import build_program
    from repro.dualcore.runtime import build_exec_plan
    from repro.models.zoo import get_graph

    board = BoardModel()
    print("\n## dual-core pipeline, model side (DUAL_BASELINE, cycles)")
    print(f"{'model':<14}{'scheme':<13}{'grp':>4}{'T_b2':>12}"
          f"{'sim T_b2':>12}{'sim ms':>8}{'fps':>8}{'speedup':>9}")
    for model in MODELS:
        graph = get_graph(model)
        program = build_program(graph, use_pallas=True, fuse=False)
        report["model_side"][model] = {}
        for scheme in SCHEMES:
            sched = build_schedule(graph, DUAL_BASELINE, board, scheme)
            es = build_exec_plan(program, sched).exec_schedule
            sim = simulate_dual_core(es)
            seq = 2 * sum(es.group_latencies)
            row = {
                "exec_groups": len(es.groups),
                "t_b2_cycles": es.t_b2(),
                "sim_t_b2_cycles": sim.cycles_two_images,
                "sim_t_b2_ms": round(board.cycles_to_seconds(
                    sim.cycles_two_images) * 1e3, 3),
                "fps": round(es.throughput_fps(), 1),
                "sequential_cycles": seq,
                "pipeline_speedup": round(seq / es.t_b2(), 3),
            }
            report["model_side"][model][scheme] = row
            print(f"{model:<14}{scheme:<13}{row['exec_groups']:>4}"
                  f"{row['t_b2_cycles']:>12,}{row['sim_t_b2_cycles']:>12,}"
                  f"{row['sim_t_b2_ms']:>8.2f}{row['fps']:>8.1f}"
                  f"{row['pipeline_speedup']:>8.2f}x")


def bench_measured(report: dict, image_size: int, reps: int) -> None:
    """Execute the balanced schedule for real: pipelined vs sequential
    wall-clock for the two-image batch, next to the simulator's T_b2."""
    import jax

    from repro.core.arch import DUAL_BASELINE, BoardModel
    from repro.core.scheduler import build_schedule
    from repro.core.simulator import simulate_dual_core
    from repro.dualcore.runtime import DualCoreRunner
    from repro.models.cnn import build_model

    board = BoardModel()
    report["devices"] = len(jax.devices())
    report["backend"] = jax.default_backend()
    report["image_size"] = image_size
    print(f"\n## dual-core pipeline, measured two-batch latency "
          f"({len(jax.devices())} local device(s), {image_size}px, "
          f"balanced scheme, Pallas group-fused)")
    for model in MODELS:
        params, _, graph = build_model(model)
        sched = build_schedule(graph, DUAL_BASELINE, board, "balanced")
        runner = DualCoreRunner(model, params, sched, use_pallas=True,
                                fuse="group")
        es = runner.plan.exec_schedule
        sim = simulate_dual_core(es)
        imgs = [jax.random.normal(k, (1, image_size, image_size, 3))
                for k in jax.random.split(jax.random.PRNGKey(0), 2)]
        runner.run_sequential(imgs[:1])        # warm the per-group jits
        _, t_pipe = runner.timed(imgs, "pipelined", reps=reps)
        _, t_seq = runner.timed(imgs, "sequential", reps=reps)
        row = {
            "scheme": "balanced",
            "exec_groups": len(es.groups),
            "pipelined_ms": round(t_pipe * 1e3, 2),
            "sequential_ms": round(t_seq * 1e3, 2),
            "measured_speedup": round(t_seq / t_pipe, 3),
            "model_speedup": round(
                2 * sum(es.group_latencies) / es.t_b2(), 3),
            "sim_t_b2_cycles": sim.cycles_two_images,
            "sim_t_b2_ms": round(board.cycles_to_seconds(
                sim.cycles_two_images) * 1e3, 3),
        }
        report["measured"][model] = row
        print(f"{model:<14} pipelined {row['pipelined_ms']:8.1f} ms  "
              f"sequential {row['sequential_ms']:8.1f} ms  "
              f"({row['measured_speedup']:.2f}x measured, "
              f"{row['model_speedup']:.2f}x model-side, "
              f"sim T_b2 {row['sim_t_b2_ms']:.2f} ms @200MHz)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="also measure wall-clock on this host and write "
                         "the JSON artifact")
    ap.add_argument("--out", default="BENCH_dualcore.json")
    ap.add_argument("--image-size", type=int, default=64)
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args(argv)

    report: dict = {"model_side": {}, "measured": {}}
    bench_model_side(report)
    if args.smoke:
        bench_measured(report, args.image_size, args.reps)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
