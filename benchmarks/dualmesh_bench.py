"""Dual-mesh serving benchmark: the paper's Table V/VI experiments
re-staged on the LM side (DESIGN.md §2), plus N-stream scaling.

For each workload mix and architecture: single-pod serialized baseline vs
the dual-mesh interleaved schedule found by the §V-B search, plus the
scheduling-scheme comparison (stage-type / greedy / round-robin /
load-balance) — the LM twin of Table V.  ``bench_stream_scaling`` sweeps
the continuous-batching stream count N in {2, 4, 8, 16}: model-side
throughput from the N-stream flow-shop makespan on a 256-chip split, and
measured tokens/s from the real runtime on the local (degenerate CPU)
dual mesh."""
from __future__ import annotations

from repro.configs.registry import get_arch, get_smoke
from repro.dualmesh import (ALLOCATIONS, TpuModel, best_schedule, build,
                            plan_admission, request_stages, search)
from repro.dualmesh.partition import abstract_split
from repro.dualmesh.schedule import stage_cost

HW = TpuModel()

WORKLOADS = {
    "balanced": [(8, 8192, 256)] * 4,
    "prefill_heavy": [(8, 16384, 32)] * 4,
    "decode_heavy": [(8, 1024, 1024)] * 4,
    "mixed": [(8, 16384, 32), (8, 1024, 1024)] * 2,
}
# (command-r-104b excluded: bf16 weights exceed the HBM constraint at any
# TP <= 16 on 256 chips — the search falls back to a best-effort plan;
# kept out of the headline table, see search() fallback note.)
ARCHS = ("qwen2_5_14b", "qwen2_moe_a2_7b", "zamba2_2_7b")

STREAM_COUNTS = (2, 4, 8, 16)


def single_mesh_baseline(stages, cfg, chips=256, tp=16, n_streams=2):
    """All streams serialized on the full pod (homogeneous baseline)."""
    return sum(stage_cost(s, cfg, chips, tp, HW)
               for s in stages) * n_streams


def bench_scheduling_schemes(arch="qwen2_5_14b"):
    print(f"\n## LM Table-V analogue — scheduling schemes ({arch})")
    cfg = get_arch(arch)
    dual = abstract_split(256, 0.5)
    rows = []
    for wname, groups in WORKLOADS.items():
        stages = request_stages(cfg, groups)
        cells = []
        for scheme in ALLOCATIONS:
            s = build(stages, cfg, dual, HW, scheme)
            cells.append(s.makespan())
        lb = best_schedule(stages, cfg, dual, HW)
        rows.append((wname, *cells, lb.makespan()))
        print(f"{wname:<15} " + " ".join(f"{c*1e3:9.1f}" for c in cells)
              + f"  lb={lb.makespan()*1e3:9.1f} ms "
              f"(+{max(cells)/lb.makespan()-1:.0%} vs worst basic)")
    return rows


def bench_dual_vs_single():
    print("\n## LM Table-VI analogue — dual-mesh vs single-pod "
          "(256 chips, makespan ms)")
    rows = []
    for arch in ARCHS:
        cfg = get_arch(arch)
        for wname, groups in WORKLOADS.items():
            stages = request_stages(cfg, groups)
            res = search(stages, cfg, n_devices=256, max_evals=10)
            single = single_mesh_baseline(stages, cfg)
            speed = single / res.makespan
            rows.append((arch, wname, res.theta, res.tp_c, res.tp_p,
                         res.makespan, single, speed))
            print(f"{arch:<22}{wname:<15} theta={res.theta:.2f} "
                  f"tp=({res.tp_c:>2},{res.tp_p:>2}) "
                  f"dual={res.makespan*1e3:8.1f} single={single*1e3:8.1f} "
                  f"speedup={speed:5.2f}x")
    avg = sum(r[-1] for r in rows) / len(rows)
    print(f"average dual-mesh speedup: {avg:.2f}x "
          f"(paper single-CNN avg: +31% throughput)")
    return rows


def bench_stream_scaling_model(arch="qwen2_5_14b",
                               workload=(8, 8192, 256)):
    """Model-side N-stream throughput: the flow-shop makespan amortizes
    the stagger fill/drain over more streams, and the makespan-aware
    admission plan picks the decode fusion width."""
    print(f"\n## N-stream scaling, model-side ({arch}, 256 chips, "
          f"per-stream batch={workload[0]} prompt={workload[1]} "
          f"gen={workload[2]})")
    cfg = get_arch(arch)
    dual = abstract_split(256, 0.5)
    stages = request_stages(cfg, [workload])
    rows = []
    for n in STREAM_COUNTS:
        sched = best_schedule(stages, cfg, dual, HW, n_streams=n)
        adm = plan_admission(cfg, dual, HW, *workload, n)
        rows.append((n, sched.makespan(), sched.throughput_tokens_per_s(),
                     adm.group_size))
        print(f"N={n:<3} makespan={sched.makespan()*1e3:9.1f} ms "
              f"tokens/s={sched.throughput_tokens_per_s():12.0f} "
              f"admission group_size={adm.group_size}")
    return rows


def bench_stream_scaling_runtime(arch="qwen2_0_5b", batch=1,
                                 prompt_len=16, gen=16):
    """Measured N-stream throughput on the local (degenerate CPU) dual
    mesh: fused decode batches amortize per-step dispatch, so tokens/s
    grows with N even without real disjoint submeshes."""
    import jax
    from repro.dualmesh import DualMeshRunner, split_mesh
    from repro.lm.model import init_params
    from repro.serving import DualMeshEngine, Request

    print(f"\n## N-stream scaling, measured on {len(jax.devices())} "
          f"local device(s) ({arch} smoke, per-stream batch={batch} "
          f"prompt={prompt_len} gen={gen})")
    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), 0.5)
    rows = {}
    for n in STREAM_COUNTS:
        runner = DualMeshRunner(cfg, params, dual,
                                max_len=prompt_len + gen + 8)
        prompts = [jax.random.randint(k, (batch, prompt_len), 0, cfg.vocab)
                   for k in jax.random.split(jax.random.PRNGKey(1), n)]
        gs = runner.planned_group_size(prompts, [gen] * n)

        def run_once():
            eng = DualMeshEngine(runner, group_size=gs)
            for p in prompts:
                eng.submit(Request(p, gen_steps=gen))
            return eng.drain()

        run_once()                                    # warm the jit caches
        runner.trace.clear()
        res = run_once()
        s = res.stats
        rows[n] = s["tokens_per_s"]
        print(f"N={n:<3} {s['wall_s']*1e3:8.1f} ms "
              f"tokens/s={s['tokens_per_s']:9.0f} "
              f"(group_size={s['group_size']}, "
              f"fused={s['fused_sizes']})")
    gain = rows[8] / rows[2] if rows.get(2) else float("nan")
    print(f"N=8 vs N=2 measured throughput: {gain:.2f}x "
          f"({'>=' if rows[8] >= rows[2] else '<'} baseline)")
    return rows


def run_all(with_runtime: bool = True):
    bench_scheduling_schemes()
    bench_dual_vs_single()
    bench_stream_scaling_model()
    if with_runtime:
        bench_stream_scaling_runtime()


if __name__ == "__main__":
    run_all()
