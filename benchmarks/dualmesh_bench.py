"""Dual-mesh serving benchmark: the paper's Table V/VI experiments
re-staged on the LM side (DESIGN.md §2).

For each workload mix and architecture: single-pod serialized baseline vs
the dual-mesh interleaved schedule found by the §V-B search, plus the
scheduling-scheme comparison (stage-type / greedy / round-robin /
load-balance) — the LM twin of Table V."""
from __future__ import annotations

from repro.configs.registry import get_arch
from repro.dualmesh import (ALLOCATIONS, TpuModel, best_schedule, build,
                            load_balance, request_stages, search)
from repro.dualmesh.partition import abstract_split
from repro.dualmesh.schedule import stage_cost

HW = TpuModel()

WORKLOADS = {
    "balanced": [(8, 8192, 256)] * 4,
    "prefill_heavy": [(8, 16384, 32)] * 4,
    "decode_heavy": [(8, 1024, 1024)] * 4,
    "mixed": [(8, 16384, 32), (8, 1024, 1024)] * 2,
}
# (command-r-104b excluded: bf16 weights exceed the HBM constraint at any
# TP <= 16 on 256 chips — the search falls back to a best-effort plan;
# kept out of the headline table, see search() fallback note.)
ARCHS = ("qwen2_5_14b", "qwen2_moe_a2_7b", "zamba2_2_7b")


def single_mesh_baseline(stages, cfg, chips=256, tp=16):
    """Both streams serialized on the full pod (homogeneous baseline)."""
    return sum(stage_cost(s, cfg, chips, tp, HW) for s in stages) * 2


def bench_scheduling_schemes(arch="qwen2_5_14b"):
    print(f"\n## LM Table-V analogue — scheduling schemes ({arch})")
    cfg = get_arch(arch)
    dual = abstract_split(256, 0.5)
    rows = []
    for wname, groups in WORKLOADS.items():
        stages = request_stages(cfg, groups)
        cells = []
        for scheme in ALLOCATIONS:
            s = build(stages, cfg, dual, HW, scheme)
            cells.append(s.makespan())
        lb = best_schedule(stages, cfg, dual, HW)
        rows.append((wname, *cells, lb.makespan()))
        print(f"{wname:<15} " + " ".join(f"{c*1e3:9.1f}" for c in cells)
              + f"  lb={lb.makespan()*1e3:9.1f} ms "
              f"(+{max(cells)/lb.makespan()-1:.0%} vs worst basic)")
    return rows


def bench_dual_vs_single():
    print("\n## LM Table-VI analogue — dual-mesh vs single-pod "
          "(256 chips, makespan ms)")
    rows = []
    for arch in ARCHS:
        cfg = get_arch(arch)
        for wname, groups in WORKLOADS.items():
            stages = request_stages(cfg, groups)
            res = search(stages, cfg, n_devices=256, max_evals=10)
            single = single_mesh_baseline(stages, cfg)
            speed = single / res.makespan
            rows.append((arch, wname, res.theta, res.tp_c, res.tp_p,
                         res.makespan, single, speed))
            print(f"{arch:<22}{wname:<15} theta={res.theta:.2f} "
                  f"tp=({res.tp_c:>2},{res.tp_p:>2}) "
                  f"dual={res.makespan*1e3:8.1f} single={single*1e3:8.1f} "
                  f"speedup={speed:5.2f}x")
    avg = sum(r[-1] for r in rows) / len(rows)
    print(f"average dual-mesh speedup: {avg:.2f}x "
          f"(paper single-CNN avg: +31% throughput)")
    return rows


def run_all():
    bench_scheduling_schemes()
    bench_dual_vs_single()
