"""Fleet serving benchmark: the Table VII multi-network workload, measured.

Serves the mbv1+mbv2+squeezenet traffic mix through a ``FleetEngine``
(shared device pool, weighted-fair step scheduling, core-complementary
interleave) and compares against the best *sequential* way to serve the
same requests on the same host with the engines this repo already had:

  * ``engine_at_a_time`` — drain each model's requests through its own
    standalone ``DualCoreEngine``, one model after another (per-model
    pipelining intact, zero cross-network overlap);
  * ``run_sequential``   — strictly serialized single-image forwards.

The fleet's win condition (the ISSUE-5 acceptance) is aggregate fps >= the
best of those baselines: multiplexing several networks over one pool must
never cost throughput, and the cross-engine interleave should buy some.
Latency percentiles come from a separate fixed Poisson-arrival replay leg
(seeded, identical across runs), broken down per model via
``Metrics.by_model``.  The planner's model-side prediction
(``fleet.planner.plan_fleet`` — deterministic, cycle-domain) rides along
for the Table-VII-style predicted-vs-measured comparison in
``benchmarks/paper_tables.py``.

Writes ``BENCH_fleet.json`` — the committed baseline CI diffs against
(``aggregate_fps`` is gated as higher-is-better, the p50/p95 fields as
lower-is-better, in ``benchmarks/compare_bench.py``).

    PYTHONPATH=src python -m benchmarks.fleet_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# A >=2-device mesh is the point of the exercise: force two host platform
# devices unless the caller already configured XLA (must happen pre-import).
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

MIX = {"mobilenet_v1": 0.4, "mobilenet_v2": 0.35, "squeezenet": 0.25}
ARRIVAL_RATE = 1.0          # requests per fleet slot (Poisson-ish)
ARRIVAL_SEED = 0


BURST = 4       # per-member slot burst: amortizes the cache locality the
#                 one-model-at-a-time baselines get for free; without it
#                 the fleet measures a few percent BEHIND them on this
#                 2-CPU host, with it par-or-slightly-ahead


def _fresh_fleet(runners, mix, co_dispatch=None, burst=BURST):
    """New engines over the already-jitted runners (cheap per rep)."""
    from repro.fleet import FleetEngine, WeightedFair
    from repro.serving import DualCoreEngine

    members = {m: DualCoreEngine(r) for m, r in runners.items()}
    return FleetEngine(members, policy=WeightedFair(), weights=mix,
                       co_dispatch=co_dispatch, burst=burst)


def bench_fleet(report: dict, image_size: int, requests: int,
                reps: int) -> None:
    import jax

    from repro.fleet import build_cnn_fleet, mix_schedule, plan_fleet
    from repro.serving import Request, poisson_arrivals, replay

    engine, pool = build_cnn_fleet(list(MIX), weights=MIX,
                                   use_pallas=True, fuse="group")
    runners = {m.name: m.engine.runner for m in engine.members}
    tags = mix_schedule(MIX, requests)
    keys = jax.random.split(jax.random.PRNGKey(0), requests)
    images = [jax.random.normal(k, (1, image_size, image_size, 3))
              for k in keys]
    by_model: dict[str, list] = {m: [] for m in MIX}
    for x, t in zip(images, tags):
        by_model[t].append(x)
    for m, r in runners.items():        # warm every member's per-group jits
        r.run_sequential(by_model[m][:1])

    print(f"\n## fleet serving ({'+'.join(MIX)}, {image_size}px, "
          f"{requests} requests, mix "
          f"{'/'.join(f'{s:.2f}' for s in MIX.values())}, "
          f"{len(jax.devices())} local device(s))")

    # steady state: everything at slot 0.  The three legs are interleaved
    # rep-by-rep (fleet, engine-at-a-time, run_sequential, repeat) with
    # best-of per leg: on this host the first-measured leg routinely loses
    # 5-10% to allocator/cache warm-in that later legs inherit for free,
    # so measuring all fleet reps before all baseline reps biases the
    # comparison either way the machine is drifting.  gc.collect keeps the
    # previous leg's deallocations out of the timed window (as in
    # serving_bench); rep 0 of each leg is an untimed warm-in.
    from repro.serving import stream_images

    # every leg is a full wall (perf_counter around engine construction +
    # submits + drain): summing the baselines' *internal* engine walls
    # would drop the inter-engine gaps the engine-at-a-time leg really
    # pays between models, while the fleet's single wall includes
    # everything — an asymmetry worth a percent
    def leg_fleet():
        t0 = time.perf_counter()
        eng = _fresh_fleet(runners, MIX)
        for x, t in zip(images, tags):
            eng.submit(Request(x, model=t))
        res = eng.drain()
        return time.perf_counter() - t0, res

    def leg_eaat():
        t0 = time.perf_counter()
        for m, r in runners.items():
            stream_images(r, by_model[m])
        return time.perf_counter() - t0

    def leg_seq():
        t0 = time.perf_counter()
        for m, r in runners.items():
            r.run_sequential(by_model[m])
        return time.perf_counter() - t0

    leg_fleet(), leg_eaat(), leg_seq()          # warm-in, untimed
    t_fleet = t_eaat = t_seq = float("inf")
    best_res = None
    for _ in range(max(2, reps)):
        gc.collect()
        wall, res = leg_fleet()
        if wall < t_fleet:
            t_fleet, best_res = wall, res
        gc.collect()
        t_eaat = min(t_eaat, leg_eaat())
        gc.collect()
        t_seq = min(t_seq, leg_seq())
    fleet_fps = requests / t_fleet
    baseline_fps = requests / min(t_eaat, t_seq)

    # latency leg: fixed Poisson-ish arrivals, best-of (a single replay's
    # p95 is one GC pause away from a phantom CI failure)
    arrivals = poisson_arrivals(requests, rate=ARRIVAL_RATE,
                                seed=ARRIVAL_SEED)
    lat: dict[str, dict[str, float]] = {}
    for _ in range(max(2, reps // 2)):
        gc.collect()
        res = replay(_fresh_fleet(runners, MIX),
                     [Request(x, model=t)
                      for x, t in zip(images, tags)], arrivals)
        for m, pm in res.metrics.by_model().items():
            cur = lat.setdefault(m, {"p50_ms": float("inf"),
                                     "p95_ms": float("inf")})
            cur["p50_ms"] = min(cur["p50_ms"], pm["p50_ms"])
            cur["p95_ms"] = min(cur["p95_ms"], pm["p95_ms"])
        agg = lat.setdefault("aggregate", {"p50_ms": float("inf"),
                                           "p95_ms": float("inf")})
        agg["p50_ms"] = min(agg["p50_ms"], res.metrics.p50_ms())
        agg["p95_ms"] = min(agg["p95_ms"], res.metrics.p95_ms())

    # deterministic model-side prediction for the Table-VII comparison
    plan = plan_fleet(MIX, max_evals=6)

    st = best_res.stats
    report["mix"] = MIX
    report["theta"] = pool.theta        # the c/p split the pool served on
    report["fleet"] = {
        "aggregate_fps": round(fleet_fps, 2),
        "policy": st["policy"],
        "co_dispatch": st["co_dispatch"],
        "burst": st["burst"],
        "slots": st["slots"],
        "dispatches": st["dispatches"],
        "per_model": {
            m: {"completed": pm["completed"],
                "requests_per_s": pm["requests_per_s"]}
            for m, pm in st["per_model"].items()},
        "latency": {m: {k: round(v, 2) for k, v in d.items()}
                    for m, d in lat.items()},
    }
    report["baseline"] = {
        "engine_at_a_time_fps": round(requests / t_eaat, 2),
        "run_sequential_fps": round(requests / t_seq, 2),
        "best_fps": round(baseline_fps, 2),
    }
    report["fleet_vs_baseline"] = round(fleet_fps / baseline_fps, 3)
    report["planner"] = plan.summary()

    print(f"{'leg':<22}{'fps':>8}")
    print(f"{'fleet (interleaved)':<22}{fleet_fps:>8.2f}")
    print(f"{'engine-at-a-time':<22}{requests / t_eaat:>8.2f}")
    print(f"{'run_sequential':<22}{requests / t_seq:>8.2f}")
    print(f"fleet vs best sequential baseline: "
          f"{report['fleet_vs_baseline']:.2f}x")
    for m, d in lat.items():
        print(f"  {m:<16} p50 {d['p50_ms']:7.1f} ms  "
              f"p95 {d['p95_ms']:7.1f} ms")
    print(f"planner predicted aggregate (model-side): "
          f"{plan.aggregate_fps:.1f} fps under {plan.config}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small images, few requests")
    ap.add_argument("--out", default="BENCH_fleet.json")
    ap.add_argument("--image-size", type=int, default=None,
                    help="input H=W (default: 64 smoke / 96 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests across the mix "
                         "(default: 9 smoke / 18 full)")
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args(argv)

    image_size = args.image_size or (64 if args.smoke else 96)
    requests = args.requests or (9 if args.smoke else 18)

    import jax

    report: dict = {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "image_size": image_size,
                    "requests": requests}
    bench_fleet(report, image_size, requests, args.reps)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
