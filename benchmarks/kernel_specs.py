"""Kernel spec table: per-Pallas-kernel block shapes, VMEM working set and
arithmetic intensity on representative shapes of the assigned archs —
the structural evidence used in place of wall-clock (CPU-only container).

AI (arithmetic intensity) is computed from true HBM traffic under the
kernel's blocking: inputs read once per tile-pass, outputs written once.
v5e ridge point = 197e12 / 819e9 ~= 240 flops/byte.
"""
from __future__ import annotations

RIDGE = 197e12 / 819e9


def gemm_case(name, M, K, N, bm, bn, bk, dtype_bytes=2):
    flops = 2.0 * M * K * N
    # k-loop accumulates in VMEM: A read once per (j) column-block pass,
    # B read once per (i) row-block pass, C written once.
    passes_a = -(-N // bn)
    passes_b = -(-M // bm)
    bytes_ = (M * K * passes_a + K * N * passes_b + M * N) * dtype_bytes
    vmem = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4
    return name, f"M{M} K{K} N{N}", (bm, bn, bk), vmem, flops / bytes_


def run_all():
    print("\n## Pallas kernel specs (TPU target, validated interpret=True)")
    print(f"{'kernel':<18}{'shape':<28}{'block':<18}"
          f"{'VMEM/step':>10}{'AI fl/B':>9}{'bound':>7}")
    rows = []
    cases = [
        gemm_case("conv_gemm im2col", 200704, 27, 32, 128, 32, 27),
        gemm_case("conv_gemm pw", 12544, 1024, 1024, 128, 128, 128),
        gemm_case("lm qkv (14b)", 4096 * 8, 5120, 6144, 128, 128, 128),
        gemm_case("lm mlp (104b)", 4096, 12288, 33792 // 16, 128, 128, 128),
    ]
    # depthwise: halo tile read once, K*K taps reuse it from VMEM
    h, c, k = 112, 64, 3
    dw_flops = 2.0 * h * h * c * k * k
    dw_bytes = ((h + 2) * (h + 2) * c + h * h * c + k * k * c) * 2
    cases.append(("depthwise", f"{h}x{h}x{c} k{k}", ("H-tile", 64),
                  (h + 2) * (h + 2) * 64 * 4, dw_flops / dw_bytes))
    # flash attention: per (q-block, kv-block) pass
    b_, hq, s, d = 8, 96, 4096, 128
    fa_flops = 4.0 * b_ * hq * s * s * d
    fa_bytes = (b_ * hq * s * d                              # q once
                + 2 * b_ * 8 * s * d * (s // 128)            # kv per q-blk
                + b_ * hq * s * d) * 2
    cases.append(("flash_attn", f"B{b_} H{hq}/8 S{s} D{d}", (128, 128),
                  (128 * d * 3 + 128 * 128) * 4, fa_flops / fa_bytes))
    # decode attention: the p-class kernel — streams KV once
    b_, hkv, s = 128, 8, 32768
    dec_flops = 4.0 * b_ * 96 * s * 128
    dec_bytes = 2 * b_ * hkv * s * 128 * 2
    cases.append(("flash_decode", f"B{b_} Hkv{hkv} S{s}", (8, 512),
                  (512 * 128 * 3) * 4, dec_flops / dec_bytes))
    # rmsnorm: one pass
    rows_, dm = 2 ** 20, 12288
    cases.append(("rmsnorm", f"rows 1M d {dm}", (256, dm),
                  256 * dm * 4 * 2, (3.0 * rows_ * dm)
                  / (2.0 * rows_ * dm * 2)))
    for name, shape, block, vmem, ai in cases:
        bound = "MXU" if ai > RIDGE else "HBM"
        rows.append((name, shape, block, vmem, ai, bound))
        print(f"{name:<18}{shape:<28}{str(block):<18}"
              f"{vmem/1024:>8.0f}KB{ai:>9.1f}{bound:>7}")
    print(f"(ridge ~{RIDGE:.0f} fl/B on v5e; depthwise/decode/rmsnorm are "
          f"HBM-bound by design — the p-class kernels)")
    return rows
