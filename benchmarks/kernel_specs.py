"""Kernel spec table: per-Pallas-kernel block shapes, VMEM working set and
arithmetic intensity on representative shapes of the assigned archs —
the structural evidence used in place of wall-clock (CPU-only container).

AI (arithmetic intensity) is computed from true HBM traffic under the
kernel's blocking: inputs read once per tile-pass, outputs written once.
v5e ridge point = 197e12 / 819e9 ~= 240 flops/byte.

``--smoke`` additionally measures real wall-clock on this container
(interpret mode) for the two perf claims this repo tracks from PR 2 on —
im2col-materializing vs implicit-GEMM conv, and the fused dw->pw block vs
the unfused two-kernel path — and writes ``BENCH_kernels.json`` so CI keeps
a perf trajectory (DESIGN.md §6).
"""
from __future__ import annotations

import argparse
import json
import sys

RIDGE = 197e12 / 819e9


def gemm_case(name, M, K, N, bm, bn, bk, dtype_bytes=2):
    flops = 2.0 * M * K * N
    # k-loop accumulates in VMEM: A read once per (j) column-block pass,
    # B read once per (i) row-block pass, C written once.
    passes_a = -(-N // bn)
    passes_b = -(-M // bm)
    bytes_ = (M * K * passes_a + K * N * passes_b + M * N) * dtype_bytes
    vmem = (bm * bk + bk * bn) * dtype_bytes + bm * bn * 4
    return name, f"M{M} K{K} N{N}", (bm, bn, bk), vmem, flops / bytes_


def run_all():
    print("\n## Pallas kernel specs (TPU target, validated interpret=True)")
    print(f"{'kernel':<18}{'shape':<28}{'block':<18}"
          f"{'VMEM/step':>10}{'AI fl/B':>9}{'bound':>7}")
    rows = []
    cases = [
        gemm_case("conv_gemm im2col", 200704, 27, 32, 128, 32, 27),
        gemm_case("conv_gemm pw", 12544, 1024, 1024, 128, 128, 128),
        gemm_case("lm qkv (14b)", 4096 * 8, 5120, 6144, 128, 128, 128),
        gemm_case("lm mlp (104b)", 4096, 12288, 33792 // 16, 128, 128, 128),
    ]
    # depthwise: halo tile read once, K*K taps reuse it from VMEM
    h, c, k = 112, 64, 3
    dw_flops = 2.0 * h * h * c * k * k
    dw_bytes = ((h + 2) * (h + 2) * c + h * h * c + k * k * c) * 2
    cases.append(("depthwise", f"{h}x{h}x{c} k{k}", ("H-tile", 64),
                  (h + 2) * (h + 2) * 64 * 4, dw_flops / dw_bytes))
    # flash attention: per (q-block, kv-block) pass
    b_, hq, s, d = 8, 96, 4096, 128
    fa_flops = 4.0 * b_ * hq * s * s * d
    fa_bytes = (b_ * hq * s * d                              # q once
                + 2 * b_ * 8 * s * d * (s // 128)            # kv per q-blk
                + b_ * hq * s * d) * 2
    cases.append(("flash_attn", f"B{b_} H{hq}/8 S{s} D{d}", (128, 128),
                  (128 * d * 3 + 128 * 128) * 4, fa_flops / fa_bytes))
    # decode attention: the p-class kernel — streams KV once
    b_, hkv, s = 128, 8, 32768
    dec_flops = 4.0 * b_ * 96 * s * 128
    dec_bytes = 2 * b_ * hkv * s * 128 * 2
    cases.append(("flash_decode", f"B{b_} Hkv{hkv} S{s}", (8, 512),
                  (512 * 128 * 3) * 4, dec_flops / dec_bytes))
    # rmsnorm: one pass
    rows_, dm = 2 ** 20, 12288
    cases.append(("rmsnorm", f"rows 1M d {dm}", (256, dm),
                  256 * dm * 4 * 2, (3.0 * rows_ * dm)
                  / (2.0 * rows_ * dm * 2)))
    for name, shape, block, vmem, ai in cases:
        bound = "MXU" if ai > RIDGE else "HBM"
        rows.append((name, shape, block, vmem, ai, bound))
        print(f"{name:<18}{shape:<28}{str(block):<18}"
              f"{vmem/1024:>8.0f}KB{ai:>9.1f}{bound:>7}")
    print(f"(ridge ~{RIDGE:.0f} fl/B on v5e; depthwise/decode/rmsnorm are "
          f"HBM-bound by design — the p-class kernels)")
    return rows


# --------------------------------------------------------------------------
# --smoke: measured wall-clock on this container -> BENCH_kernels.json
# --------------------------------------------------------------------------
def _time_ms(fn, reps: int = 3) -> float:
    from repro.kernels.util import bench_best_us
    return bench_best_us(fn, reps=reps) / 1e3


def smoke(out_path: str = "BENCH_kernels.json", reps: int = 4) -> dict:
    """Measure im2col-vs-implicit and fused-vs-unfused wall-clock on small
    model-zoo shapes, write the JSON perf artifact, return it."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import autotune
    from repro.kernels.conv_gemm.kernel import (conv2d_implicit_gemm,
                                                matmul_bias_act)
    from repro.kernels.conv_gemm.ref import im2col
    from repro.kernels.conv_gemm.ops import pointwise_conv
    from repro.kernels.depthwise.ops import depthwise
    from repro.kernels.fused_block.ops import (fused_dw_pw,
                                               fused_inverted_residual)

    keys = jax.random.split(jax.random.PRNGKey(0), 8)
    report: dict = {"backend": jax.default_backend(),
                    "interpret": jax.default_backend() == "cpu",
                    "reps": reps, "conv_implicit_gemm": [],
                    "fused_dw_pw": [], "fused_pw_dw_pw": [],
                    "autotune": []}

    print("\n## kernel smoke bench (wall-clock on this container)")
    # -- implicit GEMM vs HBM-materialized im2col (zoo conv shapes) --------
    for h, ci, co, k, s, p in [(56, 16, 64, 3, 1, 1),    # sqz fire e3x3
                               (28, 32, 128, 3, 1, 1)]:  # fire4-ish
        x = (jax.random.normal(keys[0], (1, h, h, ci)) * 0.5)
        w = (jax.random.normal(keys[1], (k, k, ci, co)) * 0.2)
        ho = (h + 2 * p - k) // s + 1
        patch_bytes = ho * ho * k * k * ci * 4        # the HBM blow-up
        ifm_bytes = h * h * ci * 4                    # implicit traffic

        def run_im2col():
            pm, (n, ho_, wo_) = im2col(x, k, k, s, p)
            return matmul_bias_act(pm, w.reshape(k * k * ci, co)
                                   ).reshape(n, ho_, wo_, co)

        t_im2col = _time_ms(run_im2col, reps)
        t_impl = _time_ms(lambda: conv2d_implicit_gemm(x, w, stride=s,
                                                       pad=p), reps)
        row = {"shape": f"{h}x{h}x{ci}->{co} k{k} s{s}",
               "im2col_ms": round(t_im2col, 2),
               "implicit_ms": round(t_impl, 2),
               "speedup": round(t_im2col / t_impl, 2),
               "im2col_hbm_patch_bytes": patch_bytes,
               "implicit_ifm_bytes": ifm_bytes,
               "hbm_traffic_ratio": round(patch_bytes / ifm_bytes, 2)}
        report["conv_implicit_gemm"].append(row)
        print(f"conv {row['shape']:<24} im2col {t_im2col:8.1f}ms  "
              f"implicit {t_impl:8.1f}ms  ({row['speedup']}x, "
              f"{row['hbm_traffic_ratio']}x less HBM)")

    # -- fused dw->pw vs unfused two-kernel path (MobileNet-v1 blocks) -----
    for h, c, co, s in [(14, 256, 256, 1),   # mbv1 dw7..11/pw
                        (14, 512, 512, 1),
                        (7, 1024, 1024, 1)]:
        x = (jax.random.normal(keys[0], (1, h, h, c)) * 0.5)
        dw_w = (jax.random.normal(keys[1], (3, 3, c)) * 0.3)
        dw_b = jnp.zeros((c,))
        pw_w = (jax.random.normal(keys[2], (c, co)) * 0.2)
        pw_b = jnp.zeros((co,))

        def run_unfused():
            y = depthwise(x, dw_w, dw_b, stride=s, pad=1, act="relu6")
            return pointwise_conv(y, pw_w, pw_b, act="relu6")

        def run_fused():
            return fused_dw_pw(x, dw_w, dw_b, pw_w, pw_b, stride=s, pad=1,
                               dw_act="relu6", pw_act="relu6")

        t_unf = _time_ms(run_unfused, reps)
        t_fus = _time_ms(run_fused, reps)
        row = {"shape": f"{h}x{h}x{c}->{co} s{s}",
               "unfused_ms": round(t_unf, 2), "fused_ms": round(t_fus, 2),
               "speedup": round(t_unf / t_fus, 2),
               "hbm_intermediate_bytes_saved": h * h * c * 4 // (s * s)}
        report["fused_dw_pw"].append(row)
        print(f"dw->pw {row['shape']:<22} unfused {t_unf:8.1f}ms  "
              f"fused {t_fus:8.1f}ms  ({row['speedup']}x)")

    # -- fused inverted residual (MobileNet-v2 blocks) ---------------------
    for h, ci, t_exp, s in [(14, 64, 6, 1),      # mbv2 b8-ish
                            (7, 160, 6, 1)]:     # mbv2 b15-ish
        cm, co = ci * t_exp, ci
        x = (jax.random.normal(keys[0], (1, h, h, ci)) * 0.5)
        ew = (jax.random.normal(keys[1], (ci, cm)) * 0.2)
        dw_w = (jax.random.normal(keys[2], (3, 3, cm)) * 0.3)
        pw = (jax.random.normal(keys[3], (cm, co)) * 0.2)
        eb, db, pb = jnp.zeros((cm,)), jnp.zeros((cm,)), jnp.zeros((co,))

        def run_unfused():
            y = pointwise_conv(x, ew, eb, act="relu6")
            y = depthwise(y, dw_w, db, stride=s, pad=1, act="relu6")
            return pointwise_conv(y, pw, pb) + x

        def run_fused():
            return fused_inverted_residual(x, ew, eb, dw_w, db, pw, pb, x,
                                           stride=s, pad=1)

        t_unf = _time_ms(run_unfused, reps)
        t_fus = _time_ms(run_fused, reps)
        row = {"shape": f"{h}x{h}x{ci} t{t_exp} s{s}",
               "unfused_ms": round(t_unf, 2), "fused_ms": round(t_fus, 2),
               "speedup": round(t_unf / t_fus, 2),
               "hbm_intermediate_bytes_saved":
                   (h * h * cm + (h // s) * (h // s) * cm) * 4}
        report["fused_pw_dw_pw"].append(row)
        print(f"pw->dw->pw {row['shape']:<18} unfused {t_unf:8.1f}ms  "
              f"fused {t_fus:8.1f}ms  ({row['speedup']}x)")

    # -- autotuner: tune one signature per kind, report the winners --------
    for sig in [autotune.LayerSig("conv", 14, 14, 32, 64, 3, 3, 1, 1),
                autotune.LayerSig("fused_dw_pw", 14, 14, 128, 128, 3, 3,
                                  1, 1)]:
        cfg = autotune.tune_layer(sig, reps=1)
        entry = autotune.load_cache()["entries"][sig.key()]
        report["autotune"].append({"sig": sig.key(), "config": cfg,
                                   "us": entry["us"]})
        print(f"autotune {sig.key():<42} -> {cfg}")

    with open(out_path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {out_path}")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="measure wall-clock and write BENCH_kernels.json")
    ap.add_argument("--out", default="BENCH_kernels.json")
    ap.add_argument("--reps", type=int, default=4)
    args = ap.parse_args(argv)
    if args.smoke:
        smoke(args.out, reps=args.reps)
    else:
        run_all()
    return 0


if __name__ == "__main__":
    sys.exit(main())
