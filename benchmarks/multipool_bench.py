"""Multi-pool serving benchmark: one pool vs N process-local pools.

Serves the same mbv1+squeezenet traffic mix two ways on the same host:

  * ``one_pool``  — a single ``FleetEngine`` over one ``DevicePool``
    (the PR-5 fleet path, now compiled to an instruction stream);
  * ``two_pool``  — two pools (each its own ``DevicePool`` + fleet)
    behind a ``MultiPoolRouter``: requests place onto the least
    outstanding pool and each pool executes its own instruction stream.

On this CPU host both pools share the physical cores, so two pools is a
*scheduling* experiment (placement + per-pool streams), not a capacity
one — the interesting check is that the router multiplexes at par rather
than collapsing.  A third leg measures migration under drain: mid-run,
``drain_pool`` evacuates pool1's queue through SEND/RECV instructions and
the run must still complete every admitted request.

Writes ``BENCH_multipool.json`` — the committed baseline CI diffs against
(the ``aggregate_fps`` leaves are gated higher-is-better in
``benchmarks/compare_bench.py``, same as BENCH_fleet.json).

    PYTHONPATH=src python -m benchmarks.multipool_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# A >=2-device mesh is the point of the exercise: force two host platform
# devices unless the caller already configured XLA (must happen pre-import).
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

MIX = {"mobilenet_v1": 0.5, "squeezenet": 0.5}
BURST = 4           # same locality amortization as fleet_bench
POOLS = 2


def _fresh_fleet(runners, pool=None):
    from repro.fleet import FleetEngine, WeightedFair
    from repro.serving import DualCoreEngine

    members = {m: DualCoreEngine(r) for m, r in runners.items()}
    return FleetEngine(members, policy=WeightedFair(), weights=MIX,
                       burst=BURST, pool=pool)


def bench_multipool(report: dict, image_size: int, requests: int,
                    reps: int) -> None:
    import jax

    from repro.fleet import MultiPoolRouter, build_cnn_fleet, mix_schedule
    from repro.serving import Request

    # one runner set per pool (each pool leases its own DevicePool split),
    # plus the single-pool reference set
    def build():
        eng, pool = build_cnn_fleet(list(MIX), weights=MIX,
                                    use_pallas=True, fuse="group")
        return {m.name: m.engine.runner for m in eng.members}, pool

    single_runners, single_pool = build()
    pool_sets = [build() for _ in range(POOLS)]

    tags = mix_schedule(MIX, requests)
    keys = jax.random.split(jax.random.PRNGKey(0), requests)
    images = [jax.random.normal(k, (1, image_size, image_size, 3))
              for k in keys]
    by_model: dict[str, list] = {m: [] for m in MIX}
    for x, t in zip(images, tags):
        by_model[t].append(x)
    for runners in [single_runners] + [rs for rs, _ in pool_sets]:
        for m, r in runners.items():    # warm every member's per-group jits
            r.run_sequential(by_model[m][:1])

    print(f"\n## multi-pool serving ({'+'.join(MIX)}, {image_size}px, "
          f"{requests} requests, 1 vs {POOLS} pools, "
          f"{len(jax.devices())} local device(s))")

    def reqs():
        return [Request(x, model=t) for x, t in zip(images, tags)]

    def leg_one_pool():
        t0 = time.perf_counter()
        eng = _fresh_fleet(single_runners, single_pool)
        for r in reqs():
            eng.submit(r)
        res = eng.drain()
        return time.perf_counter() - t0, res

    def fresh_router():
        return MultiPoolRouter({
            f"pool{i}": _fresh_fleet(rs, pool)
            for i, (rs, pool) in enumerate(pool_sets)})

    def leg_two_pool():
        t0 = time.perf_counter()
        router = fresh_router()
        for r in reqs():
            router.submit(r)
        res = router.drain()
        return time.perf_counter() - t0, res

    def leg_migration():
        """Same workload, but pool1's queue is forcibly evacuated mid-run
        (SEND on pool1, RECV on pool0) — drain-for-maintenance."""
        t0 = time.perf_counter()
        router = fresh_router()
        for r in reqs():
            router.submit(r)
        # evacuate before pool1 admits anything: with burst=4 a single
        # step already admits this whole smoke-sized queue
        moved = router.drain_pool("pool1")
        res = router.drain()
        return time.perf_counter() - t0, res, moved

    # interleave the legs rep-by-rep with best-of per leg (the machine
    # drifts either way; see fleet_bench); rep 0 is an untimed warm-in
    leg_one_pool(), leg_two_pool(), leg_migration()
    t_one = t_two = t_mig = float("inf")
    res_two = res_mig = None
    moved = 0
    for _ in range(max(2, reps)):
        gc.collect()
        t_one = min(t_one, leg_one_pool()[0])
        gc.collect()
        wall, res = leg_two_pool()
        if wall < t_two:
            t_two, res_two = wall, res
        gc.collect()
        wall, res, mv = leg_migration()
        if wall < t_mig:
            t_mig, res_mig, moved = wall, res, mv

    one_fps = requests / t_one
    two_fps = requests / t_two
    mig_fps = requests / t_mig
    assert res_two.metrics.completed == requests
    assert res_mig.metrics.completed == requests    # nothing lost in
    #                                                 transit under drain

    st = res_two.stats
    report["mix"] = MIX
    report["theta"] = single_pool.theta
    report["pools"] = POOLS
    report["one_pool"] = {"aggregate_fps": round(one_fps, 2)}
    report["two_pool"] = {
        "aggregate_fps": round(two_fps, 2),
        "steps": st["steps"],
        "per_pool_served": {p: sum(d["served"].values())
                            for p, d in st["pools"].items()},
    }
    report["migration"] = {
        "aggregate_fps": round(mig_fps, 2),
        "moved": moved,
        "completed": res_mig.metrics.completed,
        "in_transit_after": res_mig.stats["in_transit"],
    }
    report["two_vs_one"] = round(two_fps / one_fps, 3)

    print(f"{'leg':<26}{'fps':>8}")
    print(f"{'one pool':<26}{one_fps:>8.2f}")
    print(f"{f'{POOLS} pools (router)':<26}{two_fps:>8.2f}")
    print(f"{'migration under drain':<26}{mig_fps:>8.2f}  "
          f"({moved} request(s) migrated)")
    print(f"{POOLS} pools vs one: {report['two_vs_one']:.2f}x")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small images, few requests")
    ap.add_argument("--out", default="BENCH_multipool.json")
    ap.add_argument("--image-size", type=int, default=None,
                    help="input H=W (default: 64 smoke / 96 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests across the mix "
                         "(default: 8 smoke / 16 full)")
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args(argv)

    image_size = args.image_size or (64 if args.smoke else 96)
    requests = args.requests or (8 if args.smoke else 16)

    import jax

    report: dict = {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "image_size": image_size,
                    "requests": requests}
    bench_multipool(report, image_size, requests, args.reps)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
