"""Telemetry overhead benchmark: instrumented vs bare fleet serving.

Serves the same mbv1+squeezenet mix through a 2-pool
``MultiPoolRouter`` twice on the same host:

  * ``bare``         — the shared ``repro.obs`` registry disabled
    (``router.obs.enabled = False``): every ``inc``/``set``/``observe``
    is a guard-clause no-op, the PR-10 zero-cost-when-off claim;
  * ``instrumented`` — the registry live, counting every executed
    instruction, placement, retire, and wall-clock duration.

The committed contract is ``instrumented / bare >= 0.95`` — telemetry
may cost at most 5% of serving throughput — asserted here so the CI
smoke run fails loudly, and both legs' ``aggregate_fps`` leaves are
additionally gated higher-is-better against the committed baseline by
``benchmarks/compare_bench.py``.

A third leg exports the instrumented run's instruction streams as a
roofline-annotated Chrome trace and asserts the PR-10 trace shape: at
least one labeled pipeline-bubble event, and ``roofline_util`` args on
every advancing RUN slice.

    PYTHONPATH=src python -m benchmarks.obs_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# two host platform devices, one per pool (must happen pre-import)
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

MIX = {"mobilenet_v1": 0.5, "squeezenet": 0.5}
BURST = 4
POOLS = 2
MAX_OVERHEAD = 0.95     # instrumented must keep >= 95% of bare fps


def _fresh_fleet(runners, pool=None):
    from repro.fleet import FleetEngine, WeightedFair
    from repro.serving import DualCoreEngine

    members = {m: DualCoreEngine(r) for m, r in runners.items()}
    return FleetEngine(members, policy=WeightedFair(), weights=MIX,
                       burst=BURST, pool=pool)


def bench_obs(report: dict, image_size: int, requests: int,
              reps: int) -> None:
    import jax

    from repro.fleet import MultiPoolRouter, build_cnn_fleet
    from repro.fleet.trace import chrome_trace, roofline_model
    from repro.fleet import mix_schedule
    from repro.serving import Request

    def build():
        eng, pool = build_cnn_fleet(list(MIX), weights=MIX,
                                    use_pallas=True, fuse="group")
        return {m.name: m.engine.runner for m in eng.members}, pool

    pool_sets = [build() for _ in range(POOLS)]

    tags = mix_schedule(MIX, requests)
    keys = jax.random.split(jax.random.PRNGKey(0), requests)
    images = [jax.random.normal(k, (1, image_size, image_size, 3))
              for k in keys]
    by_model: dict[str, list] = {m: [] for m in MIX}
    for x, t in zip(images, tags):
        by_model[t].append(x)
    for runners, _ in pool_sets:
        for m, r in runners.items():    # warm every member's per-group jits
            r.run_sequential(by_model[m][:1])

    print(f"\n## telemetry overhead ({'+'.join(MIX)}, {image_size}px, "
          f"{requests} requests, {POOLS} pools, "
          f"{len(jax.devices())} local device(s))")

    def reqs():
        return [Request(x, model=t) for x, t in zip(images, tags)]

    def fresh_router():
        return MultiPoolRouter({
            f"pool{i}": _fresh_fleet(rs, pool)
            for i, (rs, pool) in enumerate(pool_sets)})

    def leg(enabled):
        t0 = time.perf_counter()
        router = fresh_router()
        router.obs.enabled = enabled
        for r in reqs():
            router.submit(r)
        res = router.drain()
        return time.perf_counter() - t0, router, res

    # interleave rep-by-rep with best-of per leg (same drift hedge as
    # multipool_bench); rep 0 is an untimed warm-in
    leg(False), leg(True)
    t_bare = t_inst = float("inf")
    router_inst = res_inst = None
    for _ in range(max(2, reps)):
        gc.collect()
        t_bare = min(t_bare, leg(False)[0])
        gc.collect()
        wall, router, res = leg(True)
        if wall < t_inst:
            t_inst, router_inst, res_inst = wall, router, res

    bare_fps = requests / t_bare
    inst_fps = requests / t_inst
    ratio = inst_fps / bare_fps
    assert res_inst.metrics.completed == requests

    # the instrumented run really counted: every pool shows executed
    # instructions in the slot domain
    instr = router_inst.obs.snapshot(domain="slot")["counters"][
        "fleet_instructions_total"]["series"]
    for i in range(POOLS):
        assert any(f"pool=pool{i}" in k for k in instr), instr

    # trace leg: the annotated export carries the PR-10 shape
    doc = chrome_trace(router_inst.streams(),
                       roofline=roofline_model(router_inst))
    slices = [e for e in doc["traceEvents"]
              if e["ph"] == "X" and e["name"].startswith("RUN")
              and e["args"].get("advances", 0) > 0]
    assert slices, "no advancing RUN slices in the trace"
    missing = [e["name"] for e in slices
               if "roofline_util" not in e["args"]]
    assert not missing, f"RUN slices without roofline args: {missing}"
    bubbles = [e for e in doc["traceEvents"]
               if e.get("cat") == "bubble"]
    assert bubbles, "no pipeline-bubble events in the trace"
    utils = [e["args"]["roofline_util"] for e in slices]
    assert all(0 < u <= 1.05 for u in utils), utils

    assert ratio >= MAX_OVERHEAD, (
        f"telemetry overhead too high: instrumented/bare = {ratio:.3f} "
        f"< {MAX_OVERHEAD}")

    report["bare"] = {"aggregate_fps": round(bare_fps, 2)}
    report["instrumented"] = {
        "aggregate_fps": round(inst_fps, 2),
        "slot_series": sum(
            len(m["series"]) for part in
            router_inst.obs.snapshot(domain="slot").values()
            for m in part.values()),
    }
    report["overhead_ratio"] = round(ratio, 3)
    report["trace"] = {
        "events": len(doc["traceEvents"]),
        "run_slices": len(slices),
        "bubbles": len(bubbles),
        "max_roofline_util": round(max(utils), 4),
    }

    print(f"{'leg':<26}{'fps':>8}")
    print(f"{'bare (obs off)':<26}{bare_fps:>8.2f}")
    print(f"{'instrumented':<26}{inst_fps:>8.2f}")
    print(f"instrumented vs bare: {ratio:.3f}x  "
          f"(gate: >= {MAX_OVERHEAD})")
    print(f"trace: {len(slices)} RUN slice(s) annotated, "
          f"{len(bubbles)} bubble(s)")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small images, few requests")
    ap.add_argument("--out", default="BENCH_obs.json")
    ap.add_argument("--image-size", type=int, default=None,
                    help="input H=W (default: 64 smoke / 96 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="total requests across the mix "
                         "(default: 8 smoke / 16 full)")
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args(argv)

    image_size = args.image_size or (64 if args.smoke else 96)
    requests = args.requests or (8 if args.smoke else 16)

    import jax

    report: dict = {"devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "image_size": image_size,
                    "requests": requests}
    bench_obs(report, image_size, requests, args.reps)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
