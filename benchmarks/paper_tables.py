"""Benchmarks reproducing the paper's tables (I, III, IV, V, VI, VII, VIII).

Each function returns (rows, summary) and prints a markdown table; run.py
aggregates them into bench_output.txt / EXPERIMENTS.md §Repro.
"""
from __future__ import annotations

import json
import os
import statistics

from repro.core import (ALLOCATION_SCHEMES, BoardModel, CoreConfig,
                        DualCoreConfig, P128_9, DUAL_BASELINE, DUAL_MBV1,
                        DUAL_MBV2, DUAL_SQZ, DUAL_MULTI,
                        best_schedule, build_schedule, core_area,
                        evaluate_config,
                        pe_structure_lut_equiv, search,
                        simulate_single_core, graph_latency_report)
from repro.models.zoo import get_graph

BOARD = BoardModel()
MODELS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")

TABLE_IV_BOARD = {"mobilenet_v1": 755_857, "mobilenet_v2": 637_551,
                  "squeezenet": 447_457}
TABLE_V_PAPER = {   # load-balance-heuristic column
    ("mobilenet_v1", "C(128,8)+P(64,9)"): 304.3,
    ("mobilenet_v1", "C(180,8)+P(32,9)"): 320.2,
    ("mobilenet_v1", "C(112,9)+P(72,8)"): 269.9,
    ("mobilenet_v2", "C(128,8)+P(64,9)"): 427.6,
    ("mobilenet_v2", "C(180,8)+P(32,9)"): 384.9,
    ("mobilenet_v2", "C(112,9)+P(72,8)"): 371.1,
    ("squeezenet", "C(128,8)+P(64,9)"): 529.9,
    ("squeezenet", "C(180,8)+P(32,9)"): 520.4,
    ("squeezenet", "C(112,9)+P(72,8)"): 451.3,
}
TABLE_VI_PAPER = {  # (config, fps, baseline fps)
    "mobilenet_v1": (DUAL_MBV1, 358.4, 264.6),
    "mobilenet_v2": (DUAL_MBV2, 438.4, 313.4),
    "squeezenet": (DUAL_SQZ, 534.7, 446.9),
}
TABLE_VII_PAPER = {  # multi-CNN workload, C(128,10)+P(32,12) column
    "mobilenet_v1": 326.2, "mobilenet_v2": 437.8, "squeezenet": 526.6,
    "average": 413.9,
}
FLEET_MIX = {  # fallback qps mix for table_vii_fleet when no committed
    # BENCH_fleet.json exists (the artifact's own "mix" key wins)
    "mobilenet_v1": 0.4, "mobilenet_v2": 0.35, "squeezenet": 0.25,
}


def table_i_iii_area():
    print("\n## Table I / III — resource & equivalent-area model")
    rows = []
    p = pe_structure_lut_equiv(CoreConfig("p", 64, 9))
    c = pe_structure_lut_equiv(CoreConfig("c", 128, 8))
    for name, ours, paper in [
            ("P(64,9) line buffer", p["line_buffer"], 39_868),
            ("P(64,9) multipliers", p["multipliers"], 40_896),
            ("P(64,9) adders", p["adders"], 17_859),
            ("P(64,9) total", p["total"], 98_623),
            ("C(128,8) multipliers", c["multipliers"], 72_704),
            ("C(128,8) adders", c["adders"], 31_749),
            ("C(128,8) total", c["total"], 104_453)]:
        err = (ours - paper) / paper
        rows.append((name, ours, paper, err))
        print(f"{name:<24} ours={ours:>9,.0f} paper={paper:>9,} "
              f"({err:+.2%})")
    a = core_area(P128_9, include_invariant=True)
    for name, ours, paper in [("P(128,9) LUT", a.lut, 137_149),
                              ("P(128,9) FF", a.ff, 234_046),
                              ("P(128,9) DSP", a.dsp, 577),
                              ("P(128,9) BRAM18K", a.bram18k, 237)]:
        err = (ours - paper) / paper
        rows.append((name, ours, paper, err))
        print(f"{name:<24} ours={ours:>9,} paper={paper:>9,} ({err:+.2%})")
    return rows


def table_iv_simulator():
    print("\n## Table IV — cycle-accurate simulator vs board cycles")
    rows = []
    for m in MODELS:
        g = get_graph(m)
        sim = simulate_single_core(g, P128_9, BOARD)
        board = TABLE_IV_BOARD[m]
        err = (sim.cycles - board) / board
        fps = BOARD.fps(sim.cycles)
        rows.append((m, sim.cycles, board, err, fps))
        print(f"{m:<14} sim={sim.cycles:>9,}  board={board:>9,} "
              f"({err:+.2%})  fps={fps:6.1f}")
    return rows


def table_v_scheduling(paper_faithful=True):
    print("\n## Table V — scheduling methods x PE configurations (fps)")
    cfgs = {"C(128,8)+P(64,9)": DUAL_BASELINE,
            "C(180,8)+P(32,9)": DualCoreConfig(CoreConfig("c", 180, 8),
                                               CoreConfig("p", 32, 9)),
            "C(112,9)+P(72,8)": DualCoreConfig(CoreConfig("c", 112, 9),
                                               CoreConfig("p", 72, 8))}
    rows = []
    print(f"{'model':<14}{'config':<20}"
          f"{'l-type':>8}{'greedy':>8}{'r-robin':>8}{'lb-heur':>8}"
          f"{'paper-lb':>9}{'delta':>8}")
    for m in MODELS:
        g = get_graph(m)
        for cname, cfg in cfgs.items():
            basic = [build_schedule(g, cfg, BOARD, s).throughput_fps()
                     for s in ALLOCATION_SCHEMES]
            lb = best_schedule(g, cfg, BOARD,
                               paper_faithful=paper_faithful)
            paper = TABLE_V_PAPER[(m, cname)]
            delta = (lb.throughput_fps() - paper) / paper
            rows.append((m, cname, *basic, lb.throughput_fps(), paper,
                         delta))
            print(f"{m:<14}{cname:<20}"
                  f"{basic[0]:8.1f}{basic[1]:8.1f}{basic[2]:8.1f}"
                  f"{lb.throughput_fps():8.1f}{paper:9.1f}{delta:+8.1%}")
    gains = []
    for m in MODELS:
        g = get_graph(m)
        basic = max(build_schedule(g, DUAL_BASELINE, BOARD,
                                   s).throughput_fps()
                    for s in ALLOCATION_SCHEMES)
        lb = best_schedule(g, DUAL_BASELINE, BOARD,
                           paper_faithful=True).throughput_fps()
        gains.append(lb / basic - 1)
    print(f"load-balance avg gain over basic schemes: "
          f"{statistics.mean(gains):+.1%} (paper: ~+10%)")
    return rows


def table_vi_pe_config():
    print("\n## Table VI — per-CNN PE config vs same-area single core")
    rows = []
    for m, (cfg, paper_fps, paper_base) in TABLE_VI_PAPER.items():
        g = get_graph(m)
        base = BOARD.fps(simulate_single_core(g, P128_9, BOARD).cycles)
        faith = best_schedule(g, cfg, BOARD, paper_faithful=True)
        ext = best_schedule(g, cfg, BOARD, paper_faithful=False)
        rows.append((m, base, faith.throughput_fps(),
                     ext.throughput_fps(), paper_fps))
        print(f"{m:<14} base={base:6.1f} (paper {paper_base}) | "
              f"faithful={faith.throughput_fps():6.1f} "
              f"(paper {paper_fps}; gain {faith.throughput_fps()/base-1:+.0%}"
              f" vs paper {paper_fps/paper_base-1:+.0%}) | "
              f"extended={ext.throughput_fps():6.1f} "
              f"eff={ext.runtime_pe_efficiency():.0%}")
    return rows


def table_vii_multi_cnn():
    print("\n## Table VII — multi-CNN workload configuration")
    graphs = [get_graph(m) for m in MODELS]
    rows = []
    for cfg in (DUAL_MBV1, DUAL_MBV2, DUAL_SQZ, DUAL_MULTI):
        obj, fps, _ = evaluate_config(cfg, graphs, BOARD)
        rows.append((str(cfg), fps, obj))
        print(f"{str(cfg):<22} " + "  ".join(
            f"{m.split('_')[0][:6]}{v:7.1f}" for m, v in fps.items())
            + f"  harmonic={obj:7.1f} (paper avg col: "
              f"{TABLE_VII_PAPER['average']})")
    multi_obj = rows[-1][2]
    best_single = max(r[2] for r in rows[:-1])
    print(f"paper's multi-CNN config vs best single-CNN config on our "
          f"landscape: {multi_obj/best_single-1:+.1%} (paper: +1.9%)")
    # our own design-flow search over the multi-CNN workload (§V-B)
    res = search(graphs, BOARD, max_evals=8)
    print(f"our search: {res.config} theta={res.theta:.2f} "
          f"harmonic={res.objective:7.1f} "
          f"({res.objective/best_single-1:+.1%} vs best single-CNN cfg)")
    rows.append((f"search:{res.config}", res.fps, res.objective))
    return rows


def table_vii_fleet(mix=None, config=None, max_evals=6,
                    measured_path=None):
    """Table VII extended to a qps-weighted traffic mix: the fleet
    planner's co-scheduled prediction (cycle domain, board frequency)
    next to the measured serving numbers from the committed
    ``BENCH_fleet.json`` (wall-clock on the bench host — different
    domains, compared per-column, never to each other).  The rows come
    verbatim from ``fleet.planner.plan_rows`` (a test cross-checks
    that)."""
    from repro.fleet import plan_fleet, plan_rows

    print("\n## Table VII (fleet) — qps-weighted multi-network mix, "
          "predicted vs measured")
    measured, measured_agg, rep = {}, None, None
    path = measured_path if measured_path is not None else os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..",
        "BENCH_fleet.json")
    if os.path.exists(path):
        with open(path) as f:
            rep = json.load(f)
        measured = {m: v["requests_per_s"]
                    for m, v in rep["fleet"]["per_model"].items()}
        measured_agg = rep["fleet"]["aggregate_fps"]
    if mix is None:
        # predict for the mix the bench actually measured — a retuned
        # fleet_bench.MIX must not silently drift the prediction column
        # onto a different workload (FLEET_MIX is only the no-artifact
        # fallback)
        mix = rep["mix"] if rep is not None else FLEET_MIX
    plan = plan_fleet(mix, config=config, max_evals=max_evals)
    rows = plan_rows(plan, measured, measured_agg)
    print(f"planned config {plan.config} (theta={plan.theta:.2f}); "
          f"measured column: fleet bench wall-clock on its host")
    print(f"{'model':<14}{'share':>7}{'model-side':>12}{'predicted':>11}"
          f"{'measured':>10}")
    for name, share, fps, pred, meas in rows:
        print(f"{name:<14}{share:>7.2f}{fps:>12.1f}{pred:>11.1f}"
              + (f"{meas:>10.2f}" if meas is not None else "       n/a"))
    return rows


def table_viii_soa():
    print("\n## Table VIII — throughput/DSP vs published designs "
          "(normalised 8-bit ops)")
    # our numbers from the extended flow; published rows from the paper
    published = [
        ("Light-OPU [5] mbv1", 704, 264.6, 0.21),
        ("ours(paper) mbv1", 832, 326.2, 0.23),
        ("Xilinx DPU mbv2", 2070, 587.2, 0.08),
        ("ours(paper) mbv2", 832, 437.8, 0.16),
        ("Xilinx DPU sqz", 1942, 1048.0, 0.20),
        ("ours(paper) sqz", 832, 526.6, 0.22),
    ]
    rows = []
    graphs = {m: get_graph(m) for m in MODELS}
    _, fps, _ = evaluate_config(DUAL_MULTI, list(graphs.values()), BOARD)
    for m in MODELS:
        g = graphs[m]
        dsp = DUAL_MULTI.n_dsp
        gops = 2 * g.total_macs * fps[m] / 1e9
        rows.append((m, fps[m], dsp, gops / dsp))
        print(f"ours(repro) {m:<14} fps={fps[m]:7.1f} DSP={dsp} "
              f"GOPs/DSP={gops/dsp:.3f}")
    for name, dsp, fps_, gd in published:
        print(f"published   {name:<14} fps={fps_:7.1f} DSP={dsp} "
              f"GOPs/DSP={gd:.3f}")
    return rows


def fig1_layer_efficiency():
    """Fig.1: per-layer runtime PE efficiency on uniform P(128,9) —
    the zigzag that motivates the heterogeneous design."""
    print("\n## Fig.1 — layer-wise runtime PE efficiency on P(128,9)")
    for m in MODELS:
        g = get_graph(m)
        rows, total, eff = graph_latency_report(g.topological_order(),
                                                P128_9, BOARD)
        print(f"\n{m} (weighted avg {eff:.0%}; paper avg: "
              f"{ {'mobilenet_v1': '59%', 'mobilenet_v2': '41%', 'squeezenet': '62%'}[m] }):")
        for r in rows:
            e = r.pe_efficiency(P128_9)
            bar = "#" * int(e * 40)
            print(f"  {r.layer:<16}{e:6.1%} {r.bound[:3]} |{bar}")
    return None


def run_all():
    table_i_iii_area()
    table_iv_simulator()
    fig1_layer_efficiency()
    table_v_scheduling()
    table_vi_pe_config()
    table_vii_multi_cnn()
    table_vii_fleet()
    table_viii_soa()
