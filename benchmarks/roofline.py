"""§Roofline report generator: reads results/dryrun/*.json into the
per-(arch x shape x mesh) three-term table (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import glob
import json
import os

HW_NOTE = ("constants: 197 TFLOP/s bf16/chip, 819 GB/s HBM, "
           "~50 GB/s/link ICI")


HBM_BW = 819e9


def load(results_dir: str = "results/dryrun", mesh: str = "single"):
    from repro.configs.registry import get_arch
    from repro.launch.dryrun import microbatches_for
    from repro.launch.roofline_model import hbm_bytes_per_device
    rows = []
    if not os.path.isdir(results_dir):
        print(f"[roofline] results dir {results_dir!r} does not exist; "
              f"nothing to report (run the dryrun sweep first)")
        return rows
    paths = sorted(glob.glob(os.path.join(results_dir,
                                          f"*.{mesh}.json")))
    if not paths:
        print(f"[roofline] no *.{mesh}.json results under "
              f"{results_dir!r}; nothing to report")
        return rows
    for f in paths:
        d = json.load(open(f))
        if not d.get("ok"):
            rows.append(d)
            continue
        # memory term from the analytic HBM model (the XLA CPU-backend
        # 'bytes accessed' counts unfused operand traffic, ~1000x real;
        # kept in the JSON as cost_analysis_bytes)
        cfg = get_arch(d["arch"])
        mb = (microbatches_for(cfg, d["batch"],
                               32 if mesh == "multi" else 16)
              if d["kind"] == "train" else 1)
        hbm = hbm_bytes_per_device(cfg, d["kind"], d["seq"], d["batch"],
                                   d["chips"], mb)
        d["analytic_hbm_bytes"] = hbm
        d["t_memory_s"] = hbm / HBM_BW
        terms = {"compute": d.get("t_compute_s") or 0.0,
                 "memory": d.get("t_memory_s") or 0.0,
                 "collective": d.get("t_collective_s") or 0.0}
        dom = max(terms, key=terms.get)
        step = max(terms.values())
        frac = terms["compute"] / step if step else 0.0
        d["dominant"] = dom
        d["step_bound_s"] = step
        d["roofline_fraction"] = frac
        rows.append(d)
    return rows


def what_would_help(d: dict) -> str:
    dom = d.get("dominant")
    if dom == "compute":
        u = d.get("useful_flops_ratio") or 1.0
        if u < 0.7:
            return "cut recompute/waste (remat policy, fused loss)"
        return "near roofline; larger per-chip tiles / fewer, bigger GEMMs"
    if dom == "memory":
        if d["kind"] in ("decode", "long-decode"):
            return "KV/state quantization + wider batch per HBM stream"
        return "re-layout to cut activation traffic; fuse norms/rope"
    return ("reshard to cut all-reduce volume (TP only where FSDP "
            "gathers exceed compute)")


def report(results_dir: str = "results/dryrun", mesh: str = "single",
           out_path: str | None = None) -> str:
    rows = load(results_dir, mesh)
    lines = [f"### Roofline — {mesh} pod ({HW_NOTE})", "",
             "| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | "
             "bound | step (s) | comp/step | MODEL/HLO | HBM GB | fits |",
             "|---|---|---|---|---|---|---|---|---|---|---|"]
    ok = 0
    for d in rows:
        if not d.get("ok"):
            lines.append(f"| {d['arch']} | {d['shape']} | - | - | - | "
                         f"FAILED {d.get('error','')[:40]} | | | | | |")
            continue
        ok += 1
        u = d.get("useful_flops_ratio")
        gb = (d.get("per_device_bytes") or 0) / 2 ** 30
        lines.append(
            f"| {d['arch']} | {d['shape']} "
            f"| {d['t_compute_s']:.3e} | {d['t_memory_s']:.3e} "
            f"| {d['t_collective_s']:.3e} | {d['dominant']} "
            f"| {d['step_bound_s']:.3e} | {d['roofline_fraction']:.2f} "
            f"| {u:.2f} | {gb:.1f} | {'y' if d['fits_hbm'] else 'N'} |"
            if u is not None else
            f"| {d['arch']} | {d['shape']} | - | - | - | {d['dominant']}"
            f" | | | | {gb:.1f} | {'y' if d['fits_hbm'] else 'N'} |")
    lines.append("")
    lines.append("Per-cell bottleneck guidance:")
    for d in rows:
        if d.get("ok"):
            lines.append(f"- {d['arch']}/{d['shape']}: {d['dominant']}"
                         f"-bound -> {what_would_help(d)}")
    text = "\n".join(lines)
    if out_path:
        with open(out_path, "w") as f:
            f.write(text + "\n")
    print(text)
    print(f"\n{ok}/{len(rows)} cells ok")
    return text


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="benchmarks.roofline",
        description="Render the per-(arch x shape x mesh) roofline "
                    "table from dryrun result JSONs.")
    ap.add_argument("--results-dir", default="results/dryrun",
                    help="directory of dryrun *.MESH.json results "
                         "(default: results/dryrun)")
    ap.add_argument("--mesh", default="single",
                    choices=("single", "multi"),
                    help="mesh flavor to report (default: single)")
    ap.add_argument("--out", default=None,
                    help="also write the markdown table to this path")
    args = ap.parse_args(argv)
    report(args.results_dir, args.mesh, args.out)
    return 0


if __name__ == "__main__":
    import sys

    sys.exit(main())
