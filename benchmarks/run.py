"""Benchmark entry point: PYTHONPATH=src python -m benchmarks.run

Runs every paper-table reproduction + the LM-side dual-mesh benches +
the roofline report (if dry-run results exist)."""
from __future__ import annotations

import argparse
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--skip-dualmesh", action="store_true")
    ap.add_argument("--results", default="results/dryrun")
    args = ap.parse_args(argv)
    t0 = time.time()
    print("# dual-OPU reproduction benchmarks")

    from benchmarks import paper_tables
    paper_tables.run_all()

    from benchmarks import kernel_specs
    kernel_specs.run_all()

    if not args.skip_dualmesh:
        from benchmarks import dualmesh_bench
        dualmesh_bench.run_all()

    if os.path.isdir(args.results) and os.listdir(args.results):
        from benchmarks import roofline
        print("\n# Roofline (from dry-run artifacts)")
        roofline.report(args.results, "single",
                        out_path="results/roofline_single.md")
        multi = [f for f in os.listdir(args.results)
                 if f.endswith(".multi.json")]
        if multi:
            roofline.report(args.results, "multi",
                            out_path="results/roofline_multi.md")
    else:
        print("\n(no dry-run results yet — run "
              "`python -m repro.launch.dryrun --all` first)")
    print(f"\nbenchmarks done in {time.time()-t0:.0f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
