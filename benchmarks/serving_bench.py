"""Streaming serving benchmark: both engines under the shared API.

For the CNN engine (dual-core pipeline with online slot-refill admission)
and the LM engine (dual-mesh continuous batching), measure on this host:

  * steady-state throughput — every request available at slot 0, the
    saturated-queue regime.  ``pipelined_fps`` times the full
    ``run_pipelined`` API surface (engine construction + submits + drain,
    what pre-engine callers paid) and ``engine_fps`` the engine's steady
    wall (first step -> result), both taken from the same physical runs —
    ``run_pipelined`` is a shim over the engine now, so the ratio
    measures the submit/bookkeeping overhead of the streaming surface
    (~1.0 means continuous admission costs no throughput versus the
    retired static dispatch path), not two competing implementations;
  * request latency under load — a fixed Poisson-ish arrival trace
    (``repro.serving.poisson_arrivals``, seeded, identical across runs)
    drives ``replay``; p50/p95 per-request wall-clock latency lands in the
    JSON, where ``benchmarks/compare_bench.py`` gates CI on it (p50_ms /
    p95_ms are gated fields — a >2x latency regression fails the PR).

Writes ``BENCH_serving.json`` — the committed baseline CI diffs against.

    PYTHONPATH=src python -m benchmarks.serving_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import sys
import time

# A >=2-device mesh is the point of the exercise: force two host platform
# devices unless the caller already configured XLA (must happen pre-import).
if "jax" not in sys.modules and \
        "xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=2")

CNN_MODELS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")
ARRIVAL_RATE = 1.0          # requests per scheduler slot (Poisson-ish)
ARRIVAL_SEED = 0


def bench_cnn(report: dict, image_size: int, requests: int,
              reps: int) -> None:
    """Streaming CNN engine vs the committed pipelined baseline."""
    import jax

    from repro.core.arch import DUAL_BASELINE, BoardModel
    from repro.core.scheduler import build_schedule
    from repro.dualcore.runtime import DualCoreRunner
    from repro.models.cnn import build_model
    from repro.serving import (DualCoreEngine, Request, poisson_arrivals,
                               replay, stream_images)

    board = BoardModel()
    print(f"\n## CNN serving (balanced scheme, {image_size}px, "
          f"{requests} requests, {len(jax.devices())} local device(s))")
    print(f"{'model':<14}{'pipelined fps':>14}{'engine fps':>12}"
          f"{'ratio':>7}{'p50 ms':>9}{'p95 ms':>9}")
    for model in CNN_MODELS:
        params, _, graph = build_model(model)
        sched = build_schedule(graph, DUAL_BASELINE, board, "balanced")
        runner = DualCoreRunner(model, params, sched, use_pallas=True,
                                fuse="group")
        imgs = [jax.random.normal(k, (1, image_size, image_size, 3))
                for k in jax.random.split(jax.random.PRNGKey(0), requests)]
        runner.run_sequential(imgs[:1])        # warm the per-group jits

        # steady state: saturated queue, same work as the old static path.
        # run_pipelined IS the engine shim now, so both numbers come from
        # the same physical runs, each timed at its own API surface: the
        # outer window (engine construction + submits + drain — what a
        # run_pipelined caller pays) vs the engine's steady wall
        # (first step -> result).  Same-run measurement sidesteps the
        # 2-5% coin-flips separate interleaved legs showed on this host;
        # gc.collect keeps the previous run's deallocations (2-3x swings
        # on this allocator) out of the timed window.
        t_pipe = t_eng = float("inf")
        for _ in range(max(2, reps)):
            gc.collect()
            t0 = time.perf_counter()
            res = stream_images(runner, imgs)
            t_pipe = min(t_pipe, time.perf_counter() - t0)
            t_eng = min(t_eng, res.stats["wall_s"])
            del res
        pipelined_fps = requests / t_pipe
        engine_fps = requests / t_eng

        # latency under the fixed Poisson-ish arrival trace — best-of like
        # the gated timing fields (a single replay's p95 of ~6 samples is
        # one GC pause away from a phantom CI failure)
        arrivals = poisson_arrivals(requests, rate=ARRIVAL_RATE,
                                    seed=ARRIVAL_SEED)
        p50 = p95 = float("inf")
        for _ in range(max(2, reps // 2)):
            gc.collect()
            m = replay(DualCoreEngine(runner),
                       [Request(x) for x in imgs], arrivals).metrics
            p50 = min(p50, m.p50_ms())
            p95 = min(p95, m.p95_ms())
        row = {
            "requests": requests,
            "exec_groups": len(runner.groups),
            "pipelined_fps": round(pipelined_fps, 2),
            "engine_fps": round(engine_fps, 2),
            "engine_vs_pipelined": round(engine_fps / pipelined_fps, 3),
            "arrival_rate_per_slot": ARRIVAL_RATE,
            "p50_ms": round(p50, 2),
            "p95_ms": round(p95, 2),
        }
        report["cnn"][model] = row
        print(f"{model:<14}{row['pipelined_fps']:>14.2f}"
              f"{row['engine_fps']:>12.2f}"
              f"{row['engine_vs_pipelined']:>6.2f}x"
              f"{row['p50_ms']:>9.1f}{row['p95_ms']:>9.1f}")


def bench_lm(report: dict, requests: int, batch: int, prompt_len: int,
             gen: int, arch: str = "qwen2_0_5b", reps: int = 2) -> None:
    """Streaming LM engine: tokens/s + request latency percentiles."""
    import jax

    from repro.configs.registry import get_smoke
    from repro.dualmesh import DualMeshRunner, split_mesh
    from repro.lm.model import init_params
    from repro.serving import (DualMeshEngine, Request, poisson_arrivals,
                               replay)

    cfg = get_smoke(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), 0.5)
    runner = DualMeshRunner(cfg, params, dual, max_len=prompt_len + gen + 8)
    prompts = [jax.random.randint(k, (batch, prompt_len), 0, cfg.vocab)
               for k in jax.random.split(jax.random.PRNGKey(1), requests)]
    gs = runner.planned_group_size(prompts, [gen] * requests)

    def run_once(arrivals):
        eng = DualMeshEngine(runner, group_size=gs)
        return replay(eng, [Request(p, gen_steps=gen) for p in prompts],
                      arrivals)

    run_once([0] * requests)                   # warm the jit caches
    steady = run_once([0] * requests)
    arrivals = poisson_arrivals(requests, rate=ARRIVAL_RATE,
                                seed=ARRIVAL_SEED)
    p50 = p95 = float("inf")
    for _ in range(max(2, reps // 2)):         # best-of, like every gated
        gc.collect()                           # timing field
        m = run_once(arrivals).metrics
        p50 = min(p50, m.p50_ms())
        p95 = min(p95, m.p95_ms())
    row = {
        "arch": arch, "requests": requests, "batch": batch,
        "prompt_len": prompt_len, "gen": gen, "group_size": gs,
        "tokens_per_s": round(steady.stats["tokens_per_s"], 1),
        "total_tokens": steady.stats["total_tokens"],
        "arrival_rate_per_slot": ARRIVAL_RATE,
        "p50_ms": round(p50, 2),
        "p95_ms": round(p95, 2),
    }
    report["lm"][arch] = row
    print(f"\n## LM serving ({arch} smoke, {requests} requests x "
          f"batch {batch}, prompt {prompt_len}, gen {gen})")
    print(f"steady {row['tokens_per_s']:.0f} tok/s "
          f"(group_size={gs}); under Poisson arrivals "
          f"p50 {row['p50_ms']:.0f} ms, p95 {row['p95_ms']:.0f} ms")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: small images, few requests, write the "
                         "JSON artifact")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--image-size", type=int, default=None,
                    help="CNN input H=W (default: 64 smoke / 96 full)")
    ap.add_argument("--requests", type=int, default=None,
                    help="requests per engine (default: 6 smoke / 16 full)")
    ap.add_argument("--reps", type=int, default=2)
    args = ap.parse_args(argv)

    image_size = args.image_size or (64 if args.smoke else 96)
    requests = args.requests or (6 if args.smoke else 16)

    import jax

    report: dict = {"cnn": {}, "lm": {},
                    "devices": len(jax.devices()),
                    "backend": jax.default_backend(),
                    "image_size": image_size}
    bench_cnn(report, image_size, requests, args.reps)
    bench_lm(report, requests=min(requests, 4), batch=1,
             prompt_len=16, gen=8, reps=args.reps)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
