"""Export executed fleet instruction streams as Chrome-tracing JSON.

Takes one or more serialized streams (``repro.fleet.instructions.
dump_stream`` documents — what ``FleetEngine.stream`` / ``MultiPoolRouter
.stream()`` serialize to) and writes a single ``chrome://tracing`` /
Perfetto timeline: one process row per pool, one thread track per submesh
('c-submesh' / 'p-submesh') plus 'retire' and 'control' tracks, so
pipeline bubbles — a submesh track idle while its sibling is busy — are
visible directly (the first slice of the ROADMAP observability item; same
target format as Helium's ``arm_tarmac_2_chrometracing.py``).

    PYTHONPATH=src python -m benchmarks.trace_export \
        stream_pool0.json stream_pool1.json -o trace.json

``serve fleet --trace trace.json`` exports the same thing in one step,
without the intermediate stream files.
"""
from __future__ import annotations

import argparse
import json
import sys


def main(argv=None) -> int:
    from repro.fleet.instructions import stream_from_json
    from repro.fleet.trace import write_chrome_trace

    ap = argparse.ArgumentParser(
        prog="benchmarks.trace_export",
        description="Convert serialized fleet instruction streams to "
                    "Chrome-tracing JSON.")
    ap.add_argument("streams", nargs="+", metavar="STREAM.json",
                    help="stream files written by "
                         "repro.fleet.instructions.dump_stream")
    ap.add_argument("-o", "--out", default="trace.json",
                    help="output trace path (default: trace.json)")
    args = ap.parse_args(argv)

    streams = {}
    for i, path in enumerate(args.streams):
        with open(path) as f:
            doc = json.load(f)
        name = doc.get("pool") or f"pool{i}"
        if name in streams:
            ap.error(f"two streams claim pool name {name!r} "
                     f"({path} collides); set distinct 'pool' fields")
        try:
            streams[name] = stream_from_json(doc)
        except (ValueError, KeyError, TypeError) as e:
            ap.error(f"{path} is not a fleet instruction stream ({e}); "
                     f"expected a repro.fleet dump_stream document")
    n_stamped = sum(1 for recs in streams.values() for r in recs
                    if r.t0 is not None)
    if not n_stamped:
        ap.error("no wall-clock-stamped records in the input streams "
                 "(compiled-only streams carry no timings; export an "
                 "*executed* stream)")
    n, skipped = write_chrome_trace(streams, args.out)
    print(f"[trace_export] {len(streams)} pool(s), {n_stamped} stamped "
          f"records -> {n} events in {args.out} "
          f"(open in chrome://tracing)")
    if skipped:
        print(f"[trace_export] skipped {skipped} compiled-only "
              f"record(s) with no wall-clock stamps")
    return 0


if __name__ == "__main__":
    sys.exit(main())
