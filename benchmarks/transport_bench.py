"""Transport benchmark: router throughput and wire latency across the
fleet transports (DESIGN.md §14).

Drives the same deterministic sim-member fleet (fixed-service-time stub
engines — no conv compute, so transport overhead is the only variable)
through the same forced-migration trace three ways:

  * ``local``  — 2 in-process pools behind ``MultiPoolRouter``'s default
                 :class:`LocalTransport` (in-memory mailbox)
  * ``file``   — the same 2 pools with migration spooled through a
                 :class:`FileTransport` directory (one framed envelope
                 file per SEND)
  * ``socket`` — 2 real worker processes (``python -m repro.fleet.worker
                 --sim ...``) over :class:`SocketTransport`: every
                 submit/step is a framed-envelope RPC and every migrated
                 payload crosses a localhost TCP hop

plus a per-hop wire-latency microbenchmark (ping/pong RTT percentiles on
an idle worker's control channel).  Invariants checked hard: all three
legs retire every request exactly once with *identical* statuses (a
transport may change wall-clock, never outcomes), and the socket leg's
collected streams + placement log replay bitwise on fresh in-process
pools.

Writes ``BENCH_transport.json``; its ``aggregate_fps`` leaves are gated
higher-is-better in ``benchmarks/compare_bench.py``.

    PYTHONPATH=src python -m benchmarks.transport_bench --smoke
"""
from __future__ import annotations

import argparse
import gc
import json
import os
import shutil
import sys
import tempfile
import time

SPEC = "cnn:c:2,lm:p:3:opaque"
POOLS = ("pool0", "pool1")


def _reqs(n):
    from repro.serving import Request

    return [Request(payload=i, model=("cnn" if i % 2 == 0 else "lm"))
            for i in range(n)]


def _drive(router, reqs):
    """Submit everything, force a migration two steps in, drain."""
    for r in reqs:
        router.submit(r)
    for _ in range(2):
        router.step()
    moved = router.migrate("pool0", "pool1")
    res = router.drain()
    return moved, res


def _statuses(router, n):
    return {rid: router._metrics[rid].status for rid in range(n)}


def bench_transport(report: dict, requests: int, reps: int,
                    pings: int) -> None:
    from repro.fleet import MultiPoolRouter, stream_signature
    from repro.fleet.net import FileTransport
    from repro.fleet.net.coordinator import (connect, start_workers,
                                             stop_workers)
    from repro.fleet.net.worker import build_sim_fleet

    def leg_local(transport=None):
        router = MultiPoolRouter(
            {p: build_sim_fleet(SPEC) for p in POOLS}, transport=transport)
        t0 = time.perf_counter()
        moved, res = _drive(router, _reqs(requests))
        return time.perf_counter() - t0, moved, res, router

    def leg_file():
        spool = tempfile.mkdtemp(prefix="repro_transport_bench_")
        try:
            return leg_local(FileTransport(spool))
        finally:
            shutil.rmtree(spool, ignore_errors=True)

    def leg_socket():
        procs = start_workers({p: ["--sim", SPEC] for p in POOLS})
        fleets = {}
        try:
            fleets = connect(procs, heartbeat_s=30.0)
            router = MultiPoolRouter(fleets)
            t0 = time.perf_counter()
            moved, res = _drive(router, _reqs(requests))
            wall = time.perf_counter() - t0
            rtts = []
            handle = fleets["pool0"]._handle
            for _ in range(pings):          # idle-channel RTT, per hop
                p0 = time.perf_counter()
                handle.ping()
                rtts.append(time.perf_counter() - p0)
        finally:
            stop_workers(fleets, procs)
        return wall, moved, res, router, sorted(rtts)

    print(f"\n## fleet transports (sim members {SPEC!r}, {requests} "
          f"requests, forced pool0->pool1 migration)")

    legs = {"local": leg_local, "file": leg_file, "socket": leg_socket}
    best: dict = {}
    for name, leg in legs.items():
        leg()                               # untimed warm-in
        for _ in range(max(1, reps)):
            gc.collect()
            out = leg()
            if name not in best or out[2].stats["aggregate_fps"] > \
                    best[name][2].stats["aggregate_fps"]:
                best[name] = out

    # ---- invariants: identical outcomes on every transport -----------
    ref = _statuses(best["local"][3], requests)
    assert sorted(ref) == list(range(requests)), "lost or duplicated rids"
    for name, out in best.items():
        router = out[3]
        assert len(out[2].completions) == requests, name
        assert router.duplicates_dropped == 0, name
        assert out[1] == best["local"][1] > 0, \
            f"{name}: migration moved {out[1]} != {best['local'][1]}"
        assert _statuses(router, requests) == ref, \
            f"{name}: transport changed request outcomes"

    # ---- the socket leg replays bitwise on fresh in-process pools ----
    router = best["socket"][3]
    streams = router.streams()
    fresh = MultiPoolRouter({p: build_sim_fleet(SPEC) for p in POOLS})
    fresh.replay(streams, list(router.placements), _reqs(requests),
                 list(router.events))
    for pool, recs in streams.items():
        assert stream_signature(recs) == stream_signature(
            fresh.executors[pool].records), f"replay diverged on {pool}"
    n_records = sum(len(r) for r in streams.values())

    rtts = best["socket"][4]
    rtt_p50 = rtts[len(rtts) // 2] * 1e3
    rtt_p95 = rtts[min(len(rtts) - 1, int(len(rtts) * 0.95))] * 1e3
    for name, out in best.items():
        wall, moved, res = out[0], out[1], out[2]
        report[name] = {"aggregate_fps": round(res.stats["aggregate_fps"],
                                               2),
                        "drive_wall_ms": round(wall * 1e3, 2),
                        "migrated": moved,
                        "router_steps": res.stats["steps"]}
    report["socket"]["rtt_p50_ms"] = round(rtt_p50, 4)
    report["socket"]["rtt_p95_ms"] = round(rtt_p95, 4)
    report["socket_vs_local"] = round(
        report["socket"]["aggregate_fps"]
        / report["local"]["aggregate_fps"], 4)
    report["replay"] = {"bitwise": True, "records": n_records,
                        "pools": len(streams)}

    print(f"{'transport':<10}{'agg fps':>12}{'drive ms':>10}"
          f"{'migrated':>9}")
    for name in legs:
        r = report[name]
        print(f"{name:<10}{r['aggregate_fps']:>12.2f}"
              f"{r['drive_wall_ms']:>10.2f}{r['migrated']:>9}")
    print(f"wire RTT p50 {rtt_p50*1e3:.0f} us, p95 {rtt_p95*1e3:.0f} us "
          f"over {len(rtts)} pings; socket replayed bitwise over "
          f"{n_records} records")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI shape: fewer reps and pings (same request "
                         "count — fps must stay comparable to the "
                         "committed baseline)")
    ap.add_argument("--out", default="BENCH_transport.json")
    ap.add_argument("--requests", type=int, default=96,
                    help="requests per leg (default 96)")
    ap.add_argument("--reps", type=int, default=None,
                    help="timed reps per leg, best-of "
                         "(default: 2 smoke / 4 full)")
    ap.add_argument("--pings", type=int, default=None,
                    help="RTT probes (default: 50 smoke / 200 full)")
    args = ap.parse_args(argv)

    requests = args.requests
    reps = args.reps or (2 if args.smoke else 4)
    pings = args.pings or (50 if args.smoke else 200)

    report: dict = {"spec": SPEC, "requests": requests, "reps": reps,
                    "platform": sys.platform,
                    "cpus": os.cpu_count()}
    bench_transport(report, requests, reps, pings)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
