"""The paper's §V-B co-optimization: find the best dual-core PE allocation
for a multi-CNN workload — then *execute* the winning schedule on the
pipelined dual-core runtime (search -> schedule -> measured fps, not just
simulated) — plus the LM-side twin (submesh split).

    PYTHONPATH=src python examples/design_space_search.py
"""
from repro.core import BoardModel, search as fpga_search
from repro.models.zoo import get_graph

from repro.configs.registry import get_arch
from repro.dualmesh import request_stages, search as tpu_search


def measured_fps(model: str, schedule, image_size: int = 64,
                 images: int = 4) -> float:
    """Run the found schedule for real on the local c/p submeshes and
    report measured streaming throughput through the serving engine (small
    images on CPU hosts; the absolute number is container-bound, the point
    is schedule->execution)."""
    import jax

    from repro.dualcore.runtime import DualCoreRunner
    from repro.models.cnn import init_params
    from repro.serving import stream_images

    g = get_graph(model)
    params = init_params(g, jax.random.PRNGKey(0))
    runner = DualCoreRunner(model, params, schedule, use_pallas=False)
    xs = [jax.random.normal(k, (1, image_size, image_size, 3))
          for k in jax.random.split(jax.random.PRNGKey(1), images)]
    runner.run_sequential(xs[:1])              # warm the per-group jits
    fps = max(stream_images(runner, xs).stats["fps"] for _ in range(2))
    return fps


def main():
    # FPGA side (the paper, Table VII)
    graphs = [get_graph(m) for m in
              ("mobilenet_v1", "mobilenet_v2", "squeezenet")]
    res = fpga_search(graphs, BoardModel(), max_evals=6)
    print(f"[fpga] best config {res.config} (theta={res.theta:.2f}), "
          f"harmonic fps={res.objective:.1f}")
    for m, fps in res.fps.items():
        meas = measured_fps(m, res.schedules[m])
        print(f"    {m:<14} {fps:7.1f} fps simulated   "
              f"{meas:7.1f} img/s measured (64px, local mesh)")

    # TPU side (DESIGN.md §2): same flow, submesh split for LM serving
    cfg = get_arch("qwen2_5_14b")
    stages = request_stages(cfg, [(8, 8192, 256)] * 4)
    plan = tpu_search(stages, cfg, n_devices=256, max_evals=10)
    print(f"[tpu]  theta={plan.theta:.2f} tp=({plan.tp_c},{plan.tp_p}) "
          f"makespan={plan.makespan*1e3:.1f} ms, "
          f"{plan.tokens_per_s:.0f} tok/s")


if __name__ == "__main__":
    main()
