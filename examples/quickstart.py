"""Quickstart: the paper's dual-OPU design flow end to end on MobileNet v1.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import (BoardModel, P128_9, DUAL_MBV1, best_schedule,
                        simulate_single_core, simulate_dual_core,
                        dual_core_area)
from repro.models.cnn import build_model
from repro.models.zoo import get_graph


def main():
    board = BoardModel()
    g = get_graph("mobilenet_v1")
    print(g.summary()[:600], "...\n")

    # 1. single-core baseline (paper Table IV / VI baseline)
    sim = simulate_single_core(g, P128_9, board)
    print(f"P(128,9) baseline: {sim.cycles:,} cycles "
          f"-> {board.fps(sim.cycles):.1f} fps "
          f"(paper board: 755,857 cycles / 264.6 fps)")

    # 2. heterogeneous dual-core with the paper's best MobileNet v1 config
    sched = best_schedule(g, DUAL_MBV1, board)
    dual = simulate_dual_core(sched)
    area = dual_core_area(DUAL_MBV1)
    print(f"{DUAL_MBV1}: {dual.fps:.1f} fps "
          f"(+{dual.fps/board.fps(sim.cycles)-1:.0%} vs baseline; "
          f"paper: 358.4 fps) at {area.dsp} DSP, "
          f"PE eff {dual.pe_efficiency:.0%}")

    # 3. the same model as executable JAX (+ Pallas kernels on TPU)
    params, fwd, _ = build_model("mobilenet_v1")
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 224, 224, 3))
    logits = fwd(params, x)
    print(f"JAX forward: logits {logits.shape}, "
          f"finite={bool(jnp.all(jnp.isfinite(logits)))}")


if __name__ == "__main__":
    main()
