"""Serve a small LM with batched requests on the dual-mesh runtime —
the paper's interleaved two-stream schedule on real devices
(deliverable b, serving flavour).

    PYTHONPATH=src python examples/serve_dualmesh.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import get_smoke
from repro.dualmesh import DualMeshRunner, request_stages, search, \
    split_mesh
from repro.lm.model import init_params


def main():
    cfg = get_smoke("qwen2_5_14b")
    # 1. design flow: pick theta / TP for the workload on a 256-chip pod
    stages = request_stages(cfg, [(4, 64, 32)] * 2)
    plan = search(stages, cfg, n_devices=256, max_evals=8)
    print(f"plan: theta={plan.theta:.2f} tp=({plan.tp_c},{plan.tp_p}) "
          f"makespan={plan.makespan*1e3:.1f} ms on 256 chips")

    # 2. execute the interleaved schedule on the local devices
    params = init_params(cfg, jax.random.PRNGKey(0))
    runner = DualMeshRunner(cfg, params, split_mesh(jax.devices(),
                                                    plan.theta),
                            max_len=128)
    key = jax.random.PRNGKey(1)
    pa = jax.random.randint(key, (4, 64), 0, cfg.vocab)
    pb = jax.random.randint(jax.random.PRNGKey(2), (4, 64), 0, cfg.vocab)
    t0 = time.perf_counter()
    a, b, trace = runner.run_two_streams(pa, pb, gen_steps=32)
    dt = time.perf_counter() - t0
    print(f"generated: A {a.shape}, B {b.shape} in {dt*1e3:.0f} ms")
    for kind, mesh_name, t in trace:
        print(f"  {kind:<8} on {mesh_name}-mesh  {t*1e3:7.1f} ms")


if __name__ == "__main__":
    main()
