"""Serve a small LM on the dual-mesh continuous-batching runtime through
the shared streaming engine API — the paper's interleaved schedule
generalized to an N-stream request queue on real devices (deliverable b,
serving flavour).

    PYTHONPATH=src python examples/serve_dualmesh.py
"""
import jax

from repro.configs.registry import get_smoke
from repro.dualmesh import (DualMeshRunner, TpuModel, plan_admission,
                            request_stages, search, split_mesh)
from repro.lm.model import init_params
from repro.serving import DualMeshEngine, Request

N_STREAMS = 4
BATCH, PROMPT, GEN = 4, 64, 32


def main():
    cfg = get_smoke("qwen2_5_14b")
    # 1. design flow: pick theta / TP for the N-stream workload on a
    #    256-chip pod
    stages = request_stages(cfg, [(BATCH, PROMPT, GEN)])
    plan = search(stages, cfg, n_devices=256, max_evals=8,
                  n_streams=N_STREAMS)
    print(f"plan: theta={plan.theta:.2f} tp=({plan.tp_c},{plan.tp_p}) "
          f"{N_STREAMS}-stream makespan={plan.makespan*1e3:.1f} ms "
          f"on 256 chips")

    # 2. makespan-aware admission: how many prefilled streams to fuse
    #    per decode batch
    dual = split_mesh(jax.devices(), plan.theta)
    adm = plan_admission(cfg, dual, TpuModel(), BATCH, PROMPT, GEN,
                         N_STREAMS)
    print(f"admission: fuse decode groups of {adm.group_size} "
          f"(est {adm.est_tokens_per_s:.0f} tok/s model-side)")

    # 3. execute the request queue on the local devices, through the
    #    shared engine API (submit -> step -> drain)
    params = init_params(cfg, jax.random.PRNGKey(0))
    runner = DualMeshRunner(cfg, params, dual, max_len=PROMPT + GEN + 8)
    engine = DualMeshEngine(runner, group_size=adm.group_size)
    prompts = [jax.random.randint(k, (BATCH, PROMPT), 0, cfg.vocab)
               for k in jax.random.split(jax.random.PRNGKey(1), N_STREAMS)]
    for p in prompts:
        engine.submit(Request(p, gen_steps=GEN))
    res = engine.drain()
    shapes = [tuple(o.shape) for o in res.outputs]
    print(f"generated {shapes} in {res.stats['wall_s']*1e3:.0f} ms "
          f"({res.stats['tokens_per_s']:.0f} tok/s, fused decode batches "
          f"{res.stats['fused_sizes']}, p95 request latency "
          f"{res.metrics.p95_ms():.0f} ms)")
    for kind, mesh_name, t in res.trace:
        print(f"  {kind:<8} on {mesh_name}-mesh  {t*1e3:7.1f} ms")


if __name__ == "__main__":
    main()
