"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
checkpoint/restart fault tolerance (deliverable b).

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

from repro.configs.registry import get_smoke
from repro.data.pipeline import DataConfig
from repro.train.optimizer import AdamW
from repro.train.runner import RunnerConfig, TrainRunner


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    # ~100M params: xlstm smoke scaled up
    cfg = get_smoke("xlstm_350m").scaled(
        name="xlstm_100m", n_layers=12, d_model=768, n_heads=4,
        n_kv_heads=4, d_head=192, vocab=8192)
    print(f"model: {cfg.name}, {cfg.param_count()/1e6:.0f}M params")
    runner = TrainRunner(
        cfg,
        RunnerConfig(ckpt_dir=args.ckpt, ckpt_every=50,
                     max_steps=args.steps, microbatches=2),
        optimizer=AdamW(lr=1e-3, warmup_steps=20, total_steps=args.steps),
        data_cfg=DataConfig(vocab=cfg.vocab, seq_len=128, global_batch=8))
    out = runner.run()
    first = out["metrics"][0]["loss"]
    last = out["final_loss"]
    print(f"loss {first:.3f} -> {last:.3f} over {out['final_step']} steps "
          f"({out['recoveries']} recoveries, "
          f"{out['stragglers']} straggler steps)")
    assert last < first, "loss must decrease"


if __name__ == "__main__":
    main()
