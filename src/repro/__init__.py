"""repro — dual-OPU (Zhao et al., cs.AR 2021) reproduced in JAX and ported
to multi-pod TPU.

Subpackages:
  core      the paper: PE-array models, tiling/latency/area, scheduling
            (Alg.1), branch-and-bound search, cycle-accurate simulator
  models    MobileNet v1/v2 + SqueezeNet (JAX, graph-locked)
  kernels   Pallas TPU kernels + jit wrappers + jnp oracles
  lm        the 10 assigned LM architectures (train + decode paths)
  dualmesh  the paper's design flow as a TPU serving feature
  data / train   pipeline, AdamW, checkpointing, fault-tolerant runner
  configs   exact assigned configs + smoke variants
  launch    production meshes, sharding policies, multi-pod dry-run
"""

__version__ = "1.0.0"
