"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias
[hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="command_r_plus_104b", family="dense",
        n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8,
        d_ff=33792, vocab=256000, qkv_bias=False,
        notes="GQA kv=8, no bias; ~104B params")


def smoke() -> ArchConfig:
    return full().scaled(name="command_r_plus_104b_smoke", n_layers=2,
                         d_model=128, n_heads=8, n_kv_heads=2, d_head=16,
                         d_ff=352, vocab=512)
