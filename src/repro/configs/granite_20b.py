"""granite-20b [dense]: 52L d_model=6144 48H (GQA kv=1 == MQA) d_ff=24576
vocab=49152 — llama-arch, code [arXiv:2405.04324; hf]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="granite_20b", family="dense",
        n_layers=52, d_model=6144, n_heads=48, n_kv_heads=1,
        d_ff=24576, vocab=49152,
        notes="MQA (kv=1): the most memory-bound decode of the pool")


def smoke() -> ArchConfig:
    return full().scaled(name="granite_20b_smoke", n_layers=2, d_model=96,
                         n_heads=6, n_kv_heads=1, d_head=16, d_ff=384,
                         vocab=512)
