"""granite-moe-3b-a800m [moe]: 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40e top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="granite_moe_3b_a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8,
        d_ff=512, vocab=49155,
        moe_experts=40, moe_top_k=8, moe_shared=0,
        notes="vocab 49155 not divisible by 16 -> vocab axis falls back "
              "to replicated (DESIGN.md §5)")


def smoke() -> ArchConfig:
    return full().scaled(name="granite_moe_3b_a800m_smoke", n_layers=2,
                         d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
                         d_ff=64, vocab=515, moe_experts=8, moe_top_k=2)
