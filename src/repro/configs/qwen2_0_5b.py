"""qwen2-0.5b [dense]: 24L d_model=896 14H (GQA kv=2) d_ff=4864
vocab=151936 — GQA, QKV bias [arXiv:2407.10671; hf]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2_0_5b", family="dense",
        n_layers=24, d_model=896, n_heads=14, n_kv_heads=2,
        d_ff=4864, vocab=151936, qkv_bias=True, tie_embeddings=True,
        rope_theta=1e6,
        notes="QKV bias; 14 heads do not divide a 16-way model axis -> "
              "sharding falls back per DESIGN.md §5")


def smoke() -> ArchConfig:
    return full().scaled(name="qwen2_0_5b_smoke", n_layers=2, d_model=112,
                         n_heads=14, n_kv_heads=2, d_head=8, d_ff=304,
                         vocab=512)
