"""qwen2.5-14b [dense]: 48L d_model=5120 40H (GQA kv=8) d_ff=13824
vocab=152064 — GQA, QKV bias [hf:Qwen/Qwen2.5-0.5B; hf]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2_5_14b", family="dense",
        n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=13824, vocab=152064, qkv_bias=True, rope_theta=1e6)


def smoke() -> ArchConfig:
    return full().scaled(name="qwen2_5_14b_smoke", n_layers=2, d_model=160,
                         n_heads=10, n_kv_heads=2, d_head=16, d_ff=432,
                         vocab=512)
