"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=151936, MoE 60e top-4 — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2_moe_a2_7b", family="moe",
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=1408, vocab=151936, qkv_bias=True,
        moe_experts=60, moe_top_k=4, moe_shared=4)


def smoke() -> ArchConfig:
    return full().scaled(name="qwen2_moe_a2_7b_smoke", n_layers=2,
                         d_model=128, n_heads=4, n_kv_heads=4, d_head=32,
                         d_ff=96, vocab=512, moe_experts=8, moe_top_k=2,
                         moe_shared=1)
