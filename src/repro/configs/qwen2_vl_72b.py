"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution; vision frontend STUB
(input_specs provides precomputed patch embeddings) [arXiv:2409.12191; hf]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="qwen2_vl_72b", family="vlm",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
        mrope=True, mrope_sections=(16, 24, 24), frontend="vision",
        notes="M-RoPE over (t,h,w) position streams; patch embeddings "
              "stubbed per assignment")


def smoke() -> ArchConfig:
    return full().scaled(name="qwen2_vl_72b_smoke", n_layers=2, d_model=128,
                         n_heads=8, n_kv_heads=2, d_head=16, d_ff=320,
                         vocab=512, mrope_sections=(2, 3, 3))
