"""Registry of the assigned architectures (exact configs from the
assignment) + the paper's own CNN workloads.

Each LM entry provides:
  full()   — the exact published config (dry-run / roofline only)
  smoke()  — a reduced same-family config (CPU smoke tests)
"""
from __future__ import annotations

import importlib

ARCH_IDS = (
    "command_r_plus_104b",
    "granite_20b",
    "qwen2_0_5b",
    "qwen2_5_14b",
    "qwen2_moe_a2_7b",
    "granite_moe_3b_a800m",
    "zamba2_2_7b",
    "whisper_small",
    "qwen2_vl_72b",
    "xlstm_350m",
)

CNN_IDS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")

# (seq_len, global_batch, kind); kind: train | prefill | decode | long-decode
SHAPES = {
    "train_4k": (4_096, 256, "train"),
    "prefill_32k": (32_768, 32, "prefill"),
    "decode_32k": (32_768, 128, "decode"),
    "long_500k": (524_288, 1, "long-decode"),
}

# long_500k runs only for sub-quadratic-state archs (assignment rule;
# DESIGN.md §4): the others would stream a dense KV cache quadratically
# accumulated over 524k positions.
LONG_OK = ("zamba2_2_7b", "xlstm_350m")


def get_arch(name: str):
    if name not in ARCH_IDS:
        raise KeyError(f"unknown arch {name!r}; choices: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.full()


def get_smoke(name: str):
    mod = importlib.import_module(f"repro.configs.{name}")
    return mod.smoke()


def cells(include_long: bool = True):
    """All live (arch, shape) dry-run cells."""
    out = []
    for a in ARCH_IDS:
        for s, (_, _, kind) in SHAPES.items():
            if s == "long_500k" and a not in LONG_OK:
                continue
            out.append((a, s))
    return out
