"""whisper-small [audio]: 12L d_model=768 12H d_ff=3072 vocab=51865 —
enc-dec, conv frontend STUB (input_specs provides 1500 precomputed frame
embeddings) [arXiv:2212.04356; unverified]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="whisper_small", family="audio",
        n_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
        d_ff=3072, vocab=51865,
        encoder_decoder=True, enc_layers=12, enc_positions=1500,
        frontend="audio",
        notes="conv frontend stubbed per assignment; decoder cross-attends "
              "to 1500 frame embeddings")


def smoke() -> ArchConfig:
    return full().scaled(name="whisper_small_smoke", n_layers=2,
                         enc_layers=2, d_model=96, n_heads=6, n_kv_heads=6,
                         d_head=16, d_ff=192, vocab=512, enc_positions=50)
