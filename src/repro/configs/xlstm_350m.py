"""xlstm-350m [ssm]: 24L d_model=1024 4H d_ff=0 vocab=50304 — sLSTM +
mLSTM blocks [arXiv:2405.04517; unverified].  Implemented as mLSTM blocks
(DESIGN.md §7: the 350M xLSTM is predominantly mLSTM; sLSTM's sequential
recurrence does not map to TPU training parallelism)."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="xlstm_350m", family="ssm",
        n_layers=24, d_model=1024, n_heads=4, n_kv_heads=4,
        d_ff=0, vocab=50304,
        block_type="mlstm", ssm_state=64, d_inner_mult=2,
        notes="d_ff=0: blocks carry their own 2x up-projection")


def smoke() -> ArchConfig:
    return full().scaled(name="xlstm_350m_smoke", n_layers=2, d_model=128,
                         n_heads=4, n_kv_heads=4, d_head=32, vocab=512)
