"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + shared attn block
[arXiv:2411.15242; hf]."""
from repro.lm.config import ArchConfig


def full() -> ArchConfig:
    return ArchConfig(
        name="zamba2_2_7b", family="hybrid",
        n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
        d_ff=10240, vocab=32000,
        block_type="mamba2", ssm_state=64, attn_every=6,
        notes="Mamba2 layers; one weight-shared attn+MLP block applied "
              "every 6 layers (9 applications)")


def smoke() -> ArchConfig:
    return full().scaled(name="zamba2_2_7b_smoke", n_layers=4, d_model=128,
                         n_heads=4, n_kv_heads=4, d_head=32, d_ff=256,
                         vocab=512, ssm_state=16, attn_every=2)
