"""dual-OPU core: the paper's contribution as a composable library.

Layers:
  graph      - layer-graph IR (LayerSpec / LayerGraph)
  arch       - CoreConfig (n,v), DualCoreConfig, BoardModel, ResourceBudget
  tiling     - Eq.2-4 tile sizing
  latency    - Eq.5-7 latency model + Eq.1 runtime PE efficiency
  area       - Eq.8 + BRAM/LUT/FF resource model (Tables I & III anchors)
  scheduler  - allocation / partitioning / interleaving / Alg.1 load balance
  search     - branch-and-bound theta + local (n,v) search (§V-B)
  isa        - instruction compiler (LOAD/COMPUTE/STORE/SYNC)
  simulator  - cycle-accurate instruction-level simulator (Table IV)
"""
from repro.core.arch import (ALPHA, V_CANDIDATES, BoardModel, CoreConfig,
                             DualCoreConfig, ResourceBudget, P128_9,
                             DUAL_BASELINE, DUAL_MBV1, DUAL_MBV2, DUAL_SQZ,
                             DUAL_MULTI)
from repro.core.graph import LayerGraph, LayerSpec, chain_graph
from repro.core.latency import (LayerLatency, compute_cycles, layer_latency,
                                load_cycles, total_latency,
                                graph_latency_report)
from repro.core.area import (CoreArea, core_area, dual_core_area,
                             pe_structure_lut_equiv, count_ramb18k)
from repro.core.tiling import Tiling, tile_layer
from repro.core.scheduler import (Group, Schedule, best_schedule,
                                  build_schedule, load_balance, allocate,
                                  partition, ALLOCATION_SCHEMES)
from repro.core.search import SearchResult, search, evaluate_config, \
    harmonic_mean
from repro.core.simulator import (SimTrace, simulate_single_core,
                                  simulate_dual_core, DualSimResult)

__all__ = [
    "ALPHA", "V_CANDIDATES", "BoardModel", "CoreConfig", "DualCoreConfig",
    "ResourceBudget", "P128_9", "DUAL_BASELINE", "DUAL_MBV1", "DUAL_MBV2",
    "DUAL_SQZ", "DUAL_MULTI", "LayerGraph", "LayerSpec", "chain_graph",
    "LayerLatency", "compute_cycles", "layer_latency", "load_cycles",
    "total_latency", "graph_latency_report", "CoreArea", "core_area",
    "dual_core_area", "pe_structure_lut_equiv", "count_ramb18k", "Tiling",
    "tile_layer", "Group", "Schedule", "best_schedule", "build_schedule",
    "load_balance", "allocate", "partition", "ALLOCATION_SCHEMES",
    "SearchResult", "search", "evaluate_config", "harmonic_mean", "SimTrace",
    "simulate_single_core", "simulate_dual_core", "DualSimResult",
]
