"""Hardware model of the dual-OPU (paper §III).

A *core* is an ``(n, v)`` PE array: ``n`` PEs, each an inner product over ``v``
multipliers reduced by a balanced adder tree, followed by configurable adders
that produce 2..n accumulated outputs per cycle (paper §III-B).  DSP macros are
decomposed into two 8-bit multipliers sharing one input (alpha = 2, Eq.8):
  * c-core: two multipliers share one ifm pixel, produce two output channels.
  * p-core: two pixels share one weight (needs double ifm buffers + line buffer).

The board model carries the calibrated DRAM constants of Eq.5 and the FPGA
resource budget used by the search (§V-B, Table II).
"""
from __future__ import annotations

import dataclasses


ALPHA = 2  # MACs per DSP macro per cycle (one DSP48E1 -> two 8-bit multipliers)

# Paper §V-B2: candidate values of v for the local search.  "Prime numbers are
# excluded since common channel numbers are not multiple of prime numbers."
V_CANDIDATES = (8, 9, 10, 12, 14, 15, 16, 18)


@dataclasses.dataclass(frozen=True)
class CoreConfig:
    """One core: C(n, v) or P(n, v) (paper notation, §VI-A)."""

    kind: str  # 'c' (channel-parallel) or 'p' (pixel-parallel, line buffer)
    n: int     # N_PE
    v: int     # N_vector (multipliers per PE)

    def __post_init__(self):
        if self.kind not in ("c", "p"):
            raise ValueError(f"core kind must be 'c' or 'p', got {self.kind!r}")
        if self.n < 1 or self.v < 1:
            raise ValueError(f"invalid PE config ({self.n},{self.v})")

    @property
    def has_line_buffer(self) -> bool:
        return self.kind == "p"

    @property
    def n_mult(self) -> int:
        """Total 8-bit multipliers == peak MACs per cycle."""
        return self.n * self.v

    @property
    def n_dsp(self) -> int:
        """Eq.8: N_DSP = ceil(n / alpha) * v."""
        return -(-self.n // ALPHA) * self.v

    @property
    def buffer_depth(self) -> int:
        """ifm buffer depth (T_h*T_w capacity).  Scales with the PE count:
        'P(64,9) has half multipliers, buffer depth and line buffer channels
        of P(128,9)' (§VI-A c); P(128,9) carries depth 4096."""
        return max(512, 4096 * self.n // 128)

    def __str__(self) -> str:
        return f"{self.kind.upper()}({self.n},{self.v})"


@dataclasses.dataclass(frozen=True)
class DualCoreConfig:
    """Heterogeneous dual-OPU: one c-core + one p-core (paper Fig.2)."""

    c: CoreConfig
    p: CoreConfig

    def __post_init__(self):
        if self.c.kind != "c" or self.p.kind != "p":
            raise ValueError("DualCoreConfig wants (c-core, p-core)")

    @property
    def n_dsp(self) -> int:
        return self.c.n_dsp + self.p.n_dsp

    def core(self, which: str) -> CoreConfig:
        return self.c if which == "c" else self.p

    def theta(self, dsp_budget: int) -> float:
        """Eq.10: c-core share of the DSP budget."""
        return self.c.n_mult / (ALPHA * dsp_budget)

    def __str__(self) -> str:
        return f"{self.c}+{self.p}"


@dataclasses.dataclass(frozen=True)
class BoardModel:
    """Calibrated board constants (paper §IV-B: L_dram / L_post are 'average
    values based on multiple execution traces on FPGA'; unpublished, so we
    calibrate them against Table IV and record the values in EXPERIMENTS.md).

    ``bw_dram`` is in 8-bit elements per cycle (PE precision is Int8,
    Table VIII), i.e. bytes/cycle.  XCK325T DDR3 @200 MHz core clock gives
    a theoretical 64 B/cycle; the effective value is calibrated.
    """

    freq_mhz: float = 200.0
    # Calibrated against Table IV board cycle counts (see EXPERIMENTS.md):
    # bw=21 B/cycle (4.2 GB/s effective DDR3), L_dram=250, L_post=150 give
    # MobileNet v1 +0.26%, v2 -0.84%, SqueezeNet +2.49% vs the paper's board.
    bw_dram: int = 21        # elements (bytes) per cycle, Eq.5 denominator
    l_dram: int = 250        # CAS-latency pipeline term of Eq.5 (cycles)
    l_post: int = 150        # post-processing drain term of Eq.6 (cycles)
    # When True the simulator halves effective per-core DRAM bandwidth while
    # both cores load concurrently.  The paper does not model contention
    # (loads are independent per-core buffers); keep False for fidelity.
    dram_contention: bool = False

    def cycles_to_seconds(self, cycles: float) -> float:
        return cycles / (self.freq_mhz * 1e6)

    def fps(self, cycles_per_image: float) -> float:
        if cycles_per_image <= 0:
            return float("inf")
        return self.freq_mhz * 1e6 / cycles_per_image


@dataclasses.dataclass(frozen=True)
class ResourceBudget:
    """FPGA resource upper bounds (Table II constraints).

    Defaults are the Xilinx Kintex-7 XCK325T used by the paper (Table VIII):
    203,800 LUTs / 407,600 FFs / 840 DSP48E1 / 890 RAMB18K.
    """

    n_dsp: int = 840
    n_bram18k: int = 890
    n_lut: int = 203_800
    n_ff: int = 407_600

    def fits(self, dsp: int, bram: int, lut: int, ff: int) -> bool:
        return (dsp <= self.n_dsp and bram <= self.n_bram18k
                and lut <= self.n_lut and ff <= self.n_ff)


# Published configurations from the paper (used in tests / benchmarks).
P128_9 = CoreConfig("p", 128, 9)                       # single-core baseline
DUAL_BASELINE = DualCoreConfig(CoreConfig("c", 128, 8), CoreConfig("p", 64, 9))
DUAL_MBV1 = DualCoreConfig(CoreConfig("c", 128, 12), CoreConfig("p", 8, 16))
DUAL_MBV2 = DualCoreConfig(CoreConfig("c", 160, 8), CoreConfig("p", 48, 8))
DUAL_SQZ = DualCoreConfig(CoreConfig("c", 130, 8), CoreConfig("p", 64, 10))
DUAL_MULTI = DualCoreConfig(CoreConfig("c", 128, 10), CoreConfig("p", 32, 12))
