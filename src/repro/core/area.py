"""FPGA resource model (paper §IV-C, Eq.8, Tables I & III).

Component constants are reverse-engineered from the paper's own numbers:

* Table III gives exact component LUT costs:
    - multipliers: P(64,9) -> 40,896 / 576 mult = 71.0 LUT-equiv per 8-bit
      multiplier; C(128,8) -> 72,704 / 1024 = 71.0.  (Used for *equivalent
      area* comparisons; real multipliers are DSP.)
    - adders: with count = n*(v-1) tree adders + (n-1) output accumulators,
      P(64,9): 17,859 / 575 = 31.06 LUT;  C(128,8): 31,749 / 1023 = 31.04.
      We use 31.05 — both match within 0.1%.
    - line buffer: P(64,9) has a 128-channel line buffer (2n channels, for the
      double-pixel ifm buffers) of length T_w*(T_kh-1)+T_kw = 224*2+3 = 451
      taps: 39,868 / 128 = 311.5 LUT/channel -> 0.6907 LUT per (channel*tap).
* Table I anchors the invariants for a full core (P(128,9) + buffers):
    LUT 137,149 / FF 234,046 / DSP 577 / BRAM 237.
  With the component constants above, the P(128,9) variants are
  adders (128*8+127)*31.05 = 35,734 and line buffer 256ch*311.5 = 79,744,
  leaving INVARIANT_LUT ~= 21,670 (memory controller + decoder + PP unit).

DSP:  Eq.8,  N_DSP = ceil(n/alpha)*v  (+1 invariant DSP in the PP unit,
      which makes P(128,9) = 64*9+1 = 577, matching Tables I/IV/VI exactly).
BRAM: RAMB18K counting over the configurable width x depth modes
      {36x512, 18x1k, 9x2k, 4x4k, 2x8k, 1x16k} with width-priority
      (paper: "minimum number of RAMB18K in term of width size").
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.arch import CoreConfig, DualCoreConfig

# Component constants (see module docstring for derivation).
MULT_LUT_EQUIV = 71.0          # Table III: LUT-equivalent of one 8-bit mult
ADDER_LUT = 31.05              # Table III: per adder (tree + accumulators)
LB_LUT_PER_CH_TAP = 0.6907     # Table III: line buffer LUT per channel*tap
LB_DEFAULT_TAPS = 451          # T_w*(T_kh-1)+T_kw for 224-wide ifm, 3x3 window
INVARIANT_LUT = 21_670         # Table I residual (mem ctrl, decoder, PP)
INVARIANT_DSP = 1              # Table I: 577 = 64*9 + 1
# FF constants calibrated so P(128,9)+buffers ~= 234,046 (Table I).
FF_PER_ADDER = 36.0
FF_PER_MULT_PIPE = 16.0
FF_PER_DELAYER = 16.0          # register insertion when v is not a power of 2
INVARIANT_FF = 172_130

RAMB18K_MODES = ((36, 512), (18, 1024), (9, 2048), (4, 4096),
                 (2, 8192), (1, 16384))
BASE_BUFFER_DEPTH = 4096       # P(128,9) ifm buffer depth; scales with n/128


def count_ramb18k(width_bits: int, depth: int) -> int:
    """Min RAMB18K for one bank, trying every width x depth mode with
    width-priority (fewest units across the width dimension first)."""
    if width_bits <= 0 or depth <= 0:
        return 0
    best = None
    for w, d in RAMB18K_MODES:
        cnt = math.ceil(width_bits / w) * math.ceil(depth / d)
        key = (math.ceil(width_bits / w), cnt)
        if best is None or key < best[0]:
            best = (key, cnt)
    # width-priority: among modes, min width-units; ties -> min total.
    return best[1]


@dataclasses.dataclass(frozen=True)
class CoreArea:
    dsp: int
    bram18k: int
    lut: int
    ff: int
    lut_equiv: float   # "equivalent LUT cost" of the PE structure (Table III)

    def __add__(self, other: "CoreArea") -> "CoreArea":
        return CoreArea(self.dsp + other.dsp, self.bram18k + other.bram18k,
                        self.lut + other.lut, self.ff + other.ff,
                        self.lut_equiv + other.lut_equiv)


def adder_count(core: CoreConfig) -> int:
    """n*(v-1) balanced-tree adders + (n-1) output accumulators."""
    return core.n * (core.v - 1) + (core.n - 1)


def line_buffer_channels(core: CoreConfig) -> int:
    """p-core line buffer spans 2n channels (double ifm buffers feed two
    sliding-window pixel groups, §III-B / §VI-A)."""
    return 2 * core.n if core.has_line_buffer else 0


def pe_structure_lut_equiv(core: CoreConfig,
                           lb_taps: int = LB_DEFAULT_TAPS) -> dict:
    """Table III decomposition: line buffer / multipliers / adders."""
    lb = line_buffer_channels(core) * LB_LUT_PER_CH_TAP * lb_taps
    mult = core.n_mult * MULT_LUT_EQUIV
    add = adder_count(core) * ADDER_LUT
    return {"line_buffer": lb, "multipliers": mult, "adders": add,
            "total": lb + mult + add}


def buffer_bram(core: CoreConfig) -> int:
    """RAMB18K for ifm / weight / output buffers (§IV-C b).

    ifm: ping-pong (x2), doubled again on p-core (double ifm buffers);
         width 32 elements x 8 bit, depth scales with n (P(64,9) has half the
         buffer depth of P(128,9), §VI-A).
    weights: ping-pong, width v elements, depth 1024.
    ofm: ping-pong, 36-bit accumulators, same depth as ifm.
    Bias lives in logic (paper: "bias amount is usually small").
    """
    depth = max(512, BASE_BUFFER_DEPTH * core.n // 128)
    ifm_banks = 2 * (2 if core.has_line_buffer else 1)
    ifm = ifm_banks * count_ramb18k(32 * 8, depth)
    wgt = 2 * count_ramb18k(core.v * 8, 1024)
    ofm = 2 * count_ramb18k(36, depth)
    return ifm + wgt + ofm


def core_area(core: CoreConfig, include_invariant: bool = False,
              lb_taps: int = LB_DEFAULT_TAPS) -> CoreArea:
    adders = adder_count(core)
    lb_ch = line_buffer_channels(core)
    lut = adders * ADDER_LUT + lb_ch * LB_LUT_PER_CH_TAP * lb_taps
    delayers = core.n if (core.v & (core.v - 1)) else 0   # v not power of 2
    ff = (adders * FF_PER_ADDER + core.n_mult * FF_PER_MULT_PIPE
          + delayers * FF_PER_DELAYER)
    dsp = core.n_dsp
    bram = buffer_bram(core)
    if include_invariant:
        lut += INVARIANT_LUT
        ff += INVARIANT_FF
        dsp += INVARIANT_DSP
    eq = pe_structure_lut_equiv(core, lb_taps)["total"]
    return CoreArea(dsp=int(dsp), bram18k=int(bram), lut=int(round(lut)),
                    ff=int(round(ff)), lut_equiv=eq)


def dual_core_area(cfg: DualCoreConfig) -> CoreArea:
    """Total area of a dual-OPU design: both cores + one set of invariants
    (shared memory controller / decoder / post-processing, §IV-C).  The DSP
    column counts PE DSPs only, matching Table VI/VIII "Allocated DSP"
    (832 = C(128,12)+P(8,16), 840 = C(130,8)+P(64,10))."""
    a = core_area(cfg.c) + core_area(cfg.p)
    return CoreArea(a.dsp, a.bram18k,
                    a.lut + INVARIANT_LUT, a.ff + INVARIANT_FF, a.lut_equiv)


def fits_budget(cfg, budget: ResourceBudget) -> bool:
    a = dual_core_area(cfg) if isinstance(cfg, DualCoreConfig) \
        else core_area(cfg, include_invariant=True)
    return budget.fits(a.dsp, a.bram18k, a.lut, a.ff)
