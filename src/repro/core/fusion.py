"""Graph fusion pass: detect dw->pw chains for the fused block kernels.

The paper's scheduler co-executes a depthwise layer on the p-core with the
neighbouring pointwise layers on the c-core so the intermediate feature map
never leaves the chip (§V).  This pass is the compiler half of that story
for the JAX execution path: it walks a ``LayerGraph`` in topological order
and groups layers that ``repro.kernels.fused_block`` can run in a single
pallas_call (DESIGN.md §3):

  pw_dw_pw   1x1 conv (expand) -> dwconv -> 1x1 conv (project), the
             MobileNet-v2 inverted residual.  Matched first so the expand
             conv is not left behind as a singleton.
  dw_pw      dwconv -> 1x1 conv, the MobileNet-v1 separable block (also
             covers v2's t=1 block).
  single     everything else (regular convs, fc, fan-out nodes).

A chain only fuses when it is *linear* in the graph: each producer's sole
consumer is the next layer in the chain (a feature map with a second
consumer must be materialized anyway, so fusing would duplicate work).
"""
from __future__ import annotations

import dataclasses

from repro.core.graph import LayerGraph, LayerSpec


@dataclasses.dataclass(frozen=True)
class FusionGroup:
    """One execution unit of the fused plan."""

    kind: str                   # 'single' | 'dw_pw' | 'pw_dw_pw'
    layers: tuple[str, ...]

    def __iter__(self):
        return iter(self.layers)


def _is_pw(l: LayerSpec) -> bool:
    return (l.op == "conv" and l.K_h == 1 and l.K_w == 1 and l.stride == 1
            and l.pad == 0)


def _linear_next(graph: LayerGraph, name: str) -> str | None:
    """Sole successor of ``name`` that has ``name`` as its sole
    predecessor, else None."""
    succ = graph.successors(name)
    if len(succ) != 1:
        return None
    if graph.predecessors(succ[0]) != [name]:
        return None
    return succ[0]


def plan_fusion(graph: LayerGraph) -> list[FusionGroup]:
    """Greedy fusion plan over the graph in topological order."""
    order = graph.topological_order()
    consumed: set[str] = set()
    plan: list[FusionGroup] = []
    for l in order:
        if l.name in consumed:
            continue
        group = _match(graph, l)
        plan.append(group)
        consumed.update(group.layers)
    return plan


def _match(graph: LayerGraph, l: LayerSpec) -> FusionGroup:
    # pw-expand -> dw -> pw-project (matched first: see module docstring)
    if _is_pw(l):
        dn = _linear_next(graph, l.name)
        if dn is not None and graph.layer(dn).op == "dwconv":
            pn = _linear_next(graph, dn)
            if pn is not None and _is_pw(graph.layer(pn)):
                return FusionGroup("pw_dw_pw", (l.name, dn, pn))
    # dw -> pw
    if l.op == "dwconv":
        pn = _linear_next(graph, l.name)
        if pn is not None and _is_pw(graph.layer(pn)):
            return FusionGroup("dw_pw", (l.name, pn))
    return FusionGroup("single", (l.name,))


def fused_layer_counts(graph: LayerGraph) -> dict[str, int]:
    """Summary used by benchmarks / tests: group-kind -> count."""
    counts: dict[str, int] = {}
    for g in plan_fusion(graph):
        counts[g.kind] = counts.get(g.kind, 0) + 1
    return counts
