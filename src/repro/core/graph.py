"""Layer-graph IR for the dual-OPU compiler (paper §III-C, Fig.3/Fig.4a).

Nodes are layers with the characteristic parameters the paper's models consume
(input feature-map H/W, input/output channels, kernel H/W, stride); edges are
data dependencies.  The same IR is produced by ``repro.models.extract`` from the
JAX model definitions and consumed by tiling / latency / area / scheduling.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

# Layer op kinds understood by the dual-OPU models.  ``conv`` covers regular and
# pointwise (K=1) convolution; ``dwconv`` is depthwise; ``fc`` is a 1x1 conv on a
# 1x1 feature map; ``pool``/``add``/``concat`` are post-processing-unit ops that
# the overlay fuses into the compute pipeline (latency absorbed in L_post).
CONV_OPS = ("conv", "dwconv", "fc")
FUSED_OPS = ("pool", "avgpool", "maxpool", "add", "concat", "relu", "relu6")


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer with the paper's characteristic parameters (§II, §IV)."""

    name: str
    op: str                      # 'conv' | 'dwconv' | 'fc'
    H: int                       # input feature-map height
    W: int                       # input feature-map width
    C_i: int                     # input channels
    C_o: int                     # output channels
    K_h: int = 1
    K_w: int = 1
    stride: int = 1
    pad: int = 0
    # Post-ops fused into this layer's pipeline (pool/activation/residual-add).
    fused: tuple = ()

    def __post_init__(self):
        if self.op not in CONV_OPS:
            raise ValueError(f"unsupported op {self.op!r} for {self.name!r}")
        if self.op == "dwconv" and self.C_i != self.C_o:
            raise ValueError(
                f"{self.name}: depthwise conv requires C_i == C_o "
                f"(got {self.C_i} vs {self.C_o})")

    # ---- derived quantities ------------------------------------------------
    @property
    def H_out(self) -> int:
        return max(1, (self.H + 2 * self.pad - self.K_h) // self.stride + 1)

    @property
    def W_out(self) -> int:
        return max(1, (self.W + 2 * self.pad - self.K_w) // self.stride + 1)

    @property
    def macs(self) -> int:
        """Multiply-accumulate count (N_op in Eq.1 counts MACs)."""
        pix = self.H_out * self.W_out
        if self.op == "dwconv":
            return pix * self.C_i * self.K_h * self.K_w
        return pix * self.C_o * self.C_i * self.K_h * self.K_w

    @property
    def ifm_elems(self) -> int:
        return self.H * self.W * self.C_i

    @property
    def ofm_elems(self) -> int:
        return self.H_out * self.W_out * self.C_o

    @property
    def weight_elems(self) -> int:
        if self.op == "dwconv":
            return self.K_h * self.K_w * self.C_i
        return self.K_h * self.K_w * self.C_i * self.C_o

    @property
    def bias_elems(self) -> int:
        return self.C_o

    @property
    def load_elems(self) -> int:
        """Numerator of Eq.5: ifm + weights + bias elements to load."""
        return self.ifm_elems + self.weight_elems + self.bias_elems

    def with_height(self, H: int, name_suffix: str = "") -> "LayerSpec":
        """Clone with a new input height (used by Alg.1 layer split)."""
        return dataclasses.replace(self, H=H, name=self.name + name_suffix)


@dataclasses.dataclass
class LayerGraph:
    """CNN graph G(V, E) (paper §V-A, Fig.4a)."""

    name: str
    layers: list[LayerSpec]
    # Edges as (producer_name, consumer_name).  Absent edges => sequential chain.
    edges: list[tuple[str, str]] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        names = [l.name for l in self.layers]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate layer names in graph {self.name}")
        self._index = {l.name: i for i, l in enumerate(self.layers)}
        if not self.edges:
            self.edges = [(a.name, b.name)
                          for a, b in zip(self.layers, self.layers[1:])]
        for a, b in self.edges:
            if a not in self._index or b not in self._index:
                raise ValueError(f"edge ({a},{b}) references unknown layer")

    def __len__(self) -> int:
        return len(self.layers)

    def layer(self, name: str) -> LayerSpec:
        return self.layers[self._index[name]]

    def predecessors(self, name: str) -> list[str]:
        return [a for a, b in self.edges if b == name]

    def successors(self, name: str) -> list[str]:
        return [b for a, b in self.edges if a == name]

    def topological_order(self) -> list[LayerSpec]:
        """Kahn topological sort; ties broken by definition order (paper uses
        topological order for group assignment, §V-A)."""
        indeg = {l.name: 0 for l in self.layers}
        for _, b in self.edges:
            indeg[b] += 1
        ready = [l.name for l in self.layers if indeg[l.name] == 0]
        out: list[str] = []
        while ready:
            # stable: pick the earliest-defined ready node
            ready.sort(key=lambda n: self._index[n])
            n = ready.pop(0)
            out.append(n)
            for s in self.successors(n):
                indeg[s] -= 1
                if indeg[s] == 0:
                    ready.append(s)
        if len(out) != len(self.layers):
            raise ValueError(f"graph {self.name} has a cycle")
        return [self.layer(n) for n in out]

    @property
    def total_macs(self) -> int:
        return sum(l.macs for l in self.layers)

    @property
    def total_params(self) -> int:
        return sum(l.weight_elems + l.bias_elems for l in self.layers)

    def summary(self) -> str:
        rows = [f"{'name':<22}{'op':<8}{'HxW':<12}{'Ci->Co':<14}"
                f"{'K':<6}{'s':<3}{'MACs':>12}"]
        for l in self.layers:
            rows.append(
                f"{l.name:<22}{l.op:<8}{f'{l.H}x{l.W}':<12}"
                f"{f'{l.C_i}->{l.C_o}':<14}{f'{l.K_h}x{l.K_w}':<6}"
                f"{l.stride:<3}{l.macs:>12,}")
        rows.append(f"total MACs: {self.total_macs:,}  "
                    f"params: {self.total_params:,}")
        return "\n".join(rows)


def chain_graph(name: str, layers: Sequence[LayerSpec]) -> LayerGraph:
    """Build a purely sequential graph (MobileNets are almost purely
    sequential, §II)."""
    return LayerGraph(name=name, layers=list(layers))
