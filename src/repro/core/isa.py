"""Dual-OPU instruction set + compiler (paper §VI-A a, modeled after OPU [14]).

The compiler lowers a scheduled group chain to a per-core instruction stream.
Instruction granularity is one memory block / one tile pass, which is what the
cycle-accurate simulator executes.  Instructions:

  LOAD   ifm/weight/bias block from DRAM into the ping or pong bank
  COMPUTE one (output-tile x reduction-tile) pass over a pixel block
  STORE  a ready ofm block back to DRAM (through the PP unit)
  SYNC   cross-core barrier at group boundaries (interleaved schedule slots)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable

from repro.core.arch import BoardModel, CoreConfig
from repro.core.graph import LayerSpec
from repro.core.latency import compute_cycles, load_cycles
from repro.core.scheduler import Schedule
from repro.core.tiling import tile_layer


@dataclasses.dataclass(frozen=True)
class Instr:
    op: str              # LOAD | COMPUTE | STORE | SYNC
    layer: str
    cycles: int          # latency charged by the simulator
    bank: int = 0        # ping(0) / pong(1)
    meta: tuple = ()

    def __str__(self):
        return f"{self.op:<8}{self.layer:<24}{self.cycles:>10} cyc {self.meta}"


def compile_layer(layer: LayerSpec, core: CoreConfig,
                  board: BoardModel) -> list[Instr]:
    """Lower one layer to blocked LOAD/COMPUTE/STORE instructions.

    Loads are split per spatial block (Eq.4 blocks), computes per block too,
    so the simulator can overlap block k+1's load with block k's compute via
    the ping-pong banks — reproducing Eq.7's max(T_load, T_compute) plus the
    true pipeline fill/drain that the analytic model folds into L_dram/L_post.
    """
    t = tile_layer(layer, core)
    n_blocks = math.ceil(layer.H / t.T_h) * math.ceil(layer.W / t.T_w)
    total_compute, _ = compute_cycles(layer, core, board, t)
    total_load = load_cycles(layer, board)
    # Split totals evenly across blocks; remainders charged to block 0.
    per_block_c = (total_compute - board.l_post) // n_blocks
    per_block_l = (total_load - board.l_dram) // n_blocks
    rc = (total_compute - board.l_post) - per_block_c * n_blocks
    rl = (total_load - board.l_dram) - per_block_l * n_blocks
    instrs: list[Instr] = []
    for b in range(n_blocks):
        lc = per_block_l + (rl if b == 0 else 0) + (
            board.l_dram if b == 0 else 0)   # CAS charged on first burst
        cc = per_block_c + (rc if b == 0 else 0)
        instrs.append(Instr("LOAD", layer.name, lc, bank=b % 2,
                            meta=("block", b, n_blocks)))
        instrs.append(Instr("COMPUTE", layer.name, cc, bank=b % 2,
                            meta=("block", b, n_blocks)))
    instrs.append(Instr("STORE", layer.name, board.l_post,
                        meta=("drain",)))
    return instrs


def compile_group(layers: Iterable[LayerSpec], core: CoreConfig,
                  board: BoardModel) -> list[Instr]:
    out: list[Instr] = []
    for l in layers:
        out.extend(compile_layer(l, core, board))
    return out


def compile_schedule(schedule: Schedule) -> list[list[Instr]]:
    """Per-group instruction streams, in chain order."""
    return [compile_group(g.layers, schedule.cfg.core(g.core),
                          schedule.board)
            for g in schedule.groups]
