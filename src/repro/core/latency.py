"""Latency model (paper §IV-B, Eq.5-7).

  Eq.5:  T_load    = ceil((H*W*C_i + K_h*K_w*C_i*C_o + C_o) / BW_dram) + L_dram
  Eq.6:  T_compute = passes * streamed-pixels + L_post
  Eq.7:  T_total   = sum_l max(T_compute^l, T_load^l)

The compiler overlaps load and compute through the ping-pong buffers, hence the
max() per layer.  ``T_compute`` streams one pixel-tile per cycle through the
deep MAC + post-processing pipeline; the pass count is the Eq.6 product of
channel/kernel tile counts and the pixel term is the Eq.4 padded block count.

This module is a pure function of (LayerSpec, CoreConfig, BoardModel) so the
scheduler, the branch-and-bound search and the instruction-level simulator all
share one latency definition.
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.arch import BoardModel, CoreConfig
from repro.core.graph import LayerSpec
from repro.core.tiling import Tiling, tile_layer


@dataclasses.dataclass(frozen=True)
class LayerLatency:
    layer: str
    core: str
    t_load: int
    t_compute: int
    tiling: Tiling
    macs: int

    @property
    def t_layer(self) -> int:
        """Eq.7 per-layer term."""
        return max(self.t_load, self.t_compute)

    @property
    def bound(self) -> str:
        return "memory" if self.t_load >= self.t_compute else "compute"

    def pe_efficiency(self, core: CoreConfig) -> float:
        """Runtime PE efficiency, Eq.1 with alpha*N_PE == n*v multipliers."""
        denom = core.n_mult * self.t_layer
        return self.macs / denom if denom else 0.0


def load_cycles(layer: LayerSpec, board: BoardModel) -> int:
    """Eq.5."""
    return math.ceil(layer.load_elems / board.bw_dram) + board.l_dram


def compute_cycles(layer: LayerSpec, core: CoreConfig, board: BoardModel,
                   tiling: Tiling | None = None) -> tuple[int, Tiling]:
    """Eq.6 with the streaming interpretation (see tiling.py docstring)."""
    t = tiling if tiling is not None else tile_layer(layer, core)
    if layer.op == "dwconv":
        ch_tiles = math.ceil(layer.C_i / t.T_co)
        win_tiles = (math.ceil(layer.K_h / t.T_kh)
                     * math.ceil(layer.K_w / t.T_kw))
        if not core.has_line_buffer:
            # One useful multiplier per PE: every kernel tap is a pass.
            win_tiles = layer.K_h * layer.K_w
        passes = ch_tiles * win_tiles
    else:
        passes = t.passes(layer)
    cycles = passes * t.spatial_cycles(layer) + board.l_post
    return cycles, t


def layer_latency(layer: LayerSpec, core: CoreConfig,
                  board: BoardModel) -> LayerLatency:
    t_c, tiling = compute_cycles(layer, core, board)
    return LayerLatency(layer=layer.name, core=core.kind,
                        t_load=load_cycles(layer, board),
                        t_compute=t_c, tiling=tiling, macs=layer.macs)


def total_latency(layers, core: CoreConfig, board: BoardModel) -> int:
    """Eq.7 over a sequence of layers on a single core."""
    return sum(layer_latency(l, core, board).t_layer for l in layers)


def graph_latency_report(layers, core: CoreConfig, board: BoardModel):
    """Per-layer latency + Eq.1 efficiency (reproduces Fig.1 curves)."""
    rows = [layer_latency(l, core, board) for l in layers]
    total = sum(r.t_layer for r in rows)
    total_macs = sum(r.macs for r in rows)
    overall_eff = total_macs / (core.n_mult * total) if total else 0.0
    return rows, total, overall_eff


def compute_lower_bound(layer: LayerSpec, n_dsp_core: float,
                        board: BoardModel, alpha: int = 2) -> float:
    """Eq.11: ideal compute latency ignoring tiling mismatch.

    T_compute^lb = (C_o*H*W*C_i*K_h*K_w * 2) / (alpha * N_DSP^core) + L_post
    (the *2 and /alpha cancel into MACs / multipliers; kept explicit to mirror
    the paper's formula).  For depthwise conv the MAC count has no C_o factor.
    """
    if n_dsp_core <= 0:
        return float("inf")
    ops = 2.0 * layer.macs                      # MAC -> 2 ops, as in Eq.11
    return ops / (alpha * n_dsp_core) + board.l_post
