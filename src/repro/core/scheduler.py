"""Dual-core scheduling (paper §V-A, Fig.4, Alg.1).

Pipeline:
  1. *Allocation* — assign each layer to c-core or p-core
     (layer-type / greedy / round-robin, §V-A1).
  2. *Partitioning* — merge consecutive same-core layers into groups; groups
     then alternate cores in topological order (Fig.4a).
  3. *Interleaving* — two input images run the group chain offset by one slot,
     so stream-A group k overlaps stream-B group k-1 on the other core
     (Fig.4b).  Objective: two-batch latency T_b2 (Eq.9).
  4. *Load balancing* — Alg.1: repeatedly split the tail layer of the group
     with the largest neighbour gap along the ifm height (with a T_kh-1 halo)
     and reassign the remainder to the other core (Fig.4c).
"""
from __future__ import annotations

import dataclasses

from repro.core.arch import BoardModel, DualCoreConfig
from repro.core.graph import LayerGraph, LayerSpec
from repro.core.latency import layer_latency

ALLOCATION_SCHEMES = ("layer_type", "greedy", "round_robin")


@dataclasses.dataclass
class Group:
    core: str                     # 'c' | 'p'
    layers: list[LayerSpec]

    def latency(self, cfg: DualCoreConfig, board: BoardModel) -> int:
        core = cfg.core(self.core)
        return sum(layer_latency(l, core, board).t_layer for l in self.layers)


@dataclasses.dataclass
class Schedule:
    """Alternating-core group chain + cached per-group latencies."""

    groups: list[Group]
    cfg: DualCoreConfig
    board: BoardModel
    scheme: str = "custom"

    def __post_init__(self):
        self._lat = [g.latency(self.cfg, self.board) for g in self.groups]

    @property
    def group_latencies(self) -> list[int]:
        return list(self._lat)

    def refresh(self, idx: int | None = None):
        if idx is None:
            self._lat = [g.latency(self.cfg, self.board)
                         for g in self.groups]
        else:
            self._lat[idx] = self.groups[idx].latency(self.cfg, self.board)

    def t_b2_eq9(self) -> int:
        """Eq.9 exactly as printed: sum |T_gi - T_gi+1| + T_g1 + T_gN.

        NOTE (recorded deviation, DESIGN.md §7): as printed this is NOT a
        valid two-batch latency — for N equal groups it gives 2T independent
        of N, and optimizing it drives fps above the physical MAC peak.  The
        paper describes T_b2 as "the sum of the maximal latency between any
        parallel groups", i.e. the staggered-trace makespan of Fig.4b, which
        its own throughput numbers are consistent with.  We therefore use
        ``t_b2`` (the exact makespan) as the objective and keep this printed
        form for reference only."""
        t = self._lat
        if not t:
            return 0
        n = len(t)
        return (sum(abs(t[i] - t[i + 1]) for i in range(n - 1))
                + t[0] + t[-1])

    def t_b2(self) -> int:
        """Two-batch latency: exact makespan of the Fig.4b trace.  Slot k
        runs stream-A group k and stream-B group k-1 in parallel (different
        cores by construction), with a barrier between slots:
        T_b2 = T_g1 + sum_{k=2..N} max(T_gk, T_gk-1) + T_gN."""
        t = self._lat
        if not t:
            return 0
        total = t[0]
        for i in range(1, len(t)):
            total += max(t[i], t[i - 1])
        total += t[-1]
        return total

    def throughput_fps(self, images: int = 2) -> float:
        """Average throughput of the interleaved two-image run (§VI-A b)."""
        cyc = self.t_b2()
        if cyc <= 0:
            return float("inf")
        return images * self.board.freq_mhz * 1e6 / cyc

    def runtime_pe_efficiency(self) -> float:
        """Eq.1 over the whole dual-core run: MACs of both images over
        (total multipliers of both cores) x makespan."""
        macs = 2 * sum(l.macs for g in self.groups for l in g.layers)
        peak = self.cfg.c.n_mult + self.cfg.p.n_mult
        span = self.t_b2()
        return macs / (peak * span) if span else 0.0

    def validate_alternating(self) -> bool:
        return all(a.core != b.core
                   for a, b in zip(self.groups, self.groups[1:]))


# --------------------------------------------------------------------------
# 1+2: allocation + partitioning
# --------------------------------------------------------------------------
def allocate(graph: LayerGraph, cfg: DualCoreConfig, board: BoardModel,
             scheme: str) -> list[str]:
    layers = graph.topological_order()
    if scheme == "layer_type":
        # Regular conv -> c-core, depthwise -> p-core (§V-A1).
        return ["p" if l.op == "dwconv" else "c" for l in layers]
    if scheme == "greedy":
        out = []
        for l in layers:
            tc = layer_latency(l, cfg.c, board).t_layer
            tp = layer_latency(l, cfg.p, board).t_layer
            out.append("c" if tc <= tp else "p")
        return out
    if scheme == "round_robin":
        return ["c" if i % 2 == 0 else "p" for i in range(len(layers))]
    raise ValueError(f"unknown allocation scheme {scheme!r}")


def partition(graph: LayerGraph, assignment: list[str]) -> list[Group]:
    """Merge consecutive same-core layers into groups (§V-A1)."""
    layers = graph.topological_order()
    groups: list[Group] = []
    for layer, core in zip(layers, assignment):
        if groups and groups[-1].core == core:
            groups[-1].layers.append(layer)
        else:
            groups.append(Group(core=core, layers=[layer]))
    return groups


def build_schedule(graph: LayerGraph, cfg: DualCoreConfig, board: BoardModel,
                   scheme: str) -> Schedule:
    """One schedule under a named scheme: the paper's three allocation
    schemes, or the beyond-paper ``"balanced"`` pack-to-target partitioner
    (see ``balanced_partition``)."""
    if scheme == "balanced":
        groups = balanced_partition(graph, cfg, board)
    else:
        groups = partition(graph, allocate(graph, cfg, board, scheme))
    return Schedule(groups=groups, cfg=cfg, board=board, scheme=scheme)


# --------------------------------------------------------------------------
# 4: Alg.1 — load-balance-heuristic layer splitting
# --------------------------------------------------------------------------
def _split_candidates(layer: LayerSpec) -> range:
    # h in [1, H-1]; sample at most ~64 heights for tractability on tall maps.
    step = max(1, layer.H // 64)
    return range(1, layer.H, step)


def load_balance(schedule: Schedule, max_rounds: int = 64) -> Schedule:
    """Alg.1.  Split the tail layer of the longer group of the worst
    neighbouring pair along ifm height; the remainder (with a T_kh-1 halo)
    moves to the front of the following group on the other core.  Repeat
    while T_b2 improves."""
    sched = Schedule(groups=[Group(g.core, list(g.layers))
                             for g in schedule.groups],
                     cfg=schedule.cfg, board=schedule.board,
                     scheme=schedule.scheme + "+lb")
    best = sched.t_b2()
    for _ in range(max_rounds):
        t = sched.group_latencies
        if len(t) < 2:
            break
        # Neighbour pairs by gap, largest first; try until one improves.
        pairs = sorted(range(len(t) - 1),
                       key=lambda i: -abs(t[i] - t[i + 1]))
        improved = False
        for pi in pairs:
            gp, gq = ((pi, pi + 1) if t[pi] > t[pi + 1] else (pi + 1, pi))
            if t[gp] == t[gq]:
                continue
            found = _try_split(sched, longer=gp, shorter=gq, best=best)
            if found is not None and found < best:
                best = found
                improved = True
                break
        if not improved:
            break
    return sched


def _try_split(sched: Schedule, longer: int, shorter: int,
               best: int) -> int | None:
    """Attempt the Alg.1 split of the boundary layer between groups
    ``longer`` and ``shorter``; commit the best height if it improves T_b2."""
    groups = sched.groups
    gl = groups[longer]
    if not gl.layers:
        return None
    tail_side = longer < shorter          # paper case: longer precedes shorter
    layer = gl.layers[-1] if tail_side else gl.layers[0]
    if layer.H < 2:
        return None
    tkh = layer_latency(layer, sched.cfg.core(gl.core),
                        sched.board).tiling.T_kh
    best_h, best_val = None, best
    for h in _split_candidates(layer):
        h_rest = layer.H - h + tkh - 1    # halo: h' = H - h + T_kh - 1
        if h_rest < 1 or h_rest >= layer.H:
            continue
        val = _eval_split(sched, longer, shorter, layer, h, h_rest, tail_side)
        if val < best_val:
            best_val, best_h = val, h
    if best_h is None:
        return None
    _commit_split(sched, longer, shorter, layer, best_h,
                  layer.H - best_h + tkh - 1, tail_side)
    return best_val


def _eval_split(sched, longer, shorter, layer, h, h_rest, tail_side) -> int:
    """Makespan if the boundary layer of ``longer`` keeps height h and the
    remainder (h_rest, incl. the T_kh-1 halo) moves to ``shorter``."""
    keep = layer.with_height(h, ".a")
    move = layer.with_height(h_rest, ".b")
    t = sched.group_latencies
    cl = sched.cfg.core(sched.groups[longer].core)
    cs = sched.cfg.core(sched.groups[shorter].core)
    b = sched.board
    dl = (layer_latency(keep, cl, b).t_layer
          - layer_latency(layer, cl, b).t_layer)
    ds = layer_latency(move, cs, b).t_layer
    t2 = list(t)
    t2[longer] += dl
    t2[shorter] += ds
    return t2[0] + sum(max(t2[i], t2[i - 1])
                       for i in range(1, len(t2))) + t2[-1]


def _commit_split(sched, longer, shorter, layer, h, h_rest, tail_side):
    gl, gs = sched.groups[longer], sched.groups[shorter]
    keep = layer.with_height(h, ".a")
    move = layer.with_height(h_rest, ".b")
    if tail_side:                          # longer precedes shorter
        gl.layers[-1] = keep
        gs.layers.insert(0, move)          # g_q.push_front (Alg.1)
    else:                                  # longer follows shorter
        gl.layers[0] = keep
        gs.layers.append(move)
    sched.refresh(longer)
    sched.refresh(shorter)


# --------------------------------------------------------------------------
# Allocation-aware partitioning (§V-A1): the paper forms groups so that the
# variance of parallel-group latency ratios is small.  We realise that as a
# pack-to-target partitioner: binary-search a slot time tau and greedily cut
# the topological order into alternating-core groups of latency <= tau
# (trying both starting cores), keeping the best makespan.
# --------------------------------------------------------------------------
def balanced_partition(graph: LayerGraph, cfg: DualCoreConfig,
                       board: BoardModel) -> list[Group]:
    layers = graph.topological_order()
    lat = {("c", l.name): layer_latency(l, cfg.c, board).t_layer
           for l in layers}
    lat.update({("p", l.name): layer_latency(l, cfg.p, board).t_layer
                for l in layers})

    def pack(tau: float, start: str) -> list[Group] | None:
        groups: list[Group] = []
        core = start
        cur: list[LayerSpec] = []
        cur_lat = 0
        for l in layers:
            t = lat[(core, l.name)]
            if cur and cur_lat + t > tau:
                groups.append(Group(core, cur))
                core = "p" if core == "c" else "c"
                cur, cur_lat = [], 0
                t = lat[(core, l.name)]
            cur.append(l)
            cur_lat += t
        if cur:
            groups.append(Group(core, cur))
        return groups

    total_c = sum(lat[("c", l.name)] for l in layers)
    best_groups, best_span = None, None
    for start in ("c", "p"):
        # geometric tau decay from the total work toward the largest layer:
        # each probe halves the gap to lo (more, smaller groups every
        # step); keep the best makespan seen across all probes
        lo, hi = max(lat.values()) * 0.5, float(total_c)
        for _ in range(18):
            tau = 0.5 * (lo + hi)
            groups = pack(tau, start)
            s = Schedule(groups, cfg, board, scheme="balanced")
            span = s.t_b2()
            if best_span is None or span < best_span:
                best_span, best_groups = span, groups
            hi = tau
        # coarse sweep of tau around work/slots as a second probe
        for k in range(2, min(2 * len(layers), 64)):
            tau = total_c / k
            groups = pack(tau, start)
            s = Schedule(groups, cfg, board, scheme="balanced")
            span = s.t_b2()
            if span < best_span:
                best_span, best_groups = span, groups
    assert best_groups is not None
    return best_groups


# --------------------------------------------------------------------------
# Entry point.
#   paper_faithful=True  -> exactly the paper's flow: the three allocation
#       schemes, each optionally refined by Alg.1 (Table V columns).
#   paper_faithful=False -> additionally tries our beyond-paper balanced
#       partitioner (pack-to-target, §V-A1 variance objective solved
#       directly); reported separately in EXPERIMENTS.md.
# --------------------------------------------------------------------------
def best_schedule(graph: LayerGraph, cfg: DualCoreConfig, board: BoardModel,
                  with_load_balance: bool = True,
                  paper_faithful: bool = False) -> Schedule:
    cands: list[Schedule] = []
    for scheme in ALLOCATION_SCHEMES:
        s = build_schedule(graph, cfg, board, scheme)
        cands.append(s)
        if with_load_balance:
            cands.append(load_balance(s))
    if not paper_faithful:
        bal = Schedule(balanced_partition(graph, cfg, board), cfg, board,
                       scheme="balanced")
        cands.append(bal)
        if with_load_balance:
            cands.append(load_balance(bal))
    return min(cands, key=lambda s: s.t_b2())
