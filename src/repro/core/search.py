"""Co-optimization of PE allocation and scheduling (paper §V-B).

Branch-and-bound over the c-core DSP ratio theta (Eq.10), with the Eq.11
compute lower bound, followed by an exhaustive local search over
(n_c, v_c, n_p, v_p) with v in V_CANDIDATES, all under the ResourceBudget
constraints (Table II).

The objective is pluggable:
  * single CNN  -> minimize two-batch latency T_b2 (maximize fps),
  * multi-CNN   -> maximize the harmonic mean of per-model fps (Table VII),
  * fleet mix   -> maximize the *weighted* harmonic mean under a
    {model: qps share} traffic mix (``weights=``) — the steady-state
    aggregate fps of time-multiplexing the networks in those proportions
    (``repro.fleet.planner`` drives this).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.core.arch import (ALPHA, V_CANDIDATES, BoardModel, CoreConfig,
                             DualCoreConfig, ResourceBudget)
from repro.core.area import dual_core_area
from repro.core.graph import LayerGraph
from repro.core.latency import compute_lower_bound, load_cycles
from repro.core.scheduler import ALLOCATION_SCHEMES, best_schedule


@dataclasses.dataclass
class SearchResult:
    config: DualCoreConfig
    theta: float
    fps: dict[str, float]             # per-model throughput
    objective: float                  # harmonic-mean fps (higher is better)
    schedules: dict[str, object]
    visited_thetas: list[float]


def harmonic_mean(xs: Sequence[float],
                  weights: Sequence[float] | None = None) -> float:
    """Harmonic mean of ``xs``; with ``weights`` the weighted form
    ``sum(w) / sum(w/x)``.  For per-model fps under a traffic mix this IS
    the aggregate fps of serving the models in those proportions — model m
    takes ``w_m / fps_m`` of each unit of mixed work."""
    xs = list(xs)
    if not xs or any(x <= 0 for x in xs):
        return 0.0
    if weights is None:
        return len(xs) / sum(1.0 / x for x in xs)
    if len(weights) != len(xs):
        raise ValueError(f"{len(xs)} values but {len(weights)} weights")
    if any(w < 0 for w in weights) or not sum(weights) > 0:
        raise ValueError(f"weights must be >= 0 with a positive sum "
                         f"(got {list(weights)})")
    return sum(weights) / sum(w / x for w, x in zip(weights, xs))


# --------------------------------------------------------------------------
# Lower bound at a given theta (Eq.11)
# --------------------------------------------------------------------------
def t_b2_lower_bound(graph: LayerGraph, theta: float, dsp_budget: int,
                     board: BoardModel) -> float:
    """Lower bound of T_b2 at DSP split theta: every layer runs at the ideal
    MAC rate of its (best-case) core, still bounded below by its load time.

    The bound relaxes tiling mismatch (Eq.11) and group structure: the best
    possible T_b2 is 2x the larger of the two per-core workload sums when
    perfectly balanced, >= sum over layers of per-layer lower bounds spread
    over both cores.  We use the paper's per-sch bound: evaluate Eq.9 with
    T_compute replaced by Eq.11 under each allocation scheme and take the
    minimum — a valid lower bound for the schedules the flow can emit."""
    dsp_c = theta * dsp_budget
    dsp_p = (1.0 - theta) * dsp_budget
    best = math.inf
    layers = graph.topological_order()
    for scheme in ALLOCATION_SCHEMES:
        if scheme == "layer_type":
            assign = ["p" if l.op == "dwconv" else "c" for l in layers]
        elif scheme == "round_robin":
            assign = ["c" if i % 2 == 0 else "p" for i in range(len(layers))]
        else:  # greedy on the lower bounds themselves
            assign = []
            for l in layers:
                tc = max(compute_lower_bound(l, dsp_c, board),
                         load_cycles(l, board))
                tp = max(compute_lower_bound(l, dsp_p, board),
                         load_cycles(l, board))
                assign.append("c" if tc <= tp else "p")
        # group merge + Eq.9 on lower-bound latencies
        t: list[float] = []
        cur_core = None
        for l, a in zip(layers, assign):
            dsp = dsp_c if a == "c" else dsp_p
            lat = max(compute_lower_bound(l, dsp, board),
                      load_cycles(l, board))
            if a == cur_core:
                t[-1] += lat
            else:
                t.append(lat)
                cur_core = a
        if not t:
            continue
        tb2 = t[0] + sum(max(t[i], t[i - 1])
                         for i in range(1, len(t))) + t[-1]
        best = min(best, tb2)
    return best


def objective_lower_bound(graphs: Sequence[LayerGraph], theta: float,
                          dsp_budget: int, board: BoardModel,
                          weights: Sequence[float] | None = None) -> float:
    """Upper bound on achievable (weighted-)harmonic-mean fps at this theta
    (from the T_b2 lower bounds) — valid for pruning because the weighted
    harmonic mean is monotone in every per-model fps."""
    fps = []
    for g in graphs:
        lb = t_b2_lower_bound(g, theta, dsp_budget, board)
        fps.append(2 * board.freq_mhz * 1e6 / lb if lb > 0 else math.inf)
    return harmonic_mean(fps, weights)


# --------------------------------------------------------------------------
# Local search: (n_c, v_c, n_p, v_p) at a fixed theta
# --------------------------------------------------------------------------
def configs_at_theta(theta: float, budget: ResourceBudget,
                     slack: float = 0.08) -> list[DualCoreConfig]:
    """Enumerate (n_c,v_c,n_p,v_p) whose DSP split is within ``slack`` of
    theta and which fit the full resource budget."""
    out = []
    dsp_budget = budget.n_dsp
    for v_c in V_CANDIDATES:
        n_c = int(theta * ALPHA * dsp_budget / v_c)
        n_c -= n_c % 2                      # PE pairs share DSP macros
        if n_c < 2:
            continue
        dsp_c = (n_c // 2) * v_c
        for v_p in V_CANDIDATES:
            n_p = int((dsp_budget - dsp_c - 1) * ALPHA / v_p)
            n_p -= n_p % 2
            if n_p < 2:
                continue
            cfg = DualCoreConfig(CoreConfig("c", n_c, v_c),
                                 CoreConfig("p", n_p, v_p))
            area = dual_core_area(cfg)
            if not budget.fits(area.dsp, area.bram18k, area.lut, area.ff):
                # back off p-core size until it fits (greedy allocation of
                # leftover resources, §V-B2)
                while n_p > 2:
                    n_p -= 2
                    cfg = DualCoreConfig(CoreConfig("c", n_c, v_c),
                                         CoreConfig("p", n_p, v_p))
                    area = dual_core_area(cfg)
                    if budget.fits(area.dsp, area.bram18k, area.lut, area.ff):
                        break
                else:
                    continue
                if not budget.fits(area.dsp, area.bram18k,
                                   area.lut, area.ff):
                    continue
            if abs(cfg.theta(dsp_budget) - theta) <= slack:
                out.append(cfg)
    return out


def evaluate_config(cfg: DualCoreConfig, graphs: Sequence[LayerGraph],
                    board: BoardModel,
                    with_load_balance: bool = True,
                    weights: Sequence[float] | None = None):
    fps, scheds = {}, {}
    for g in graphs:
        s = best_schedule(g, cfg, board, with_load_balance=with_load_balance)
        scheds[g.name] = s
        fps[g.name] = s.throughput_fps()
    return harmonic_mean(list(fps.values()), weights), fps, scheds


# --------------------------------------------------------------------------
# Branch-and-bound over theta (§V-B2)
# --------------------------------------------------------------------------
def search(graphs: Sequence[LayerGraph], board: BoardModel,
           budget: ResourceBudget | None = None,
           theta0: float = 0.5, min_interval: float = 0.04,
           max_evals: int = 24,
           with_load_balance: bool = True,
           weights: Sequence[float] | None = None) -> SearchResult:
    """Branch on theta starting at 0.5, bound with Eq.11, then local-search
    (n,v) pairs at promising thetas.  Early termination when an interval's
    bound cannot beat the incumbent (paper §V-B2).  ``weights`` (aligned
    with ``graphs``) switches the objective to the weighted harmonic mean —
    the fleet planner's aggregate-fps-under-a-traffic-mix objective."""
    budget = budget or ResourceBudget()
    incumbent: tuple[float, DualCoreConfig, dict, dict] | None = None
    visited: list[float] = []
    evals = 0

    def consider(theta: float):
        nonlocal incumbent, evals
        visited.append(theta)
        for cfg in configs_at_theta(theta, budget):
            if evals >= max_evals * 4:
                return
            evals += 1
            obj, fps, scheds = evaluate_config(cfg, graphs, board,
                                               with_load_balance, weights)
            if incumbent is None or obj > incumbent[0]:
                incumbent = (obj, cfg, fps, scheds)

    # Interval worklist: (lo, hi).  Evaluate midpoint, prune by bound.
    work = [(0.05, 0.95)]
    consider(theta0)
    while work and len(visited) < max_evals:
        lo, hi = work.pop(0)
        if hi - lo < min_interval:
            continue
        mid = 0.5 * (lo + hi)
        ub = objective_lower_bound(graphs, mid, budget.n_dsp, board, weights)
        # ub is the *best possible* fps at mid; prune if it can't beat
        # the incumbent (early termination).
        if incumbent is not None and ub <= incumbent[0]:
            continue
        consider(mid)
        work.append((lo, mid))
        work.append((mid, hi))

    if incumbent is None:
        raise RuntimeError("search found no feasible configuration")
    obj, cfg, fps, scheds = incumbent
    return SearchResult(config=cfg, theta=cfg.theta(budget.n_dsp),
                        fps=fps, objective=obj, schedules=scheds,
                        visited_thetas=visited)
