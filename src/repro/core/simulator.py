"""Cycle-accurate instruction-level simulator (paper §VI-A d, Table IV).

Executes the compiled instruction streams on a machine model with, per core:
a DRAM load engine and a compute+PP engine connected by ping-pong buffers.
A COMPUTE on bank b may start once the LOAD into bank b has finished and the
previous COMPUTE has drained; a LOAD into bank b may start once the COMPUTE
that last read bank b has finished (double-buffer hazard).  This reproduces
Eq.7's max(T_load, T_compute) overlap plus true fill/drain effects.

For the dual-core interleaved schedule, two streams advance through the group
chain offset by one slot (Fig.4b); a SYNC barrier at every slot boundary
models the data hand-off between cores.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.core.arch import BoardModel, CoreConfig
from repro.core.graph import LayerGraph
from repro.core.isa import Instr, compile_group, compile_schedule
from repro.core.scheduler import Schedule


@dataclasses.dataclass
class SimTrace:
    cycles: int
    instr_count: int
    busy_cycles: dict[str, int]          # per engine
    per_layer: dict[str, int]

    def pe_efficiency(self, macs: int, n_mult: int) -> float:
        return macs / (n_mult * self.cycles) if self.cycles else 0.0


def run_stream(instrs: Sequence[Instr], board: BoardModel,
               start_cycle: int = 0) -> SimTrace:
    """Simulate one core executing one instruction stream."""
    load_free = start_cycle       # when the load engine is next available
    comp_free = start_cycle
    bank_loaded = [start_cycle, start_cycle]   # LOAD completion per bank
    bank_released = [start_cycle, start_cycle]  # last COMPUTE read done
    busy = {"load": 0, "compute": 0}
    per_layer: dict[str, int] = {}
    t_end = start_cycle
    layer_start: dict[str, int] = {}
    for ins in instrs:
        if ins.op == "LOAD":
            begin = max(load_free, bank_released[ins.bank])
            end = begin + ins.cycles
            load_free = end
            bank_loaded[ins.bank] = end
            busy["load"] += ins.cycles
        elif ins.op == "COMPUTE":
            begin = max(comp_free, bank_loaded[ins.bank])
            end = begin + ins.cycles
            comp_free = end
            bank_released[ins.bank] = end
            busy["compute"] += ins.cycles
        elif ins.op == "STORE":
            begin = comp_free
            end = begin + ins.cycles
            comp_free = end
            busy["compute"] += ins.cycles
        else:  # SYNC handled by the dual-core driver
            continue
        t_end = max(t_end, end)
        layer_start.setdefault(ins.layer, begin)
        per_layer[ins.layer] = end - layer_start[ins.layer]
    return SimTrace(cycles=t_end - start_cycle, instr_count=len(instrs),
                    busy_cycles=busy, per_layer=per_layer)


def simulate_single_core(graph: LayerGraph, core: CoreConfig,
                         board: BoardModel) -> SimTrace:
    """One image through one core, layers in topological order (the P(128,9)
    baseline of Tables IV/VI)."""
    instrs = compile_group(graph.topological_order(), core, board)
    return run_stream(instrs, board)


@dataclasses.dataclass
class DualSimResult:
    cycles_two_images: int
    slot_latencies: list[int]
    fps: float
    pe_efficiency: float


def simulate_dual_core(schedule: Schedule) -> DualSimResult:
    """Two interleaved images through the dual-core schedule (Fig.4b).

    Slot k runs stream-A group k and stream-B group k-1 concurrently on
    different cores, with a barrier between slots (the hand-off of feature
    maps between cores goes through DRAM, which the per-group instruction
    streams already charge).  Optionally halves effective DRAM bandwidth
    while both cores are active (board.dram_contention).
    """
    board = schedule.board
    group_instrs = compile_schedule(schedule)
    n = len(group_instrs)
    slot_lat: list[int] = []
    contention = 1.3 if board.dram_contention else 1.0
    for k in range(n + 1):
        a = run_stream(group_instrs[k], board).cycles if k < n else 0
        b = run_stream(group_instrs[k - 1], board).cycles if k >= 1 else 0
        both = a > 0 and b > 0
        lat = max(a, b)
        if both and board.dram_contention:
            lat = int(lat * contention)
        slot_lat.append(lat)
    total = sum(slot_lat)
    macs = 2 * sum(l.macs for g in schedule.groups for l in g.layers)
    peak = schedule.cfg.c.n_mult + schedule.cfg.p.n_mult
    return DualSimResult(
        cycles_two_images=total,
        slot_latencies=slot_lat,
        fps=2 * board.freq_mhz * 1e6 / total if total else float("inf"),
        pe_efficiency=macs / (peak * total) if total else 0.0)
