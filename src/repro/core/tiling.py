"""Tile sizing (paper §IV-A, Eq.2-4).

Given a PE configuration ``(n, v)`` and a layer, pick
``(T_kh, T_kw, T_ci, T_co, T_h, T_w)`` such that

  Eq.2:  T_kh * T_kw * T_ci * T_co = n * v,   T_kh * T_kw * T_ci = i * v
  Eq.3:  i minimizes ceil(C_o/T_co) * ceil(C_i*K_h*K_w / (T_ci*T_kh*T_kw))
  Eq.4:  (T_h, T_w) maximize buffer utilisation
         H*W / (ceil(H/T_h) * ceil(W/T_w) * T_h * T_w)
         (the paper prints argmin of the inverse ratio; the intent — minimise
          padded pixels — is an argmax of utilisation, which we implement)

Core-type rules (paper §III-B):
  * c-core has no line buffer  ->  T_kh = T_kw = 1 always.
  * p-core may set T_kh, T_kw > 1; the line buffer expands the ifm by
    T_kh x T_kw before broadcast.  Channels packed per PE is
    floor(v / (T_kh*T_kw)) (the paper prints ceil; floor is the physically
    realisable packing and is what we use — a PE cannot multiply more than v
    operands per cycle).
  * depthwise conv has no cross-channel reduction: on p-core each PE owns one
    channel and reduces over the window; on c-core (no line buffer) only one
    multiplier per PE does useful work (this is the paper's motivation for the
    heterogeneous design, §II).
"""
from __future__ import annotations

import dataclasses
import math

from repro.core.arch import CoreConfig
from repro.core.graph import LayerSpec

# Upper bound on ifm buffer depth (T_h * T_w); matches the RAMB18K-backed
# buffer depths the area model can realise (paper §IV-C uses up to 1x16k).
MAX_BUFFER_DEPTH = 4096


@dataclasses.dataclass(frozen=True)
class Tiling:
    T_kh: int
    T_kw: int
    T_ci: int
    T_co: int
    T_h: int
    T_w: int
    i: int          # PEs ganged per output (Eq.2)
    # im2col fold (OPU [14] first-layer reshaping): the whole C_i*K_h*K_w
    # reduction is laid out as one inner-product input via on-chip buffer
    # addressing; the PE array then streams *output* pixels.  Used when the
    # channel count is too small to fill the array (e.g. 3-channel conv1).
    fold: bool = False

    @property
    def reduction(self) -> int:
        return self.T_kh * self.T_kw * self.T_ci

    def passes(self, layer: LayerSpec) -> int:
        """Number of (output-tile x reduction-tile) passes (Eq.3 / Eq.6)."""
        if self.fold:
            red = layer.C_i * layer.K_h * layer.K_w
            return (math.ceil(layer.C_o / self.T_co)
                    * math.ceil(red / self.reduction))
        return (math.ceil(layer.C_o / self.T_co)
                * math.ceil(layer.C_i / self.T_ci)
                * math.ceil(layer.K_h / self.T_kh)
                * math.ceil(layer.K_w / self.T_kw))

    def spatial_cycles(self, layer: LayerSpec) -> int:
        """Padded pixel count streamed per pass (Eq.4's block structure):
        ceil(H/T_h)*ceil(W/T_w) blocks, T_h*T_w pixels each, one pixel/cycle.
        Folded layers stream output pixels (im2col buffer addressing)."""
        H = layer.H_out if self.fold else layer.H
        W = layer.W_out if self.fold else layer.W
        th, tw = min(self.T_h, H), min(self.T_w, W)
        return math.ceil(H / th) * math.ceil(W / tw) * th * tw

    def utilization(self, core: CoreConfig) -> float:
        """Static PE-array utilisation: live multipliers / (n*v)."""
        return (self.T_kh * self.T_kw * self.T_ci * self.T_co) / core.n_mult


def _spatial_tiles(H: int, W: int, width: int,
                   max_depth: int = MAX_BUFFER_DEPTH) -> tuple[int, int]:
    """Eq.4: pick (T_h, T_w) maximising H*W / (ceil*ceil*T_h*T_w), subject to
    the ifm buffer capacity T_h*T_w <= max_depth."""
    best = None
    best_util = -1.0
    # Candidate tile heights: exact fit if possible, else divisors-ish sweep.
    cand_h = sorted({min(H, max_depth), *range(1, min(H, 256) + 1)})
    for th in cand_h:
        tw = min(W, max(1, max_depth // th))
        if th * tw > max_depth:
            continue
        padded = math.ceil(H / th) * math.ceil(W / tw) * th * tw
        util = (H * W) / padded
        if util > best_util + 1e-12:
            best_util, best = util, (th, tw)
    assert best is not None
    return best


def tile_layer(layer: LayerSpec, core: CoreConfig) -> Tiling:
    """Choose the tiling of ``layer`` on ``core`` (Eq.2-4)."""
    n, v = core.n, core.v
    T_h, T_w = _spatial_tiles(layer.H, layer.W, width=1,
                              max_depth=core.buffer_depth)

    if layer.op == "dwconv":
        return _tile_depthwise(layer, core, T_h, T_w)

    # Regular / pointwise convolution (and fc == 1x1 conv on 1x1 map).
    best: Tiling | None = None
    best_key: tuple | None = None
    window_opts = [(1, 1)]
    if core.has_line_buffer and (layer.K_h > 1 or layer.K_w > 1):
        for tkh in range(1, layer.K_h + 1):
            for tkw in range(1, layer.K_w + 1):
                if tkh * tkw <= v:
                    window_opts.append((tkh, tkw))
    for tkh, tkw in window_opts:
        ch_per_pe = max(1, v // (tkh * tkw))
        i_max = max(1, math.ceil(layer.C_i / ch_per_pe))
        for i in range(1, min(i_max, n) + 1):
            t_ci = min(i * ch_per_pe, layer.C_i)
            t_co = n // i
            if t_co < 1:
                break
            t_co = min(t_co, layer.C_o)
            t = Tiling(tkh, tkw, t_ci, t_co, T_h, T_w, i)
            # Rank by total compute passes (Eq.3), tie-break on fewer live
            # multipliers == lower resource cost (paper §IV-A last sentence).
            key = (t.passes(layer), -t.utilization(core))
            if best_key is None or key < best_key:
                best, best_key = t, key
    # im2col fold candidates (OPU [14] reshaping): the whole C_i*K_h*K_w
    # reduction is addressed as one inner-product input and the layer
    # streams output pixels.  Only the c-core uses this mode — it has no
    # line buffer, so K>1 windows are realised through ifm-buffer
    # addressing; the p-core's line buffer physically streams input pixels.
    red = layer.C_i * layer.K_h * layer.K_w
    if (not core.has_line_buffer and layer.K_h * layer.K_w > 1
            and layer.C_i <= v and red <= n * v):
        i = max(1, math.ceil(red / v))
        t_co = n // i
        if t_co >= 1:
            t = Tiling(layer.K_h, layer.K_w, layer.C_i,
                       min(t_co, layer.C_o), T_h, T_w, i, fold=True)
            # Compare on total cycles (passes x pixels): fold changes the
            # pixel term (output- vs input-pixel streaming), so the Eq.3
            # pass count alone cannot rank it.
            tot_fold = t.passes(layer) * t.spatial_cycles(layer)
            tot_best = best.passes(layer) * best.spatial_cycles(layer)
            if tot_fold < tot_best:
                best = t
    assert best is not None
    return best


def _tile_depthwise(layer: LayerSpec, core: CoreConfig,
                    T_h: int, T_w: int) -> Tiling:
    if core.has_line_buffer:
        # Window packed inside one PE (T_kh*T_kw <= v), one channel per PE.
        tkh = min(layer.K_h, core.v)
        tkw = max(1, min(layer.K_w, core.v // tkh))
        t_c = min(core.n, layer.C_i)
        return Tiling(tkh, tkw, 1, t_c, T_h, T_w, i=1)
    # c-core: no line buffer -> single-tap reduction; one useful multiplier
    # per PE.  This is the degenerate case motivating the dual-core design.
    t_c = min(core.n, layer.C_i)
    return Tiling(1, 1, 1, t_c, T_h, T_w, i=1)


def dw_channel_tiles(layer: LayerSpec, core: CoreConfig, t: Tiling) -> int:
    """Channel tiles for depthwise conv: each PE owns one channel."""
    return math.ceil(layer.C_i / t.T_co)
