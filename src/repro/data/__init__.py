from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM

__all__ = ["DataConfig", "Prefetcher", "SyntheticLM"]
