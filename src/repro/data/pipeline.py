"""Synthetic-token data pipeline: deterministic, host-sharded, prefetched.

Production shape without external deps: each host materialises only its
shard of the global batch (``host_id``/``num_hosts``), batches are a pure
function of (seed, step) so a restarted/elastic job regenerates identical
data, and a background thread keeps a prefetch queue ahead of the step
loop (overlaps host data work with device compute).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    # Zipf-ish marginal so the loss curve is non-trivial (pure uniform
    # tokens give a flat, uninformative loss).
    zipf_a: float = 1.2


class SyntheticLM:
    """Deterministic synthetic LM stream: repeated structured n-gram
    patterns so a model can actually reduce loss."""

    def __init__(self, cfg: DataConfig, host_id: int = 0,
                 num_hosts: int = 1):
        assert cfg.global_batch % num_hosts == 0
        self.cfg = cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.host_batch = cfg.global_batch // num_hosts

    def batch_at(self, step: int) -> dict:
        """Batch for ``step`` (pure function of (seed, step, host))."""
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, self.host_id]))
        base = rng.zipf(cfg.zipf_a, size=(self.host_batch, cfg.seq_len))
        tokens = (base % (cfg.vocab - 2)).astype(np.int32) + 1
        # plant learnable structure: token[t+1] = f(token[t]) on half the
        # positions
        shifted = (tokens * 31 + 7) % (cfg.vocab - 2) + 1
        mask = rng.random((self.host_batch, cfg.seq_len)) < 0.5
        tokens[:, 1:] = np.where(mask[:, 1:], shifted[:, :-1],
                                 tokens[:, 1:])
        labels = np.concatenate([tokens[:, 1:],
                                 np.zeros((self.host_batch, 1), np.int32)],
                                axis=1)
        return {"tokens": tokens, "labels": labels}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch queue over any step-indexed source."""

    def __init__(self, source: SyntheticLM, start_step: int = 0,
                 depth: int = 2):
        self.source = source
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def _run(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self.q.put((step, self.source.batch_at(step)), timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def next(self) -> tuple[int, dict]:
        return self.q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
