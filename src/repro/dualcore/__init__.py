"""Dual-core CNN execution: step programs + the pipelined c/p-submesh
runtime that turns a scheduler ``Schedule`` into real overlapped execution
(the missing half of the paper's Fig.4b)."""
from repro.dualcore.program import (ACT_OF, Program, Step, build_program,
                                    run_layer)
from repro.dualcore.runtime import (DualCoreRunner, ExecGroup, ExecPlan,
                                    build_exec_plan)

__all__ = [
    "ACT_OF",
    "Program",
    "Step",
    "build_program",
    "run_layer",
    "DualCoreRunner",
    "ExecGroup",
    "ExecPlan",
    "build_exec_plan",
]
