"""Step-program IR: one uniform execution representation of the CNN zoo.

The dual-core runtime needs to execute *parts* of a network on different
submeshes, so the hand-written per-model forward functions are factored into
a flat list of :class:`Step` objects — the program.  Each step covers one or
more graph layers (a fused MobileNet block is one step), reads/writes named
buffers in an environment dict, and knows how to run itself given the
parameter pytree.  ``repro.models.cnn`` runs the whole program in order (the
sequential forward — numerically identical to the pre-refactor code);
``repro.dualcore.runtime`` partitions the same program into alternating
c-/p-core groups from a :class:`~repro.core.scheduler.Schedule` and pipelines
images through them.  Because both paths execute the *same* step objects, the
pipelined outputs are bitwise-equal to the sequential forward by
construction (a test asserts it).

Buffer conventions: the main chain flows through ``"h"``; the final logits
land in ``"out"``; SqueezeNet fire modules use ``"sq"``/``"e1"`` for the
squeeze/expand branches; the MobileNet-v2 per-layer path stashes the block
input in ``"res"`` for the residual add.  ``collect`` dicts receive
activation *shapes* (never values), recorded at trace time, with exactly the
same keys as the pre-refactor forwards.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.fusion import (FusionGroup, _is_pw, _linear_next,
                               plan_fusion)
from repro.core.graph import LayerGraph, LayerSpec
from repro.kernels.conv_gemm.ops import conv2d_gemm
from repro.kernels.conv_gemm.ref import conv2d_ref
from repro.kernels.depthwise.ops import depthwise
from repro.kernels.depthwise.ref import depthwise_conv2d_ref
from repro.kernels.fused_block.ops import (fused_dw_pw,
                                           fused_inverted_residual)
from repro.models.zoo import get_graph

Params = dict[str, dict[str, jax.Array]]
Env = dict[str, jax.Array]


def run_layer(l: LayerSpec, x: jax.Array, p: dict[str, jax.Array],
              act: str | None, use_pallas: bool) -> jax.Array:
    """One graph layer on either execution backend (XLA ref / Pallas)."""
    if l.op == "dwconv":
        if use_pallas:
            return depthwise(x, p["w"], p["b"], stride=l.stride, pad=l.pad,
                             act=act)
        return depthwise_conv2d_ref(x, p["w"], p["b"], stride=l.stride,
                                    pad=l.pad, act=act)
    if use_pallas:
        return conv2d_gemm(x, p["w"], p["b"], stride=l.stride, pad=l.pad,
                           act=act)
    return conv2d_ref(x, p["w"], p["b"], stride=l.stride, pad=l.pad, act=act)


def avgpool_all(x: jax.Array) -> jax.Array:
    return jnp.mean(x, axis=(1, 2), keepdims=True)


def maxpool(x: jax.Array, window: int = 3, stride: int = 2) -> jax.Array:
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


def _pad_pool(x: jax.Array) -> jax.Array:
    """SqueezeNet v1.1 pool: pad bottom/right so 2x-stride covers the map."""
    return maxpool(jnp.pad(x, ((0, 0), (0, 1), (0, 1), (0, 0)),
                           constant_values=-jnp.inf))


def mbv1_act(name: str) -> str | None:
    return None if name == "fc" else "relu6"


def mbv2_act(name: str) -> str | None:
    if name in ("fc",) or name.endswith("_project"):
        return None                 # linear bottleneck / classifier head
    return "relu6"


def sqz_act(name: str) -> str | None:
    return "relu"


ACT_OF: dict[str, Callable[[str], str | None]] = {
    "mobilenet_v1": mbv1_act,
    "mobilenet_v2": mbv2_act,
    "squeezenet": sqz_act,
}


# --------------------------------------------------------------------------
# Step / Program
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Step:
    """One execution unit: reads buffers from the env, writes buffers back.

    ``fn(params, env, collect)`` mutates ``env`` in place; ``collect`` (when
    not None) receives ``name -> shape`` entries at trace time.  ``layers``
    are the graph layers this step computes — the hook the scheduler's
    core-assignment uses.
    """

    name: str
    layers: tuple[str, ...]
    reads: tuple[str, ...]
    writes: tuple[str, ...]
    fn: Callable[[Params, Env, dict | None], None]

    def __repr__(self) -> str:  # keep traces readable
        return f"Step({self.name}, layers={list(self.layers)})"


@dataclasses.dataclass
class Program:
    """Ordered step list + the graph and activation map it was built from."""

    graph: LayerGraph
    steps: list[Step]
    act_of: Callable[[str], str | None]
    use_pallas: bool

    def run(self, params: Params, x: jax.Array,
            collect: dict | None = None) -> jax.Array:
        """Sequential execution — the plain forward pass."""
        env: Env = {"h": x}
        for s in self.steps:
            s.fn(params, env, collect)
        return env["out"]


# --------------------------------------------------------------------------
# step constructors (shared by the builders and the runtime's group fusion)
# --------------------------------------------------------------------------
def layer_step(graph: LayerGraph, name: str,
               act_of: Callable[[str], str | None],
               use_pallas: bool) -> Step:
    """Plain single-layer step on the main chain."""
    l = graph.layer(name)
    act = act_of(name)

    def fn(params, env, collect):
        env["h"] = run_layer(l, env["h"], params[name], act, use_pallas)
        if collect is not None:
            collect[name] = env["h"].shape

    return Step(name=name, layers=(name,), reads=("h",), writes=("h",),
                fn=fn)


def fused_step(graph: LayerGraph, kind: str, names: tuple[str, ...],
               act_of: Callable[[str], str | None]) -> Step:
    """One fused-block pallas_call (dw->pw or pw->dw->pw) as a step."""
    last = names[-1]

    if kind == "dw_pw":
        d, p = (graph.layer(nm) for nm in names)

        def fn(params, env, collect):
            pd, pp = params[d.name], params[p.name]
            env["h"] = fused_dw_pw(env["h"], pd["w"], pd["b"], pp["w"],
                                   pp["b"], stride=d.stride, pad=d.pad,
                                   dw_act=act_of(d.name),
                                   pw_act=act_of(p.name))
            if collect is not None:
                collect[last] = env["h"].shape

    elif kind == "pw_dw_pw":
        e, d, p = (graph.layer(nm) for nm in names)
        with_res = ("add" in p.fused and d.stride == 1 and e.C_i == p.C_o)

        def fn(params, env, collect):
            res = env["h"] if with_res else None
            pe, pd, pp = params[e.name], params[d.name], params[p.name]
            env["h"] = fused_inverted_residual(
                env["h"], pe["w"], pe["b"], pd["w"], pd["b"], pp["w"],
                pp["b"], res, stride=d.stride, pad=d.pad,
                exp_act=act_of(e.name), dw_act=act_of(d.name),
                proj_act=act_of(p.name))
            if collect is not None:
                collect[last] = env["h"].shape

    else:
        raise ValueError(f"unknown fused step kind {kind!r}")

    return Step(name="+".join(names), layers=tuple(names), reads=("h",),
                writes=("h",), fn=fn)


def head_step(graph: LayerGraph, name: str,
              act_of: Callable[[str], str | None], use_pallas: bool,
              avgpool_first: bool) -> Step:
    """Classifier head: optional global avgpool, the fc/conv layer, flatten
    into ``out``."""
    l = graph.layer(name)
    act = act_of(name)

    def fn(params, env, collect):
        h = env["h"]
        if avgpool_first:
            h = avgpool_all(h)
        h = run_layer(l, h, params[name], act, use_pallas)
        if collect is not None:
            collect[name] = h.shape
        env["out"] = h.reshape(h.shape[0], -1)

    return Step(name=name, layers=(name,), reads=("h",), writes=("out",),
                fn=fn)


# --------------------------------------------------------------------------
# model builders
# --------------------------------------------------------------------------
def _fused_chain_steps(graph: LayerGraph,
                       act_of: Callable[[str], str | None]) -> list[Step]:
    """The Pallas fusion-plan path for the (almost) sequential nets: one
    fused_block pallas_call per dw->pw / pw->dw->pw group, singles for the
    rest (mirrors the pre-refactor ``_forward_fused_chain``)."""
    steps: list[Step] = []
    for grp in plan_fusion(graph):
        first = graph.layer(grp.layers[0])
        if grp.kind in ("dw_pw", "pw_dw_pw"):
            steps.append(fused_step(graph, grp.kind, grp.layers, act_of))
        elif first.op == "fc" and "avgpool" in first.fused:
            steps.append(head_step(graph, first.name, act_of,
                                   use_pallas=True, avgpool_first=True))
        else:
            steps.append(layer_step(graph, first.name, act_of,
                                    use_pallas=True))
    return steps


def _mbv1_steps(graph: LayerGraph, use_pallas: bool,
                fuse: bool) -> list[Step]:
    if use_pallas and fuse:
        return _fused_chain_steps(graph, mbv1_act)
    steps = [layer_step(graph, l.name, mbv1_act, use_pallas)
             for l in graph.layers[:-1]]
    steps.append(head_step(graph, "fc", mbv1_act, use_pallas,
                           avgpool_first=True))
    return steps


def _mbv2_layer_step(graph: LayerGraph, name: str,
                     use_pallas: bool) -> Step:
    """MobileNet-v2 per-layer step with the residual stash/add protocol of
    the pre-refactor loop: ``_expand`` records the block input, ``_project``
    adds it back when the graph marks the block residual."""
    l = graph.layer(name)
    act = mbv2_act(name)
    stash = name.endswith("_expand")
    add = name.endswith("_project") and "add" in l.fused

    def fn(params, env, collect):
        h = env["h"]
        if stash:
            env["res"] = h          # block input, for the residual add
        out = run_layer(l, h, params[name], act, use_pallas)
        if add and "res" in env and env["res"].shape == out.shape:
            out = out + env["res"]
        env["h"] = out
        if collect is not None:
            collect[name] = out.shape

    reads = ("h", "res") if add else ("h",)
    writes = ("h", "res") if stash else ("h",)
    return Step(name=name, layers=(name,), reads=reads, writes=writes,
                fn=fn)


def _mbv2_steps(graph: LayerGraph, use_pallas: bool,
                fuse: bool) -> list[Step]:
    if use_pallas and fuse:
        return _fused_chain_steps(graph, mbv2_act)
    steps = [_mbv2_layer_step(graph, l.name, use_pallas)
             for l in graph.layers[:-1]]
    steps.append(head_step(graph, "fc", mbv2_act, use_pallas,
                           avgpool_first=True))
    return steps


def _sqz_fire_steps(graph: LayerGraph, fire: str, use_pallas: bool,
                    pool_after: bool) -> list[Step]:
    sq_l = graph.layer(f"{fire}_squeeze")
    e1_l = graph.layer(f"{fire}_e1x1")
    e3_l = graph.layer(f"{fire}_e3x3")

    def sq_fn(params, env, collect):
        env["sq"] = run_layer(sq_l, env["h"], params[sq_l.name], "relu",
                              use_pallas)
        if collect is not None:
            collect[sq_l.name] = env["sq"].shape

    def e1_fn(params, env, collect):
        env["e1"] = run_layer(e1_l, env["sq"], params[e1_l.name], "relu",
                              use_pallas)
        if collect is not None:
            collect[e1_l.name] = env["e1"].shape

    def e3_fn(params, env, collect):
        e3 = run_layer(e3_l, env["sq"], params[e3_l.name], "relu",
                       use_pallas)
        if collect is not None:
            collect[e3_l.name] = e3.shape
        h = jnp.concatenate([env["e1"], e3], axis=-1)
        env["h"] = _pad_pool(h) if pool_after else h

    return [
        Step(f"{fire}_squeeze", (sq_l.name,), ("h",), ("sq",), sq_fn),
        Step(f"{fire}_e1x1", (e1_l.name,), ("sq",), ("e1",), e1_fn),
        Step(f"{fire}_e3x3", (e3_l.name,), ("sq", "e1"), ("h",), e3_fn),
    ]


def _sqz_steps(graph: LayerGraph, use_pallas: bool,
               fuse: bool) -> list[Step]:
    # no dwconv layers -> the fusion plan is all singletons; the per-layer
    # kernels are already the fastest Pallas path (``fuse`` is a no-op)
    conv1 = graph.layer("conv1")

    def conv1_fn(params, env, collect):
        h = run_layer(conv1, env["h"], params["conv1"], "relu", use_pallas)
        if collect is not None:
            collect["conv1"] = h.shape
        env["h"] = _pad_pool(h)

    steps = [Step("conv1", ("conv1",), ("h",), ("h",), conv1_fn)]
    pool_after = {"fire3", "fire5"}        # v1.1 pool placement
    for i in range(2, 10):
        steps += _sqz_fire_steps(graph, f"fire{i}", use_pallas,
                                 pool_after=f"fire{i}" in pool_after)
    # conv10 head: conv -> global avgpool -> flatten (pool after the conv)
    conv10 = graph.layer("conv10")

    def conv10_fn(params, env, collect):
        h = run_layer(conv10, env["h"], params["conv10"], "relu", use_pallas)
        if collect is not None:
            collect["conv10"] = h.shape
        env["out"] = avgpool_all(h).reshape(h.shape[0], -1)

    steps.append(Step("conv10", ("conv10",), ("h",), ("out",), conv10_fn))
    return steps


_BUILDERS = {
    "mobilenet_v1": _mbv1_steps,
    "mobilenet_v2": _mbv2_steps,
    "squeezenet": _sqz_steps,
}


def build_program(name_or_graph: str | LayerGraph, *,
                  use_pallas: bool = False, fuse: bool = True) -> Program:
    """Build the step program for one zoo model.

    ``use_pallas`` selects the kernel backend per layer; ``fuse`` (Pallas
    path only) runs the fusion plan's dw->pw / pw->dw->pw groups as single
    fused pallas_calls — exactly the pre-refactor forward semantics.

    Programs are pure (steps close over specs and read params per call),
    so the by-name path is cached: repeated forward calls don't re-plan
    fusion or re-allocate the step closures.
    """
    if isinstance(name_or_graph, str):
        return _cached_program(name_or_graph, use_pallas, fuse)
    return _build(name_or_graph, use_pallas, fuse)


@functools.lru_cache(maxsize=None)
def _cached_program(name: str, use_pallas: bool, fuse: bool) -> Program:
    return _build(get_graph(name), use_pallas, fuse)


def _build(graph: LayerGraph, use_pallas: bool, fuse: bool) -> Program:
    try:
        builder = _BUILDERS[graph.name]
    except KeyError:
        raise KeyError(f"no step builder for graph {graph.name!r}; "
                       f"choices: {sorted(_BUILDERS)}") from None
    steps = builder(graph, use_pallas, fuse)
    return Program(graph=graph, steps=steps, act_of=ACT_OF[graph.name],
                   use_pallas=use_pallas)


def regroup_fused(program: Program,
                  groups: list[list[Step]]) -> list[list[Step]]:
    """Within-group fusion: given per-layer steps partitioned into core
    groups, re-run the fusion matcher *inside* each group so dw->pw chains
    that the schedule kept on one core run as single fused pallas_calls,
    while chains the schedule split across cores stay per-layer.

    Only plain main-chain steps fuse (single-layer, reads==writes==("h",));
    branch/head/residual steps pass through untouched.
    """
    graph, act_of = program.graph, program.act_of
    out: list[list[Step]] = []
    for grp in groups:
        fused: list[Step] = []
        i = 0
        while i < len(grp):
            s = grp[i]
            window = grp[i:i + 3]
            m = _match_in(graph, window) if _plain(s) else None
            if m is not None:
                fused.append(fused_step(graph, m.kind, m.layers, act_of))
                i += len(m.layers)
            else:
                fused.append(s)
                i += 1
        out.append(fused)
    return out


def _plain(s: Step) -> bool:
    return (len(s.layers) == 1 and s.reads == ("h",)
            and s.writes == ("h",))


def _match_in(graph: LayerGraph,
              window: list[Step]) -> FusionGroup | None:
    """Fusion match constrained to consecutive plain steps of one group —
    the same fusability rules as ``core.fusion`` (_is_pw/_linear_next),
    with the extra constraint that the whole chain stays in the group
    (``window`` never crosses a group boundary)."""
    chain = []
    for s in window:
        if not _plain(s):
            break
        chain.append(s.layers[0])
    sub = [graph.layer(n) for n in chain]

    def linear(a, b):                # b is a's sole consumer and vice versa
        return _linear_next(graph, a) == b

    if (len(sub) >= 3 and _is_pw(sub[0]) and sub[1].op == "dwconv"
            and _is_pw(sub[2]) and linear(chain[0], chain[1])
            and linear(chain[1], chain[2])):
        return FusionGroup("pw_dw_pw", tuple(chain[:3]))
    if (len(sub) >= 2 and sub[0].op == "dwconv" and _is_pw(sub[1])
            and linear(chain[0], chain[1])):
        return FusionGroup("dw_pw", tuple(chain[:2]))
    return None
