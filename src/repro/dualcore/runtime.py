"""Pipelined dual-core CNN runtime: execute a Schedule for real (Fig.4b).

``core/scheduler.py`` builds the alternating c/p group chain and predicts the
two-batch latency T_b2; this module is the missing execution half.  The
device pool splits into a c-submesh and a p-submesh (``dualmesh.partition``,
the Eq.10 theta split); each schedule group compiles to one jitted step
placed on its core's submesh (c-groups dispatch the implicit-GEMM conv
kernels, p-groups the depthwise / fused-block kernels); and N input images
stream through the group chain with the paper's one-slot offset, so stream
i runs group k while stream i+1 runs group k-1 on the other core.  JAX
dispatch is asynchronous: both group calls of a slot are in flight together
and the per-submesh execution queues realise the overlap.

Mapping a :class:`~repro.core.scheduler.Schedule` (layer-level) onto an
executable step program (``dualcore.program``) happens in
:func:`build_exec_plan`: each step is assigned the core where the schedule
put the dominant share of its cycles, consecutive same-core steps merge into
exec groups, and the merged chain is itself re-expressed as a ``Schedule``
(``plan.exec_schedule``) so T_b2 / the instruction-level simulator stay
directly comparable with what actually runs.
"""
from __future__ import annotations

import dataclasses
import time

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.arch import BoardModel, DualCoreConfig
from repro.core.graph import LayerGraph
from repro.core.latency import layer_latency
from repro.core.scheduler import Group, Schedule
from repro.dualcore.program import (Env, Params, Program, Step,
                                    build_program, regroup_fused)
from repro.dualmesh.partition import DualMesh, split_mesh


@dataclasses.dataclass
class ExecGroup:
    """One pipeline stage: consecutive same-core steps."""

    core: str                    # 'c' | 'p'
    steps: list[Step]

    @property
    def layers(self) -> list[str]:
        return [n for s in self.steps for n in s.layers]


@dataclasses.dataclass
class ExecPlan:
    """Executable partition of a program + its analytical twin."""

    groups: list[ExecGroup]
    exec_schedule: Schedule      # the merged chain as a Schedule (T_b2 etc.)
    live_after: list[set[str]]   # env keys that must survive each boundary


def _layer_core_map(schedule: Schedule) -> dict[str, tuple[str, int]]:
    """Base layer name -> (core, height); the tallest split of a
    load-balanced layer wins (it carries the dominant share of the work)."""
    out: dict[str, tuple[str, int]] = {}
    for g in schedule.groups:
        for l in g.layers:
            base = l.name.split(".")[0]
            cur = out.get(base)
            if cur is None or l.H > cur[1]:
                out[base] = (g.core, l.H)
    return out


def _step_core(step: Step, lmap: dict[str, tuple[str, int]],
               graph: LayerGraph, cfg: DualCoreConfig,
               board: BoardModel) -> str:
    """Core carrying the dominant share of the step's cycles.  A fused step
    whose layers the schedule spread across both cores must still run on
    one device — the latency-weighted majority decides."""
    weight = {"c": 0, "p": 0}
    for name in step.layers:
        core = lmap[name][0]
        lat = layer_latency(graph.layer(name), cfg.core(core),
                            board).t_layer
        weight[core] += lat
    return "c" if weight["c"] >= weight["p"] else "p"


def build_exec_plan(program: Program, schedule: Schedule,
                    group_fusion: bool = False) -> ExecPlan:
    """Partition ``program`` into alternating-core exec groups per the
    schedule's allocation.  With ``group_fusion`` the per-layer steps of
    each group are re-fused (dw->pw chains the schedule kept on one core
    become single fused pallas_calls)."""
    graph = program.graph
    lmap = _layer_core_map(schedule)
    missing = [n for s in program.steps for n in s.layers if n not in lmap]
    if missing:
        raise ValueError(f"schedule does not cover layers {missing[:4]}; "
                         f"was it built from graph {graph.name!r}?")
    cores = [_step_core(s, lmap, graph, schedule.cfg, schedule.board)
             for s in program.steps]
    # merge consecutive same-core steps
    parts: list[list[Step]] = []
    part_cores: list[str] = []
    for step, core in zip(program.steps, cores):
        if part_cores and part_cores[-1] == core:
            parts[-1].append(step)
        else:
            parts.append([step])
            part_cores.append(core)
    if group_fusion:
        parts = regroup_fused(program, parts)
    groups = [ExecGroup(core=c, steps=p)
              for c, p in zip(part_cores, parts)]
    exec_schedule = Schedule(
        groups=[Group(g.core, [graph.layer(n) for n in g.layers])
                for g in groups],
        cfg=schedule.cfg, board=schedule.board,
        scheme=schedule.scheme + "+exec")
    # liveness: buffers read after each boundary before being rewritten
    # (plus the final output) — the env a group must hand to the next
    live_after: list[set[str]] = []
    live = {"out"}
    for g in reversed(groups):
        live_after.append(set(live))
        for s in reversed(g.steps):
            live -= set(s.writes)
            live |= set(s.reads)
    live_after.reverse()
    return ExecPlan(groups=groups, exec_schedule=exec_schedule,
                    live_after=live_after)


# --------------------------------------------------------------------------
# runner
# --------------------------------------------------------------------------
@dataclasses.dataclass
class GroupHandle:
    """Executable handle on one exec group: what a RUN instruction needs
    to advance a stream one stage — the group's jitted fn bound to its
    core's resident params, with the cross-core env hop applied when the
    caller says which core the env currently sits on.  Handles stay valid
    across :meth:`DualCoreRunner.relocate` (they close over the runner,
    not over device buffers)."""

    runner: "DualCoreRunner"
    index: int
    core: str

    def __call__(self, env: Env, *, prev_core: str | None = None) -> Env:
        r = self.runner
        if prev_core is not None and prev_core != self.core:
            env = r._place(env, self.core)
        return r._fns[self.index](r._params[self.core], env)


class DualCoreRunner:
    """Executes one CNN's schedule on the c/p submeshes, images pipelined
    with the one-slot offset of Fig.4b.

    fuse='group' (default) builds the per-layer program and re-fuses dw->pw
    chains *within* each exec group — fusion never crosses a core boundary,
    so the schedule's allocation is honoured exactly.  fuse=True partitions
    the full fusion-plan program (the sequential ``use_pallas=True`` path,
    bitwise-identical steps); fuse=False keeps every layer its own kernel.
    """

    def __init__(self, graph: LayerGraph | str, params: Params,
                 schedule: Schedule, *, devices=None, theta: float = 0.5,
                 use_pallas: bool = True, fuse: bool | str = "group",
                 jit_groups: bool = True, donate: bool | None = None):
        # the fused-block kernels are Pallas-only: on the XLA path both
        # fusion modes degrade to per-layer steps
        group_fusion = fuse == "group" and use_pallas
        self.program = build_program(
            graph, use_pallas=use_pallas,
            fuse=bool(fuse) and not group_fusion)
        self.graph = self.program.graph
        self.schedule = schedule
        self.plan = build_exec_plan(self.program, schedule,
                                    group_fusion=group_fusion)
        self.groups = self.plan.groups
        # ``devices`` may be an already-split DualMesh — a fleet pool
        # leases one split to every member so they share the submeshes
        self.dual: DualMesh = (devices if isinstance(devices, DualMesh)
                               else split_mesh(devices, theta))
        self._distinct = self.dual.c_mesh is not self.dual.p_mesh
        self._shard = {"c": NamedSharding(self.dual.c_mesh, P()),
                       "p": NamedSharding(self.dual.p_mesh, P())}
        # each core gets exactly the params its groups consume
        self._params = {
            core: jax.device_put(
                {n: params[n] for g in self.groups if g.core == core
                 for n in g.layers},
                self._shard[core])
            for core in ("c", "p")}
        self.jit_groups = jit_groups
        if donate is None:           # donation is a no-op on CPU backends
            donate = jax.default_backend() in ("tpu", "gpu")
        # group 0 must not donate: its env holds the caller's image array,
        # which re-runs (timed reps, warm-up + measure) reuse
        self._fns = [self._compile(i, donate and i > 0)
                     for i in range(len(self.groups))]

    def _compile(self, gi: int, donate: bool):
        steps = self.groups[gi].steps
        live = self.plan.live_after[gi]

        def group_fn(params: Params, env: Env) -> Env:
            env = dict(env)
            for s in steps:
                s.fn(params, env, None)
            return {k: v for k, v in env.items() if k in live}

        if not self.jit_groups:
            return group_fn
        if donate:                   # inter-group buffer donation: the env
            #                          flows linearly through the chain
            return jax.jit(group_fn, donate_argnums=(1,))
        return jax.jit(group_fn)

    def _place(self, env: Env, core: str) -> Env:
        if not self._distinct:
            return env
        return jax.device_put(env, self._shard[core])

    # ------------------------------------------------------------------
    # executor-facing surface: what a RUN instruction needs
    # ------------------------------------------------------------------
    @property
    def handles(self) -> list[GroupHandle]:
        """One :class:`GroupHandle` per exec group, in chain order."""
        return [GroupHandle(runner=self, index=i, core=g.core)
                for i, g in enumerate(self.groups)]

    def place_input(self, x) -> Env:
        """Wrap a raw input into the env of a new stream, placed on the
        first group's core — the admission half of a RUN."""
        return self._place({"h": x}, self.groups[0].core)

    def relocate(self, dual: DualMesh) -> None:
        """Move this runner onto a re-split pool (the runner-side half of
        a REBALANCE): rebuild the shardings for the new c/p submeshes and
        re-place the resident params.  The jitted group fns are kept —
        XLA retraces a call whose argument shardings changed, so
        correctness is preserved and recompilation happens lazily, only
        for groups that actually run again."""
        self.dual = dual
        self._distinct = dual.c_mesh is not dual.p_mesh
        self._shard = {"c": NamedSharding(dual.c_mesh, P()),
                       "p": NamedSharding(dual.p_mesh, P())}
        self._params = {core: jax.device_put(self._params[core],
                                             self._shard[core])
                        for core in ("c", "p")}

    # ------------------------------------------------------------------
    def run_pipelined(self, images, record: list | None = None):
        """Stream every image through the exec-group chain, offset by one
        slot: at slot k, stream i executes group k-i (different cores for
        neighbouring streams by the alternation invariant).  All calls of a
        slot are dispatched before any is awaited (async overlap).

        Compatibility shim: the slot loop now lives in the streaming engine
        (``repro.serving.DualCoreEngine``) whose online admission refills
        drained slots from a live request queue — this method submits a
        ready image list and drains, which reproduces the original static
        dispatch schedule exactly.

        ``record``, when given, receives ``(slot, stream, group, core)``
        tuples in dispatch order — the execution trace the tests check
        against the analytical slot offsets.
        """
        from repro.serving.cnn import stream_images

        return stream_images(self, images, record=record).outputs

    def run_sequential(self, images):
        """Strictly serialized baseline: one image at a time through the
        whole chain, awaiting completion before the next image starts (only
        one core active at any moment — the denominator of the pipeline
        speedup)."""
        outs = []
        for x in images:
            env = self._place({"h": x}, self.groups[0].core)
            for g in range(len(self.groups)):
                if g > 0 and self.groups[g].core != self.groups[g - 1].core:
                    env = self._place(env, self.groups[g].core)
                env = self._fns[g](self._params[self.groups[g].core], env)
            jax.block_until_ready(env["out"])
            outs.append(env["out"])
        return outs

    # ------------------------------------------------------------------
    def timed(self, images, mode: str = "pipelined",
              reps: int = 1) -> tuple[list, float]:
        """Best-of-``reps`` wall-clock of a full run.  With reps > 1 the
        best rep excludes jit compilation (it lands in the first rep)."""
        run = (self.run_pipelined if mode == "pipelined"
               else self.run_sequential)
        outs, best = None, float("inf")
        for _ in range(max(1, reps)):
            t0 = time.perf_counter()
            outs = run(images)
            best = min(best, time.perf_counter() - t0)
        return outs, best
