"""dual-mesh: the paper's heterogeneous dual-core design flow as a
first-class TPU serving feature (DESIGN.md §2).

  partition  - theta split of a chip pool into c-/p-submeshes (Eq.10)
  cost       - 3-term roofline stage model (Eq.5-7 port)
  schedule   - N-stream staggered scheduling + Alg.1 load balance +
               makespan-aware admission planning (N=2 = the paper's case)
  search     - branch-and-bound theta + (tp_c, tp_p) local search (§V-B)
  runtime    - continuous-batching dual-submesh execution (chunked prefill
               on c, fused decode groups on p; async jit overlap)
"""
from repro.dualmesh.cost import StageCost, TpuModel, decode_cost, \
    prefill_cost
from repro.dualmesh.partition import DualMesh, split_mesh, theta_candidates
from repro.dualmesh.schedule import (ALLOCATIONS, AdmissionPlan,
                                     DualSchedule, Stage, best_schedule,
                                     build, load_balance, plan_admission,
                                     request_stages, wave_makespan)
from repro.dualmesh.search import DualSearchResult, search
from repro.dualmesh.runtime import DualMeshRunner, ServeResult

__all__ = ["StageCost", "TpuModel", "decode_cost", "prefill_cost",
           "DualMesh", "split_mesh", "theta_candidates", "ALLOCATIONS",
           "AdmissionPlan", "DualSchedule", "Stage", "best_schedule",
           "build", "load_balance", "plan_admission", "request_stages",
           "wave_makespan", "DualSearchResult", "search",
           "DualMeshRunner", "ServeResult"]
