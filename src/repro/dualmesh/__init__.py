"""dual-mesh: the paper's heterogeneous dual-core design flow as a
first-class TPU serving feature (DESIGN.md §2).

  partition  - theta split of a chip pool into c-/p-submeshes (Eq.10)
  cost       - 3-term roofline stage model (Eq.5-7 port)
  schedule   - interleaved two-stream scheduling + Alg.1 load balance
  search     - branch-and-bound theta + (tp_c, tp_p) local search (§V-B)
  runtime    - real dual-submesh execution (async jit on disjoint devices)
"""
from repro.dualmesh.cost import StageCost, TpuModel, decode_cost, \
    prefill_cost
from repro.dualmesh.partition import DualMesh, split_mesh, theta_candidates
from repro.dualmesh.schedule import (ALLOCATIONS, DualSchedule, Stage,
                                     best_schedule, build, load_balance,
                                     request_stages)
from repro.dualmesh.search import DualSearchResult, search
from repro.dualmesh.runtime import DualMeshRunner

__all__ = ["StageCost", "TpuModel", "decode_cost", "prefill_cost",
           "DualMesh", "split_mesh", "theta_candidates", "ALLOCATIONS",
           "DualSchedule", "Stage", "best_schedule", "build",
           "load_balance", "request_stages", "DualSearchResult", "search",
           "DualMeshRunner"]
