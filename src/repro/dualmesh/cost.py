"""Roofline cost model for LM serving stages (the TPU port of the paper's
Eq.5-7 latency model; DESIGN.md §2).

The dual-OPU models a layer as max(T_load, T_compute) through ping-pong
buffers; on a TPU submesh a serving stage is max of three terms:

    t_compute    = stage FLOPs / (chips * peak)
    t_memory     = HBM bytes touched / (chips * hbm_bw)
    t_collective = TP-collective bytes / (chips * ici_bw)

Prefill is compute-bound (the c-class stage: regular-conv analogue);
decode streams the whole KV cache / recurrent state per token and is
memory-bound (the p-class stage: depthwise analogue).  The same constants
feed EXPERIMENTS.md §Roofline, so the scheduler optimises exactly the
quantity the analysis reports.
"""
from __future__ import annotations

import dataclasses
import math

from repro.lm.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class TpuModel:
    peak_flops: float = 197e12      # bf16 per chip (v5e)
    hbm_bw: float = 819e9           # bytes/s per chip
    ici_bw: float = 50e9            # bytes/s per link
    hbm_bytes: int = 16 * 1024 ** 3
    mfu_ceiling: float = 0.6        # achievable fraction of peak for GEMMs
    bw_ceiling: float = 0.8        # achievable fraction of HBM bandwidth
    # Per-decode-step latency floor: dispatch + TP-collective latency +
    # DP sync.  This is the TPU analogue of the paper's runtime-PE-
    # efficiency gap: it is the term that makes decode prefer a small
    # submesh (adding chips cannot buy back the per-step floor), exactly
    # as depthwise conv could not use the c-core's MACs (§II).
    step_floor_base: float = 25e-6
    step_floor_tp: float = 8e-6     # x log2(tp)
    step_floor_dp: float = 2e-6     # x log2(chips / tp)

    def step_floor(self, chips: int, tp: int) -> float:
        tp = max(1, tp)
        dp = max(1, chips // tp)
        t = self.step_floor_base
        if tp > 1:
            t += self.step_floor_tp * math.log2(tp)
        if dp > 1:
            t += self.step_floor_dp * math.log2(dp)
        return t


@dataclasses.dataclass(frozen=True)
class StageCost:
    t_compute: float
    t_memory: float
    t_collective: float

    @property
    def latency(self) -> float:
        # compute/memory overlap within a stage is limited; collectives can
        # overlap with compute -> max() of the three (paper Eq.7 discipline)
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bound(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)


def _weight_bytes(cfg: ArchConfig, active: bool = True) -> float:
    n = cfg.active_param_count() if active else cfg.param_count()
    return 2.0 * n                       # bf16


def prefill_cost(cfg: ArchConfig, batch: int, seq: int, chips: int,
                 hw: TpuModel = TpuModel(),
                 tp: int = 8) -> StageCost:
    """Process ``batch`` prompts of ``seq`` tokens on ``chips`` devices."""
    tokens = batch * seq
    flops = 2.0 * cfg.active_param_count() * tokens
    if cfg.block_type == "transformer":
        flops += 4.0 * cfg.n_layers * batch * seq * seq * cfg.q_dim / 2
    t_c = flops / (chips * hw.peak_flops * hw.mfu_ceiling)
    # weights stream once per stage (good blocking); activations ~2x
    act = 2.0 * tokens * cfg.d_model * 2 * cfg.n_layers
    t_m = (_weight_bytes(cfg) / max(1, chips) + act / chips) \
        / (hw.hbm_bw * hw.bw_ceiling)
    # TP collectives: 2 all-reduces of the activations per layer across tp
    coll = 2.0 * cfg.n_layers * tokens * cfg.d_model * 2 * (tp - 1) / tp
    t_x = coll / (chips * hw.ici_bw)
    return StageCost(t_c, t_m, t_x)


def decode_cost(cfg: ArchConfig, batch: int, kv_len: int, chips: int,
                steps: int = 1, hw: TpuModel = TpuModel(),
                tp: int = 8) -> StageCost:
    """Generate ``steps`` tokens for ``batch`` sequences with a ``kv_len``
    cache (or O(1) recurrent state)."""
    flops = 2.0 * cfg.active_param_count() * batch * steps
    if cfg.block_type == "transformer":
        flops += 4.0 * cfg.n_layers * batch * kv_len * cfg.q_dim * steps
    t_c = flops / (chips * hw.peak_flops * hw.mfu_ceiling)
    # every step reads all active weights + the whole KV cache / state
    kv = 0.0
    if cfg.block_type == "transformer" or cfg.attn_every:
        layers = (cfg.n_layers if cfg.block_type == "transformer"
                  else cfg.n_layers // max(1, cfg.attn_every))
        kv = 2.0 * layers * batch * cfg.n_kv_heads * cfg.d_head * kv_len * 2
    if cfg.block_type in ("mamba2", "mlstm"):
        din = cfg.d_inner
        state = cfg.n_layers * batch * cfg.ssm_heads * \
            (din // cfg.ssm_heads) * max(cfg.ssm_state, 1) * 4
        kv += state
    t_m = steps * (_weight_bytes(cfg) + kv) / (chips * hw.hbm_bw
                                               * hw.bw_ceiling)
    coll = 2.0 * cfg.n_layers * batch * cfg.d_model * 2 * (tp - 1) / tp \
        * steps
    t_x = coll / (chips * hw.ici_bw)
    floor = steps * cfg.n_layers * hw.step_floor(chips, tp) / 4
    return StageCost(t_c, max(t_m, floor), t_x)
