"""theta-split of a device pool into the c-submesh and p-submesh
(the TPU port of the paper's Eq.10 DSP ratio; DESIGN.md §2).

The paper splits one FPGA's DSP budget between a channel-parallel c-core and
a pixel-parallel p-core; here we split a pod's chips between a
compute-shaped submesh (prefill / training: bigger TP groups feed the MXU)
and a bandwidth-shaped submesh (decode: more, smaller TP groups maximise
aggregate HBM streams).
"""
from __future__ import annotations

import dataclasses
import math

import jax
import numpy as np
from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class DualMesh:
    c_mesh: Mesh                 # prefill / compute-bound stages
    p_mesh: Mesh                 # decode / memory-bound stages
    theta: float                 # realised c-share of the chips

    @property
    def c_chips(self) -> int:
        return math.prod(self.c_mesh.shape.values())

    @property
    def p_chips(self) -> int:
        return math.prod(self.p_mesh.shape.values())


def _factor_mesh(devs, tp: int, axes=("data", "model")) -> Mesh:
    n = len(devs)
    tp = max(1, min(tp, n))
    while n % tp:
        tp -= 1
    arr = np.asarray(devs).reshape(n // tp, tp)
    return Mesh(arr, axes)


def split_mesh(devices=None, theta: float = 0.5, tp_c: int = 16,
               tp_p: int = 4) -> DualMesh:
    """Split ``devices`` into c/p submeshes with c-share ~= theta.

    tp_c / tp_p are the per-submesh tensor-parallel widths: the c-submesh
    defaults to wide TP (compute: bigger GEMM tiles per collective), the
    p-submesh to narrow TP (decode: KV streams stay local).  With a single
    device (CPU tests) both submeshes alias it (degenerate but functional).
    """
    devs = list(devices if devices is not None else jax.devices())
    if len(devs) < 2:
        m = _factor_mesh(devs, 1)
        return DualMesh(m, m, theta=0.5)
    n_c = min(len(devs) - 1, max(1, round(theta * len(devs))))
    c = _factor_mesh(devs[:n_c], tp_c)
    p = _factor_mesh(devs[n_c:], tp_p)
    return DualMesh(c, p, theta=n_c / len(devs))


@dataclasses.dataclass(frozen=True)
class _AbstractSubMesh:
    """Duck-typed stand-in for planning without real devices: the scheduler
    and cost model only read ``shape``."""
    shape: dict


def abstract_split(n_devices: int, theta: float, tp_c: int = 16,
                   tp_p: int = 4) -> DualMesh:
    """Plan-time split: chip counts + TP widths only (no jax devices).
    Used by the design-flow search for pods larger than the local host."""
    n_c = min(n_devices - 1, max(1, round(theta * n_devices)))
    n_p = n_devices - n_c
    tc = max(1, min(tp_c, n_c))
    while n_c % tc:
        tc -= 1
    tp_ = max(1, min(tp_p, n_p))
    while n_p % tp_:
        tp_ -= 1
    c = _AbstractSubMesh({"data": n_c // tc, "model": tc})
    p = _AbstractSubMesh({"data": n_p // tp_, "model": tp_})
    return DualMesh(c, p, theta=n_c / n_devices)  # type: ignore[arg-type]


def theta_candidates(n_devices: int, tp_c: int = 16,
                     tp_p: int = 4) -> list[float]:
    """Feasible thetas: both submeshes must factor into their TP widths."""
    out = []
    for n_c in range(1, n_devices):
        n_p = n_devices - n_c
        if n_c % math.gcd(n_c, tp_c) == 0 and n_p % math.gcd(n_p, tp_p) == 0:
            out.append(n_c / n_devices)
    return sorted(set(out))
