"""Dual-mesh execution runtime: run the interleaved schedule for real.

Two jitted programs live on disjoint device sets (the c-/p-submeshes); JAX
dispatch is asynchronous, so a prefill on the c-submesh and a decode batch
on the p-submesh genuinely overlap — the Fig.4b trace on silicon.  On this
CPU container both submeshes alias one device (degenerate but exercises the
whole control path; tests use it).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dualmesh.partition import DualMesh
from repro.dualmesh.schedule import DualSchedule, Stage
from repro.lm.config import ArchConfig
from repro.lm.model import decode_step, init_cache
from repro.lm.steps import make_serve_step


@dataclasses.dataclass
class StreamState:
    tokens: jax.Array          # running token buffer (B, t)
    cache: Any
    done_prefill: bool = False


class DualMeshRunner:
    """Executes prefill stages on the c-submesh and decode stages on the
    p-submesh, two request streams interleaved (stream B lags stream A by
    one group, as in the paper's two-image schedule)."""

    def __init__(self, cfg: ArchConfig, params, dual: DualMesh,
                 max_len: int = 256):
        self.cfg = cfg
        self.dual = dual
        self.max_len = max_len
        # place one replica of the params on each submesh
        self.params_c = jax.device_put(
            params, NamedSharding(dual.c_mesh, P()))
        self.params_p = (self.params_c if dual.p_mesh is dual.c_mesh
                         else jax.device_put(
                             params, NamedSharding(dual.p_mesh, P())))
        cdev = dual.c_mesh.devices.flat[0]
        pdev = dual.p_mesh.devices.flat[0]

        def prefill_fn(params, tokens, cache):
            return decode_step(params, cfg, tokens, cache)

        def decode_fn(params, token, cache):
            logits, cache = decode_step(params, cfg, token, cache)
            nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
            return nxt, cache

        self._prefill = jax.jit(prefill_fn, device=cdev)
        self._decode = jax.jit(decode_fn, device=pdev)
        self.trace: list[tuple[str, str, float]] = []

    def new_stream(self, prompt: jax.Array) -> StreamState:
        cache = init_cache(self.cfg, prompt.shape[0], self.max_len)
        return StreamState(tokens=prompt, cache=cache)

    def run_prefill(self, st: StreamState) -> StreamState:
        t0 = time.perf_counter()
        logits, cache = self._prefill(self.params_c, st.tokens, st.cache)
        nxt = jnp.argmax(logits[:, -1, :self.cfg.vocab], axis=-1)[:, None]
        st = StreamState(tokens=jnp.concatenate([st.tokens, nxt], 1),
                         cache=cache, done_prefill=True)
        self.trace.append(("prefill", "c", time.perf_counter() - t0))
        return st

    def run_decode(self, st: StreamState, steps: int) -> StreamState:
        t0 = time.perf_counter()
        tok = st.tokens[:, -1:]
        cache = st.cache
        toks = [st.tokens]
        for _ in range(steps):
            tok, cache = self._decode(self.params_p, tok, cache)
            toks.append(tok)
        self.trace.append(("decode", "p", time.perf_counter() - t0))
        return StreamState(tokens=jnp.concatenate(toks, 1), cache=cache,
                           done_prefill=True)

    def run_two_streams(self, prompt_a: jax.Array, prompt_b: jax.Array,
                        gen_steps: int = 8):
        """The Fig.4b interleave: A prefills (c) alone; then A decodes (p)
        while B prefills (c); then B decodes (p)."""
        a = self.new_stream(prompt_a)
        b = self.new_stream(prompt_b)
        a = self.run_prefill(a)
        # slot 2: these two dispatches overlap (async on disjoint devices)
        a_fut = self.run_decode(a, gen_steps)
        b_fut = self.run_prefill(b)
        b = self.run_decode(b_fut, gen_steps)
        return a_fut.tokens, b.tokens, self.trace
