"""Dual-mesh execution runtime: N-stream continuous batching for real.

Two jitted programs live on disjoint device sets (the c-/p-submeshes); JAX
dispatch is asynchronous, so a chunked prefill on the c-submesh and a fused
decode batch on the p-submesh genuinely overlap — the Fig.4b trace on
silicon, generalized from two images to an online request queue.  On this
CPU container both submeshes alias one device (degenerate but exercises the
whole control path; tests use it).

The scheduler loop now lives behind the shared streaming engine API
(``repro.serving.DualMeshEngine`` — submit/step/drain, pluggable admission,
bounded queue); ``DualMeshRunner.serve`` survives as a submit-everything-
and-drain compatibility shim.  One engine step, i.e. one scheduler slot:

  1. advance every active decode group by a quantum of fused steps on the
     p-submesh (batch = sum of member batches — continuous batching);
  2. the c-submesh, now idle, admits the next queued request and runs its
     chunked prefill;
  3. members that reached their generation target are evicted from their
     group (their cache rows are sliced out); drained groups retire;
  4. prefilled streams whose cache positions align are fused into a new
     decode group once ``group_size`` of them are ready (or the queue is
     empty) — the makespan-aware admission policy from
     schedule.plan_admission.

Streams can only fuse at equal cache position because ``DecodeCache.pos``
is a scalar shared by every row (mid-flight joins would need per-row
positions / attention masks); equal-length prompts — the benchmark and
serving-CLI shape — always align, and unequal ones simply form separate
groups.  ``run_two_streams`` survives as the N=2, group_size=1 special
case and reproduces the paper's two-image interleave exactly.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dualmesh.partition import DualMesh
from repro.dualmesh.schedule import plan_admission
from repro.lm.config import ArchConfig
from repro.lm.model import DecodeCache, decode_step, init_cache


def _cache_batch_map(cache: DecodeCache, fn) -> DecodeCache:
    """Apply ``fn`` to every per-row cache field (batch axis 1); the
    scalar ``pos`` passes through untouched."""
    return DecodeCache(*[
        f if name == "pos" or f is None else fn(f)
        for name, f in zip(DecodeCache._fields, cache)])


def _concat_caches(caches: Sequence[DecodeCache]) -> DecodeCache:
    first = caches[0]
    if len(caches) == 1:
        return first
    out = []
    for name, f in zip(DecodeCache._fields, first):
        if name == "pos" or f is None:
            out.append(f)
        else:
            out.append(jnp.concatenate(
                [getattr(c, name) for c in caches], axis=1))
    return DecodeCache(*out)


def _take_rows(cache: DecodeCache, rows) -> DecodeCache:
    idx = jnp.asarray(rows)
    return _cache_batch_map(cache, lambda f: jnp.take(f, idx, axis=1))


@dataclasses.dataclass
class StreamState:
    """One admitted request stream."""
    rid: int
    tokens: jax.Array          # running token buffer (B, t)
    cache: Any
    gen_target: int            # decode steps still owed after prefill
    done_prefill: bool = False


@dataclasses.dataclass
class _Member:
    """A stream's slice of a fused decode group."""
    rid: int
    row0: int                  # first row in the fused batch
    batch: int
    prefix: jax.Array          # tokens up to (and incl.) the prefill emit
    remaining: int


@dataclasses.dataclass
class DecodeGroup:
    """Several position-aligned streams decoding as one fused batch."""
    members: list[_Member]
    last_tok: jax.Array        # (B_total, 1)
    cache: Any
    history: list[jax.Array] = dataclasses.field(default_factory=list)

    @property
    def batch(self) -> int:
        return sum(m.batch for m in self.members)


@dataclasses.dataclass
class ServeResult:
    outputs: list[jax.Array]   # per request, in submission order
    trace: list[tuple[str, str, float]]
    stats: dict


class DualMeshRunner:
    """Executes chunked prefills on the c-submesh and fused decode batches
    on the p-submesh, N request streams interleaved (each stream staggered
    behind its predecessor, as in the paper's two-image schedule)."""

    def __init__(self, cfg: ArchConfig, params, dual: DualMesh,
                 max_len: int = 256):
        self.cfg = cfg
        self.dual = dual
        self.max_len = max_len
        self._shard_c = NamedSharding(dual.c_mesh, P())
        self._shard_p = NamedSharding(dual.p_mesh, P())
        # place one replica of the params on each submesh
        self.params_c = jax.device_put(params, self._shard_c)
        self.params_p = (self.params_c if dual.p_mesh is dual.c_mesh
                         else jax.device_put(params, self._shard_p))

        def prefill_fn(params, tokens, cache):
            return decode_step(params, cfg, tokens, cache)

        def decode_fn(params, token, cache):
            logits, cache = decode_step(params, cfg, token, cache)
            nxt = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
            return nxt, cache

        # submesh placement follows the (committed) inputs — params and
        # caches are device_put onto the right submesh, so no deprecated
        # jit(..., device=...) is needed.
        self._prefill = jax.jit(prefill_fn)
        self._decode = jax.jit(decode_fn)
        self.trace: list[tuple[str, str, float]] = []

    # ------------------------------------------------------------------
    # stream lifecycle
    # ------------------------------------------------------------------
    def new_stream(self, prompt: jax.Array, gen_steps: int = 0,
                   rid: int = 0) -> StreamState:
        cache = init_cache(self.cfg, prompt.shape[0], self.max_len)
        return StreamState(rid=rid,
                           tokens=jax.device_put(prompt, self._shard_c),
                           cache=jax.device_put(cache, self._shard_c),
                           gen_target=gen_steps)

    def run_prefill(self, st: StreamState,
                    chunk: int | None = None) -> StreamState:
        """Chunked prefill on the c-submesh: the prompt is processed in
        ``chunk``-token slices (the Alg.1 split knob); the final slice's
        logits emit the first generated token."""
        t0 = time.perf_counter()
        tokens, cache = st.tokens, st.cache
        plen = tokens.shape[1]
        step = chunk if chunk and 0 < chunk < plen else plen
        logits = None
        for lo in range(0, plen, step):
            logits, cache = self._prefill(
                self.params_c, tokens[:, lo:lo + step], cache)
        nxt = jnp.argmax(logits[:, -1, :self.cfg.vocab], axis=-1)[:, None]
        out = StreamState(rid=st.rid,
                          tokens=jnp.concatenate([tokens, nxt], 1),
                          cache=cache, gen_target=st.gen_target,
                          done_prefill=True)
        self.trace.append(("prefill", "c", time.perf_counter() - t0))
        return out

    # ------------------------------------------------------------------
    # fused decode groups (continuous batching on the p-submesh)
    # ------------------------------------------------------------------
    def _fuse(self, streams: list[StreamState]) -> DecodeGroup:
        members, row = [], 0
        for s in streams:
            b = s.tokens.shape[0]
            members.append(_Member(rid=s.rid, row0=row, batch=b,
                                   prefix=s.tokens,
                                   remaining=s.gen_target))
            row += b
        last = jnp.concatenate([s.tokens[:, -1:] for s in streams], 0)
        cache = _concat_caches([s.cache for s in streams])
        return DecodeGroup(members=members,
                           last_tok=jax.device_put(last, self._shard_p),
                           cache=jax.device_put(cache, self._shard_p))

    def _decode_group(self, g: DecodeGroup, steps: int) -> None:
        t0 = time.perf_counter()
        tok, cache = g.last_tok, g.cache
        for _ in range(steps):
            tok, cache = self._decode(self.params_p, tok, cache)
            g.history.append(tok)
        g.last_tok, g.cache = tok, cache
        for m in g.members:
            m.remaining -= steps
        self.trace.append(("decode", "p", time.perf_counter() - t0))

    def _evict(self, g: DecodeGroup, outputs: dict) -> DecodeGroup | None:
        """Slice finished members' rows out of the fused batch."""
        done = [m for m in g.members if m.remaining <= 0]
        if not done:
            return g
        for m in done:
            cols = [h[m.row0:m.row0 + m.batch] for h in g.history]
            if cols:
                # prefix lives on the c-submesh, history on the p-submesh;
                # on a real (non-degenerate) split the concat needs both
                # operands co-located
                prefix = jax.device_put(m.prefix, self._shard_p)
                outputs[m.rid] = jnp.concatenate([prefix] + cols, 1)
            else:
                outputs[m.rid] = m.prefix
        alive = [m for m in g.members if m.remaining > 0]
        if not alive:
            return None
        rows = [r for m in alive for r in range(m.row0, m.row0 + m.batch)]
        g.cache = _take_rows(g.cache, rows)
        g.last_tok = jnp.take(g.last_tok, jnp.asarray(rows), axis=0)
        g.history = [jnp.take(h, jnp.asarray(rows), axis=0)
                     for h in g.history]
        row = 0
        for m in alive:
            m.row0 = row
            row += m.batch
        g.members = alive
        return g

    # ------------------------------------------------------------------
    # the scheduler loop — now a compatibility shim over the shared
    # streaming engine API (repro.serving.DualMeshEngine owns the loop)
    # ------------------------------------------------------------------
    def serve(self, prompts: Sequence[jax.Array],
              gen_steps: int | Sequence[int] = 8,
              group_size: int | None = None,
              prefill_chunk: int | None = None,
              quantum: int | None = None,
              hw=None) -> ServeResult:
        """Run a ready request list to completion (compatibility shim:
        submit everything to a fresh :class:`repro.serving.DualMeshEngine`
        and drain it — new code should drive the engine directly).

        gen_steps      total generated tokens per request (the prefill
                       emits the first; int or one per request)
        group_size     decode fusion width; default = the makespan-aware
                       plan_admission choice (homogeneous queues) else
                       everything position-aligned
        prefill_chunk  chunked-prefill slice (None = whole prompt)
        quantum        fused decode steps per scheduler slot (None = run a
                       group until its earliest member finishes)
        """
        from repro.serving import DualMeshEngine, Request

        n = len(prompts)
        gens = ([int(gen_steps)] * n if isinstance(gen_steps, int)
                else list(gen_steps))
        assert len(gens) == n
        if group_size is None:
            group_size = self.planned_group_size(prompts, gens, hw)
        engine = DualMeshEngine(self, group_size=max(1, group_size),
                                prefill_chunk=prefill_chunk,
                                quantum=quantum)
        for p, g in zip(prompts, gens):
            engine.submit(Request(payload=p, gen_steps=g))
        res = engine.drain()
        return ServeResult(outputs=res.outputs, trace=res.trace,
                           stats=res.stats)

    def planned_group_size(self, prompts, gens, hw=None) -> int:
        """Makespan-aware default fusion width (homogeneous queues only;
        mixed shapes fall back to fuse-everything-aligned)."""
        shapes = {p.shape for p in prompts}
        if len(shapes) != 1 or len(set(gens)) != 1:
            return len(prompts)
        from repro.dualmesh.cost import TpuModel
        b, plen = prompts[0].shape
        plan = plan_admission(self.cfg, self.dual, hw or TpuModel(),
                              b, plen, gens[0], len(prompts))
        return plan.group_size

    # ------------------------------------------------------------------
    # the paper's two-image interleave — now the N=2 special case
    # ------------------------------------------------------------------
    def run_two_streams(self, prompt_a: jax.Array, prompt_b: jax.Array,
                        gen_steps: int = 8):
        """Fig.4b: A prefills (c) alone; then A decodes (p) while B
        prefills (c); then B decodes (p).  Exactly ``serve`` with
        group_size=1.  Note ``gen_steps`` here counts post-prefill decode
        steps (seed semantics), so each output has prompt+1+gen tokens."""
        res = self.serve([prompt_a, prompt_b], gen_steps=gen_steps + 1,
                         group_size=1)
        return res.outputs[0], res.outputs[1], res.trace
