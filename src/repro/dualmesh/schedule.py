"""Interleaved dual-stream scheduling for LM serving — the paper's §V
algorithms re-targeted (DESIGN.md §2 mapping):

  paper                         | here
  ------------------------------+------------------------------------------
  layer graph G(V,E)            | request stage chain: prefill -> decode
  c-core / p-core groups        | c-submesh / p-submesh stage groups
  interleave 2 images (Fig.4b)  | interleave 2 request streams
  Alg.1 split along ifm height  | split prefill along sequence (chunked
                                |   prefill) / decode along steps
  T_b2 (two-batch makespan)     | two-stream makespan (same recurrence)

The same three allocation seeds (stage-type / greedy / round-robin) and the
same largest-gap split heuristic are used, so Table-V-style comparisons are
reproducible on the LM side (benchmarks/dualmesh_bench.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

from repro.dualmesh.cost import StageCost, TpuModel, decode_cost, \
    prefill_cost
from repro.dualmesh.partition import DualMesh
from repro.lm.config import ArchConfig

ALLOCATIONS = ("stage_type", "greedy", "round_robin")


@dataclasses.dataclass(frozen=True)
class Stage:
    """One schedulable unit of a request batch."""
    kind: str                 # 'prefill' | 'decode'
    batch: int
    seq: int                  # prefill: tokens to process; decode: kv_len
    steps: int = 1            # decode steps in this stage

    def split_seq(self, left: int) -> tuple["Stage", "Stage"]:
        assert self.kind == "prefill" and 0 < left < self.seq
        return (dataclasses.replace(self, seq=left),
                dataclasses.replace(self, seq=self.seq - left))

    def split_steps(self, left: int) -> tuple["Stage", "Stage"]:
        assert self.kind == "decode" and 0 < left < self.steps
        return (dataclasses.replace(self, steps=left),
                dataclasses.replace(self, steps=self.steps - left))


def stage_cost(st: Stage, cfg: ArchConfig, chips: int, tp: int,
               hw: TpuModel) -> float:
    if st.kind == "prefill":
        return prefill_cost(cfg, st.batch, st.seq, chips, hw, tp).latency
    return decode_cost(cfg, st.batch, st.seq, chips, st.steps, hw,
                       tp).latency


@dataclasses.dataclass
class MeshGroup:
    mesh: str                 # 'c' | 'p'
    stages: list[Stage]

    def latency(self, cfg, dual: DualMesh, hw) -> float:
        chips = dual.c_chips if self.mesh == "c" else dual.p_chips
        tp = (dual.c_mesh.shape.get("model", 1) if self.mesh == "c"
              else dual.p_mesh.shape.get("model", 1))
        return sum(stage_cost(s, cfg, chips, tp, hw) for s in self.stages)


@dataclasses.dataclass
class DualSchedule:
    groups: list[MeshGroup]
    cfg: ArchConfig
    dual: DualMesh
    hw: TpuModel
    scheme: str = "custom"

    def latencies(self) -> list[float]:
        return [g.latency(self.cfg, self.dual, self.hw)
                for g in self.groups]

    def makespan(self) -> float:
        """Two-stream staggered makespan (the paper's corrected T_b2)."""
        t = self.latencies()
        if not t:
            return 0.0
        total = t[0]
        for i in range(1, len(t)):
            total += max(t[i], t[i - 1])
        return total + t[-1]

    def throughput_tokens_per_s(self) -> float:
        toks = 2 * sum(s.seq if s.kind == "prefill" else s.steps * s.batch
                       for g in self.groups for s in g.stages)
        span = self.makespan()
        return toks / span if span else float("inf")


def request_stages(cfg: ArchConfig, prompts: Sequence[tuple[int, int, int]]
                   ) -> list[Stage]:
    """prompts: (batch, prompt_len, gen_len) per request group ->
    alternating prefill/decode stage chain (the 'layer graph')."""
    out = []
    for batch, plen, glen in prompts:
        out.append(Stage("prefill", batch, plen))
        out.append(Stage("decode", batch, plen, steps=glen))
    return out


def allocate(stages: list[Stage], cfg, dual: DualMesh, hw,
             scheme: str) -> list[str]:
    if scheme == "stage_type":     # layer-type analogue
        return ["c" if s.kind == "prefill" else "p" for s in stages]
    if scheme == "round_robin":
        return ["c" if i % 2 == 0 else "p" for i in range(len(stages))]
    if scheme == "greedy":
        out = []
        for s in stages:
            tc = stage_cost(s, cfg, dual.c_chips,
                            dual.c_mesh.shape.get("model", 1), hw)
            tp_ = stage_cost(s, cfg, dual.p_chips,
                             dual.p_mesh.shape.get("model", 1), hw)
            out.append("c" if tc <= tp_ else "p")
        return out
    raise ValueError(scheme)


def build(stages, cfg, dual, hw, scheme) -> DualSchedule:
    groups: list[MeshGroup] = []
    for s, m in zip(stages, allocate(stages, cfg, dual, hw, scheme)):
        if groups and groups[-1].mesh == m:
            groups[-1].stages.append(s)
        else:
            groups.append(MeshGroup(m, [s]))
    return DualSchedule(groups, cfg, dual, hw, scheme)


def load_balance(sched: DualSchedule, rounds: int = 32) -> DualSchedule:
    """Alg.1 analogue: split the boundary stage of the worst-gap pair along
    its sequence (prefill) or steps (decode) and move the remainder to the
    neighbouring group on the other submesh."""
    s = DualSchedule([MeshGroup(g.mesh, list(g.stages))
                      for g in sched.groups], sched.cfg, sched.dual,
                     sched.hw, sched.scheme + "+lb")
    best = s.makespan()
    for _ in range(rounds):
        t = s.latencies()
        if len(t) < 2:
            break
        pairs = sorted(range(len(t) - 1), key=lambda i: -abs(t[i] - t[i + 1]))
        improved = False
        for pi in pairs:
            longer, shorter = (pi, pi + 1) if t[pi] > t[pi + 1] \
                else (pi + 1, pi)
            val = _try_split(s, longer, shorter, best)
            if val is not None and val < best - 1e-12:
                best = val
                improved = True
                break
        if not improved:
            break
    return s


def _try_split(s: DualSchedule, longer: int, shorter: int,
               best: float) -> float | None:
    gl = s.groups[longer]
    if not gl.stages:
        return None
    tail = longer < shorter
    st = gl.stages[-1] if tail else gl.stages[0]
    axis = st.seq if st.kind == "prefill" else st.steps
    if axis < 2:
        return None
    best_cut, best_val = None, best
    step = max(1, axis // 16)
    for cut in range(step, axis, step):
        a, b = (st.split_seq(cut) if st.kind == "prefill"
                else st.split_steps(cut))
        keep, move = (a, b) if tail else (b, a)
        trial = [MeshGroup(g.mesh, list(g.stages)) for g in s.groups]
        if tail:
            trial[longer].stages[-1] = keep
            trial[shorter].stages.insert(0, move)
        else:
            trial[longer].stages[0] = keep
            trial[shorter].stages.append(move)
        val = DualSchedule(trial, s.cfg, s.dual, s.hw).makespan()
        if val < best_val:
            best_val, best_cut = val, cut
    if best_cut is None:
        return None
    a, b = (st.split_seq(best_cut) if st.kind == "prefill"
            else st.split_steps(best_cut))
    keep, move = (a, b) if tail else (b, a)
    if tail:
        gl.stages[-1] = keep
        s.groups[shorter].stages.insert(0, move)
    else:
        gl.stages[0] = keep
        s.groups[shorter].stages.append(move)
    return best_val


def best_schedule(stages, cfg, dual: DualMesh,
                  hw: TpuModel = TpuModel(),
                  with_load_balance: bool = True) -> DualSchedule:
    cands = []
    for scheme in ALLOCATIONS:
        b = build(stages, cfg, dual, hw, scheme)
        cands.append(b)
        if with_load_balance:
            cands.append(load_balance(b))
    return min(cands, key=lambda x: x.makespan())
