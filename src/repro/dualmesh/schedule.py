"""Interleaved N-stream scheduling for LM serving — the paper's §V
algorithms re-targeted (DESIGN.md §2 mapping) and generalized from the
two-image interleave to N concurrent request streams:

  paper                         | here
  ------------------------------+------------------------------------------
  layer graph G(V,E)            | request stage chain: prefill -> decode
  c-core / p-core groups        | c-submesh / p-submesh stage groups
  interleave 2 images (Fig.4b)  | stagger N request streams (N=2 = Fig.4b)
  Alg.1 split along ifm height  | split prefill along sequence (chunked
                                |   prefill) / decode along steps
  T_b2 (two-batch makespan)     | N-stream flow-shop makespan; the N=2
                                |   case is exactly the corrected T_b2

N-stream serving
----------------
``DualSchedule`` now carries ``n_streams``: the same stage chain is run by
N identical streams, each staggered behind its predecessor.  ``makespan``
runs a greedy FIFO simulation over the group latencies t: each submesh
serves one group at a time, stream j's group i becomes ready when its
group i-1 completes, and the globally earliest-startable ready group is
dispatched next (ties broken by ready time, then stream order).  No
submesh is ever double-booked, at any N.  For N=2 the simulated makespan
equals the two-stream closed form t[0] + sum(max(t[i], t[i-1])) + t[-1]
(the paper's corrected T_b2) for chains of any length — validated to
machine precision over randomized chains in tests/test_nstream.py — so
existing Table-V comparisons are exactly the N=2 special case.

``plan_admission`` is the makespan-aware admission policy used by the
runtime (runtime.DualMeshRunner.serve): prefills serialize on the
c-submesh while decode groups of ``group_size`` fused streams run batched
on the p-submesh; the policy picks the fusion size minimizing the
projected makespan of the whole request queue.

The same three allocation seeds (stage-type / greedy / round-robin) and the
same largest-gap split heuristic are used, so Table-V-style comparisons are
reproducible on the LM side (benchmarks/dualmesh_bench.py).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.dualmesh.cost import TpuModel, decode_cost, prefill_cost
from repro.dualmesh.partition import DualMesh
from repro.lm.config import ArchConfig

ALLOCATIONS = ("stage_type", "greedy", "round_robin")


@dataclasses.dataclass(frozen=True)
class Stage:
    """One schedulable unit of a request batch."""
    kind: str                 # 'prefill' | 'decode'
    batch: int
    seq: int                  # prefill: tokens to process; decode: kv_len
    steps: int = 1            # decode steps in this stage

    @property
    def tokens(self) -> int:
        """Tokens this stage processes (prefill) or emits (decode)."""
        return self.batch * (self.seq if self.kind == "prefill"
                             else self.steps)

    def split_seq(self, left: int) -> tuple["Stage", "Stage"]:
        assert self.kind == "prefill" and 0 < left < self.seq
        return (dataclasses.replace(self, seq=left),
                dataclasses.replace(self, seq=self.seq - left))

    def split_steps(self, left: int) -> tuple["Stage", "Stage"]:
        assert self.kind == "decode" and 0 < left < self.steps
        return (dataclasses.replace(self, steps=left),
                dataclasses.replace(self, steps=self.steps - left))


def stage_cost(st: Stage, cfg: ArchConfig, chips: int, tp: int,
               hw: TpuModel) -> float:
    if st.kind == "prefill":
        return prefill_cost(cfg, st.batch, st.seq, chips, hw, tp).latency
    return decode_cost(cfg, st.batch, st.seq, chips, st.steps, hw,
                       tp).latency


@dataclasses.dataclass
class MeshGroup:
    mesh: str                 # 'c' | 'p'
    stages: list[Stage]

    def latency(self, cfg, dual: DualMesh, hw) -> float:
        chips = dual.c_chips if self.mesh == "c" else dual.p_chips
        tp = (dual.c_mesh.shape.get("model", 1) if self.mesh == "c"
              else dual.p_mesh.shape.get("model", 1))
        return sum(stage_cost(s, cfg, chips, tp, hw) for s in self.stages)


@dataclasses.dataclass
class DualSchedule:
    groups: list[MeshGroup]
    cfg: ArchConfig
    dual: DualMesh
    hw: TpuModel
    scheme: str = "custom"
    n_streams: int = 2        # identical streams running this chain

    def latencies(self) -> list[float]:
        return [g.latency(self.cfg, self.dual, self.hw)
                for g in self.groups]

    def makespan(self, n_streams: int | None = None) -> float:
        """N-stream staggered makespan: greedy FIFO simulation with each
        submesh serving one group at a time (see module docstring).  The
        N=2 case equals the paper's corrected T_b2 closed form."""
        n = self.n_streams if n_streams is None else n_streams
        t = self.latencies()
        if not t or n < 1:
            return 0.0
        meshes = [g.mesh for g in self.groups]
        free: dict[str, float] = {}
        nxt = [0] * n                  # next group index per stream
        prev_done = [0.0] * n          # completion of the stream's last group
        for _ in range(n * len(t)):
            best = None
            for j in range(n):
                i = nxt[j]
                if i == len(t):
                    continue
                ready = prev_done[j]
                start = max(ready, free.get(meshes[i], 0.0))
                key = (start, ready, j)
                if best is None or key < best[0]:
                    best = (key, j, i, start)
            _, j, i, start = best
            end = start + t[i]
            free[meshes[i]] = end
            prev_done[j] = end
            nxt[j] += 1
        return max(prev_done)

    def stream_tokens(self) -> int:
        """Tokens one stream processes/emits over the whole chain
        (prefill counts batch*seq prompt tokens; decode batch*steps)."""
        return sum(s.tokens for g in self.groups for s in g.stages)

    def total_tokens(self, n_streams: int | None = None) -> int:
        n = self.n_streams if n_streams is None else n_streams
        return n * self.stream_tokens()

    def throughput_tokens_per_s(self, n_streams: int | None = None
                                ) -> float:
        """Token accounting matches the runtime: every stream's prompt
        tokens plus its emitted decode tokens, over the N-stream
        makespan (no hardcoded two-stream factor)."""
        span = self.makespan(n_streams)
        toks = self.total_tokens(n_streams)
        return toks / span if span else float("inf")


def request_stages(cfg: ArchConfig, prompts: Sequence[tuple[int, int, int]]
                   ) -> list[Stage]:
    """prompts: (batch, prompt_len, gen_len) per request group ->
    alternating prefill/decode stage chain (the 'layer graph')."""
    out = []
    for batch, plen, glen in prompts:
        out.append(Stage("prefill", batch, plen))
        out.append(Stage("decode", batch, plen, steps=glen))
    return out


def allocate(stages: list[Stage], cfg, dual: DualMesh, hw,
             scheme: str) -> list[str]:
    if scheme == "stage_type":     # layer-type analogue
        return ["c" if s.kind == "prefill" else "p" for s in stages]
    if scheme == "round_robin":
        return ["c" if i % 2 == 0 else "p" for i in range(len(stages))]
    if scheme == "greedy":
        out = []
        for s in stages:
            tc = stage_cost(s, cfg, dual.c_chips,
                            dual.c_mesh.shape.get("model", 1), hw)
            tp_ = stage_cost(s, cfg, dual.p_chips,
                             dual.p_mesh.shape.get("model", 1), hw)
            out.append("c" if tc <= tp_ else "p")
        return out
    raise ValueError(scheme)


def build(stages, cfg, dual, hw, scheme, n_streams: int = 2
          ) -> DualSchedule:
    groups: list[MeshGroup] = []
    for s, m in zip(stages, allocate(stages, cfg, dual, hw, scheme)):
        if groups and groups[-1].mesh == m:
            groups[-1].stages.append(s)
        else:
            groups.append(MeshGroup(m, [s]))
    return DualSchedule(groups, cfg, dual, hw, scheme, n_streams)


def load_balance(sched: DualSchedule, rounds: int = 32) -> DualSchedule:
    """Alg.1 analogue: split the boundary stage of the worst-gap pair along
    its sequence (prefill) or steps (decode) and move the remainder to the
    neighbouring group on the other submesh.  Optimizes the schedule's own
    N-stream makespan, so the split point shifts with N."""
    s = DualSchedule([MeshGroup(g.mesh, list(g.stages))
                      for g in sched.groups], sched.cfg, sched.dual,
                     sched.hw, sched.scheme + "+lb", sched.n_streams)
    best = s.makespan()
    for _ in range(rounds):
        t = s.latencies()
        if len(t) < 2:
            break
        pairs = sorted(range(len(t) - 1), key=lambda i: -abs(t[i] - t[i + 1]))
        improved = False
        for pi in pairs:
            longer, shorter = (pi, pi + 1) if t[pi] > t[pi + 1] \
                else (pi + 1, pi)
            val = _try_split(s, longer, shorter, best)
            if val is not None and val < best - 1e-12:
                best = val
                improved = True
                break
        if not improved:
            break
    return s


def _try_split(s: DualSchedule, longer: int, shorter: int,
               best: float) -> float | None:
    gl = s.groups[longer]
    if not gl.stages:
        return None
    tail = longer < shorter
    st = gl.stages[-1] if tail else gl.stages[0]
    axis = st.seq if st.kind == "prefill" else st.steps
    if axis < 2:
        return None
    best_cut, best_val = None, best
    step = max(1, axis // 16)
    for cut in range(step, axis, step):
        a, b = (st.split_seq(cut) if st.kind == "prefill"
                else st.split_steps(cut))
        keep, move = (a, b) if tail else (b, a)
        trial = [MeshGroup(g.mesh, list(g.stages)) for g in s.groups]
        if tail:
            trial[longer].stages[-1] = keep
            trial[shorter].stages.insert(0, move)
        else:
            trial[longer].stages[0] = keep
            trial[shorter].stages.append(move)
        val = DualSchedule(trial, s.cfg, s.dual, s.hw,
                           n_streams=s.n_streams).makespan()
        if val < best_val:
            best_val, best_cut = val, cut
    if best_cut is None:
        return None
    a, b = (st.split_seq(best_cut) if st.kind == "prefill"
            else st.split_steps(best_cut))
    keep, move = (a, b) if tail else (b, a)
    if tail:
        gl.stages[-1] = keep
        s.groups[shorter].stages.insert(0, move)
    else:
        gl.stages[0] = keep
        s.groups[shorter].stages.append(move)
    return best_val


def best_schedule(stages, cfg, dual: DualMesh,
                  hw: TpuModel = TpuModel(),
                  with_load_balance: bool = True,
                  n_streams: int = 2) -> DualSchedule:
    cands = []
    for scheme in ALLOCATIONS:
        b = build(stages, cfg, dual, hw, scheme, n_streams)
        cands.append(b)
        if with_load_balance:
            cands.append(load_balance(b))
    return min(cands, key=lambda x: x.makespan())


# ==========================================================================
# Makespan-aware admission (the runtime's continuous-batching policy)
# ==========================================================================
@dataclasses.dataclass(frozen=True)
class AdmissionPlan:
    """Decode-fusion policy for a homogeneous request queue: admit new
    streams whenever the c-submesh is idle; launch a fused decode group as
    soon as ``group_size`` streams are prefilled (or the queue drains)."""
    n_streams: int
    group_size: int
    est_makespan: float
    est_tokens_per_s: float


def _submesh_tp(dual: DualMesh, mesh: str) -> int:
    m = dual.c_mesh if mesh == "c" else dual.p_mesh
    return m.shape.get("model", 1)


def wave_makespan(cfg: ArchConfig, dual: DualMesh, hw: TpuModel,
                  batch: int, prompt_len: int, gen_steps: int,
                  n_streams: int, group_size: int) -> float:
    """Projected makespan of the wave-fused execution: prefills serialize
    on the c-submesh (one stream per wave slot); each decode group of
    ``group_size`` streams runs batched (batch*size) on the p-submesh and
    can only launch once its last member has prefilled."""
    t_pf = prefill_cost(cfg, batch, prompt_len, dual.c_chips, hw,
                        _submesh_tp(dual, "c")).latency
    tp_p = _submesh_tp(dual, "p")
    p_free = 0.0
    admitted = 0
    while admitted < n_streams:
        size = min(group_size, n_streams - admitted)
        admitted += size
        prefill_done = admitted * t_pf          # c-submesh serialized
        t_dec = decode_cost(cfg, batch * size, prompt_len + gen_steps,
                            dual.p_chips, gen_steps, hw, tp_p).latency
        p_free = max(p_free, prefill_done) + t_dec
    return p_free


def plan_admission(cfg: ArchConfig, dual: DualMesh, hw: TpuModel,
                   batch: int, prompt_len: int, gen_steps: int,
                   n_streams: int,
                   max_group: int | None = None) -> AdmissionPlan:
    """Pick the decode fusion size minimizing projected makespan.

    Small groups maximize prefill/decode overlap (a group launches early);
    large groups amortize the per-step decode floor over a bigger fused
    batch (decode is floor/memory-bound, cost.TpuModel.step_floor).  The
    argmin trades the two — the N-stream generalization of the paper's
    workload-balancing between the two cores."""
    hi = min(n_streams, max_group or n_streams)
    best: AdmissionPlan | None = None
    toks = n_streams * batch * (prompt_len + gen_steps)
    for g in range(1, max(1, hi) + 1):
        span = wave_makespan(cfg, dual, hw, batch, prompt_len, gen_steps,
                             n_streams, g)
        if best is None or span < best.est_makespan - 1e-12:
            best = AdmissionPlan(n_streams, g, span,
                                 toks / span if span else float("inf"))
    assert best is not None
    return best
