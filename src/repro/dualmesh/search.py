"""Co-optimisation of the device split and the schedule — the paper's §V-B
branch-and-bound, re-targeted from DSP ratios to submesh splits.

Branch on theta (c-submesh chip share, Eq.10 analogue), bound with the
ideal roofline (Eq.11 analogue: every stage at its best submesh's peak,
ignoring scheduling structure), then local-search the discrete knobs
(tp_c, tp_p — the (n, v) analogue: chips x TP width per submesh).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.dualmesh.cost import TpuModel
from repro.dualmesh.partition import DualMesh, split_mesh
from repro.dualmesh.schedule import Stage, best_schedule, stage_cost
from repro.lm.config import ArchConfig

TP_CANDIDATES = (1, 2, 4, 8, 16)


@dataclasses.dataclass
class DualSearchResult:
    dual: DualMesh
    theta: float
    tp_c: int
    tp_p: int
    makespan: float
    tokens_per_s: float
    schedule: object
    visited: list[float]
    n_streams: int = 2


def makespan_lower_bound(stages: Sequence[Stage], cfg: ArchConfig,
                         n_devices: int, theta: float,
                         hw: TpuModel) -> float:
    """Eq.11 analogue: each stage at the ideal rate of its preferred
    submesh, perfect overlap across the two submeshes."""
    n_c = max(1, round(theta * n_devices))
    n_p = max(1, n_devices - n_c)
    t_c = t_p = 0.0
    for s in stages:
        cost_c = stage_cost(s, cfg, n_c, min(16, n_c), hw)
        cost_p = stage_cost(s, cfg, n_p, min(16, n_p), hw)
        if cost_c <= cost_p:
            t_c += cost_c
        else:
            t_p += cost_p
    return max(t_c, t_p)      # perfect pipeline: the busier mesh bounds


def search(stages: Sequence[Stage], cfg: ArchConfig, devices=None,
           n_devices: int | None = None, hw: TpuModel = TpuModel(),
           max_evals: int = 16, n_streams: int = 2) -> DualSearchResult:
    """Plan on chip counts (``n_devices``, abstract) or on real devices.
    ``n_streams`` is the number of concurrent staggered request streams
    the schedule is optimized for (2 = the paper's two-image case)."""
    from repro.dualmesh.partition import abstract_split
    import jax
    devs = list(devices) if devices is not None else None
    n = n_devices or (len(devs) if devs else len(jax.devices()))
    use_abstract = devs is None or len(devs) < n
    incumbent: DualSearchResult | None = None
    visited: list[float] = []

    def fits(tp: int, chips: int) -> bool:
        """Per-device HBM: TP-sharded weights + this workload's KV share."""
        w = 2.0 * cfg.param_count() / max(1, tp)
        kv = 0.0
        for s in stages:
            if s.kind == "decode" and cfg.block_type == "transformer":
                kv += (2.0 * cfg.n_layers * s.batch * cfg.n_kv_heads
                       * cfg.d_head * s.seq * 2) / max(1, chips)
        return w + kv <= 0.75 * hw.hbm_bytes

    def evaluate(theta: float, relax: bool = False):
        nonlocal incumbent
        visited.append(theta)
        for tp_c in TP_CANDIDATES:
            for tp_p in TP_CANDIDATES:
                if tp_c > n or tp_p > n:
                    continue
                if use_abstract:
                    dual = abstract_split(n, theta, tp_c, tp_p)
                else:
                    dual = split_mesh(devs, theta, tp_c, tp_p)
                if not relax and not (fits(tp_c, dual.c_chips)
                                      and fits(tp_p, dual.p_chips)):
                    continue
                sched = best_schedule(stages, cfg, dual, hw,
                                      n_streams=n_streams)
                ms = sched.makespan()
                if incumbent is None or ms < incumbent.makespan:
                    incumbent = DualSearchResult(
                        dual=dual, theta=dual.theta, tp_c=tp_c, tp_p=tp_p,
                        makespan=ms,
                        tokens_per_s=sched.throughput_tokens_per_s(),
                        schedule=sched, visited=visited,
                        n_streams=n_streams)

    evaluate(0.5)
    work = [(0.1, 0.9)]
    while work and len(visited) < max_evals:
        lo, hi = work.pop(0)
        if hi - lo < 0.08:
            continue
        mid = 0.5 * (lo + hi)
        # admissible at any n_streams: the N-stream makespan is bounded
        # below by one chain's busy time.  (Scaling by n_streams is NOT
        # admissible — the bound's per-stage best-mesh assignment can
        # exceed what a split/balanced schedule achieves per stream, and
        # an inadmissible bound prunes every theta after the first.)
        lb = makespan_lower_bound(stages, cfg, n, mid, hw)
        if incumbent is not None and lb >= incumbent.makespan:
            continue                      # prune (early termination, §V-B2)
        evaluate(mid)
        work += [(lo, mid), (mid, hi)]
    if incumbent is None:
        # no (theta, tp) combo satisfies the HBM constraint at bf16 weights
        # (e.g. 104B on a 256-chip pod): fall back to the best-effort plan
        # and let the caller see it — weight quantization territory.
        evaluate(0.5, relax=True)
    assert incumbent is not None
    incumbent.visited = visited
    return incumbent
