"""repro.fleet — multi-network serving over one device pool (DESIGN.md §10).

Multiplexes several models through one front end: a :class:`DevicePool`
leases the shared c/p submesh split to every member engine, a
:class:`Router` routes model-tagged requests and picks which member's exec
group dispatches each step (round-robin / shortest-queue / weighted-fair /
deadline-EDF), :class:`FleetEngine` implements the ``repro.serving``
protocol over the members (interleaving core-complementary groups from
*different* networks on the two submeshes — the multi-network Fig.4b),
and :func:`plan_fleet` co-schedules a ``{model: qps share}`` mix through
the §V-B design-space search (the Table VII flow).
"""
from repro.fleet.engine import FleetEngine, Member, build_cnn_fleet
from repro.fleet.planner import (FleetPlan, mix_schedule, normalize_mix,
                                 plan_fleet, plan_rows)
from repro.fleet.pool import DevicePool, Lease
from repro.fleet.router import (POLICY_NAMES, DeadlineEDF, MemberView,
                                RoundRobin, Router, SchedulingPolicy,
                                ShortestQueue, WeightedFair, make_policy)

__all__ = [
    "DeadlineEDF",
    "DevicePool",
    "FleetEngine",
    "FleetPlan",
    "Lease",
    "Member",
    "MemberView",
    "POLICY_NAMES",
    "RoundRobin",
    "Router",
    "SchedulingPolicy",
    "ShortestQueue",
    "WeightedFair",
    "build_cnn_fleet",
    "make_policy",
    "mix_schedule",
    "normalize_mix",
    "plan_fleet",
    "plan_rows",
]
