"""repro.fleet — multi-network serving over one device pool (DESIGN.md §10).

Multiplexes several models through one front end: a :class:`DevicePool`
leases the shared c/p submesh split to every member engine, a
:class:`Router` routes model-tagged requests and picks which member's exec
group dispatches each step (round-robin / shortest-queue / weighted-fair /
deadline-EDF), :class:`FleetEngine` implements the ``repro.serving``
protocol over the members (interleaving core-complementary groups from
*different* networks on the two submeshes — the multi-network Fig.4b),
and :func:`plan_fleet` co-schedules a ``{model: qps share}`` mix through
the §V-B design-space search (the Table VII flow).

Fleet execution itself is instruction-based (DESIGN.md §11): every
``FleetEngine.step`` lowers its scheduling decisions to RUN/FREE
instructions (:mod:`repro.fleet.instructions`, :mod:`~.compiler`) executed
and recorded by a :class:`PoolExecutor`; :func:`compile_fleet` lowers a
whole run ahead of time, and :class:`MultiPoolRouter` drives N pools as
one engine with SEND/RECV migration and REBALANCE theta re-leasing.

Fault tolerance (DESIGN.md §12): a seeded :class:`FaultPlan` armed as a
:class:`FaultInjector` perturbs execution at instruction boundaries
(injected RUN errors, pool crashes, dropped SENDs, latency skew); the
executor retries within a :class:`RecoveryConfig` budget, the router
recovers crashed pools' un-retired requests onto survivors, and every
recovery decision lands in a seq-watermarked event log that replays
bitwise alongside the instruction streams.

Closed-loop SLO adaptation (DESIGN.md §13): a :class:`ControlLoop`
attached to a fleet observes a sliding completion window every K slots
and injects SET_PARAM (member weight, LM fusion width) and REBALANCE
instructions into the recorded stream, with a seq-watermarked decision
log as the audit trail — controlled runs replay bitwise with no
controller attached.

Distributed transport (DESIGN.md §14): :mod:`repro.fleet.net` binds the
router's SEND/RECV mailbox surface three ways — :class:`LocalTransport`
(the in-memory default), :class:`FileTransport` (spool directory), and
``SocketTransport`` behind real worker processes
(``python -m repro.fleet.worker``) driven by the unchanged
:class:`MultiPoolRouter` placement/migration/recovery logic.
"""
from repro.fleet.compiler import (SlotCompiler, compile_fleet,
                                  stream_signature, validate_stream)
from repro.fleet.control import (ControlAction, ControlLoop, Decision,
                                 RebalanceTheta, Retune, Reweight,
                                 decisions_from_json, decisions_to_json,
                                 dump_decisions, load_decisions,
                                 lower_action, verify_decisions)
from repro.fleet.engine import FleetEngine, Member, build_cnn_fleet
from repro.fleet.executor import MultiPoolRouter, PoolExecutor
from repro.fleet.faults import (Fault, FaultInjector, FaultPlan,
                                InjectedFault, PoolCrash, RecoveryConfig)
from repro.fleet.instructions import (COMPAT_VERSIONS, SCHEMA_VERSION,
                                      ExecRecord, Free, Instruction,
                                      Rebalance, Recv, Run, Send, SetParam,
                                      dump_stream, load_stream,
                                      stream_from_json, stream_to_json)
from repro.fleet.net import FileTransport, LocalTransport, SocketTransport
from repro.fleet.planner import (FleetPlan, mix_schedule, normalize_mix,
                                 plan_fleet, plan_rows)
from repro.fleet.pool import DevicePool, Lease
from repro.fleet.router import (POLICY_NAMES, DeadlineEDF, MemberView,
                                RoundRobin, Router, SchedulingPolicy,
                                ShortestQueue, WeightedFair, make_policy)

__all__ = [
    "COMPAT_VERSIONS",
    "ControlAction",
    "ControlLoop",
    "DeadlineEDF",
    "Decision",
    "DevicePool",
    "ExecRecord",
    "Fault",
    "FaultInjector",
    "FaultPlan",
    "FileTransport",
    "FleetEngine",
    "FleetPlan",
    "Free",
    "InjectedFault",
    "Instruction",
    "Lease",
    "LocalTransport",
    "Member",
    "MemberView",
    "MultiPoolRouter",
    "POLICY_NAMES",
    "PoolCrash",
    "PoolExecutor",
    "Rebalance",
    "RebalanceTheta",
    "RecoveryConfig",
    "Recv",
    "Retune",
    "Reweight",
    "RoundRobin",
    "Router",
    "Run",
    "SCHEMA_VERSION",
    "SchedulingPolicy",
    "Send",
    "SetParam",
    "ShortestQueue",
    "SlotCompiler",
    "SocketTransport",
    "WeightedFair",
    "build_cnn_fleet",
    "compile_fleet",
    "decisions_from_json",
    "decisions_to_json",
    "dump_decisions",
    "dump_stream",
    "load_decisions",
    "load_stream",
    "lower_action",
    "make_policy",
    "mix_schedule",
    "normalize_mix",
    "plan_fleet",
    "plan_rows",
    "stream_from_json",
    "stream_signature",
    "stream_to_json",
    "validate_stream",
    "verify_decisions",
]
