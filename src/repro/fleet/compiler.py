"""Lower fleet scheduling decisions into instruction streams.

Two entry points, one decision kernel:

* :class:`SlotCompiler.lower_slot` is the single place cross-engine
  scheduling decisions become instructions.  Given the per-member
  :class:`~repro.fleet.router.MemberView`\\ s of one scheduler slot it asks
  the :class:`~repro.fleet.router.SchedulingPolicy` for the primary member,
  orders the co-dispatched rest core-complementary-first (the cross-network
  Fig.4b move), applies the ``co_dispatch`` width and ``burst`` depth, and
  emits ``RUN*(pure) RUN*(fused) FREE*`` — dispatches strictly before any
  materialization, the block-last rule as an instruction ordering invariant
  instead of a loop convention.  The live ``FleetEngine.step`` is now a
  shim over exactly this (compile one slot, execute it).

* :func:`compile_fleet` lowers a whole run ahead of time: it simulates the
  ``replay`` driving loop against :class:`MemberModel` mirrors of the
  member engines — queue depth, pipeline occupancy, per-group cores and
  latencies, the admission policy — without touching a device, and returns
  the full :class:`~repro.fleet.instructions.ExecRecord` stream the live
  fleet would execute for that arrival trace.  Replaying it through
  ``fleet.executor.PoolExecutor.replay`` reproduces the live dispatch
  trace and outputs bitwise (tested); this is what makes per-pool state
  serializable — a router can ship the stream to a pool instead of
  holding a Python loop over its engines.

Members whose slot dynamics the mirror cannot model (an opaque engine with
no ``advance``/``retire`` split and no declared service model, e.g. the LM
``DualMeshEngine``) are rejected by :func:`compile_fleet` with a pointer
at the recorded-stream path: the live shim records the same instruction
stream it executes, which replays identically.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Sequence

from repro.fleet.instructions import ExecRecord, Free, Instruction, Run
from repro.fleet.router import MemberView, SchedulingPolicy
from repro.serving.api import Request


def observe(index: int, name: str, engine, *, weight: float,
            dispatches: int, want_deadlines: bool,
            want_cores: bool) -> MemberView | None:
    """Build the policy-facing view of one member (or None when it has no
    work).  Shared by the live ``FleetEngine._views`` and the compiler's
    mirror loop so compiled and live decisions see identical inputs.
    ``head_deadline`` costs an O(queue) scan and ``next_core`` a walk over
    the in-flight groups — pay them only when something reads them."""
    if not engine.has_work:
        return None
    head = None
    if want_deadlines and hasattr(engine, "pending_requests"):
        deadlines = [r.deadline for r in engine.pending_requests()
                     if r.deadline is not None]
        head = min(deadlines) if deadlines else None
    return MemberView(
        index=index, name=name, queued=engine.queued,
        in_flight=engine.in_flight, weight=weight, dispatches=dispatches,
        head_deadline=head,
        next_core=(getattr(engine, "next_core", None)
                   if want_cores else None),
        has_work=True,
        batched=hasattr(engine, "advance"))


class SlotCompiler:
    """Lowers one scheduler slot's decisions into instructions."""

    def __init__(self, policy: SchedulingPolicy, *,
                 co_dispatch: int | None = None, burst: int = 1):
        self.policy = policy
        self.co_dispatch = co_dispatch
        self.burst = burst

    @property
    def uses_deadlines(self) -> bool:
        """True when the scheduling policy orders by request deadlines."""
        return getattr(self.policy, "uses_deadlines", False)

    @property
    def wants_cores(self) -> bool:
        """True when co-dispatch needs each member's dominant core."""
        return self.co_dispatch is None or self.co_dispatch > 0

    def lower_slot(self, views: Sequence[MemberView],
                   total_dispatches: int) -> list[Instruction]:
        """One slot: policy primary first, then up to ``co_dispatch``
        members core-complementary-first, each RUN up to ``burst`` slots
        deep; every RUN precedes every FREE."""
        i = self.policy.pick(views, total_dispatches)
        by_index = {v.index: v for v in views}
        if i not in by_index:
            raise ValueError(f"policy {self.policy!r} picked member {i}, "
                             f"not among workable {sorted(by_index)}")
        primary = by_index[i]
        batch = [primary]
        rest = [v for v in views if v.index != primary.index]
        if rest and self.wants_cores:
            want = "p" if primary.next_core == "c" else "c"
            # complementary dominant core first, then member order
            rest.sort(key=lambda v: (v.next_core != want, v.index))
            limit = (len(rest) if self.co_dispatch is None
                     else self.co_dispatch)
            batch.extend(rest[:limit])
        runs = [Run(member=v.name, slots=self.burst, core=v.next_core,
                    primary=v.index == primary.index)
                for v in batch if v.batched]
        # opaque members fuse dispatch and block — run them after every
        # pure dispatch is in flight, before any deferrable FREE
        fused = [Run(member=v.name, slots=self.burst, core=v.next_core,
                     primary=v.index == primary.index, fused=True)
                 for v in batch if not v.batched]
        frees = [Free(member=v.name) for v in batch if v.batched]
        return runs + fused + frees


# --------------------------------------------------------------------------
# ahead-of-time compilation against member mirrors
# --------------------------------------------------------------------------
class CompileError(ValueError):
    """The fleet configuration cannot be lowered ahead of time."""


@dataclasses.dataclass
class _Flight:
    remaining_or_group: int          # pipeline: next group; service: left


class MemberModel:
    """Device-free mirror of one member engine's slot dynamics.

    Two shapes, both exact:

    * ``pipeline`` (a ``DualCoreEngine``): capacity = number of exec
      groups, streams advance one group per slot, at most one admission
      per slot into group 0, ``next_core`` priced from the exec
      schedule's per-group latencies — the same arithmetic as
      ``DualCoreEngine.next_dispatch_cycles``.
    * ``service`` (any engine declaring ``capacity`` + ``service_steps`` +
      a fixed ``next_core``, e.g. the test stubs): requests occupy a slot
      for ``service_steps`` advances, admissions per the policy's count.
    """

    def __init__(self, name: str, *, capacity: int, max_queue: int | None,
                 policy, kind: str, service_steps: int = 1,
                 group_cores: Sequence[str] = (),
                 group_latencies: Sequence[float] = (),
                 fixed_core: str | None = None):
        self.name = name
        self.capacity = capacity
        self.max_queue = max_queue
        self.policy = policy
        self.kind = kind
        self.service_steps = service_steps
        self.group_cores = list(group_cores)
        self.group_latencies = list(group_latencies)
        self.fixed_core = fixed_core
        self._pending: list[Request] = []
        self._flight: list[int] = []         # pipeline: next group index;
        #                                      service: remaining advances
        self.completed = 0
        self.shed = 0

    # -- construction ---------------------------------------------------
    @classmethod
    def of_engine(cls, name: str, engine) -> "MemberModel":
        """Build the device-free mirror of one live member engine."""
        runner = getattr(engine, "runner", None)
        if runner is not None and hasattr(runner, "plan"):
            sched = runner.plan.exec_schedule
            return cls(name, capacity=len(runner.groups),
                       max_queue=engine.max_queue, policy=engine.policy,
                       kind="pipeline",
                       group_cores=[g.core for g in runner.groups],
                       group_latencies=list(sched.group_latencies))
        if hasattr(engine, "service_steps") and hasattr(engine, "capacity"):
            return cls(name, capacity=engine.capacity,
                       max_queue=engine.max_queue,
                       policy=getattr(engine, "policy", None),
                       kind="service",
                       service_steps=engine.service_steps,
                       fixed_core=getattr(engine, "next_core", None)
                       or getattr(engine, "_core", None))
        raise CompileError(
            f"member {name!r} ({type(engine).__name__}) is opaque — no "
            f"advance/retire split and no declared service model — so its "
            f"slot dynamics cannot be mirrored ahead of time; drive the "
            f"live FleetEngine (its step() records the same instruction "
            f"stream it executes) and replay that")

    # -- the engine-shaped surface `observe` reads ----------------------
    @property
    def has_work(self) -> bool:
        """True while the mirror holds queued or in-flight work."""
        return bool(self._pending or self._flight)

    @property
    def queued(self) -> int:
        """Requests waiting for admission."""
        return len(self._pending)

    @property
    def in_flight(self) -> int:
        """Streams currently in the mirrored pipeline."""
        return len(self._flight)

    def pending_requests(self) -> list[Request]:
        """Snapshot of the queued (unadmitted) requests."""
        return list(self._pending)

    @property
    def next_core(self) -> str | None:
        """Dominant core of the next dispatch (None when idle)."""
        if not self.has_work:
            return None
        if self.kind == "service":
            return self.fixed_core
        cyc = {"c": 0.0, "p": 0.0}
        for g in self._flight:
            cyc[self.group_cores[g]] += self.group_latencies[g]
        if self._pending and len(self._flight) < self.capacity:
            cyc[self.group_cores[0]] += self.group_latencies[0]
        return "c" if cyc["c"] >= cyc["p"] else "p"

    # -- dynamics -------------------------------------------------------
    def submit(self, req: Request) -> bool:
        """Mirror of ``EngineBase.submit``: False = QueueFull refusal."""
        if self.max_queue is not None \
                and len(self._pending) >= self.max_queue:
            return False
        self._pending.append(req)
        return True

    def _pop_admission(self) -> Request:
        select = getattr(self.policy, "select", None)
        if select is None or len(self._pending) <= 1:
            return self._pending.pop(0)
        return self._pending.pop(int(select(list(self._pending))))

    def shed_expired(self, now: float | int) -> int:
        """Mirror of ``EngineBase.shed_expired`` under the executor's
        slot clock: drop past-deadline queue entries before the slot's
        admission, so the compiled stream prices the same queue the live
        run admits from.  Returns the number shed."""
        pol = self.policy
        if not getattr(pol, "sheds", False):
            return 0
        kept = [r for r in self._pending
                if r.deadline is None
                or not pol.expired(r.deadline, pol.now(float(now)))]
        n = len(self._pending) - len(kept)
        self._pending = kept
        self.shed += n
        return n

    def advance(self) -> int:
        """One scheduler slot; returns the number of streams finishing."""
        finished = 0
        if self.kind == "pipeline":
            kept = []
            for g in self._flight:
                if g + 1 >= self.capacity:
                    finished += 1
                else:
                    kept.append(g + 1)
            self._flight = kept
            n = self.policy.admit(queued=len(self._pending),
                                  in_flight=len(self._flight),
                                  capacity=self.capacity)
            n = max(0, min(n, 1, self.capacity - len(self._flight),
                           len(self._pending)))
            if n:
                self._pop_admission()
                if self.capacity <= 1:          # single-group chain
                    finished += 1
                else:
                    self._flight.append(1)
        else:
            for i in range(len(self._flight)):
                self._flight[i] -= 1
            finished = sum(1 for r in self._flight if r <= 0)
            self._flight = [r for r in self._flight if r > 0]
            n = (self.policy.admit(queued=len(self._pending),
                                   in_flight=len(self._flight),
                                   capacity=self.capacity)
                 if self.policy is not None else len(self._pending))
            for _ in range(max(0, min(n, len(self._pending),
                                      self.capacity - len(self._flight)))):
                self._pop_admission()
                self._flight.append(self.service_steps)
        self.completed += finished
        return finished


def compile_fleet(fleet, requests: Sequence[Request],
                  arrivals: Sequence[int] | None = None
                  ) -> list[ExecRecord]:
    """Lower a ``FleetEngine`` configuration + its policy's decisions into
    the instruction stream ``replay(fleet, requests, arrivals)`` would
    execute — ahead of time, against member mirrors, touching no device.

    The policy object is deep-copied (stateful policies like RoundRobin
    must not have their live state consumed by compilation).  Requests
    only contribute their routing/ordering metadata (model tag, deadline,
    priority); payloads never enter the stream.
    """
    if getattr(fleet, "controller", None) is not None:
        raise CompileError(
            "cannot compile a fleet with a ControlLoop attached: the "
            "controller's decisions depend on observed latencies and "
            "arrival timing, which no device-free mirror can predict "
            "ahead of time; drive the live FleetEngine (its step() "
            "records every injected SET_PARAM/REBALANCE) and replay the "
            "recorded stream")
    models: dict[str, MemberModel] = {
        m.name: MemberModel.of_engine(m.name, m.engine)
        for m in fleet.members}
    weights = {m.name: m.weight for m in fleet.members}
    compiler = SlotCompiler(copy.deepcopy(fleet.policy),
                            co_dispatch=fleet.co_dispatch,
                            burst=fleet.burst)
    arrivals = (list(arrivals) if arrivals is not None
                else [0] * len(requests))
    if len(arrivals) != len(requests):
        raise ValueError(f"{len(requests)} requests but "
                         f"{len(arrivals)} arrival times")
    order = sorted(range(len(requests)), key=lambda i: arrivals[i])
    dispatches = dict.fromkeys(models, 0)
    total_dispatches = 0
    stream: list[ExecRecord] = []
    slot = 0                     # fleet slot counter (skips empty views)
    seq = 0
    refused: list[int] = []
    nxt, step = 0, 0
    names = list(models)
    while nxt < len(order) or refused \
            or any(m.has_work for m in models.values()):
        due, refused = refused, []
        while nxt < len(order) and arrivals[order[nxt]] <= step:
            due.append(order[nxt])
            nxt += 1
        for i in due:
            req = (requests[i] if isinstance(requests[i], Request)
                   else Request(requests[i]))
            name = fleet.router.route(req)
            if not models[name].submit(req):
                refused.append(i)
            # refused requests retry first next step, like replay()
        views = [v for v in (
            observe(i, n, models[n], weight=weights[n],
                    dispatches=dispatches[n],
                    want_deadlines=compiler.uses_deadlines,
                    want_cores=compiler.wants_cores)
            for i, n in enumerate(names)) if v is not None]
        if views:
            for instr in compiler.lower_slot(views, total_dispatches):
                adv = 0
                if isinstance(instr, Run):
                    model = models[instr.member]
                    model.shed_expired(slot)    # same dispatch-boundary
                    #       sweep the executor runs (slot clock), so the
                    #       mirror admits from the same queue
                    for _ in range(instr.slots):
                        if not model.has_work:
                            break
                        model.advance()
                        adv += 1
                    dispatches[instr.member] += adv
                    total_dispatches += adv
                stream.append(ExecRecord(instr=instr, slot=slot, seq=seq,
                                         advances=adv))
                seq += 1
            slot += 1
        step += 1
    return stream


def stream_signature(records: Sequence[ExecRecord]
                     ) -> list[tuple[int, int, Instruction, int]]:
    """The replay-comparable core of a stream: (seq, slot, instruction,
    advances) — wall-clock stamps excluded (they never reproduce)."""
    return [(r.seq, r.slot, r.instr, r.advances) for r in records]


def validate_stream(records: Sequence[ExecRecord]) -> None:
    """Structural invariants every well-formed stream satisfies: slots
    monotone, seq strictly increasing, and within a slot every RUN
    precedes every FREE (the block-last rule)."""
    last_slot, last_seq = -1, -1
    freed_in_slot = False
    for r in records:
        if r.slot < last_slot:
            raise ValueError(f"slot went backwards at seq {r.seq}: "
                             f"{last_slot} -> {r.slot}")
        if r.seq <= last_seq:
            raise ValueError(f"seq not strictly increasing at {r.seq}")
        if r.slot != last_slot:
            freed_in_slot = False
        if isinstance(r.instr, Free):
            freed_in_slot = True
        elif isinstance(r.instr, Run) and freed_in_slot:
            raise ValueError(f"RUN after FREE within slot {r.slot} "
                             f"(seq {r.seq}): dispatch must precede "
                             f"materialization")
        last_slot, last_seq = r.slot, r.seq
