"""Closed-loop SLO adaptation for fleet serving (DESIGN.md §13).

PR 7 shipped the *open* half of SLO handling: deadline shedding, goodput
accounting, seeded chaos.  Weights, theta, and the LM fusion width were
still frozen at plan time, so a drifting traffic mix could only shed its
way back under the SLO.  :class:`ControlLoop` closes the loop: every
``interval`` fleet slots it observes a sliding window of per-model
completions (:class:`~repro.serving.api.MetricsWindow` p95 + shed rate,
queue depth, and the router's arrival tallies) and emits typed
:data:`ControlAction`\\ s:

  ================  =====================================================
  action            trigger -> lowering
  ================  =====================================================
  Reweight          window arrival mix drifts > ``reweight_deadband``
                    (total-variation) from the members' normalized
                    weights -> one ``SET_PARAM(member, "weight", share)``
                    per member, snapping weighted-fair entitlements to
                    the observed mix
  Retune            a retunable member's window p95 breaches
                    ``band[1] * slo_ms`` -> ``SET_PARAM(member,
                    "group_size", width // 2)`` (smaller fusion width =
                    lower queueing delay per admitted stream); once
                    breached, p95 back under ``band[0] * slo_ms`` widens
                    it again toward the configured width (the two-band
                    rule is the hysteresis)
  RebalanceTheta    aggregate window shed rate > ``shed_high`` for
                    ``sustain`` consecutive observations ->
                    ``REBALANCE(theta)`` re-planned for the observed
                    mix; the trigger re-arms only after the rate falls
                    below ``shed_low`` (hysteresis), and ``cooldown``
                    observations must pass after *any* REBALANCE — the
                    controller's own or a §12 recovery's — before
                    another fires (the §12 interlock)
  ================  =====================================================

Actions lower through the instruction stream (``executor.inject``), so a
controlled run replays bitwise from its recorded stream with **no
controller attached** — the mutations are instructions, not side
effects.  Each emitted action is also appended to :attr:`decisions`, a
seq-watermarked decision log (the audit trail binding every injected
instruction to the window stats that motivated it), serializable via
:func:`decisions_to_json` and checkable against a stream via
:func:`verify_decisions` — the same recipe shape as §12's recovery
event log.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.fleet.instructions import (ExecRecord, Instruction, Rebalance,
                                      SetParam)
from repro.serving.api import Completion, MetricsWindow


# --------------------------------------------------------------------------
# typed actions
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Reweight:
    """Set one member's fleet weight share toward the observed mix."""

    member: str
    weight: float

    kind = "reweight"


@dataclasses.dataclass(frozen=True)
class Retune:
    """Set one retunable engine knob (e.g. the LM ``group_size``)."""

    member: str
    param: str
    value: int

    kind = "retune"


@dataclasses.dataclass(frozen=True)
class RebalanceTheta:
    """Re-lease the pool's c/p split at a newly planned theta."""

    theta: float

    kind = "rebalance"


Action = Reweight | Retune | RebalanceTheta

#: what a ControlLoop emits (alias kept for the public API surface)
ControlAction = Action

_KIND_TYPES = {"reweight": Reweight, "retune": Retune,
               "rebalance": RebalanceTheta}


def lower_action(action: Action) -> Instruction:
    """Lower one control action to its fleet instruction."""
    if isinstance(action, Reweight):
        return SetParam(member=action.member, param="weight",
                        value=float(action.weight))
    if isinstance(action, Retune):
        return SetParam(member=action.member, param=action.param,
                        value=action.value)
    if isinstance(action, RebalanceTheta):
        return Rebalance(theta=action.theta)
    raise TypeError(f"unknown control action {action!r}")


# --------------------------------------------------------------------------
# the decision log
# --------------------------------------------------------------------------
DECISION_LOG_VERSION = 1


@dataclasses.dataclass
class Decision:
    """One emitted action: its stream position and its evidence.

    ``seq`` is the stream sequence number of the instruction the action
    lowered to (captured as the watermark at injection), ``slot`` the
    fleet slot it was injected at, ``reason`` a human-readable trigger
    description, and ``observed`` the compact window-stats snapshot that
    motivated it.  The stream alone replays the run; the decision log is
    the audit trail tying each injected instruction back to *why*.
    """

    seq: int
    slot: int
    action: Action
    reason: str
    observed: dict = dataclasses.field(default_factory=dict)


def decisions_to_json(decisions: Sequence[Decision]) -> dict:
    """Serialize a decision log (versioned, like the instruction schema)."""
    return {
        "version": DECISION_LOG_VERSION,
        "decisions": [{
            "seq": d.seq,
            "slot": d.slot,
            "kind": d.action.kind,
            "action": dataclasses.asdict(d.action),
            "reason": d.reason,
            "observed": d.observed,
        } for d in decisions],
    }


def decisions_from_json(doc: dict) -> list[Decision]:
    """Deserialize a decision log; unknown versions/kinds are hard errors."""
    version = doc.get("version")
    if version != DECISION_LOG_VERSION:
        raise ValueError(f"decision log version {version!r} != supported "
                         f"{DECISION_LOG_VERSION}")
    out = []
    for d in doc["decisions"]:
        kind = d.get("kind")
        if kind not in _KIND_TYPES:
            raise ValueError(f"unknown decision kind {kind!r}; one of "
                             f"{sorted(_KIND_TYPES)}")
        out.append(Decision(seq=d["seq"], slot=d["slot"],
                            action=_KIND_TYPES[kind](**d["action"]),
                            reason=d.get("reason", ""),
                            observed=d.get("observed", {})))
    return out


def dump_decisions(decisions: Sequence[Decision], path: str) -> None:
    """Write a decision log next to its streams (JSON)."""
    with open(path, "w") as f:
        json.dump(decisions_to_json(decisions), f, indent=1)


def load_decisions(path: str) -> list[Decision]:
    """Read a decision log written by :func:`dump_decisions`."""
    with open(path) as f:
        return decisions_from_json(json.load(f))


def verify_decisions(records: Sequence[ExecRecord],
                     decisions: Sequence[Decision]) -> None:
    """Check a decision log against the stream it annotates.

    Every decision must point (by ``seq``) at a record whose instruction
    is exactly the decision's action lowered — the invariant that makes
    the log an audit trail of the stream rather than a parallel story.
    Raises ``ValueError`` on any mismatch.
    """
    by_seq = {r.seq: r for r in records}
    for d in decisions:
        r = by_seq.get(d.seq)
        if r is None:
            raise ValueError(f"decision at seq {d.seq} has no matching "
                             f"stream record")
        want = lower_action(d.action)
        if r.instr != want:
            raise ValueError(f"decision at seq {d.seq} lowered to {want!r} "
                             f"but the stream recorded {r.instr!r}")


# --------------------------------------------------------------------------
# the control loop
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Observation:
    """One observation-window snapshot the controller decides from."""

    slot: int
    arrivals: dict[str, int]            # router arrivals since last obs
    queued: dict[str, int]              # per-member queue depth now
    window: dict[str, dict]             # MetricsWindow.by_model()
    shed_rate: float                    # aggregate over the window
    weights: dict[str, float]           # current normalized weights

    def mix(self) -> dict[str, float]:
        """Observed traffic mix: arrival shares this interval, empty when
        nothing arrived.  Deliberately arrival-only — during the drain
        tail the completion mix reflects leftover queue composition, and
        reweighting toward *that* would chase the backlog instead of the
        traffic."""
        total = sum(self.arrivals.values())
        if total > 0:
            return {m: n / total for m, n in self.arrivals.items() if n}
        return {}


def _tv(a: dict[str, float], b: dict[str, float]) -> float:
    """Total-variation distance between two normalized mixes."""
    return 0.5 * sum(abs(a.get(k, 0.0) - b.get(k, 0.0))
                     for k in set(a) | set(b))


class ControlLoop:
    """Closed-loop fleet controller (module docstring for the rules).

    fleet              the ``FleetEngine`` to control; the loop attaches
                       itself as ``fleet.controller`` and is consulted
                       once per executed slot
    interval           fleet slots between observations (K)
    window             completions the sliding window holds
    slo_ms             per-request latency SLO the retune rule guards
                       (None disables retuning)
    band               (low, high) fractions of ``slo_ms``: p95 above
                       high*slo breaches, below low*slo recovers — the
                       gap is the retune hysteresis
    reweight_deadband  total-variation distance between observed mix and
                       current weights below which no reweight fires
                       (the reweight hysteresis)
    shed_high          window shed rate that (sustained) triggers a
                       REBALANCE
    shed_low           rate below which the shed trigger re-arms
    sustain            consecutive over-``shed_high`` observations needed
                       to fire
    cooldown           observations after *any* REBALANCE (controller's
                       or §12 recovery's) before another may fire
    plan_evals         search budget for ``planner.plan_fleet`` when
                       re-planning theta
    min_group          floor for group_size halving (default 1)
    """

    def __init__(self, fleet, *, interval: int = 8, window: int = 64,
                 slo_ms: float | None = None,
                 band: tuple[float, float] = (0.5, 1.0),
                 reweight_deadband: float = 0.15,
                 shed_high: float = 0.25, shed_low: float = 0.05,
                 sustain: int = 2, cooldown: int = 4,
                 plan_evals: int = 4, min_group: int = 1):
        if interval < 1:
            raise ValueError(f"interval must be >= 1 (got {interval})")
        if not 0.0 <= band[0] <= band[1]:
            raise ValueError(f"band must be 0 <= low <= high (got {band})")
        if not 0.0 <= shed_low <= shed_high <= 1.0:
            raise ValueError(f"need 0 <= shed_low <= shed_high <= 1 "
                             f"(got {shed_low}, {shed_high})")
        self.fleet = fleet
        self.interval = interval
        self.window = MetricsWindow(window)
        self.slo_ms = slo_ms
        self.band = band
        self.reweight_deadband = reweight_deadband
        self.shed_high = shed_high
        self.shed_low = shed_low
        self.sustain = max(1, sustain)
        self.cooldown = cooldown
        self.plan_evals = plan_evals
        self.min_group = max(1, min_group)
        self.decisions: list[Decision] = []
        self.observations = 0
        # --- hysteresis / cooldown state --------------------------------
        self._last_routed: dict[str, int] = {}
        self._breached: set[str] = set()        # members in p95 breach
        self._configured: dict[str, int] = {}   # member -> original width
        self._shed_streak = 0
        self._shed_armed = True
        self._cooldown_left = 0
        self._seen_seq = 0      # stream watermark of the §12 scan
        fleet.controller = self

    # ------------------------------------------------------------------
    def on_slot(self, completions: Sequence[Completion]) -> None:
        """Per-slot hook ``FleetEngine.step`` calls after executing.

        Feeds the window every slot; every ``interval``-th slot it
        observes, decides, and injects the resulting instructions.
        Actions are only emitted while the fleet still has work — a
        trailing injected instruction would never execute in replay,
        breaking the stream-covers-the-run invariant.
        """
        self.window.observe(completions)
        if self.fleet._slot % self.interval != 0:
            return
        if not self.fleet.has_work:
            return
        obs = self.observe()
        for action, reason in self.decide(obs):
            self._apply(action, reason, obs)

    # ------------------------------------------------------------------
    def observe(self) -> Observation:
        """Snapshot the window, queues, and arrival deltas."""
        self.observations += 1
        if self._cooldown_left > 0:
            self._cooldown_left -= 1
        self._scan_foreign_rebalances()
        routed = dict(self.fleet.router.routed)
        arrivals = {m: routed.get(m, 0) - self._last_routed.get(m, 0)
                    for m in routed}
        self._last_routed = routed
        total = self.window.stats()
        weights = {m.name: m.weight for m in self.fleet.members}
        wsum = sum(weights.values())
        if wsum > 0:
            weights = {k: v / wsum for k, v in weights.items()}
        return Observation(
            slot=self.fleet._slot,
            arrivals=arrivals,
            queued={m.name: m.engine.queued for m in self.fleet.members},
            window=self.window.by_model(),
            shed_rate=total["shed_rate"],
            weights=weights)

    def _scan_foreign_rebalances(self) -> None:
        """Start/refresh the cooldown when anyone else REBALANCEd.

        §12 recovery and the drift detector inject REBALANCE without
        asking the controller; racing them with another re-lease would
        thrash the pool.  Scanning the stream since the last observation
        catches every source, because every REBALANCE is a recorded
        instruction.
        """
        for r in reversed(self.fleet.executor.records):
            if r.seq < self._seen_seq:
                break
            if isinstance(r.instr, Rebalance):
                self._cooldown_left = self.cooldown
                break
        self._seen_seq = self.fleet.executor._seq.n

    # ------------------------------------------------------------------
    def decide(self, obs: Observation) -> list[tuple[Action, str]]:
        """Pure-ish decision step: observation -> (action, reason) list.

        Mutates only the controller's hysteresis state, never the fleet —
        lowering and injection happen in the caller.
        """
        out: list[tuple[Action, str]] = []
        out.extend(self._decide_reweight(obs))
        out.extend(self._decide_retune(obs))
        out.extend(self._decide_rebalance(obs))
        return out

    def _decide_reweight(self, obs: Observation) -> list[tuple[Action, str]]:
        mix = obs.mix()
        if not mix:
            return []
        tv = _tv(mix, obs.weights)
        if tv <= self.reweight_deadband:
            return []
        reason = (f"arrival mix TV distance {tv:.3f} > deadband "
                  f"{self.reweight_deadband} from weights")
        return [(Reweight(member=m.name,
                          weight=round(mix.get(m.name, 0.0), 6)), reason)
                for m in self.fleet.members]

    def _decide_retune(self, obs: Observation) -> list[tuple[Action, str]]:
        if self.slo_ms is None:
            return []
        out: list[tuple[Action, str]] = []
        lo, hi = self.band[0] * self.slo_ms, self.band[1] * self.slo_ms
        for m in self.fleet.members:
            width = getattr(m.engine, "group_size", None)
            if width is None or not hasattr(m.engine, "retune"):
                continue
            stats = obs.window.get(m.name)
            p95 = stats["p95_ms"] if stats else None
            if p95 is None:
                continue
            if p95 > hi:
                # still hot: keep narrowing, one halving per observation
                new = max(self.min_group, int(width) // 2)
                if new < width:
                    self._breached.add(m.name)
                    self._configured.setdefault(m.name, int(width))
                    out.append((
                        Retune(member=m.name, param="group_size",
                               value=new),
                        f"{m.name} p95 {p95:.1f}ms > {hi:.1f}ms "
                        f"({self.band[1]} * slo {self.slo_ms}ms): "
                        f"narrow fusion {width} -> {new}"))
            elif m.name in self._breached and p95 < lo:
                # recovered: widen one doubling per observation, back
                # toward the configured width; between the bands nothing
                # moves — the gap is the hysteresis
                target = self._configured.get(m.name, int(width))
                new = min(target, max(int(width) * 2, self.min_group))
                if new >= target:
                    self._breached.discard(m.name)
                if new > width:
                    out.append((
                        Retune(member=m.name, param="group_size",
                               value=new),
                        f"{m.name} p95 {p95:.1f}ms < {lo:.1f}ms "
                        f"({self.band[0]} * slo {self.slo_ms}ms): "
                        f"widen fusion {width} -> {new}"))
        return out

    def _decide_rebalance(self, obs: Observation) -> list[tuple[Action, str]]:
        if self.fleet.pool is None:
            return []
        if obs.shed_rate > self.shed_high:
            if self._shed_armed:
                self._shed_streak += 1
        elif obs.shed_rate < self.shed_low:
            self._shed_streak = 0
            self._shed_armed = True
        if (self._shed_streak < self.sustain or not self._shed_armed
                or self._cooldown_left > 0):
            return []
        mix = obs.mix() or obs.weights
        from repro.fleet.planner import plan_fleet

        theta = plan_fleet(mix, max_evals=self.plan_evals).theta
        self._shed_streak = 0
        self._shed_armed = False    # re-arms only below shed_low
        self._cooldown_left = self.cooldown
        return [(RebalanceTheta(theta=round(theta, 6)),
                 f"shed rate {obs.shed_rate:.3f} > {self.shed_high} for "
                 f"{self.sustain} observations: re-lease at theta "
                 f"{theta:.4f} for mix {mix}")]

    # ------------------------------------------------------------------
    def _apply(self, action: Action, reason: str, obs: Observation) -> None:
        """Lower one action, inject it into the stream, log the decision
        at the injected instruction's seq watermark."""
        wm = self.fleet.executor._seq.n
        self.fleet.executor.inject(lower_action(action))
        # wall domain: replay has no controller — it re-executes the
        # *lowered* instructions, which the executor counts in slot domain
        self.fleet.executor.obs.counter(
            "control_decisions_total", "controller actions applied, by kind",
            "wall").inc(labels={"kind": action.kind,
                                "pool": self.fleet.executor.name})
        self.decisions.append(Decision(
            seq=wm, slot=self.fleet._slot, action=action, reason=reason,
            observed={"shed_rate": round(obs.shed_rate, 4),
                      "arrivals": dict(obs.arrivals),
                      "queued": dict(obs.queued)}))

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        """Controller summary merged into ``result().stats['control']``."""
        kinds: dict[str, int] = {}
        for d in self.decisions:
            kinds[d.action.kind] = kinds.get(d.action.kind, 0) + 1
        return {"interval": self.interval,
                "window": self.window.size,
                "observations": self.observations,
                "decisions": len(self.decisions),
                "by_kind": kinds}
