"""FleetEngine: several networks through one serving front end.

:class:`FleetEngine` implements the shared ``repro.serving`` protocol
(submit / step / drain / result), so everything that drives a single-model
engine — ``replay``, arrival traces, the benchmarks — drives a fleet
unchanged.  Members are themselves engines (``DualCoreEngine`` per CNN;
a ``DualMeshEngine`` can sit alongside for LM+CNN mixes); the fleet owns
the *cross-engine* decisions and nothing else:

  1. ``submit`` routes on ``Request.model`` (``fleet.router.Router``) and
     forwards into the member's own bounded queue — so backpressure stays
     isolated per member: a full mobilenet_v1 queue raises ``QueueFull``
     for mobilenet_v1 traffic while squeezenet keeps accepting.

  2. ``step`` picks the PRIMARY member via the pluggable
     :class:`~repro.fleet.router.SchedulingPolicy` (round-robin /
     shortest-queue / weighted-fair / deadline-EDF): its exec group is
     dispatched first, at the front of the slot.

  3. The fleet then co-dispatches up to ``co_dispatch`` further members
     into the same slot, ordered by the scheduler's per-group latency
     model (``DualCoreEngine.next_dispatch_cycles``): the member whose
     dominant core for the coming slot is the *opposite* of the
     primary's goes next, so a conv-heavy group of network A and a
     dw-heavy group of network B land on the c- and p-submeshes of the
     shared pool back to back — the multi-network analog of the paper's
     Fig.4b two-image offset, and the mechanism behind the Table VII
     multi-CNN throughput claim.  The default (``co_dispatch=None``)
     admits every member with work into the slot, keeping both submesh
     queues saturated; ``co_dispatch=0`` steps only the policy's pick
     per slot — the latency-sensitive mode where EDF/priority ordering
     fully controls what reaches the devices.

  4. Dispatch strictly precedes materialization: every batched member
     ``advance``s (async dispatch into the submesh queues) before any
     member ``retire``s (the ``block_until_ready`` on finished streams) —
     the block-last rule the engines apply within their own slot,
     extended across engines.  Blocking member A's retiring stream before
     member B's groups enter the queues would serialize exactly the
     cross-network overlap this layer exists for.  Members without the
     split (a bare ``step()``, which fuses dispatch and block) run after
     every pure dispatch and before any deferrable retire — their
     unavoidable block never precedes an avoidable dispatch.

  5. ``burst`` advances each batched member that many consecutive slots
     per fleet step (retiring once, at the end).  Interleaving networks
     at slot granularity thrashes the locality a one-network-at-a-time
     drain gets for free (weights and activations of every member
     resident at once); short per-member bursts amortize it — the
     time-multiplexed-modes idea of the multi-mode inference engine line
     of work — at the cost of up to ``burst-1`` slots of added queueing
     for the other members.  On the degenerate 2-CPU host mesh (where
     each host device's XLA threadpool already spans the cores, so the
     sequential baselines leave nothing idle) burst=4 is what lifts the
     fleet from a few percent *behind* one-engine-at-a-time to
     par-or-ahead (1.01-1.18x across runs, BENCH_fleet.json); the real
     win is expected on multi-chip submeshes with separate memories,
     where the model-side Table VII prediction applies.

Per-request metrics are accounted at the fleet boundary: latency runs
from fleet submit to member completion, tagged with the model, so
``result().metrics.by_model()`` gives the per-network p50/p95 next to the
aggregate.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Mapping, Sequence

from repro.fleet.compiler import SlotCompiler, observe
from repro.fleet.executor import PoolExecutor
from repro.fleet.pool import DevicePool
from repro.fleet.router import (MemberView, RoundRobin, Router,
                                SchedulingPolicy)
from repro.serving.api import (AdmissionPolicy, Completion, EngineBase,
                               Metrics, QueueFull, Request, RequestMetrics,
                               Ticket)


@dataclasses.dataclass
class Member:
    """One network's engine inside the fleet."""

    name: str
    engine: object                   # anything satisfying serving.Engine
    weight: float = 1.0              # traffic-mix share (unnormalized ok)
    dispatches: int = 0              # fleet steps received
    rid_map: dict[int, int] = dataclasses.field(default_factory=dict)
    #                                  member rid -> fleet rid


class FleetEngine(EngineBase):
    """Multiplex member engines over one device pool (module docstring).

    members      {model name: engine}; insertion order is the round-robin
                 / tie-break order
    policy       cross-engine :class:`SchedulingPolicy` (default
                 RoundRobin)
    weights      {model name: qps share} for weighted-fair scheduling and
                 the stats breakdown (default: equal)
    admission    per-model :class:`AdmissionPolicy` map installed onto the
                 member engines (e.g. ``{"mobilenet_v1":
                 DeadlineAdmission()}``); members keep their own policy
                 when absent from the map
    co_dispatch  max members co-dispatched into a slot beyond the primary
                 (None = every member with work, the throughput default;
                 0 = policy-only stepping, the latency-sensitive mode)
    burst        consecutive slots each batched member advances per fleet
                 step (locality amortization, module docstring point 5)
    pool         the shared :class:`DevicePool`, for stats only — runners
                 must already hold their leases
    """

    def __init__(self, members: Mapping[str, object], *,
                 policy: SchedulingPolicy | None = None,
                 weights: Mapping[str, float] | None = None,
                 admission: Mapping[str, AdmissionPolicy] | None = None,
                 co_dispatch: int | None = None,
                 burst: int = 1,
                 pool: DevicePool | None = None):
        super().__init__(max_queue=None)   # members bound their own queues
        self.router = Router(list(members))
        self.members = [Member(name=n, engine=e,
                               weight=(weights or {}).get(n, 1.0))
                        for n, e in members.items()]
        self._by_name = {m.name: m for m in self.members}
        for name, pol in (admission or {}).items():
            if name not in self._by_name:
                raise KeyError(f"admission policy for unknown member "
                               f"{name!r} (members: {list(members)})")
            self._by_name[name].engine.policy = pol
        self.policy = policy or RoundRobin()
        if co_dispatch is not None and co_dispatch < 0:
            raise ValueError(f"co_dispatch must be >= 0 or None "
                             f"(got {co_dispatch})")
        self.co_dispatch = co_dispatch
        if burst < 1:
            raise ValueError(f"burst must be >= 1 (got {burst})")
        self.burst = burst
        self.pool = pool
        self._slot = 0
        self._dispatches = 0
        # execution back end: step() compiles each slot's decisions into
        # instructions and the executor runs them (and records the
        # executed stream — ``self.stream``); a MultiPoolRouter re-homes
        # this executor to give it a pool name and SEND/RECV transport
        self.executor = PoolExecutor(self)
        # closed-loop controller (fleet.control.ControlLoop attaches
        # itself here); consulted once per executed slot — its actions
        # inject SET_PARAM/REBALANCE into the recorded stream, so a
        # controlled run replays with no controller attached (§13)
        self.controller = None

    # ------------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        """True while any member holds queued or in-flight work."""
        return any(m.engine.has_work for m in self.members)

    @property
    def in_flight(self) -> int:
        """Total admitted requests across members."""
        return sum(m.engine.in_flight for m in self.members)

    @property
    def queued(self) -> int:
        """Total queued (unadmitted) requests across members."""
        return sum(m.engine.queued for m in self.members)

    # ------------------------------------------------------------------
    def submit(self, request: Request | object) -> Ticket:
        """Route on the model tag into the member's own queue.  A full
        member queue raises ``QueueFull`` *before* any fleet bookkeeping,
        leaving the other members' traffic untouched."""
        req = request if isinstance(request, Request) else Request(request)
        name = self.router.route(req)
        member = self._by_name[name]
        submitted_at = time.perf_counter()
        obs = self.executor.obs
        try:
            mticket = member.engine.submit(
                Request(payload=req.payload, gen_steps=req.gen_steps,
                        model=name, deadline=req.deadline,
                        priority=req.priority))
        except QueueFull:
            # refusals depend on the caller's retry cadence, not the
            # stream — wall domain (successful admissions are slot:
            # replay re-submits them at their placement watermarks)
            obs.counter("serve_queue_full_total",
                        "submissions refused by a full member queue",
                        "wall").inc(labels={"pool": self.executor.name,
                                            "model": name})
            raise
        obs.counter("serve_requests_total",
                    "requests admitted into member queues", "slot").inc(
            labels={"pool": self.executor.name, "model": name})
        obs.gauge("serve_queue_depth", "queued requests across members",
                  "slot").set(self.queued,
                              labels={"pool": self.executor.name})
        rid = self._next_rid
        self._next_rid += 1
        req.rid = rid                    # the engine contract: rid is
        #                                  stamped on the caller's request
        self._metrics[rid] = RequestMetrics(rid=rid,
                                            submitted_at=submitted_at,
                                            model=name)
        self._order.append(rid)
        member.rid_map[mticket.rid] = rid
        return Ticket(rid=rid, submitted_at=submitted_at)

    # ------------------------------------------------------------------
    def _views(self) -> list[MemberView]:
        # head_deadline costs an O(queue) scan per member per slot and
        # next_core a walk over the in-flight groups — pay them only when
        # something reads them (a deadline-aware policy; co-dispatch
        # ordering), not on every slot of every policy.  The view builder
        # itself lives in ``fleet.compiler.observe`` so the AOT compiler's
        # member mirrors feed the policy identical inputs.
        want_deadlines = getattr(self.policy, "uses_deadlines", False)
        want_cores = self.co_dispatch is None or self.co_dispatch > 0
        views = (observe(i, m.name, m.engine, weight=m.weight,
                         dispatches=m.dispatches,
                         want_deadlines=want_deadlines,
                         want_cores=want_cores)
                 for i, m in enumerate(self.members))
        return [v for v in views if v is not None]

    @property
    def stream(self):
        """The instruction stream executed so far (``ExecRecord`` list) —
        serialize with ``instructions.stream_to_json``, replay with
        ``executor.PoolExecutor.replay``."""
        return self.executor.records

    def step(self) -> list[Completion]:
        """One fleet slot, as compile-then-execute: lower this slot's
        scheduling decisions (policy primary first, then up to
        ``co_dispatch`` members core-complementary-first, ``burst`` deep,
        every RUN before any FREE — module docstring points 2-4) into
        instructions, and replay them through the executor.  The executed
        stream accumulates on :attr:`stream`; a stream compiled ahead of
        time for the same arrivals replays to the same trace bitwise
        (``compiler.compile_fleet``, tested)."""
        self._start_clock()
        views = self._views()
        if not views:
            return []
        compiler = SlotCompiler(self.policy, co_dispatch=self.co_dispatch,
                                burst=self.burst)
        instrs = compiler.lower_slot(views, self._dispatches)
        done = self.executor.execute_slot(instrs, self._slot)
        self._slot += 1
        if self.controller is not None:
            self.controller.on_slot(done)
        return done

    def withdraw_pending(self, max_n: int | None = None, *,
                         member: str | None = None
                         ) -> list[tuple[int, Request]]:
        """Remove up to ``max_n`` queued (unadmitted) requests from the
        member queues — all members, or just ``member`` — un-accounting
        them at both the member and fleet boundary.  Returns
        ``(fleet rid, request)`` pairs; the SEND instruction (cross-pool
        migration) is the caller."""
        names = ([member] if member is not None
                 else [m.name for m in self.members])
        out: list[tuple[int, Request]] = []
        for name in names:
            if name not in self._by_name:
                raise KeyError(f"no member {name!r} "
                               f"(members: {[m.name for m in self.members]})")
            if max_n is not None and len(out) >= max_n:
                break
            m = self._by_name[name]
            take = None if max_n is None else max_n - len(out)
            for mrid, req in m.engine.withdraw_pending(take):
                frid = m.rid_map.pop(mrid)
                del self._metrics[frid]
                self._order.remove(frid)
                req.rid = None
                req.model = name        # keep the route after migration
                out.append((frid, req))
        return out

    def _adopt(self, member: Member, c: Completion) -> Completion:
        """Re-account a member completion at the fleet boundary: fleet
        rid and submit time, member start/finish stamps, no re-blocking
        (the member already materialized the output)."""
        frid = member.rid_map.pop(c.ticket.rid)
        m = self._metrics[frid]
        m.started_at = c.metrics.started_at
        m.finished_at = c.metrics.finished_at
        m.slo_ok = c.metrics.slo_ok
        m.deadline = c.metrics.deadline
        if c.metrics.status != "ok":    # shed/failed win; "ok" never
            m.status = c.metrics.status     # downgrades a prior status
        fc = Completion(ticket=Ticket(rid=frid,
                                      submitted_at=m.submitted_at),
                        output=c.output, metrics=m)
        self._completions[frid] = fc
        return fc

    # ------------------------------------------------------------------
    def _extra_stats(self, metrics: Metrics) -> dict:
        per_member = {}
        for m in self.members:
            done = [r for r in metrics.requests if r.model == m.name]
            per_member[m.name] = {
                "weight": m.weight,
                "dispatches": m.dispatches,
                "completed": len(done),
                "queued": m.engine.queued,
                "in_flight": m.engine.in_flight,
            }
        out = {"engine": "fleet",
               "policy": type(self.policy).__name__,
               "co_dispatch": self.co_dispatch,
               "burst": self.burst,
               "slots": self._slot,
               "dispatches": self._dispatches,
               "aggregate_fps": metrics.requests_per_s(),
               "goodput_fps": metrics.goodput_fps(),
               "per_member": per_member,
               "per_model": metrics.by_model()}
        if self.pool is not None:
            out["pool"] = self.pool.stats()
        if self.controller is not None:
            out["control"] = self.controller.stats()
        return out


# --------------------------------------------------------------------------
# fleet assembly
# --------------------------------------------------------------------------
def build_cnn_fleet(models: Sequence[str], *,
                    pool: DevicePool | None = None,
                    theta: float = 0.5,
                    scheme: str = "balanced",
                    plan=None,
                    use_pallas: bool = True,
                    fuse: bool | str = "group",
                    jit_groups: bool = True,
                    policy: SchedulingPolicy | None = None,
                    weights: Mapping[str, float] | None = None,
                    admission: Mapping[str, AdmissionPolicy] | None = None,
                    max_queue: int | None = None,
                    co_dispatch: int | None = None,
                    burst: int = 1,
                    ) -> tuple[FleetEngine, DevicePool]:
    """Stand up a CNN fleet: one shared :class:`DevicePool`, one
    ``DualCoreRunner`` + ``DualCoreEngine`` per model (each leasing the
    pool's c/p split), wrapped in a :class:`FleetEngine`.

    ``plan`` (a ``fleet.planner.FleetPlan``) supplies the co-scheduled
    PE config, per-model schedules and mix weights; without one, every
    model is scheduled under ``DUAL_BASELINE`` with ``scheme``
    (``"best"`` runs the full §V-A flow per model).
    """
    from repro.core.arch import BoardModel, DUAL_BASELINE
    from repro.core.scheduler import best_schedule, build_schedule
    from repro.dualcore.runtime import DualCoreRunner
    from repro.models.cnn import build_model
    from repro.serving.cnn import DualCoreEngine

    board = BoardModel()
    if pool is None:
        # a plan's theta is part of the planned configuration — the pool
        # split must realise it, not the default
        pool = DevicePool(theta=plan.theta if plan is not None else theta)
    elif plan is not None and abs(pool.theta - plan.theta) > 1e-9:
        raise ValueError(
            f"pool theta={pool.theta} contradicts the plan's "
            f"theta={plan.theta:.4f}; serving a planned configuration on "
            f"a different split would silently invalidate the "
            f"predicted-vs-measured comparison")
    if plan is not None and weights is None:
        weights = plan.mix
    members: dict[str, DualCoreEngine] = {}
    for model in models:
        params, _, graph = build_model(model)
        if plan is not None:
            cfg = plan.config
            sched = plan.schedules[model]
        else:
            cfg = DUAL_BASELINE
            sched = (best_schedule(graph, cfg, board)
                     if scheme == "best"
                     else build_schedule(graph, cfg, board, scheme))
        runner = DualCoreRunner(model, params, sched,
                                devices=pool.lease(model),
                                use_pallas=use_pallas, fuse=fuse,
                                jit_groups=jit_groups)
        members[model] = DualCoreEngine(runner, max_queue=max_queue)
    engine = FleetEngine(members, policy=policy, weights=weights,
                         admission=admission, co_dispatch=co_dispatch,
                         burst=burst, pool=pool)
    return engine, pool
