"""Instruction-stream execution: one pool, and a router over many.

:class:`PoolExecutor` is the fleet's execution back end.  It holds no
scheduling opinion: it executes :mod:`repro.fleet.instructions` against
one ``FleetEngine``'s members — the decisions are already in the stream.
The live ``FleetEngine.step`` feeds it one compiled slot at a time (and
the executor records what it ran); :meth:`PoolExecutor.replay` feeds it a
whole pre-compiled or previously-recorded stream, reproducing the live
run's dispatch trace and outputs bitwise (tested) with no central policy
loop — the property that makes a pool drivable from a serialized stream
instead of Python object references.

:class:`MultiPoolRouter` is the first consumer of that property: N
process-local pools standing in for N hosts, each wrapped in its own
executor, presented as one engine (submit / step / drain / result).  The
router owns only cross-pool concerns:

  * placement — submit routes to the pool with the least outstanding
    work for the request's model;
  * migration — :meth:`migrate` / :meth:`drain_pool` move queued
    (unadmitted) requests between pools as a SEND on the source and a
    RECV on the destination, with request identity re-mapped at the
    router boundary (payloads ride the transport's mailbox —
    ``net.transport``: in-memory, spool files, or sockets — never the
    serialized stream);
  * dynamic theta re-leasing — when a pool's observed traffic mix
    drifts past ``rebalance_drift`` (total-variation distance from the
    mix its split was planned for), the router re-plans theta via
    ``planner.plan_fleet`` and issues a REBALANCE, which revokes the
    pool's leases, re-splits c/p at the new theta (Eq.10), and relocates
    member params + in-flight envs onto the new submeshes.

Per-request metrics are re-accounted at each boundary exactly as the
fleet does to its members: latency runs from router submit to member
completion, whichever pool finally served it.
"""
from __future__ import annotations

import time
from typing import Mapping, Sequence

from repro.fleet.faults import (FaultInjector, InjectedFault, PoolCrash,
                                RecoveryConfig)
from repro.fleet.net.transport import LocalTransport
from repro.fleet.instructions import (ExecRecord, Free, Instruction, Recv,
                                      Rebalance, Run, Send, SetParam)
from repro.obs import DEFAULT_COUNT_BOUNDS, Registry
from repro.serving.api import (Completion, EngineBase, QueueFull, Request,
                               RequestMetrics, Ticket)


class SeqCounter:
    """A peekable monotonic counter: the next value to be issued is
    :attr:`n`.  The router records each submission's position in the
    instruction stream as the seq watermark at submit time — everything
    :meth:`MultiPoolRouter.replay` needs to re-interleave submissions
    with execution."""

    def __init__(self):
        self.n = 0

    def __next__(self) -> int:
        v = self.n
        self.n += 1
        return v


class PoolExecutor:
    """Replays instruction streams against one fleet's members.

    fleet      the ``FleetEngine`` whose members (and pool) instructions
               act on
    name       this pool's name in a multi-pool topology (SEND/RECV peers
               address each other by it)
    transport  mailbox binding for SEND/RECV (a ``net.transport`` class:
               the router installs its own — LocalTransport by default,
               SocketTransport inside a worker process); None =
               single-pool, migration instructions are an error
    record     keep the executed stream in :attr:`records` (ExecRecord
               per instruction, with observed advances + wall-clock) —
               what serializes, replays, and exports to Chrome tracing
    injector   optional :class:`~repro.fleet.faults.FaultInjector`,
               consulted at every instruction boundary *before* any
               engine state moves (so a retried instruction re-executes
               against an unchanged pool)
    recovery   :class:`~repro.fleet.faults.RecoveryConfig`: retry budget
               and backoff for injected RUN failures, the per-RUN
               timeout, and the degradation thresholds the router reads
    """

    def __init__(self, fleet, *, name: str = "pool0", transport=None,
                 record: bool = True, injector: FaultInjector | None = None,
                 recovery: RecoveryConfig | None = None):
        self.fleet = fleet
        self.name = name
        self.transport = transport
        self.records: list[ExecRecord] = []
        self._record = record
        self.injector = injector
        self.recovery = recovery or RecoveryConfig()
        self.retries = 0     # RUN attempts re-issued after injected faults
        self.timeouts = 0    # RUNs whose wall time exceeded run_timeout_s
        self._seq = SeqCounter()          # router replaces with a shared
        #                                   counter in multi-pool runs
        self.obs = Registry()             # ...and with a shared registry:
        #                                   one telemetry namespace per run
        self._held: dict[str, list] = {}  # member -> flights whose FREE
        #                                   has not executed yet

    # ------------------------------------------------------------------
    def _arm(self, instr: Instruction, slot: int) -> int:
        """Pass one instruction boundary through the fault injector.
        An :class:`InjectedFault` is retried with bounded exponential
        backoff (the fault fires before any engine state moves, so a
        retry is a clean re-execution); retries exhausted escalate to
        :class:`PoolCrash` — the router's recovery problem.  Returns the
        retries spent, stamped on the record."""
        if self.injector is None:
            return 0
        attempt = 0
        while True:
            try:
                self.injector.before(self.name, instr, slot)
                return attempt
            except InjectedFault as e:
                attempt += 1
                self.retries += 1
                if attempt > self.recovery.max_retries:
                    raise PoolCrash(
                        f"pool {self.name!r}: {instr.op} at slot {slot} "
                        f"still failing after {attempt} attempts "
                        f"(max_retries={self.recovery.max_retries}): {e}"
                    ) from e
                if self.recovery.backoff_s:
                    time.sleep(self.recovery.backoff_s
                               * (2 ** (attempt - 1)))

    def execute(self, instr: Instruction, slot: int) -> list[Completion]:
        """Execute one instruction; returns the completions it
        materialized (FREE, fused RUN, and SLO sheds at a RUN)."""
        retries = self._arm(instr, slot)
        t0 = time.perf_counter()
        fleet = self.fleet
        done: list[Completion] = []
        advances = 0
        shed_n = 0
        if isinstance(instr, Run):
            m = fleet._by_name[instr.member]
            # SLO shedding happens at the dispatch boundary, clocked by
            # the fleet slot — the deterministic domain replay re-derives
            shed = getattr(m.engine, "shed_expired", None)
            if shed is not None:
                expired = list(shed(slot))
                shed_n = len(expired)
                done.extend(fleet._adopt(m, c) for c in expired)
            if instr.fused:
                # opaque member: step() fuses dispatch and block
                for _ in range(instr.slots):
                    if not m.engine.has_work:
                        break
                    done.extend(fleet._adopt(m, c)
                                for c in m.engine.step())
                    m.dispatches += 1
                    fleet._dispatches += 1
                    advances += 1
            else:
                flights = self._held.setdefault(instr.member, [])
                for _ in range(instr.slots):
                    if not m.engine.has_work:
                        break
                    flights.extend(m.engine.advance())
                    m.dispatches += 1
                    fleet._dispatches += 1
                    advances += 1
        elif isinstance(instr, Free):
            m = fleet._by_name[instr.member]
            flights = self._held.pop(instr.member, [])
            done.extend(fleet._adopt(m, c)
                        for c in m.engine.retire(flights))
        elif isinstance(instr, Send):
            if self.transport is None:
                raise RuntimeError(f"pool {self.name!r} executed SEND with "
                                   f"no transport attached; migration "
                                   f"needs a MultiPoolRouter")
            pairs = fleet.withdraw_pending(instr.count,
                                           member=instr.member)
            if (self.injector is not None
                    and self.injector.drops_send(self.name, slot)):
                # lost in transit: the transport un-accounts and (live)
                # re-routes the payloads; the record looks like a normal
                # SEND — the drop itself rides the router's recovery log
                advances = self.transport.drop_send(
                    self.name, instr.peer, pairs, seq=self._seq.n,
                    live=True)
            else:
                advances = self.transport.send(self.name, instr.peer,
                                               pairs)
        elif isinstance(instr, Recv):
            if self.transport is None:
                raise RuntimeError(f"pool {self.name!r} executed RECV with "
                                   f"no transport attached")
            advances = self.transport.recv(self.name, instr.peer,
                                           instr.count, fleet.submit)
        elif isinstance(instr, Rebalance):
            self._rebalance(instr.theta)
        elif isinstance(instr, SetParam):
            self._set_param(instr)
        else:
            raise TypeError(f"unknown fleet instruction {instr!r}")
        t1 = time.perf_counter()
        if (isinstance(instr, Run)
                and self.recovery.run_timeout_s is not None
                and t1 - t0 > self.recovery.run_timeout_s):
            # synchronous execution cannot abort a RUN that already
            # finished — a timeout is a strike, and the router degrades
            # the pool at timeout_strikes (drain + stop placing)
            self.timeouts += 1
            self.obs.counter("fleet_run_timeouts_total",
                             "RUNs past run_timeout_s (strikes)",
                             "wall").inc(labels={"pool": self.name})
        self._observe(instr, slot, advances, shed_n, retries, t1 - t0)
        if self._record:
            self.records.append(ExecRecord(
                instr=instr, slot=slot, seq=next(self._seq),
                advances=advances, t0=t0, t1=t1, retries=retries))
        return done

    def _observe(self, instr: Instruction, slot: int, advances: int,
                 shed_n: int, retries: int, dt: float) -> None:
        """Instrument one *completed* instruction.  Runs after every
        state mutation and never before a possible :class:`PoolCrash`
        escape, so slot-domain counters fire exactly once per recorded
        instruction — live and under :meth:`replay` alike — from values
        the stream signature pins (op, core, advances, slot).  Wall-clock
        values (duration, injector retries) land in the ``wall`` domain."""
        obs = self.obs
        if not obs.enabled:
            return
        pool = {"pool": self.name}
        obs.counter("fleet_instructions_total",
                    "instructions executed, by op", "slot").inc(
            labels={"pool": self.name, "op": instr.op})
        obs.gauge("fleet_slot", "latest executed fleet slot",
                  "slot").set(slot, labels=pool)
        if isinstance(instr, Run):
            member = {"pool": self.name, "member": instr.member}
            obs.counter("fleet_advances_total",
                        "flight advances dispatched by RUNs",
                        "slot").inc(advances, labels=member)
            core = "fused" if instr.fused else (instr.core or "mixed")
            obs.counter("fleet_submesh_busy_slots_total",
                        "RUN advances by dominant submesh", "slot").inc(
                advances, labels={"pool": self.name, "core": core})
            obs.histogram("fleet_run_advances",
                          "advances per RUN instruction", "slot",
                          bounds=DEFAULT_COUNT_BOUNDS).observe(
                advances, labels=pool)
            obs.gauge("fleet_in_flight", "member flights in the pipeline",
                      "slot").set(
                self.fleet._by_name[instr.member].engine.in_flight,
                labels=member)
            obs.counter("fleet_shed_total",
                        "completions shed at the dispatch boundary",
                        "slot").inc(shed_n, labels=member)
        elif isinstance(instr, Free):
            obs.gauge("fleet_in_flight", "member flights in the pipeline",
                      "slot").set(
                self.fleet._by_name[instr.member].engine.in_flight,
                labels={"pool": self.name, "member": instr.member})
        elif isinstance(instr, Send):
            obs.counter("fleet_sent_total",
                        "requests withdrawn onto the mailbox by SENDs",
                        "slot").inc(advances, labels={
                            "pool": self.name, "peer": instr.peer})
        elif isinstance(instr, Recv):
            obs.counter("fleet_recv_total",
                        "requests delivered from the mailbox by RECVs",
                        "slot").inc(advances, labels={
                            "pool": self.name, "peer": instr.peer})
        elif isinstance(instr, SetParam):
            obs.counter("fleet_set_params_total",
                        "SET_PARAM instructions, by param", "slot").inc(
                labels={"pool": self.name, "param": instr.param})
        if retries:
            obs.counter("fleet_run_retries_total",
                        "RUN attempts re-issued after injected faults",
                        "wall").inc(retries, labels=pool)
        obs.histogram("fleet_instr_seconds",
                      "wall-clock window per executed instruction",
                      "wall").observe(dt, labels={"pool": self.name,
                                                  "op": instr.op})

    def execute_slot(self, instrs: Sequence[Instruction],
                     slot: int) -> list[Completion]:
        """Execute one slot's instructions in order.  The compiler's
        RUN-before-FREE ordering is what preserves the block-last rule;
        the executor does not re-sort."""
        done: list[Completion] = []
        for instr in instrs:
            done.extend(self.execute(instr, slot))
        return done

    def inject(self, instr: Instruction) -> list[Completion]:
        """Execute one out-of-band instruction (migration, rebalance) at
        the pool's current slot, recording it in the stream."""
        return self.execute(instr, self.fleet._slot)

    # ------------------------------------------------------------------
    def _set_param(self, instr: SetParam) -> None:
        """Apply one SET_PARAM: ``weight`` mutates the member's fleet
        share directly; any other param dispatches to the member
        engine's ``retune()`` hook (e.g. the LM ``group_size``).  The
        mutation is a recorded instruction, so replaying the stream
        re-applies it at the same position — controlled runs stay
        bitwise replayable with no controller attached (§13)."""
        fleet = self.fleet
        m = fleet._by_name.get(instr.member)
        if m is None:
            raise KeyError(f"SET_PARAM for unknown member "
                           f"{instr.member!r} (members: "
                           f"{[x.name for x in fleet.members]})")
        if instr.param == "weight":
            m.weight = float(instr.value)
            return
        retune = getattr(m.engine, "retune", None)
        if retune is None:
            raise RuntimeError(
                f"member {instr.member!r} has no retune() hook; cannot "
                f"SET_PARAM {instr.param!r} (only 'weight' applies to "
                f"every member)")
        retune(**{instr.param: instr.value})

    # ------------------------------------------------------------------
    def _rebalance(self, theta: float) -> None:
        """Revoke every lease, re-split the pool at ``theta``, re-lease,
        and relocate members' params and in-flight envs."""
        pool = self.fleet.pool
        if pool is None:
            raise RuntimeError(f"pool {self.name!r} executed REBALANCE "
                               f"but the fleet holds no DevicePool")
        held = pool.revoke_all()
        dual = pool.resplit(theta)
        for m in self.fleet.members:
            if m.name in held:
                pool.lease(m.name)
            if hasattr(m.engine, "relocate"):
                m.engine.relocate(dual)

    # ------------------------------------------------------------------
    def replay(self, records: Sequence[ExecRecord],
               requests: Sequence[Request | object] = (),
               arrivals: Sequence[int] | None = None):
        """Drive the fleet from a compiled or previously-recorded stream:
        the ``serving.api.replay`` arrival loop, with each non-empty slot
        executed from the stream instead of asked of the policy.  Returns
        the fleet's final ``ServeResult``.

        The stream must cover the run: running out of instructions while
        members still hold work means the stream was compiled for a
        different request trace, and raises.
        """
        from repro.serving.api import QueueFull

        fleet = self.fleet
        slots: list[tuple[int, list[Instruction]]] = []
        for r in records:
            if slots and slots[-1][0] == r.slot:
                slots[-1][1].append(r.instr)
            else:
                slots.append((r.slot, [r.instr]))
        arrivals = (list(arrivals) if arrivals is not None
                    else [0] * len(requests))
        if len(arrivals) != len(requests):
            raise ValueError(f"{len(requests)} requests but "
                             f"{len(arrivals)} arrival times")
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        refused: list[int] = []
        gi, nxt, step = 0, 0, 0
        while nxt < len(order) or refused or fleet.has_work:
            due, refused = refused, []
            while nxt < len(order) and arrivals[order[nxt]] <= step:
                due.append(order[nxt])
                nxt += 1
            for i in due:
                try:
                    fleet.submit(requests[i])
                except QueueFull:
                    refused.append(i)   # retry first next step, as replay()
            if fleet.has_work:
                if gi >= len(slots):
                    raise ValueError(
                        f"instruction stream exhausted after {gi} slots "
                        f"with work still outstanding (queued="
                        f"{fleet.queued}, in_flight={fleet.in_flight}); "
                        f"was it compiled for this request trace?")
                fleet._start_clock()
                slot_no, instrs = slots[gi]
                gi += 1
                self.execute_slot(instrs, slot_no)
                fleet._slot = slot_no + 1
            step += 1
        return fleet.result()


# --------------------------------------------------------------------------
# multi-pool serving
# --------------------------------------------------------------------------
class MultiPoolRouter(EngineBase):
    """One engine surface over N pools (module docstring).

    fleets           {pool name: FleetEngine}; each fleet keeps (and the
                     router adopts) its own :class:`PoolExecutor`
    rebalance_drift  total-variation distance between a pool's observed
                     and planned traffic mix beyond which the router
                     re-plans theta and issues REBALANCE (None = never)
    rebalance_every  slots between drift checks
    plan_evals       search budget handed to ``planner.plan_fleet`` when
                     re-planning theta
    injector         optional :class:`~repro.fleet.faults.FaultInjector`
                     armed on every pool's executor
    recovery         :class:`~repro.fleet.faults.RecoveryConfig` shared
                     by every executor and the router's own degradation
                     / crash-recovery decisions

    Fault tolerance (DESIGN.md §12): a :class:`PoolCrash` raised by a
    pool's step marks the pool dead and re-routes its un-retired
    requests — reconstructed from the source map the placement log
    maintains, re-submitted from the router's journal — onto surviving
    pools (``status="recovered"``); requests no surviving pool can serve
    complete as ``status="failed"``.  Every recovery decision is logged
    as a seq-watermarked event on :attr:`events`, which extends the
    placement log: :meth:`replay` applies the events at the same stream
    positions, so a faulted run replays bitwise — same streams, same
    shed set, same recovered and failed rids — with no injector
    attached.  Retirement is at-most-once: a completion for an
    already-completed rid is dropped (``duplicates_dropped``).
    """

    def __init__(self, fleets: Mapping[str, object], *,
                 rebalance_drift: float | None = None,
                 rebalance_every: int = 16,
                 plan_evals: int = 8,
                 injector: FaultInjector | None = None,
                 recovery: RecoveryConfig | None = None,
                 transport=None):
        super().__init__(max_queue=None)
        if not fleets:
            raise ValueError("a MultiPoolRouter needs at least one pool")
        self.executors: dict[str, PoolExecutor] = {}
        self._seq = SeqCounter()
        self.obs = Registry()
        self.recovery = recovery or RecoveryConfig()
        # the SEND/RECV mailbox (net.transport); accounting stays here,
        # on the on_send/on_drop/on_recv hooks, whatever carries payloads
        self.transport = (transport if transport is not None
                          else LocalTransport())
        self.transport.bind(self)
        self.transport.obs = self.obs
        for name, fleet in fleets.items():
            ex = fleet.executor
            ex.name = name
            ex.transport = self.transport
            ex._seq = self._seq         # router-wide order across pools
            ex.obs = self.obs           # ...and one telemetry namespace
            ex.recovery = self.recovery
            if injector is not None:
                ex.injector = injector
            self.executors[name] = ex
        self.rebalance_drift = rebalance_drift
        self.rebalance_every = rebalance_every
        self.plan_evals = plan_evals
        self.rebalances: list[tuple[str, float]] = []
        self.placements: list[tuple[int, str]] = []
        #    per submission, in order: (stream seq watermark at submit
        #    time, pool placed on) — with the per-pool streams, the full
        #    recipe for re-executing the run (:meth:`replay`)
        self._sources: dict[tuple[str, int], int] = {}
        #                    (pool, fleet rid) -> router rid
        self._served: dict[str, dict[str, int]] = {
            name: {} for name in self.executors}
        self._steps = 0
        # --- fault-tolerance state -------------------------------------
        self.dead: dict[str, str] = {}       # pool -> crash reason
        self.degraded: set[str] = set()      # drained, not placed on
        self.events: list[tuple] = []
        #    chronological recovery log, seq-watermarked like placements:
        #    ("fail", wm, pool) | ("recover", wm, pool, rid) |
        #    ("drop", seq_of_send) — with streams + placements, the full
        #    recipe for replaying a faulted run
        self.duplicates_dropped = 0
        self._journal: dict[int, Request] = {}
        #    rid -> device-free copy of the request, kept until
        #    retirement — what crash recovery re-submits
        self._retry: list[int] = []          # rids awaiting re-placement
        #                                      (every candidate was full)
        self._recovery_done: list[Completion] = []
        #    terminal completions recovery produced outside a step
        self._replay_drops: set[int] = set()

    # ------------------------------------------------------------------
    @property
    def pools(self) -> list[str]:
        """Pool names, in construction order."""
        return list(self.executors)

    @property
    def alive(self) -> list[str]:
        """Pool names not marked dead."""
        return [n for n in self.executors if n not in self.dead]

    @property
    def in_transit(self) -> int:
        """Requests currently riding the SEND/RECV mailbox."""
        return self.transport.in_transit

    @property
    def has_work(self) -> bool:
        # a dead pool's fleet may hold phantom queued/in-flight state —
        # its requests were already re-routed or failed, so it does not
        # count as outstanding work
        """True while any live pool, the mailbox, or retry/recovery backlogs
        hold work."""
        return (any(self.executors[n].fleet.has_work for n in self.alive)
                or self.in_transit > 0 or bool(self._retry)
                or bool(self._recovery_done))

    @property
    def queued(self) -> int:
        """Queued requests across live pools, mailbox, and retry backlog."""
        return (sum(self.executors[n].fleet.queued for n in self.alive)
                + self.in_transit + len(self._retry))

    @property
    def in_flight(self) -> int:
        """Admitted requests across live pools."""
        return sum(self.executors[n].fleet.in_flight for n in self.alive)

    # ------------------------------------------------------------------
    def _outstanding(self, name: str) -> int:
        ex = self.executors[name]
        return ex.fleet.queued + ex.fleet.in_flight

    def _placeable(self, model: str | None = None) -> list[str]:
        """Pools new work may be placed on: not dead, not degraded, and
        (with a model tag) serving the model."""
        return [n for n in self.executors
                if n not in self.dead and n not in self.degraded
                and (model is None
                     or model in self.executors[n].fleet.router.names)]

    def submit(self, request: Request | object) -> Ticket:
        """Route to the pool with the least outstanding work among the
        live pools whose fleet serves the request's model (degraded
        pools only as a last resort)."""
        req = request if isinstance(request, Request) else Request(request)
        cands = self._placeable(req.model)
        if not cands:       # every serving pool degraded: place anyway —
            #                 degraded beats rejected
            cands = [n for n in self.alive
                     if req.model is None
                     or req.model in self.executors[n].fleet.router.names]
        if not cands:
            served = {n: self.executors[n].fleet.router.names
                      for n in self.alive}
            raise KeyError(f"no pool serves model {req.model!r} among "
                           f"live pools (pools serve: {served})")
        name = min(cands, key=self._outstanding)
        try:
            return self._submit_to(name, req)
        except PoolCrash as e:      # a remote pool can die at the submit
            #                         boundary; recover and re-place
            self._recovery_done.extend(self._fail_pool(name, str(e)))
            return self.submit(req)

    def _submit_to(self, pool: str, req: Request) -> Ticket:
        """Submit into a specific pool, with router-level accounting and
        the placement logged (seq watermark, pool) for replay."""
        ex = self.executors[pool]
        submitted_at = time.perf_counter()
        ticket = ex.fleet.submit(
            Request(payload=req.payload, gen_steps=req.gen_steps,
                    model=req.model, deadline=req.deadline,
                    priority=req.priority))
        rid = self._next_rid
        self._next_rid += 1
        req.rid = rid
        self._metrics[rid] = RequestMetrics(rid=rid,
                                            submitted_at=submitted_at,
                                            model=req.model)
        self._order.append(rid)
        self._sources[(pool, ticket.rid)] = rid
        self.placements.append((self._seq.n, pool))
        self.obs.counter("router_placements_total",
                         "requests placed, by pool", "slot").inc(
            labels={"pool": pool})
        self._journal[rid] = Request(payload=req.payload,
                                     gen_steps=req.gen_steps,
                                     model=req.model,
                                     deadline=req.deadline,
                                     priority=req.priority)
        return Ticket(rid=rid, submitted_at=submitted_at)

    def step(self) -> list[Completion]:
        """One slot on every live pool (each pool compiles + executes its
        own slot), recovering from any :class:`PoolCrash` a pool's step
        escalates, then the periodic degradation and drift checks."""
        self._start_clock()
        done: list[Completion] = []
        if self._recovery_done:     # terminal completions a recovery
            done.extend(self._recovery_done)    # produced between steps
            self._recovery_done = []
        self._flush_retry(done)
        for name in list(self.executors):
            if name in self.dead:
                continue
            ex = self.executors[name]
            try:
                pool_done = ex.fleet.step()
            except PoolCrash as e:
                done.extend(self._fail_pool(name, str(e)))
                continue
            done.extend(c2 for c2 in (self._adopt(name, c)
                                      for c in pool_done)
                        if c2 is not None)
        self._steps += 1
        if self.obs.enabled:
            # live loop shape (replay never calls step): wall domain
            self.obs.counter("router_steps_total", "router step calls",
                             "wall").inc()
            self.obs.gauge("router_queue_depth",
                           "queued requests across live pools + mailbox",
                           "wall").set(self.queued)
            self.obs.gauge("router_in_transit",
                           "requests riding the SEND/RECV mailbox",
                           "wall").set(self.in_transit)
        self._check_degradation()
        if (self.rebalance_drift is not None
                and self._steps % self.rebalance_every == 0):
            self._check_drift()
        return done

    def _adopt(self, pool: str, c: Completion) -> Completion | None:
        """Re-account a pool completion at the router boundary (same move
        as ``FleetEngine._adopt`` one layer down).  Returns None for a
        duplicate retirement (a rid already completed — at-most-once is
        the router's invariant, not the pools')."""
        key = (pool, c.ticket.rid)
        if key not in self._sources:
            raise ValueError(
                f"pool {pool!r} retired rid {c.ticket.rid}, but the "
                f"placement log routed no outstanding request there — "
                f"the streams and the placement log disagree (offending "
                f"member rid {c.ticket.rid} on pool {pool!r})")
        rid = self._sources.pop(key)
        if rid in self._completions:
            self.duplicates_dropped += 1
            self.obs.counter("router_duplicates_dropped_total",
                             "duplicate retirements dropped "
                             "(at-most-once)", "wall").inc()
            return None
        m = self._metrics[rid]
        m.started_at = c.metrics.started_at
        m.finished_at = c.metrics.finished_at
        m.slo_ok = c.metrics.slo_ok
        m.deadline = c.metrics.deadline
        if c.metrics.status != "ok":
            # shed/failed always win; a member's plain "ok" never
            # clobbers a "recovered" the router already stamped
            m.status = c.metrics.status
        fc = Completion(ticket=Ticket(rid=rid,
                                      submitted_at=m.submitted_at),
                        output=c.output, metrics=m)
        self._completions[rid] = fc
        self._journal.pop(rid, None)
        model = c.metrics.model or "?"
        served = self._served[pool]
        served[model] = served.get(model, 0) + 1
        self.obs.counter("router_retired_total",
                         "completions retired at the router, by "
                         "pool/model/status", "slot").inc(
            labels={"pool": pool, "model": model, "status": m.status})
        return fc

    # ------------------------------------------------------------------
    # crash recovery (DESIGN.md §12)
    # ------------------------------------------------------------------
    def _log_event(self, ev: tuple) -> None:
        """Append one recovery event and count it.  Every event-log
        write — live (`_fail_pool`, `_reroute`, `on_drop`) and replayed
        (`_apply_event` re-appends at the same watermark) — funnels
        through here, so ``router_recovery_events_total`` is a pure
        function of the event log and replays dict-equal."""
        self.events.append(ev)
        self.obs.counter("router_recovery_events_total",
                         "recovery events logged, by kind", "slot").inc(
            labels={"kind": ev[0]})

    def _pop_sources(self, pool: str) -> list[int]:
        """Withdraw and return the router rids of every request the
        placement log still maps onto ``pool``."""
        keys = [k for k in self._sources if k[0] == pool]
        return [self._sources.pop(k) for k in keys]

    def _fail_request(self, rid: int) -> Completion:
        """Retire ``rid`` as failed: no surviving pool can serve it."""
        self.obs.counter("router_failed_total",
                         "requests no surviving pool could serve",
                         "slot").inc()
        m = self._metrics[rid]
        m.status = "failed"
        m.finished_at = time.perf_counter()
        fc = Completion(ticket=Ticket(rid=rid,
                                      submitted_at=m.submitted_at),
                        output=None, metrics=m)
        self._completions[rid] = fc
        self._journal.pop(rid, None)
        return fc

    def _reroute(self, rid: int, *, wm: int) -> list[Completion]:
        """Re-place one un-retired request on a surviving pool, logging
        the recovery at seq watermark ``wm``.  Returns the terminal
        completions produced (a failure when nothing can serve it; empty
        on a successful or deferred re-placement)."""
        req = self._journal.get(rid)
        if req is None:     # already terminal (shouldn't happen, but a
            return []       # lost journal entry must not crash recovery)
        cands = sorted(self._placeable(req.model), key=self._outstanding)
        if not cands:
            return [self._fail_request(rid)]
        for name in cands:
            try:
                ticket = self.executors[name].fleet.submit(
                    Request(payload=req.payload, gen_steps=req.gen_steps,
                            model=req.model, deadline=req.deadline,
                            priority=req.priority))
            except QueueFull:
                continue
            except PoolCrash as e:  # the candidate died mid-recovery:
                #                     fail it too, keep trying the rest
                self._recovery_done.extend(self._fail_pool(name, str(e)))
                continue
            self._sources[(name, ticket.rid)] = rid
            self._metrics[rid].status = "recovered"
            self._log_event(("recover", wm, name, rid))
            return []
        self._retry.append(rid)     # every candidate full: try again at
        return []                   # the next step boundary

    def _flush_retry(self, done: list[Completion]) -> None:
        """Re-attempt rids whose recovery found every candidate full."""
        if not self._retry:
            return
        backlog, self._retry = self._retry, []
        wm = self._seq.n
        for rid in backlog:
            done.extend(self._reroute(rid, wm=wm))

    def _fail_pool(self, name: str, reason: str) -> list[Completion]:
        """Mark pool ``name`` dead and recover its un-retired requests:
        re-route each onto a surviving pool (``status="recovered"``) or
        retire it as failed.  Logged on :attr:`events` at the current
        seq watermark so replay re-derives the same decisions."""
        self.dead[name] = reason
        wm = self._seq.n
        self._log_event(("fail", wm, name))
        done: list[Completion] = []
        ex = self.executors[name]
        lost: list[int] = []
        for key in [k for k in self._sources if k[0] == name]:
            c = ex.fleet._completions.get(key[1])
            if c is not None:
                # the crash interrupted the step after this request had
                # already retired on the pool — harvest the completion
                # instead of re-running it (replay reaches it through
                # the recorded stream, before the fail event applies)
                fc = self._adopt(name, c)
                if fc is not None:
                    done.append(fc)
            else:
                lost.append(self._sources.pop(key))
        # payloads in transit TO the dead pool (SENT, not yet RECVed)
        # would strand the mailbox forever — recover them too
        lost.extend(self.transport.drain_for(name))
        for rid in sorted(lost):
            done.extend(self._reroute(rid, wm=wm))
        self._degrade_after_crash(name)
        return done

    def _degrade_after_crash(self, dead_pool: str) -> None:
        """Graceful degradation: re-lease the survivor now carrying the
        recovered load (a REBALANCE in its stream marks the adoption).
        The split is kept at the survivor's current theta: theta depends
        on the mix *proportions*, which the merged load preserves — only
        the magnitude doubled — and re-planning mid-crash would stall
        recovery behind a re-jit of every member at a new split."""
        if not self.recovery.rebalance_on_crash:
            return
        from repro.fleet.planner import normalize_mix

        cands = [n for n in self._placeable()
                 if self.executors[n].fleet.pool is not None]
        if not cands:       # stub fleets (no DevicePool): nothing to
            return          # re-split
        target = min(cands, key=self._outstanding)
        ex = self.executors[target]
        mix = normalize_mix({m.name: m.weight for m in ex.fleet.members})
        try:
            self.rebalance(target, mix=mix, theta=ex.fleet.pool.theta)
        except Exception:   # degraded-but-alive beats a re-lease error
            pass            # escalating a crash we already survived

    def _check_degradation(self) -> None:
        """Degrade pools whose RUN timeouts crossed ``timeout_strikes``:
        drain their queue to a sibling and stop placing new work there
        (in-flight work finishes where it is).  Degradation only affects
        live placement — the drain's SEND/RECV land in the recorded
        streams, so replay needs no event."""
        if self.recovery.run_timeout_s is None:
            return
        for name, ex in self.executors.items():
            if name in self.dead or name in self.degraded:
                continue
            if ex.timeouts < self.recovery.timeout_strikes:
                continue
            if not [n for n in self._placeable() if n != name]:
                continue    # nowhere to shift the load: keep serving
            self.degraded.add(name)
            self.drain_pool(name)

    # ------------------------------------------------------------------
    # migration (SEND on the source, RECV on the destination)
    # ------------------------------------------------------------------
    def migrate(self, src: str, dst: str, *, member: str | None = None,
                count: int | None = None) -> int:
        """Move up to ``count`` queued requests from pool ``src`` to pool
        ``dst`` (None = all queued; ``member`` restricts to one model).
        Returns the number moved."""
        if src == dst:
            raise ValueError(f"cannot migrate pool {src!r} to itself")
        for name in (src, dst):
            if name not in self.executors:
                raise KeyError(f"unknown pool {name!r} "
                               f"(pools: {self.pools})")
        try:
            self.executors[src].inject(Send(peer=dst, member=member,
                                            count=count))
        except PoolCrash as e:      # crash at the SEND boundary: nothing
            #                         left the source — normal recovery
            self._recovery_done.extend(self._fail_pool(src, str(e)))
            return 0
        moved = self.transport.pending(src, dst)
        self.obs.counter("router_migrations_total",
                         "requests moved by migrate()/drain_pool()",
                         "wall").inc(moved, labels={"src": src,
                                                    "dst": dst})
        try:
            self.executors[dst].inject(Recv(peer=src))
        except PoolCrash as e:      # crash at the RECV boundary: the
            #                         payloads are in transit — _fail_pool
            #                         drains the mailbox and re-routes
            self._recovery_done.extend(self._fail_pool(dst, str(e)))
        return moved

    def drain_pool(self, name: str) -> int:
        """Evacuate every queued request of pool ``name`` to the least
        outstanding placeable sibling (in-flight work finishes where it
        is; the pool takes no new admissions once its queue is empty)."""
        others = [n for n in self._placeable() if n != name]
        if not others:
            raise ValueError(f"cannot drain {name!r}: no other live, "
                             f"non-degraded pool to drain into")
        dst = min(others, key=self._outstanding)
        return self.migrate(name, dst)

    # accounting hooks the transport calls at SEND/RECV boundaries ------
    def on_send(self, src: str, dst: str,
                pairs) -> list[tuple[int, Request]] | None:
        """Account one SEND: translate member rids to router rids for
        the transport to carry.  Returns None when replay re-drops a
        recorded loss — the payloads must vanish here too, or the later
        RECV delivers requests the live run never saw."""
        if self._seq.n in self._replay_drops:
            self.on_drop(src, dst, pairs, seq=self._seq.n, live=False)
            return None
        if dst not in self.executors:
            raise KeyError(f"SEND to unknown pool {dst!r} "
                           f"(pools: {self.pools})")
        return [(self._sources.pop((src, frid)), req)
                for frid, req in pairs]

    def on_drop(self, src: str, dst: str, pairs, *, seq: int,
                live: bool) -> int:
        """A SEND lost in transit: un-account the withdrawn requests and
        (live) re-route each onto a placeable pool.  Logged as
        ``("drop", seq)`` so replay drops the same SEND, plus one
        recover event per re-placement at watermark ``seq + 1`` — the
        live resubmission happened *after* the SEND withdrew its
        payloads, so replay must apply it after the SEND record too.
        Returns ``len(pairs)`` either way: the record's ``advances``
        match a delivered SEND bitwise."""
        self._log_event(("drop", seq))
        for frid, _req in pairs:
            rid = self._sources.pop((src, frid))
            if live:
                self._recovery_done.extend(self._reroute(rid, wm=seq + 1))
        return len(pairs)

    def on_recv(self, dst: str, rid: int, frid: int) -> None:
        """Account one delivered payload: router rid ``rid`` now lives on
        pool ``dst`` under member rid ``frid``."""
        self._sources[(dst, frid)] = rid

    # ------------------------------------------------------------------
    # dynamic theta re-leasing
    # ------------------------------------------------------------------
    def observed_mix(self, pool: str) -> dict[str, float]:
        """Per-model share of the traffic pool ``pool`` has completed
        since its last rebalance."""
        served = self._served[pool]
        total = sum(served.values())
        if not total:
            return {}
        return {m: n / total for m, n in served.items()}

    def _check_drift(self) -> None:
        from repro.fleet.planner import normalize_mix

        for name, ex in self.executors.items():
            fleet = ex.fleet
            if fleet.pool is None or name in self.dead:
                continue
            observed = self.observed_mix(name)
            if len(observed) < 2:       # one model (or nothing) served:
                continue                # no mix to drift
            planned = normalize_mix(
                {m.name: m.weight for m in fleet.members})
            drift = 0.5 * sum(
                abs(observed.get(k, 0.0) - planned.get(k, 0.0))
                for k in set(observed) | set(planned))
            if drift > self.rebalance_drift:
                try:
                    self.rebalance(name, mix=observed)
                except PoolCrash as e:      # crash at the REBALANCE
                    self._recovery_done.extend(    # boundary
                        self._fail_pool(name, str(e)))

    def rebalance(self, pool: str, *, mix: Mapping[str, float],
                  theta: float | None = None) -> float:
        """Re-plan ``pool`` for traffic ``mix`` and issue REBALANCE.
        ``theta`` overrides the planner (tests pin the split); the pool's
        planned weights are reset to ``mix`` so the drift detector
        measures against the new baseline."""
        from repro.fleet.planner import plan_fleet

        ex = self.executors[pool]
        if theta is None:
            theta = plan_fleet(mix, max_evals=self.plan_evals).theta
        ex.inject(Rebalance(theta=theta))
        for m in ex.fleet.members:
            if m.name in mix:
                m.weight = mix[m.name]
                if getattr(ex, "remote", False):
                    # a proxy member's weight is a mirror; the worker's
                    # copy is what schedules — lower the reset through
                    # the stream so replay re-applies it in position
                    ex.inject(SetParam(member=m.name, param="weight",
                                       value=float(mix[m.name])))
        self._served[pool] = {}
        self.rebalances.append((pool, theta))
        return theta

    # ------------------------------------------------------------------
    def stream(self) -> list[ExecRecord]:
        """The executed multi-pool stream, interleaved by the router-wide
        sequence number."""
        out = [r for ex in self.executors.values() for r in ex.records]
        out.sort(key=lambda r: r.seq)
        return out

    def streams(self) -> dict[str, list[ExecRecord]]:
        """Per-pool executed streams (what serializes: one
        ``stream_to_json(records, pool=name)`` document per pool)."""
        return {name: list(ex.records)
                for name, ex in self.executors.items()}

    def replay(self, streams: Mapping[str, Sequence[ExecRecord]],
               placements: Sequence[tuple[int, str]],
               requests: Sequence[Request | object],
               events: Sequence[tuple] = ()):
        """Re-execute a recorded multi-pool run on this (fresh) router:
        every record across every pool executes in router-wide seq order,
        and the i-th request re-submits to its recorded pool exactly when
        it did originally (its placement's seq watermark: before the
        first record with seq >= watermark).  No scheduling or placement
        decision is re-made — the streams plus the placement log ARE the
        run — so the re-executed streams and per-request outputs are
        bitwise-identical to the recording (tested, including runs with
        SEND/RECV migration and mid-run REBALANCE).

        ``events`` extends the recipe to faulted runs: the recorded
        :attr:`events` log replays each crash, recovery and dropped SEND
        at the same stream position (its seq watermark, applied in log
        order) — so a run recorded under fault injection replays bitwise
        with no injector attached, reproducing the same recovered,
        failed and shed sets."""
        unknown = set(streams) - set(self.executors)
        if unknown:
            raise KeyError(f"streams for unknown pools {sorted(unknown)} "
                           f"(pools: {self.pools})")
        if len(placements) != len(requests):
            raise ValueError(f"{len(requests)} requests but "
                             f"{len(placements)} placements")
        events = [tuple(e) for e in events]
        self._replay_drops = {e[1] for e in events if e[0] == "drop"}
        # rids recovered by an event *after* index i: a pool failure
        # only fails the rids no later event recovers
        later_recov: list[set[int]] = [set() for _ in
                                       range(len(events) + 1)]
        for i in range(len(events) - 1, -1, -1):
            later_recov[i] = set(later_recov[i + 1])
            if events[i][0] == "recover":
                later_recov[i].add(events[i][3])
        reqs = [r if isinstance(r, Request) else Request(r)
                for r in requests]
        merged = sorted(((r, pool) for pool, recs in streams.items()
                         for r in recs), key=lambda t: t[0].seq)
        pi = ei = 0
        for r, pool in merged:
            while pi < len(placements) and placements[pi][0] <= r.seq:
                self._submit_to(placements[pi][1], reqs[pi])
                pi += 1
            while ei < len(events) and events[ei][1] <= r.seq:
                self._apply_event(events[ei], later_recov[ei + 1], reqs)
                ei += 1
            ex = self.executors[pool]
            fleet = ex.fleet
            fleet._start_clock()
            self._start_clock()
            for c in ex.execute(r.instr, r.slot):
                self._adopt(pool, c)
            if isinstance(r.instr, (Run, Free)):
                fleet._slot = r.slot + 1
        for _wm, pool in placements[pi:]:   # submissions after the last
            #                                 record (an already-idle run)
            self._submit_to(pool, reqs[pi])
            pi += 1
        while ei < len(events):             # events after the last record
            self._apply_event(events[ei], later_recov[ei + 1], reqs)
            ei += 1
        if self.has_work:
            raise ValueError(
                f"recorded streams exhausted with work still outstanding "
                f"(queued={self.queued}, in_flight={self.in_flight}); "
                f"were they recorded from this request trace?")
        return self.result()

    def _apply_event(self, event: tuple, recovered_later: set[int],
                     reqs: Sequence[Request]) -> None:
        """Apply one recorded recovery event at its replay position.
        Router rids are dense 0..n-1 in submission order, so ``reqs[rid]``
        is the request an event names."""
        kind = event[0]
        if kind == "fail":
            _kind, wm, pool = event
            self.dead[pool] = "replayed crash"
            self._log_event(("fail", wm, pool))
            lost = self._pop_sources(pool)
            # in-transit payloads died with it
            lost.extend(self.transport.drain_for(pool))
            for rid in sorted(lost):
                if rid not in recovered_later:
                    self._fail_request(rid)
        elif kind == "recover":
            _kind, wm, pool, rid = event
            req = reqs[rid]
            ticket = self.executors[pool].fleet.submit(
                Request(payload=req.payload, gen_steps=req.gen_steps,
                        model=req.model, deadline=req.deadline,
                        priority=req.priority))
            self._sources[(pool, ticket.rid)] = rid
            self._metrics[rid].status = "recovered"
            self._log_event(("recover", wm, pool, rid))
        elif kind == "drop":
            pass    # consumed via _replay_drops inside send(); the
            #         replayed drop_send re-logs it at the same position
        else:
            raise ValueError(f"unknown recovery event kind {kind!r} "
                             f"in {event!r}")

    def _extra_stats(self, metrics) -> dict:
        per_pool = {}
        for name, ex in self.executors.items():
            fleet = ex.fleet
            per_pool[name] = {
                "slots": fleet._slot,
                "dispatches": fleet._dispatches,
                "served": dict(self._served[name]),
                "queued": fleet.queued,
                "in_flight": fleet.in_flight,
                "retries": ex.retries,
                "timeouts": ex.timeouts,
            }
            if name in self.dead:
                per_pool[name]["dead"] = self.dead[name]
            if fleet.pool is not None:
                per_pool[name]["pool"] = fleet.pool.stats()
        return {"engine": "multipool",
                "pools": per_pool,
                "steps": self._steps,
                "rebalances": [{"pool": p, "theta": round(t, 4)}
                               for p, t in self.rebalances],
                "in_transit": self.in_transit,
                "dead": sorted(self.dead),
                "degraded": sorted(self.degraded),
                "duplicates_dropped": self.duplicates_dropped,
                "recovery_events": len(self.events),
                "shed": metrics.count("shed"),
                "failed": metrics.count("failed"),
                "recovered": metrics.count("recovered"),
                "aggregate_fps": metrics.requests_per_s(),
                "goodput_fps": metrics.goodput_fps(),
                "per_model": metrics.by_model()}
