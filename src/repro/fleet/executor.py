"""Instruction-stream execution: one pool, and a router over many.

:class:`PoolExecutor` is the fleet's execution back end.  It holds no
scheduling opinion: it executes :mod:`repro.fleet.instructions` against
one ``FleetEngine``'s members — the decisions are already in the stream.
The live ``FleetEngine.step`` feeds it one compiled slot at a time (and
the executor records what it ran); :meth:`PoolExecutor.replay` feeds it a
whole pre-compiled or previously-recorded stream, reproducing the live
run's dispatch trace and outputs bitwise (tested) with no central policy
loop — the property that makes a pool drivable from a serialized stream
instead of Python object references.

:class:`MultiPoolRouter` is the first consumer of that property: N
process-local pools standing in for N hosts, each wrapped in its own
executor, presented as one engine (submit / step / drain / result).  The
router owns only cross-pool concerns:

  * placement — submit routes to the pool with the least outstanding
    work for the request's model;
  * migration — :meth:`migrate` / :meth:`drain_pool` move queued
    (unadmitted) requests between pools as a SEND on the source and a
    RECV on the destination, with request identity re-mapped at the
    router boundary (payloads ride the router's mailbox, never the
    serialized stream);
  * dynamic theta re-leasing — when a pool's observed traffic mix
    drifts past ``rebalance_drift`` (total-variation distance from the
    mix its split was planned for), the router re-plans theta via
    ``planner.plan_fleet`` and issues a REBALANCE, which revokes the
    pool's leases, re-splits c/p at the new theta (Eq.10), and relocates
    member params + in-flight envs onto the new submeshes.

Per-request metrics are re-accounted at each boundary exactly as the
fleet does to its members: latency runs from router submit to member
completion, whichever pool finally served it.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Mapping, Sequence

from repro.fleet.instructions import (ExecRecord, Free, Instruction, Recv,
                                      Rebalance, Run, Send)
from repro.serving.api import (Completion, EngineBase, Request,
                               RequestMetrics, Ticket)


class SeqCounter:
    """A peekable monotonic counter: the next value to be issued is
    :attr:`n`.  The router records each submission's position in the
    instruction stream as the seq watermark at submit time — everything
    :meth:`MultiPoolRouter.replay` needs to re-interleave submissions
    with execution."""

    def __init__(self):
        self.n = 0

    def __next__(self) -> int:
        v = self.n
        self.n += 1
        return v


class PoolExecutor:
    """Replays instruction streams against one fleet's members.

    fleet      the ``FleetEngine`` whose members (and pool) instructions
               act on
    name       this pool's name in a multi-pool topology (SEND/RECV peers
               address each other by it)
    transport  mailbox provider for SEND/RECV (a ``MultiPoolRouter``);
               None = single-pool, migration instructions are an error
    record     keep the executed stream in :attr:`records` (ExecRecord
               per instruction, with observed advances + wall-clock) —
               what serializes, replays, and exports to Chrome tracing
    """

    def __init__(self, fleet, *, name: str = "pool0", transport=None,
                 record: bool = True):
        self.fleet = fleet
        self.name = name
        self.transport = transport
        self.records: list[ExecRecord] = []
        self._record = record
        self._seq = SeqCounter()          # router replaces with a shared
        #                                   counter in multi-pool runs
        self._held: dict[str, list] = {}  # member -> flights whose FREE
        #                                   has not executed yet

    # ------------------------------------------------------------------
    def execute(self, instr: Instruction, slot: int) -> list[Completion]:
        """Execute one instruction; returns the completions it
        materialized (only FREE and fused RUN ever do)."""
        t0 = time.perf_counter()
        fleet = self.fleet
        done: list[Completion] = []
        advances = 0
        if isinstance(instr, Run):
            m = fleet._by_name[instr.member]
            if instr.fused:
                # opaque member: step() fuses dispatch and block
                for _ in range(instr.slots):
                    if not m.engine.has_work:
                        break
                    done.extend(fleet._adopt(m, c)
                                for c in m.engine.step())
                    m.dispatches += 1
                    fleet._dispatches += 1
                    advances += 1
            else:
                flights = self._held.setdefault(instr.member, [])
                for _ in range(instr.slots):
                    if not m.engine.has_work:
                        break
                    flights.extend(m.engine.advance())
                    m.dispatches += 1
                    fleet._dispatches += 1
                    advances += 1
        elif isinstance(instr, Free):
            m = fleet._by_name[instr.member]
            flights = self._held.pop(instr.member, [])
            done.extend(fleet._adopt(m, c)
                        for c in m.engine.retire(flights))
        elif isinstance(instr, Send):
            if self.transport is None:
                raise RuntimeError(f"pool {self.name!r} executed SEND with "
                                   f"no transport attached; migration "
                                   f"needs a MultiPoolRouter")
            pairs = fleet.withdraw_pending(instr.count,
                                           member=instr.member)
            advances = self.transport.send(self.name, instr.peer, pairs)
        elif isinstance(instr, Recv):
            if self.transport is None:
                raise RuntimeError(f"pool {self.name!r} executed RECV with "
                                   f"no transport attached")
            advances = self.transport.recv(self.name, instr.peer,
                                           instr.count, fleet.submit)
        elif isinstance(instr, Rebalance):
            self._rebalance(instr.theta)
        else:
            raise TypeError(f"unknown fleet instruction {instr!r}")
        if self._record:
            self.records.append(ExecRecord(
                instr=instr, slot=slot, seq=next(self._seq),
                advances=advances, t0=t0, t1=time.perf_counter()))
        return done

    def execute_slot(self, instrs: Sequence[Instruction],
                     slot: int) -> list[Completion]:
        """Execute one slot's instructions in order.  The compiler's
        RUN-before-FREE ordering is what preserves the block-last rule;
        the executor does not re-sort."""
        done: list[Completion] = []
        for instr in instrs:
            done.extend(self.execute(instr, slot))
        return done

    def inject(self, instr: Instruction) -> list[Completion]:
        """Execute one out-of-band instruction (migration, rebalance) at
        the pool's current slot, recording it in the stream."""
        return self.execute(instr, self.fleet._slot)

    # ------------------------------------------------------------------
    def _rebalance(self, theta: float) -> None:
        """Revoke every lease, re-split the pool at ``theta``, re-lease,
        and relocate members' params and in-flight envs."""
        pool = self.fleet.pool
        if pool is None:
            raise RuntimeError(f"pool {self.name!r} executed REBALANCE "
                               f"but the fleet holds no DevicePool")
        held = pool.revoke_all()
        dual = pool.resplit(theta)
        for m in self.fleet.members:
            if m.name in held:
                pool.lease(m.name)
            if hasattr(m.engine, "relocate"):
                m.engine.relocate(dual)

    # ------------------------------------------------------------------
    def replay(self, records: Sequence[ExecRecord],
               requests: Sequence[Request | object] = (),
               arrivals: Sequence[int] | None = None):
        """Drive the fleet from a compiled or previously-recorded stream:
        the ``serving.api.replay`` arrival loop, with each non-empty slot
        executed from the stream instead of asked of the policy.  Returns
        the fleet's final ``ServeResult``.

        The stream must cover the run: running out of instructions while
        members still hold work means the stream was compiled for a
        different request trace, and raises.
        """
        from repro.serving.api import QueueFull

        fleet = self.fleet
        slots: list[tuple[int, list[Instruction]]] = []
        for r in records:
            if slots and slots[-1][0] == r.slot:
                slots[-1][1].append(r.instr)
            else:
                slots.append((r.slot, [r.instr]))
        arrivals = (list(arrivals) if arrivals is not None
                    else [0] * len(requests))
        if len(arrivals) != len(requests):
            raise ValueError(f"{len(requests)} requests but "
                             f"{len(arrivals)} arrival times")
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        refused: list[int] = []
        gi, nxt, step = 0, 0, 0
        while nxt < len(order) or refused or fleet.has_work:
            due, refused = refused, []
            while nxt < len(order) and arrivals[order[nxt]] <= step:
                due.append(order[nxt])
                nxt += 1
            for i in due:
                try:
                    fleet.submit(requests[i])
                except QueueFull:
                    refused.append(i)   # retry first next step, as replay()
            if fleet.has_work:
                if gi >= len(slots):
                    raise ValueError(
                        f"instruction stream exhausted after {gi} slots "
                        f"with work still outstanding (queued="
                        f"{fleet.queued}, in_flight={fleet.in_flight}); "
                        f"was it compiled for this request trace?")
                fleet._start_clock()
                slot_no, instrs = slots[gi]
                gi += 1
                self.execute_slot(instrs, slot_no)
                fleet._slot = slot_no + 1
            step += 1
        return fleet.result()


# --------------------------------------------------------------------------
# multi-pool serving
# --------------------------------------------------------------------------
class MultiPoolRouter(EngineBase):
    """One engine surface over N pools (module docstring).

    fleets           {pool name: FleetEngine}; each fleet keeps (and the
                     router adopts) its own :class:`PoolExecutor`
    rebalance_drift  total-variation distance between a pool's observed
                     and planned traffic mix beyond which the router
                     re-plans theta and issues REBALANCE (None = never)
    rebalance_every  slots between drift checks
    plan_evals       search budget handed to ``planner.plan_fleet`` when
                     re-planning theta
    """

    def __init__(self, fleets: Mapping[str, object], *,
                 rebalance_drift: float | None = None,
                 rebalance_every: int = 16,
                 plan_evals: int = 8):
        super().__init__(max_queue=None)
        if not fleets:
            raise ValueError("a MultiPoolRouter needs at least one pool")
        self.executors: dict[str, PoolExecutor] = {}
        self._seq = SeqCounter()
        for name, fleet in fleets.items():
            ex = fleet.executor
            ex.name = name
            ex.transport = self
            ex._seq = self._seq         # router-wide order across pools
            self.executors[name] = ex
        self.rebalance_drift = rebalance_drift
        self.rebalance_every = rebalance_every
        self.plan_evals = plan_evals
        self.rebalances: list[tuple[str, float]] = []
        self.placements: list[tuple[int, str]] = []
        #    per submission, in order: (stream seq watermark at submit
        #    time, pool placed on) — with the per-pool streams, the full
        #    recipe for re-executing the run (:meth:`replay`)
        self._sources: dict[tuple[str, int], int] = {}
        #                    (pool, fleet rid) -> router rid
        self._mail: dict[tuple[str, str], deque] = {}
        #                  (src, dst) -> deque[(router rid, Request)]
        self._served: dict[str, dict[str, int]] = {
            name: {} for name in self.executors}
        self._steps = 0

    # ------------------------------------------------------------------
    @property
    def pools(self) -> list[str]:
        return list(self.executors)

    @property
    def in_transit(self) -> int:
        return sum(len(box) for box in self._mail.values())

    @property
    def has_work(self) -> bool:
        return (any(ex.fleet.has_work for ex in self.executors.values())
                or self.in_transit > 0)

    @property
    def queued(self) -> int:
        return (sum(ex.fleet.queued for ex in self.executors.values())
                + self.in_transit)

    @property
    def in_flight(self) -> int:
        return sum(ex.fleet.in_flight for ex in self.executors.values())

    # ------------------------------------------------------------------
    def submit(self, request: Request | object) -> Ticket:
        """Route to the pool with the least outstanding work among the
        pools whose fleet serves the request's model."""
        req = request if isinstance(request, Request) else Request(request)
        cands = [(name, ex) for name, ex in self.executors.items()
                 if req.model is None or req.model in ex.fleet.router.names]
        if not cands:
            served = {n: ex.fleet.router.names
                      for n, ex in self.executors.items()}
            raise KeyError(f"no pool serves model {req.model!r} "
                           f"(pools serve: {served})")
        name, _ex = min(cands,
                        key=lambda kv: kv[1].fleet.queued
                        + kv[1].fleet.in_flight)
        return self._submit_to(name, req)

    def _submit_to(self, pool: str, req: Request) -> Ticket:
        """Submit into a specific pool, with router-level accounting and
        the placement logged (seq watermark, pool) for replay."""
        ex = self.executors[pool]
        submitted_at = time.perf_counter()
        ticket = ex.fleet.submit(
            Request(payload=req.payload, gen_steps=req.gen_steps,
                    model=req.model, deadline=req.deadline,
                    priority=req.priority))
        rid = self._next_rid
        self._next_rid += 1
        req.rid = rid
        self._metrics[rid] = RequestMetrics(rid=rid,
                                            submitted_at=submitted_at,
                                            model=req.model)
        self._order.append(rid)
        self._sources[(pool, ticket.rid)] = rid
        self.placements.append((self._seq.n, pool))
        return Ticket(rid=rid, submitted_at=submitted_at)

    def step(self) -> list[Completion]:
        """One slot on every pool (each pool compiles + executes its own
        slot), then the periodic drift check."""
        self._start_clock()
        done: list[Completion] = []
        for name, ex in self.executors.items():
            done.extend(self._adopt(name, c) for c in ex.fleet.step())
        self._steps += 1
        if (self.rebalance_drift is not None
                and self._steps % self.rebalance_every == 0):
            self._check_drift()
        return done

    def _adopt(self, pool: str, c: Completion) -> Completion:
        """Re-account a pool completion at the router boundary (same move
        as ``FleetEngine._adopt`` one layer down)."""
        rid = self._sources.pop((pool, c.ticket.rid))
        m = self._metrics[rid]
        m.started_at = c.metrics.started_at
        m.finished_at = c.metrics.finished_at
        fc = Completion(ticket=Ticket(rid=rid,
                                      submitted_at=m.submitted_at),
                        output=c.output, metrics=m)
        self._completions[rid] = fc
        model = c.metrics.model or "?"
        served = self._served[pool]
        served[model] = served.get(model, 0) + 1
        return fc

    # ------------------------------------------------------------------
    # migration (SEND on the source, RECV on the destination)
    # ------------------------------------------------------------------
    def migrate(self, src: str, dst: str, *, member: str | None = None,
                count: int | None = None) -> int:
        """Move up to ``count`` queued requests from pool ``src`` to pool
        ``dst`` (None = all queued; ``member`` restricts to one model).
        Returns the number moved."""
        if src == dst:
            raise ValueError(f"cannot migrate pool {src!r} to itself")
        for name in (src, dst):
            if name not in self.executors:
                raise KeyError(f"unknown pool {name!r} "
                               f"(pools: {self.pools})")
        self.executors[src].inject(Send(peer=dst, member=member,
                                        count=count))
        box = self._mail.get((src, dst))
        moved = len(box) if box else 0
        self.executors[dst].inject(Recv(peer=src))
        return moved

    def drain_pool(self, name: str) -> int:
        """Evacuate every queued request of pool ``name`` to the least
        outstanding sibling (in-flight work finishes where it is; the
        pool takes no new admissions once its queue is empty)."""
        others = [n for n in self.executors if n != name]
        if not others:
            raise ValueError(f"cannot drain {name!r}: it is the only pool")
        dst = min(others, key=lambda n: self.executors[n].fleet.queued
                  + self.executors[n].fleet.in_flight)
        return self.migrate(name, dst)

    # transport surface used by PoolExecutor SEND/RECV ------------------
    def send(self, src: str, dst: str, pairs) -> int:
        if dst not in self.executors:
            raise KeyError(f"SEND to unknown pool {dst!r} "
                           f"(pools: {self.pools})")
        box = self._mail.setdefault((src, dst), deque())
        for frid, req in pairs:
            box.append((self._sources.pop((src, frid)), req))
        return len(pairs)

    def recv(self, dst: str, src: str, count: int | None, submit) -> int:
        box = self._mail.get((src, dst))
        n = 0
        while box and (count is None or n < count):
            rid, req = box.popleft()
            ticket = submit(req)
            self._sources[(dst, ticket.rid)] = rid
            n += 1
        return n

    # ------------------------------------------------------------------
    # dynamic theta re-leasing
    # ------------------------------------------------------------------
    def observed_mix(self, pool: str) -> dict[str, float]:
        """Per-model share of the traffic pool ``pool`` has completed
        since its last rebalance."""
        served = self._served[pool]
        total = sum(served.values())
        if not total:
            return {}
        return {m: n / total for m, n in served.items()}

    def _check_drift(self) -> None:
        from repro.fleet.planner import normalize_mix

        for name, ex in self.executors.items():
            fleet = ex.fleet
            if fleet.pool is None:
                continue
            observed = self.observed_mix(name)
            if len(observed) < 2:       # one model (or nothing) served:
                continue                # no mix to drift
            planned = normalize_mix(
                {m.name: m.weight for m in fleet.members})
            drift = 0.5 * sum(
                abs(observed.get(k, 0.0) - planned.get(k, 0.0))
                for k in set(observed) | set(planned))
            if drift > self.rebalance_drift:
                self.rebalance(name, mix=observed)

    def rebalance(self, pool: str, *, mix: Mapping[str, float],
                  theta: float | None = None) -> float:
        """Re-plan ``pool`` for traffic ``mix`` and issue REBALANCE.
        ``theta`` overrides the planner (tests pin the split); the pool's
        planned weights are reset to ``mix`` so the drift detector
        measures against the new baseline."""
        from repro.fleet.planner import plan_fleet

        ex = self.executors[pool]
        if theta is None:
            theta = plan_fleet(mix, max_evals=self.plan_evals).theta
        ex.inject(Rebalance(theta=theta))
        for m in ex.fleet.members:
            if m.name in mix:
                m.weight = mix[m.name]
        self._served[pool] = {}
        self.rebalances.append((pool, theta))
        return theta

    # ------------------------------------------------------------------
    def stream(self) -> list[ExecRecord]:
        """The executed multi-pool stream, interleaved by the router-wide
        sequence number."""
        out = [r for ex in self.executors.values() for r in ex.records]
        out.sort(key=lambda r: r.seq)
        return out

    def streams(self) -> dict[str, list[ExecRecord]]:
        """Per-pool executed streams (what serializes: one
        ``stream_to_json(records, pool=name)`` document per pool)."""
        return {name: list(ex.records)
                for name, ex in self.executors.items()}

    def replay(self, streams: Mapping[str, Sequence[ExecRecord]],
               placements: Sequence[tuple[int, str]],
               requests: Sequence[Request | object]):
        """Re-execute a recorded multi-pool run on this (fresh) router:
        every record across every pool executes in router-wide seq order,
        and the i-th request re-submits to its recorded pool exactly when
        it did originally (its placement's seq watermark: before the
        first record with seq >= watermark).  No scheduling or placement
        decision is re-made — the streams plus the placement log ARE the
        run — so the re-executed streams and per-request outputs are
        bitwise-identical to the recording (tested, including runs with
        SEND/RECV migration and mid-run REBALANCE)."""
        unknown = set(streams) - set(self.executors)
        if unknown:
            raise KeyError(f"streams for unknown pools {sorted(unknown)} "
                           f"(pools: {self.pools})")
        if len(placements) != len(requests):
            raise ValueError(f"{len(requests)} requests but "
                             f"{len(placements)} placements")
        merged = sorted(((r, pool) for pool, recs in streams.items()
                         for r in recs), key=lambda t: t[0].seq)
        pi = 0
        for r, pool in merged:
            while pi < len(placements) and placements[pi][0] <= r.seq:
                self._submit_to(placements[pi][1], requests[pi]
                                if isinstance(requests[pi], Request)
                                else Request(requests[pi]))
                pi += 1
            ex = self.executors[pool]
            fleet = ex.fleet
            fleet._start_clock()
            self._start_clock()
            for c in ex.execute(r.instr, r.slot):
                self._adopt(pool, c)
            if isinstance(r.instr, (Run, Free)):
                fleet._slot = r.slot + 1
        for _wm, pool in placements[pi:]:   # submissions after the last
            #                                 record (an already-idle run)
            self._submit_to(pool, requests[pi]
                            if isinstance(requests[pi], Request)
                            else Request(requests[pi]))
            pi += 1
        if self.has_work:
            raise ValueError(
                f"recorded streams exhausted with work still outstanding "
                f"(queued={self.queued}, in_flight={self.in_flight}); "
                f"were they recorded from this request trace?")
        return self.result()

    def _extra_stats(self, metrics) -> dict:
        per_pool = {}
        for name, ex in self.executors.items():
            fleet = ex.fleet
            per_pool[name] = {
                "slots": fleet._slot,
                "dispatches": fleet._dispatches,
                "served": dict(self._served[name]),
                "queued": fleet.queued,
                "in_flight": fleet.in_flight,
            }
            if fleet.pool is not None:
                per_pool[name]["pool"] = fleet.pool.stats()
        return {"engine": "multipool",
                "pools": per_pool,
                "steps": self._steps,
                "rebalances": [{"pool": p, "theta": round(t, 4)}
                               for p, t in self.rebalances],
                "in_transit": self.in_transit,
                "aggregate_fps": metrics.requests_per_s(),
                "per_model": metrics.by_model()}
