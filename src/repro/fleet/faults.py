"""Seeded, deterministic fault injection for fleet serving (DESIGN.md §12).

The fleet runtime executes everything through instruction streams
(:mod:`repro.fleet.instructions`), which gives failures a natural unit:
the instruction boundary.  A :class:`FaultPlan` is a list of
:class:`Fault` declarations — *this pool's RUN raises*, *this pool dies
at slot k*, *this SEND is lost in transit*, *this pool runs slow* — and a
:class:`FaultInjector` arms the plan inside ``PoolExecutor.execute``:
before any engine state moves, the executor asks the injector whether
this ``(pool, instruction, slot)`` boundary fails.  Because injection
happens strictly before execution, a retried instruction re-executes
against an unchanged pool, and because every fault fires as a pure
function of the boundary (no RNG at fire time), a faulted run is exactly
reproducible: re-running the same plan against the same arrival trace
produces the same failures, the same recoveries, and the same recorded
streams.

Fault kinds and what recovers them:

  ``run_error``   a RUN raises :class:`InjectedFault` ``times``
                  consecutive attempts — recovered by the executor's
                  bounded retry (``RecoveryConfig.max_retries``); retries
                  exhausted escalate to :class:`PoolCrash`.
  ``pool_crash``  the pool raises :class:`PoolCrash` at the first
                  instruction boundary at/after ``slot`` — recovered by
                  ``MultiPoolRouter`` crash recovery (un-retired requests
                  reconstructed from the placement log and re-routed to
                  surviving pools).
  ``send_drop``   one SEND's payloads vanish in transit — recovered by
                  the router re-routing the in-transit requests from its
                  journal.
  ``latency``     every RUN on the pool sleeps ``skew_s`` (a slow host)
                  — detected by the per-RUN timeout
                  (``RecoveryConfig.run_timeout_s``); ``timeout_strikes``
                  timeouts degrade the pool (drained, no new placements).

``FaultPlan.generate(seed, ...)`` draws a random plan from a seeded
generator — the property tests sweep seeds and assert every faulted run
replays bitwise from its recorded streams + recovery log.
"""
from __future__ import annotations

import dataclasses
import json
import random
import time
from typing import Sequence

FAULT_KINDS = ("run_error", "pool_crash", "send_drop", "latency")


class InjectedFault(RuntimeError):
    """A recoverable injected failure at one instruction boundary (the
    executor retries the instruction, bounded by ``RecoveryConfig``)."""


class PoolCrash(RuntimeError):
    """A pool died: either an injected ``pool_crash`` fault or an
    injected RUN failure that exhausted its retries.  The pool executes
    nothing further; ``MultiPoolRouter`` recovers its un-retired
    requests onto surviving pools."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declared failure.

    kind     one of :data:`FAULT_KINDS`
    pool     the pool it arms on
    slot     first fleet slot at/after which it can fire
    member   ``run_error`` only: restrict to one member's RUNs (None =
             any RUN on the pool)
    times    ``run_error`` only: consecutive attempts that fail before
             the RUN succeeds (> max_retries escalates to a crash)
    skew_s   ``latency`` only: seconds each RUN on the pool sleeps
    """

    kind: str
    pool: str = "pool0"
    slot: int = 0
    member: str | None = None
    times: int = 1
    skew_s: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.slot < 0:
            raise ValueError(f"fault slot must be >= 0 (got {self.slot})")
        if self.times < 1:
            raise ValueError(f"fault times must be >= 1 (got {self.times})")
        if self.kind == "latency" and not self.skew_s > 0:
            raise ValueError(f"latency fault needs skew_s > 0 "
                             f"(got {self.skew_s})")


@dataclasses.dataclass
class FaultPlan:
    """A reproducible failure scenario: an ordered list of faults plus
    the seed that generated it (None for hand-written plans).  JSON
    round-trips via :meth:`to_json` / :meth:`from_json` — the ``serve
    fleet --faults PLAN.json`` format."""

    faults: tuple[Fault, ...] = ()
    seed: int | None = None

    def __post_init__(self):
        self.faults = tuple(self.faults)

    def to_json(self) -> dict:
        """Serialize to the ``--faults PLAN.json`` document format."""
        return {"version": 1, "seed": self.seed,
                "faults": [dataclasses.asdict(f) for f in self.faults]}

    @classmethod
    def from_json(cls, doc: dict) -> "FaultPlan":
        """Parse a plan document; unknown versions or fields raise."""
        if not isinstance(doc, dict):
            raise ValueError(f"a fault plan is a JSON object "
                             f"(got {type(doc).__name__})")
        version = doc.get("version")
        if version != 1:
            raise ValueError(f"fault plan version {version!r} != "
                             f"supported 1")
        raw = doc.get("faults")
        if not isinstance(raw, list):
            raise ValueError("fault plan needs a 'faults' list")
        fields = {f.name for f in dataclasses.fields(Fault)}
        faults = []
        for i, d in enumerate(raw):
            if not isinstance(d, dict):
                raise ValueError(f"fault {i} is not an object: {d!r}")
            unknown = set(d) - fields
            if unknown:
                raise ValueError(f"fault {i} has unknown fields "
                                 f"{sorted(unknown)} (expected a subset "
                                 f"of {sorted(fields)})")
            faults.append(Fault(**d))
        return cls(faults=tuple(faults), seed=doc.get("seed"))

    def dump(self, path: str) -> None:
        """Write :meth:`to_json` to ``path``."""
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        """Read and validate a plan written by :meth:`dump`."""
        with open(path) as f:
            try:
                doc = json.load(f)
            except json.JSONDecodeError as e:
                raise ValueError(f"fault plan {path!r} is not valid "
                                 f"JSON: {e}") from e
        return cls.from_json(doc)

    @classmethod
    def generate(cls, seed: int, *, pools: Sequence[str],
                 members: Sequence[str] = (), n: int = 3,
                 max_slot: int = 10,
                 allow_total_crash: bool = False) -> "FaultPlan":
        """Draw a random plan from a seeded generator: up to ``n``
        faults over the given pools (and members, for run_error
        targeting).  At most ``len(pools) - 1`` pool crashes unless
        ``allow_total_crash`` — a scenario with no survivor fails every
        request instead of recovering, which is reproducible too but
        rarely what a chaos sweep wants."""
        rng = random.Random(seed)
        pools = list(pools)
        crash_budget = (len(pools) if allow_total_crash
                        else max(0, len(pools) - 1))
        crashed: list[str] = []
        faults: list[Fault] = []
        for _ in range(rng.randint(1, max(1, n))):
            kind = rng.choice(FAULT_KINDS)
            pool = rng.choice(pools)
            slot = rng.randint(0, max_slot)
            if kind == "pool_crash":
                if len(crashed) >= crash_budget or pool in crashed:
                    kind = "run_error"
                else:
                    crashed.append(pool)
            if kind == "run_error":
                member = (rng.choice(list(members))
                          if members and rng.random() < 0.5 else None)
                faults.append(Fault(kind=kind, pool=pool, slot=slot,
                                    member=member,
                                    times=rng.randint(1, 2)))
            elif kind == "pool_crash":
                faults.append(Fault(kind=kind, pool=pool, slot=slot))
            elif kind == "send_drop":
                faults.append(Fault(kind=kind, pool=pool, slot=slot))
            else:
                faults.append(Fault(kind=kind, pool=pool, slot=slot,
                                    skew_s=rng.uniform(0.001, 0.005)))
        return cls(faults=tuple(faults), seed=seed)


@dataclasses.dataclass
class RecoveryConfig:
    """How the executor and router respond to failures.

    max_retries        attempts re-issued for a RUN that raised an
                       :class:`InjectedFault` before escalating to
                       :class:`PoolCrash`
    backoff_s          base of the exponential retry backoff (0 = retry
                       immediately; tests and replays want 0)
    run_timeout_s      RUN wall time beyond which the executor counts a
                       timeout (None = never) — detection for latency
                       skew, since synchronous execution cannot abort a
                       RUN that already completed
    timeout_strikes    timeouts on one pool before the router degrades
                       it: drains its queue to a sibling and stops
                       placing new requests on it
    rebalance_on_crash re-plan theta on the surviving pool after a crash
                       (skipped automatically for fleets with no
                       DevicePool)
    heartbeat_s        distributed fleets only (DESIGN.md §14): the
                       read deadline on every coordinator->worker RPC;
                       a worker silent past it is declared crashed
                       (None = wait forever — debugger-friendly, not
                       production-friendly)
    """

    max_retries: int = 2
    backoff_s: float = 0.0
    run_timeout_s: float | None = None
    timeout_strikes: int = 3
    rebalance_on_crash: bool = True
    heartbeat_s: float | None = 30.0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError(f"max_retries must be >= 0 "
                             f"(got {self.max_retries})")
        if self.backoff_s < 0:
            raise ValueError(f"backoff_s must be >= 0 "
                             f"(got {self.backoff_s})")
        if self.run_timeout_s is not None and not self.run_timeout_s > 0:
            raise ValueError(f"run_timeout_s must be > 0 or None "
                             f"(got {self.run_timeout_s})")
        if self.timeout_strikes < 1:
            raise ValueError(f"timeout_strikes must be >= 1 "
                             f"(got {self.timeout_strikes})")
        if self.heartbeat_s is not None and not self.heartbeat_s > 0:
            raise ValueError(f"heartbeat_s must be > 0 or None "
                             f"(got {self.heartbeat_s})")


class FaultInjector:
    """Arms a :class:`FaultPlan` at instruction boundaries.

    ``before(pool, instr, slot)`` is called by ``PoolExecutor.execute``
    before any engine state moves; it raises :class:`InjectedFault` /
    :class:`PoolCrash` or sleeps (latency skew) per the plan.
    ``drops_send(pool, slot)`` is consulted at SEND boundaries.  Firing
    is deterministic — each fault tracks how often it has fired, never a
    random draw — so the same plan against the same stream fails
    identically every run."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.fired = [0] * len(plan.faults)

    def before(self, pool: str, instr, slot: int) -> None:
        """Fire any armed fault at this instruction boundary (called per
        executed instruction)."""
        op = getattr(instr, "op", None)
        for i, f in enumerate(self.plan.faults):
            if f.pool != pool or slot < f.slot:
                continue
            if f.kind == "pool_crash":
                if self.fired[i] == 0:
                    self.fired[i] += 1
                    raise PoolCrash(f"injected crash of pool {pool!r} at "
                                    f"slot {slot} (fault {i})")
            elif f.kind == "run_error" and op == "RUN":
                if f.member is not None and instr.member != f.member:
                    continue
                if self.fired[i] < f.times:
                    self.fired[i] += 1
                    raise InjectedFault(
                        f"injected RUN failure on pool {pool!r} member "
                        f"{instr.member!r} at slot {slot} "
                        f"(fault {i}, firing {self.fired[i]}/{f.times})")
            elif f.kind == "latency" and op == "RUN":
                self.fired[i] += 1
                time.sleep(f.skew_s)

    def drops_send(self, pool: str, slot: int) -> bool:
        """True exactly once per armed send_drop fault on this pool."""
        for i, f in enumerate(self.plan.faults):
            if (f.kind == "send_drop" and f.pool == pool
                    and slot >= f.slot and self.fired[i] == 0):
                self.fired[i] += 1
                return True
        return False

    def summary(self) -> dict:
        """Per-fault fire counts, for bench reports and run summaries."""
        return {"seed": self.plan.seed,
                "faults": [{"kind": f.kind, "pool": f.pool,
                            "slot": f.slot, "fired": n}
                           for f, n in zip(self.plan.faults, self.fired)]}
