"""Fleet instruction set: the serializable form of fleet execution.

PR 5's ``FleetEngine.step`` was an imperative Python walk over member
engines — the scheduling decisions (policy pick, core-complementary
co-dispatch ordering, burst) and their execution (advance / step / retire
calls) were fused in one loop, so per-pool state was unserializable and a
router could not drive pools it does not hold Python references to.  This
module is the cut point: every cross-engine decision lowers to one of five
instructions (the ``decentralized_distributed_runtime`` idiom from alpa,
SNIPPETS.md §3, and the same compile-the-schedule-then-replay move the
paper's own overlay ISA makes in ``core/isa.py``):

  RUN        advance one member's exec-group pipeline up to ``slots``
             consecutive scheduler slots on its submesh (``fused`` marks
             members without the advance/retire split, whose step() blocks)
  FREE       materialize + release the member's finished in-flight slots
             (the block-last rule: every RUN of a slot precedes any FREE)
  SEND       emit ``count`` queued requests of one member out of this pool
             toward a peer pool (cross-pool migration / drain)
  RECV       accept requests a peer SENT and enqueue them on the member
  REBALANCE  re-split this pool's c/p submeshes at a new theta (dynamic
             re-leasing when the observed traffic mix drifts)
  SET_PARAM  set one tunable of a member mid-run (fleet weight share, LM
             decode fusion width) — how the §13 control loop's decisions
             land in the stream (schema v2)

Instructions are plain frozen dataclasses, JSON-serializable under a
versioned schema (:data:`SCHEMA_VERSION`); :class:`ExecRecord` wraps one
executed instruction with its observed slot, sequence number, advance
count and wall-clock window — the executed stream is what round-trips
through JSON (``stream_to_json`` / ``stream_from_json``), replays through
``fleet.executor.PoolExecutor.replay``, and exports to Chrome tracing
(``benchmarks/trace_export.py``).

Schema v2 adds SET_PARAM and nothing else.  The compatibility rule: a v1
stream is a valid v2 stream (no v1 op changed shape or meaning), so v1
recordings replay unchanged; a stream that *claims* version 1 but
contains SET_PARAM is schema drift and a hard error, like any unknown
op or field.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

SCHEMA_VERSION = 2

#: schema versions ``stream_from_json`` accepts: v1 streams predate
#: SET_PARAM but are otherwise identical, and must replay unchanged
COMPAT_VERSIONS = (1, 2)

OPS = ("RUN", "FREE", "SEND", "RECV", "REBALANCE", "SET_PARAM")

#: ops only a ``version >= 2`` stream may carry
_V2_OPS = ("SET_PARAM",)


@dataclasses.dataclass(frozen=True)
class Run:
    """Advance ``member``'s pipeline up to ``slots`` consecutive scheduler
    slots.  ``core`` is the predicted dominant submesh of the dispatch
    ('c' | 'p' | None when the compiler did not price it); ``primary``
    marks the scheduling policy's pick for the slot; ``fused`` marks an
    opaque member whose step() fuses dispatch and block (it must execute
    after every pure dispatch of the slot)."""

    member: str
    slots: int = 1
    core: str | None = None
    primary: bool = False
    fused: bool = False

    op = "RUN"


@dataclasses.dataclass(frozen=True)
class Free:
    """Materialize the outputs of ``member``'s finished streams and free
    their pipeline slots.  FREEs trail every RUN of the slot — blocking
    earlier would serialize exactly the cross-network overlap the fleet
    exists for."""

    member: str

    op = "FREE"


@dataclasses.dataclass(frozen=True)
class Send:
    """Withdraw up to ``count`` queued (unadmitted) requests of ``member``
    from this pool and hand them to pool ``peer`` (None member = every
    member).  The matching :class:`Recv` executes on the peer; the router
    carries the payloads through its mailbox — payloads never appear in
    the serialized stream."""

    peer: str
    member: str | None = None
    count: int | None = None

    op = "SEND"


@dataclasses.dataclass(frozen=True)
class Recv:
    """Enqueue the requests pool ``peer`` SENT onto this pool's members
    (each request carries its model tag; ``count`` is the observed number
    accepted, stamped by the executor)."""

    peer: str
    count: int | None = None

    op = "RECV"


@dataclasses.dataclass(frozen=True)
class Rebalance:
    """Re-split this pool's c/p submeshes at ``theta`` (Eq.10): revoke
    every lease, re-lease the new split, and relocate members' params and
    in-flight envs onto it."""

    theta: float

    op = "REBALANCE"


@dataclasses.dataclass(frozen=True)
class SetParam:
    """Set one tunable parameter of ``member`` mid-run (schema v2).

    ``param`` is either ``"weight"`` (the member's fleet share, applied
    by the executor directly) or the name of a keyword the member
    engine's ``retune()`` hook accepts (e.g. ``"group_size"``, the LM
    decode fusion width).  This is how §13 control-loop decisions enter
    the instruction stream: because the mutation is a recorded
    instruction rather than a side effect, a controlled run replays
    bitwise with no controller attached.
    """

    member: str
    param: str
    value: float

    op = "SET_PARAM"


Instruction = Run | Free | Send | Recv | Rebalance | SetParam

_OP_TYPES = {"RUN": Run, "FREE": Free, "SEND": Send, "RECV": Recv,
             "REBALANCE": Rebalance, "SET_PARAM": SetParam}


@dataclasses.dataclass
class ExecRecord:
    """One executed instruction: the instruction plus what execution
    observed — the fleet slot it ran in, a router-wide sequence number
    (replay interleaves multi-pool streams by it), how many scheduler
    slots a RUN actually advanced (burst truncates at an empty pipeline),
    and the wall-clock window (perf_counter seconds) for trace export."""

    instr: Instruction
    slot: int
    seq: int = 0
    advances: int = 0
    t0: float | None = None
    t1: float | None = None
    retries: int = 0      # attempts re-issued after injected RUN faults
    #                       (observational, like t0/t1: excluded from
    #                       stream_signature so a clean replay of a
    #                       faulted recording still matches bitwise)


def instr_to_dict(instr: Instruction) -> dict:
    """One instruction -> its JSON record (``op`` plus fields)."""
    d = {"op": instr.op}
    d.update(dataclasses.asdict(instr))
    return d


def instr_from_dict(d: dict) -> Instruction:
    """Inverse of :func:`instr_to_dict`; unknown ops or fields raise."""
    d = dict(d)
    op = d.pop("op", None)
    if op not in _OP_TYPES:
        raise ValueError(f"unknown fleet instruction op {op!r}; "
                         f"one of {OPS}")
    cls = _OP_TYPES[op]
    fields = {f.name for f in dataclasses.fields(cls)}
    unknown = set(d) - fields
    if unknown:
        raise ValueError(f"{op} instruction has unknown fields "
                         f"{sorted(unknown)} (schema drift? expected "
                         f"{sorted(fields)})")
    return cls(**d)


def stream_to_json(records: Sequence[ExecRecord], *,
                   pool: str | None = None) -> dict:
    """Serialize an executed (or compiled) stream.  Compiled-only records
    carry ``t0``/``t1`` = None; both forms round-trip."""
    return {
        "version": SCHEMA_VERSION,
        "pool": pool,
        "records": [{
            "instr": instr_to_dict(r.instr),
            "slot": r.slot,
            "seq": r.seq,
            "advances": r.advances,
            "t0": r.t0,
            "t1": r.t1,
            **({"retries": r.retries} if r.retries else {}),
        } for r in records],
    }


def stream_from_json(doc: dict) -> list[ExecRecord]:
    """Deserialize a stream, accepting any :data:`COMPAT_VERSIONS` schema.

    v1 streams (pre-SET_PARAM) load and replay unchanged; a v1 document
    that nevertheless carries a v2-only op is schema drift and raises.
    """
    version = doc.get("version")
    if version not in COMPAT_VERSIONS:
        raise ValueError(f"fleet instruction stream schema version "
                         f"{version!r} not in supported {COMPAT_VERSIONS}")
    if version < SCHEMA_VERSION:
        drift = [r["instr"].get("op") for r in doc["records"]
                 if r["instr"].get("op") in _V2_OPS]
        if drift:
            raise ValueError(
                f"stream claims schema version {version} but contains "
                f"version-{SCHEMA_VERSION} ops {sorted(set(drift))} "
                f"(schema drift)")
    return [ExecRecord(instr=instr_from_dict(r["instr"]), slot=r["slot"],
                       seq=r.get("seq", 0), advances=r.get("advances", 0),
                       t0=r.get("t0"), t1=r.get("t1"),
                       retries=r.get("retries", 0))
            for r in doc["records"]]


def dump_stream(records: Sequence[ExecRecord], path: str, *,
                pool: str | None = None) -> None:
    """Write :func:`stream_to_json` to ``path``."""
    with open(path, "w") as f:
        json.dump(stream_to_json(records, pool=pool), f, indent=1)


def load_stream(path: str) -> list[ExecRecord]:
    """Read a stream document written by :func:`dump_stream`."""
    with open(path) as f:
        return stream_from_json(json.load(f))
