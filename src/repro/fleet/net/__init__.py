"""Distributed fleet transport (DESIGN.md §14).

The instruction stream (§11) plus the seq-watermarked placement log is a
coordination protocol; this package gives it a wire.  ``wire`` frames
versioned JSON envelopes; ``transport`` implements the SEND/RECV mailbox
surface three ways (in-memory, spool files, sockets); ``coordinator``
drives N worker processes through the unchanged ``MultiPoolRouter``
placement/migration/recovery logic; ``worker`` is the per-pool process
entrypoint (``python -m repro.fleet.worker``)."""
from repro.fleet.net.transport import (FileTransport, LocalTransport,
                                       SocketTransport)
from repro.fleet.net.wire import (WIRE_VERSION, Channel, WireClosed,
                                  WireError, decode_completion,
                                  decode_request, encode_completion,
                                  encode_request, read_env, write_env)

__all__ = [
    "WIRE_VERSION", "Channel", "WireClosed", "WireError",
    "decode_completion", "decode_request", "encode_completion",
    "encode_request", "read_env", "write_env",
    "FileTransport", "LocalTransport", "SocketTransport",
    "RemoteFleet", "WorkerHandle", "WorkerProc", "connect",
    "start_workers", "stop_workers",
]


def __getattr__(name):
    """Lazy coordinator exports: ``coordinator`` must import after
    ``executor`` (it builds on the router), and ``executor`` imports this
    package for :class:`LocalTransport` — laziness breaks the cycle."""
    if name in ("RemoteFleet", "WorkerHandle", "WorkerProc", "connect",
                "start_workers", "stop_workers"):
        from repro.fleet.net import coordinator
        return getattr(coordinator, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
