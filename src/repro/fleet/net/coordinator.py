"""Coordinator side of distributed fleet serving (DESIGN.md §14).

The :class:`~repro.fleet.executor.MultiPoolRouter` drives pools through
two duck-typed surfaces — ``fleet.submit/step`` and ``executor.inject``
plus recorded ``executor.records`` — so distribution needs no router
changes: :class:`RemoteFleet`/:class:`RemoteExecutor` implement those
surfaces over a :class:`WorkerHandle` RPC channel to one worker process,
and the router's placement, least-outstanding, migration, REBALANCE and
§12 crash-recovery logic runs unchanged against them.

Sequencing is what keeps replay bitwise: every ``step``/``inject`` RPC
carries the router-wide seq watermark as its base; the worker stamps its
records from it and the reply's records advance the shared counter — so
the collected per-worker streams, the placement log and the recovery
events are exactly what a process-local run would have recorded, and
``MultiPoolRouter.replay`` re-executes them on a fresh single-process
fleet.

Crash detection is connection loss or heartbeat (read) timeout on any
RPC: the handle raises :class:`~repro.fleet.faults.PoolCrash`, which the
router's existing ``_fail_pool`` path turns into journal-driven re-routes
onto survivors with at-most-once retirement.  A worker that crashes
*gracefully* (an injected fault escalating in-process) replies with its
partial records and unharvested completions first, so the coordinator's
recorded view matches in-process crash semantics record-for-record.
"""
from __future__ import annotations

import dataclasses
import json
import os
import socket
import subprocess
import sys
import time
import types

from repro.fleet.executor import SeqCounter
from repro.fleet.faults import PoolCrash
from repro.fleet.instructions import (SCHEMA_VERSION, instr_to_dict,
                                      stream_from_json)
from repro.fleet.net import wire
from repro.serving.api import QueueFull, Request, Ticket

#: stdout line a worker prints once it is listening and warmed
READY_PREFIX = "REPRO_WORKER_READY "

_UPCALLS = frozenset({"migrate_out", "migrate_drop", "migrate_req",
                      "migrate_map"})


def dial(address: str, *, timeout_s: float | None = None) -> socket.socket:
    """Connect to a worker address (``tcp:HOST:PORT`` | ``unix:PATH``)."""
    kind, _, rest = address.partition(":")
    if kind == "tcp":
        host, _, port = rest.rpartition(":")
        return socket.create_connection((host, int(port)),
                                        timeout=timeout_s)
    if kind == "unix":
        sock = socket.socket(socket.AF_UNIX)
        sock.settimeout(timeout_s)
        sock.connect(rest)
        return sock
    raise ValueError(f"unknown address scheme in {address!r}; "
                     f"use tcp:HOST:PORT or unix:PATH")


class WorkerHandle:
    """RPC client for one worker process: framed request-reply with the
    worker's ``migrate_*`` upcalls answered inline, and any transport
    failure escalated to :class:`PoolCrash` (the §12 entry point)."""

    def __init__(self, pool: str, channel: wire.Channel):
        self.pool = pool
        self.chan = channel
        self.ex = None          # RemoteExecutor back-ref (set on build)
        self.lost: str | None = None
        self.state: dict = {}
        self.members: list[dict] = []
        self._hello()

    def _hello(self) -> None:
        self.chan.send({"kind": "hello", "pool": self.pool})
        ack = self.chan.recv()
        if ack["kind"] == "error":
            raise RuntimeError(f"worker {self.pool!r} refused hello: "
                               f"{ack.get('msg')}")
        if ack["kind"] != "hello_ack" or ack["pool"] != self.pool:
            raise wire.WireError(f"bad hello_ack from {self.pool!r}: "
                                 f"{ack}")
        if ack["schema"] != SCHEMA_VERSION:
            raise wire.WireError(
                f"worker {self.pool!r} speaks stream schema "
                f"{ack['schema']}, coordinator speaks {SCHEMA_VERSION}")
        self.members = ack["members"]
        self.state = ack["state"]

    # ------------------------------------------------------------------
    @property
    def _router(self):
        # the router reaches us through ex.fleet; we reach it back
        # through the transport it bound (LocalTransport.bind)
        router = getattr(self.ex.transport, "router", None)
        if router is None:
            raise RuntimeError(f"worker {self.pool!r} issued a migrate "
                               f"upcall before a MultiPoolRouter adopted "
                               f"its RemoteFleet")
        return router

    def _upcall(self, env: dict) -> None:
        """Answer one worker upcall against the coordinator mailbox +
        router accounting hooks, mirroring LocalTransport exactly."""
        router = self._router
        transport = router.transport
        kind = env["kind"]
        if kind == "migrate_out":
            pairs = [(frid, wire.decode_request(doc))
                     for frid, doc in env["pairs"]]
            try:
                n = transport.send(env["src"], env["dst"], pairs)
            except KeyError as e:
                self.chan.send({"kind": "error", "etype": "KeyError",
                                "msg": str(e)})
                return
            self.chan.send({"kind": "migrate_ack", "n": n})
        elif kind == "migrate_drop":
            pairs = [(frid, wire.decode_request(doc))
                     for frid, doc in env["pairs"]]
            n = transport.drop_send(env["src"], env["dst"], pairs,
                                    seq=env["seq"], live=env["live"])
            self.chan.send({"kind": "migrate_ack", "n": n})
        elif kind == "migrate_req":
            items = transport.take(env["src"], env["dst"], env["count"])
            self.chan.send({"kind": "migrate_deliver",
                            "items": [[rid, wire.encode_request(req)]
                                      for rid, req in items]})
        elif kind == "migrate_map":
            for rid, frid in env["mapped"]:
                router.on_recv(env["dst"], rid, frid)
            self.chan.send({"kind": "migrate_map_ack",
                            "n": len(env["mapped"])})

    def rpc(self, env: dict) -> dict:
        """One request-reply exchange; upcalls are served in between.
        Raises :class:`PoolCrash` on connection loss or heartbeat
        timeout (and on every call after one)."""
        if self.lost is not None:
            raise PoolCrash(f"worker {self.pool!r} is gone: {self.lost}")
        obs = self._obs
        if self.chan.obs is None and obs is not None:
            self.chan.obs = obs      # coordinator-side net_* counters
        try:
            self.chan.send(env)
            while True:
                reply = self.chan.recv()
                if reply["kind"] in _UPCALLS:
                    self._upcall(reply)
                    continue
                return reply
        except (wire.WireError, OSError) as e:
            self.lost = str(e) or type(e).__name__
            self.chan.close()
            if obs is not None:
                # wall domain: a silent or vanished worker is a fact
                # about the transport, never the stream
                obs.counter("net_heartbeat_misses_total",
                            "RPCs lost to worker silence/disconnect",
                            "wall").inc(labels={"pool": self.pool})
            raise PoolCrash(f"worker {self.pool!r} connection lost "
                            f"({self.lost})") from e

    def call(self, ex, kind: str, **fields) -> dict:
        """One executor-sequenced RPC: ship the shared seq watermark,
        absorb the reply's records/completions/state, advance the
        counter, and map error envelopes back to their exceptions."""
        base = ex._seq.n
        reply = self.rpc({"kind": kind, "seq": base, **fields})
        self._absorb(ex, reply, base)
        if reply["kind"] == "error":
            raise _map_error(reply)
        return reply

    def _absorb(self, ex, reply: dict, base: int) -> None:
        recs = reply.get("records")
        if recs:
            ex.records.extend(stream_from_json(
                {"version": SCHEMA_VERSION, "pool": self.pool,
                 "records": recs}))
            ex._seq.n = base + len(recs)
        state = reply.get("state")
        if state is not None:
            self.state = state
            ex.retries = state["retries"]
            ex.timeouts = state["timeouts"]
        if reply["kind"] == "error":
            # a graceful crash ships the fatal step's unharvested
            # completions; mirror them so _fail_pool's harvest works
            for doc in reply.get("completions") or ():
                c = wire.decode_completion(doc)
                ex.fleet._completions[c.ticket.rid] = c

    @property
    def _obs(self):
        """The adopting router's registry (None before adoption)."""
        return getattr(self.ex, "obs", None)

    def ping(self) -> dict:
        """Heartbeat probe; returns the worker's state snapshot."""
        t0 = time.perf_counter()
        reply = self.rpc({"kind": "ping"})
        if reply["kind"] != "pong":
            raise wire.WireError(f"expected pong, got {reply['kind']!r}")
        obs = self._obs
        if obs is not None:
            obs.histogram("net_rtt_seconds",
                          "ping round-trip time, per worker").observe(
                time.perf_counter() - t0, labels={"pool": self.pool})
        self.state = reply["state"]
        return reply["state"]

    def collect(self, ex) -> dict | None:
        """Pull the worker's cumulative telemetry snapshot and absorb it
        into ``ex.obs`` under this pool's name.  Best-effort: a worker
        that died since the last collect just keeps its previous
        snapshot (at most one unshipped window is lost)."""
        obs = self._obs
        if obs is None or not obs.enabled:
            return None
        try:
            reply = self.rpc({"kind": "telemetry"})
        except PoolCrash:
            return None
        if reply["kind"] != "telemetry_snap":
            raise wire.WireError(f"expected telemetry_snap, got "
                                 f"{reply['kind']!r}")
        snap = reply["snapshot"]
        obs.absorb(snap, source=self.pool)
        return snap

    def shutdown(self) -> None:
        """Ask the worker to exit cleanly; best-effort."""
        if self.lost is not None:
            return
        try:
            self.chan.send({"kind": "shutdown"})
            while self.chan.recv()["kind"] != "bye":
                pass
        except (wire.WireError, OSError):
            pass
        finally:
            self.lost = "shut down"
            self.chan.close()


def _map_error(env: dict) -> Exception:
    etype, msg = env.get("etype"), env.get("msg", "")
    if etype == "PoolCrash":
        return PoolCrash(msg)
    if etype == "QueueFull":
        return QueueFull(msg)
    if etype == "KeyError":
        return KeyError(msg)
    if etype in ("ValueError", "TypeError"):
        return {"ValueError": ValueError, "TypeError": TypeError}[etype](msg)
    return RuntimeError(f"{etype}: {msg}")


# --------------------------------------------------------------------------
# router-facing proxies
# --------------------------------------------------------------------------
@dataclasses.dataclass
class RemoteMember:
    """Coordinator mirror of one worker fleet member (name + weight are
    what placement and REBALANCE accounting read)."""

    name: str
    weight: float


class RemoteExecutor:
    """``PoolExecutor`` stand-in: records mirror the worker's executed
    stream; ``inject`` runs one out-of-band instruction remotely."""

    remote = True   # the router pushes weight resets as SET_PARAM

    def __init__(self, handle: WorkerHandle):
        self._handle = handle
        handle.ex = self
        self.name = handle.pool
        self.fleet = None           # RemoteFleet back-ref
        self.transport = None       # router installs its mailbox binding
        self.records = []
        self.retries = handle.state.get("retries", 0)
        self.timeouts = handle.state.get("timeouts", 0)
        self.injector = None
        self.recovery = None
        self._seq = SeqCounter()    # router replaces with the shared one

    def inject(self, instr):
        """Execute one out-of-band instruction on the worker."""
        reply = self._handle.call(self, "inject",
                                  instr=instr_to_dict(instr))
        return [wire.decode_completion(c) for c in reply["completions"]]


class RemoteFleet:
    """``FleetEngine`` stand-in over one worker process.  State reads
    (queued / in_flight / has_work / slot / dispatches) come from the
    snapshot every RPC reply carries — exact, because a worker's state
    only moves inside an RPC."""

    def __init__(self, handle: WorkerHandle):
        self._handle = handle
        self.executor = RemoteExecutor(handle)
        self.executor.fleet = self
        self.pool = None            # no local DevicePool: the worker owns
        #                             devices; drift/degrade checks skip
        self.controller = None
        self._completions: dict = {}    # filled from graceful-crash
        #                                 replies for _fail_pool's harvest
        self.members = [RemoteMember(m["name"], m["weight"])
                        for m in handle.members]
        self.router = types.SimpleNamespace(
            names=[m.name for m in self.members])

    # state mirror ------------------------------------------------------
    @property
    def queued(self) -> int:
        """Queued requests on the worker (last snapshot)."""
        return self._handle.state["queued"]

    @property
    def in_flight(self) -> int:
        """Admitted requests on the worker (last snapshot)."""
        return self._handle.state["in_flight"]

    @property
    def has_work(self) -> bool:
        """Whether the worker holds work (last snapshot)."""
        return self._handle.state["has_work"]

    @property
    def _slot(self) -> int:
        return self._handle.state["slot"]

    @property
    def _dispatches(self) -> int:
        return self._handle.state["dispatches"]

    # engine surface ----------------------------------------------------
    def submit(self, request) -> Ticket:
        """Submit one request to the worker; its fleet-rid comes back."""
        req = (request if isinstance(request, Request)
               else Request(request))
        reply = self._handle.call(self.executor, "submit",
                                  req=wire.encode_request(req))
        return Ticket(rid=reply["rid"],
                      submitted_at=time.perf_counter())

    def step(self):
        """One fleet slot on the worker; completions come back decoded."""
        reply = self._handle.call(self.executor, "step")
        return [wire.decode_completion(c) for c in reply["completions"]]


# --------------------------------------------------------------------------
# worker process lifecycle
# --------------------------------------------------------------------------
@dataclasses.dataclass
class WorkerProc:
    """One spawned worker process and the address it listens on."""

    pool: str
    address: str
    proc: subprocess.Popen

    def kill(self) -> None:
        """SIGKILL the worker (chaos testing's crash lever)."""
        self.proc.kill()


def start_workers(specs: dict, *, python: str = sys.executable,
                  ready_timeout_s: float = 180.0,
                  env: dict | None = None) -> dict[str, WorkerProc]:
    """Spawn one worker process per pool.  ``specs`` maps pool name ->
    extra ``repro.fleet.worker`` CLI args (e.g. ``["--sim",
    "cnn:c:2"]``); each worker gets an ephemeral localhost port and is
    awaited until it prints its READY line (listening + members built +
    jits warmed)."""
    run_env = dict(os.environ if env is None else env)
    src = os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))))
    run_env["PYTHONPATH"] = (src + os.pathsep + run_env["PYTHONPATH"]
                            if run_env.get("PYTHONPATH") else src)
    procs: dict[str, WorkerProc] = {}
    try:
        for pool, extra in specs.items():
            cmd = [python, "-m", "repro.fleet.worker", "--pool", pool,
                   "--listen", "tcp:127.0.0.1:0", *extra]
            proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                    env=run_env, text=True)
            procs[pool] = WorkerProc(pool=pool, address="", proc=proc)
        deadline = time.monotonic() + ready_timeout_s
        for pool, wp in procs.items():
            wp.address = _await_ready(wp, pool, deadline)
    except Exception:
        for wp in procs.values():
            wp.proc.kill()
        raise
    return procs


def _await_ready(wp: WorkerProc, pool: str, deadline: float) -> str:
    while True:
        if time.monotonic() > deadline:
            raise TimeoutError(f"worker {pool!r} not ready in time")
        line = wp.proc.stdout.readline()
        if not line:
            raise RuntimeError(
                f"worker {pool!r} exited before its READY line "
                f"(rc={wp.proc.poll()})")
        if line.startswith(READY_PREFIX):
            doc = json.loads(line[len(READY_PREFIX):])
            if doc["pool"] != pool:
                raise RuntimeError(f"worker announced pool "
                                   f"{doc['pool']!r}, expected {pool!r}")
            return doc["address"]


def connect(procs: dict[str, WorkerProc], *,
            heartbeat_s: float | None = 30.0,
            dial_timeout_s: float = 30.0) -> dict[str, RemoteFleet]:
    """Dial every worker and return ``{pool: RemoteFleet}`` — the mapping
    ``MultiPoolRouter(fleets)`` takes.  ``heartbeat_s`` is the read
    deadline on every RPC: a worker silent past it is declared crashed."""
    fleets: dict[str, RemoteFleet] = {}
    for pool, wp in procs.items():
        sock = dial(wp.address, timeout_s=dial_timeout_s)
        chan = wire.Channel(sock, timeout_s=heartbeat_s)
        fleets[pool] = RemoteFleet(WorkerHandle(pool, chan))
    return fleets


def stop_workers(fleets: dict[str, RemoteFleet],
                 procs: dict[str, WorkerProc] | None = None,
                 *, timeout_s: float = 10.0) -> None:
    """Shut every worker down (best-effort) and reap the processes."""
    for fleet in fleets.values():
        fleet._handle.shutdown()
    for wp in (procs or {}).values():
        try:
            wp.proc.wait(timeout=timeout_s)
        except subprocess.TimeoutExpired:
            wp.proc.kill()
            wp.proc.wait()
