"""SEND/RECV transports behind ``PoolExecutor``'s mailbox surface.

A transport carries migrated request payloads between pools; the
*accounting* (rid translation, recovery events, live re-routes) stays on
the :class:`~repro.fleet.executor.MultiPoolRouter`, reached through three
hooks — ``on_send`` / ``on_drop`` / ``on_recv`` — so every transport
enforces identical bookkeeping and the placement/recovery logs stay
transport-agnostic.  The executor-facing surface is what SEND/RECV
instructions call:

    send(src, dst, pairs)                   deliver withdrawn requests
    drop_send(src, dst, pairs, seq, live)   a SEND lost in transit
    recv(dst, src, count, submit)           drain into the destination

and the router-facing surface is what placement, migration accounting
and crash recovery call:

    bind(router)        attach the owning router (its hooks)
    in_transit          total payloads riding the mailbox
    pending(src, dst)   payloads on one edge
    take(src, dst, n)   pop payloads without submitting them (the
                        coordinator delivers them to a remote RECV)
    drain_for(dst)      pop every payload addressed to a dead pool,
                        returning the stranded router rids

:class:`LocalTransport` is the in-memory deque the router always had —
now a named default binding.  :class:`FileTransport` spools each SEND as
a framed ``frame`` envelope file (one file per SEND, consumed head-first
by RECV) — a debuggable, replayable on-disk mailbox.
:class:`SocketTransport` is the *worker-side* binding: it forwards the
three executor calls to the coordinator as ``migrate_*`` upcalls on the
worker's control channel (see ``net.coordinator`` for the other side).
"""
from __future__ import annotations

import os
from collections import deque

from repro.fleet.net import wire


class LocalTransport:
    """In-memory (src, dst) -> deque mailbox; the default binding for
    process-local multi-pool serving."""

    obs = None     # optional repro.obs.Registry (the router sets it)

    def __init__(self):
        self.router = None
        self._mail: dict[tuple[str, str], deque] = {}

    def bind(self, router) -> None:
        """Attach the owning router (accounting hooks)."""
        self.router = router

    # executor-facing ---------------------------------------------------
    def send(self, src: str, dst: str, pairs) -> int:
        """Deliver withdrawn requests into the (src, dst) mailbox; the
        router's ``on_send`` translates rids (and may swallow the SEND
        during the replay of a recorded drop)."""
        carried = self.router.on_send(src, dst, pairs)
        if carried is not None:
            self._mail.setdefault((src, dst), deque()).extend(carried)
        return len(pairs)

    def drop_send(self, src: str, dst: str, pairs, *, seq: int,
                  live: bool) -> int:
        """A SEND lost in transit: nothing is carried; the router logs
        the drop and (live) re-routes the payloads."""
        return self.router.on_drop(src, dst, pairs, seq=seq, live=live)

    def recv(self, dst: str, src: str, count: int | None, submit) -> int:
        """Drain up to ``count`` mailbox payloads into ``submit`` on the
        destination pool."""
        n = 0
        for rid, req in self.take(src, dst, count):
            self.router.on_recv(dst, rid, submit(req).rid)
            n += 1
        return n

    # router-facing -----------------------------------------------------
    @property
    def in_transit(self) -> int:
        """Total payloads riding the mailbox."""
        return sum(len(box) for box in self._mail.values())

    def pending(self, src: str, dst: str) -> int:
        """Payloads waiting on the (src, dst) edge."""
        return len(self._mail.get((src, dst), ()))

    def take(self, src: str, dst: str,
             count: int | None) -> list[tuple[int, object]]:
        """Pop up to ``count`` (router rid, Request) payloads from the
        (src, dst) edge without submitting them."""
        box = self._mail.get((src, dst))
        out: list[tuple[int, object]] = []
        while box and (count is None or len(out) < count):
            out.append(box.popleft())
        return out

    def drain_for(self, dst: str) -> list[int]:
        """Pop every payload addressed to ``dst`` (it died); return the
        stranded router rids for recovery."""
        lost: list[int] = []
        for (_s, d), box in self._mail.items():
            if d == dst:
                while box:
                    rid, _req = box.popleft()
                    lost.append(rid)
        return lost


class FileTransport:
    """Spool-directory mailbox: each SEND is one framed ``frame``
    envelope file under ``spool_dir``, named ``NNNNNNNN.src.dst.frame``
    so lexical order is delivery order.  RECV consumes files head-first,
    rewriting a partially-consumed frame in place.  Everything on disk is
    the wire format — inspectable with ``wire.read_env`` — which is the
    point: a spool directory is a replayable, debuggable trace of every
    payload that crossed pools."""

    obs = None     # optional repro.obs.Registry (the router sets it)

    def __init__(self, spool_dir: str):
        os.makedirs(spool_dir, exist_ok=True)
        self.spool_dir = spool_dir
        self.router = None
        self._n = 0     # monotonically-named frames, delivery order

    def bind(self, router) -> None:
        """Attach the owning router (accounting hooks)."""
        self.router = router

    # spool internals ---------------------------------------------------
    def _frames(self, src: str | None = None,
                dst: str | None = None) -> list[str]:
        names = sorted(n for n in os.listdir(self.spool_dir)
                       if n.endswith(".frame"))
        out = []
        for n in names:
            _seq, s, d, _ext = n.split(".")
            if (src is None or s == src) and (dst is None or d == dst):
                out.append(n)
        return out

    def _read(self, name: str) -> dict:
        with open(os.path.join(self.spool_dir, name), "rb") as f:
            return wire.read_env(f)

    def _write(self, name: str, env: dict) -> None:
        buf = wire.pack_env(env)
        with open(os.path.join(self.spool_dir, name), "wb") as f:
            f.write(buf)
            f.flush()
        if self.obs is not None and self.obs.enabled:
            # wall domain: spool traffic depends on drop timing
            self.obs.counter("net_envelopes_total",
                             "envelopes on the wire", "wall").inc(
                labels={"dir": "out", "kind": str(env.get("kind"))})
            self.obs.counter("net_bytes_total", "framed bytes sent",
                             "wall").inc(len(buf), labels={"dir": "out"})

    # executor-facing ---------------------------------------------------
    def send(self, src: str, dst: str, pairs) -> int:
        """Spool one frame file carrying the withdrawn requests."""
        carried = self.router.on_send(src, dst, pairs)
        if carried is not None and carried:
            env = {"kind": "frame", "src": src, "dst": dst,
                   "items": [[rid, wire.encode_request(req)]
                             for rid, req in carried]}
            self._write(f"{self._n:08d}.{src}.{dst}.frame", env)
            self._n += 1
        return len(pairs)

    def drop_send(self, src: str, dst: str, pairs, *, seq: int,
                  live: bool) -> int:
        """A SEND lost in transit: no frame is spooled."""
        return self.router.on_drop(src, dst, pairs, seq=seq, live=live)

    def recv(self, dst: str, src: str, count: int | None, submit) -> int:
        """Consume spooled frames head-first into ``submit``."""
        n = 0
        for rid, req in self.take(src, dst, count):
            self.router.on_recv(dst, rid, submit(req).rid)
            n += 1
        return n

    # router-facing -----------------------------------------------------
    @property
    def in_transit(self) -> int:
        """Total payloads spooled across all edges."""
        return sum(len(self._read(n)["items"]) for n in self._frames())

    def pending(self, src: str, dst: str) -> int:
        """Payloads spooled on the (src, dst) edge."""
        return sum(len(self._read(n)["items"])
                   for n in self._frames(src, dst))

    def take(self, src: str, dst: str,
             count: int | None) -> list[tuple[int, object]]:
        """Pop up to ``count`` payloads from the (src, dst) edge,
        rewriting a partially-consumed head frame."""
        out: list[tuple[int, object]] = []
        for name in self._frames(src, dst):
            if count is not None and len(out) >= count:
                break
            env = self._read(name)
            items = env["items"]
            room = (len(items) if count is None
                    else min(len(items), count - len(out)))
            out.extend((rid, wire.decode_request(doc))
                       for rid, doc in items[:room])
            rest = items[room:]
            path = os.path.join(self.spool_dir, name)
            if rest:
                self._write(name, {**env, "items": rest})
            else:
                os.remove(path)
        return out

    def drain_for(self, dst: str) -> list[int]:
        """Delete every frame addressed to ``dst``; return the stranded
        router rids."""
        lost: list[int] = []
        for name in self._frames(dst=dst):
            lost.extend(rid for rid, _doc in self._read(name)["items"])
            os.remove(os.path.join(self.spool_dir, name))
        return lost


class SocketTransport:
    """Worker-side SEND/RECV binding: each executor call becomes a
    ``migrate_*`` upcall on the worker's control channel, answered
    inline by the coordinator (which owns the real mailbox and the
    router hooks).  Only the executor-facing surface exists here — a
    worker never sees the fleet-wide mailbox."""

    def __init__(self, channel: wire.Channel):
        self.chan = channel

    def _ack(self, expect: str) -> dict:
        env = self.chan.recv()
        if env["kind"] == "error":
            raise _raise_remote(env)
        if env["kind"] != expect:
            raise wire.WireError(f"expected {expect!r} from the "
                                 f"coordinator, got {env['kind']!r}")
        return env

    def send(self, src: str, dst: str, pairs) -> int:
        """Ship withdrawn requests up to the coordinator's mailbox."""
        self.chan.send({"kind": "migrate_out", "src": src, "dst": dst,
                        "pairs": [[frid, wire.encode_request(req)]
                                  for frid, req in pairs]})
        return self._ack("migrate_ack")["n"]

    def drop_send(self, src: str, dst: str, pairs, *, seq: int,
                  live: bool) -> int:
        """Report a dropped SEND so the coordinator logs + re-routes."""
        self.chan.send({"kind": "migrate_drop", "src": src, "dst": dst,
                        "pairs": [[frid, wire.encode_request(req)]
                                  for frid, req in pairs],
                        "seq": seq, "live": live})
        return self._ack("migrate_ack")["n"]

    def recv(self, dst: str, src: str, count: int | None, submit) -> int:
        """Pull payloads for a RECV from the coordinator's mailbox, then
        report the member-rid mapping so the coordinator re-accounts."""
        self.chan.send({"kind": "migrate_req", "src": src, "dst": dst,
                        "count": count})
        items = self._ack("migrate_deliver")["items"]
        mapped = [[rid, submit(wire.decode_request(doc)).rid]
                  for rid, doc in items]
        self.chan.send({"kind": "migrate_map", "dst": dst,
                        "mapped": mapped})
        self._ack("migrate_map_ack")
        return len(mapped)


def _raise_remote(env: dict) -> Exception:
    """Re-raise a coordinator ``error`` envelope worker-side."""
    etype, msg = env.get("etype"), env.get("msg", "")
    if etype == "KeyError":
        raise KeyError(msg)
    raise RuntimeError(f"{etype}: {msg}")
