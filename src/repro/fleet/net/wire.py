"""Framed wire protocol for distributed fleet serving (DESIGN.md §14).

One message on the wire is an **envelope**: a 4-byte big-endian length
prefix followed by that many bytes of UTF-8 JSON.  Every envelope carries
``v`` (the wire schema version) and ``kind``; the remaining fields are
kind-specific and validated against a per-kind whitelist on *read* — an
unknown kind, an unknown field, or a version mismatch is schema drift
and raises :class:`WireError` hard, exactly like the instruction-stream
schema (``instructions.instr_from_dict``).  The protocol is versioned
independently of the stream schema: envelopes *carry* schema-v2
instruction documents and stream records, they do not redefine them.

Payload values (request payloads, completion outputs) are JSON with two
tagged escape hatches: ndarrays ride as ``{"__nd__": [dtype, shape,
base64]}`` and raw bytes as ``{"__b__": base64}``.  jax arrays are
materialized to numpy at the boundary — a worker owns its own devices;
device placement never crosses the wire.

The coordinator/worker RPC surface is strict request-reply, with one
carve-out: while serving a ``step``/``inject`` RPC a worker may issue
``migrate_*`` **upcalls** (its SEND/RECV instructions need the
coordinator's mailbox); the coordinator answers each inline and keeps
waiting for the original reply, so frames never interleave.
"""
from __future__ import annotations

import base64
import json
import struct

import numpy as np

from repro.serving.api import Completion, Request, RequestMetrics, Ticket

#: wire schema version; envelopes are stamped with it.  v2 added the
#: telemetry pull (``telemetry``/``telemetry_snap``); everything a v1
#: peer could say is unchanged, so both versions stay readable
WIRE_VERSION = 2
#: versions this reader accepts
WIRE_COMPAT = (1, 2)
#: kinds that did not exist in v1 — a v1 envelope carrying one is drift
_V2_KINDS = ("telemetry", "telemetry_snap")

_LEN = struct.Struct(">I")
_MAX_FRAME = 1 << 30    # 1 GiB: a corrupt length prefix fails loudly


class WireError(ValueError):
    """Protocol violation: bad framing, version or kind/field drift."""


class WireClosed(WireError):
    """The peer closed the connection at a frame boundary (or mid-frame,
    which additionally means a message was truncated)."""


#: envelope kinds -> the fields each may carry (beyond ``v``/``kind``).
#: Coordinator -> worker: hello, submit, step, inject, ping, shutdown.
#: Worker -> coordinator: the ``*_ack``/``*_done`` replies, ``error``,
#: and the migrate upcalls issued mid-RPC.  ``frame`` is the on-disk
#: spool format of :class:`~repro.fleet.net.transport.FileTransport`.
ENVELOPE_FIELDS: dict[str, frozenset] = {
    "hello": frozenset({"pool"}),
    "hello_ack": frozenset({"pool", "schema", "members", "state"}),
    "submit": frozenset({"req", "seq"}),
    "submit_ack": frozenset({"rid", "records", "completions", "state"}),
    "step": frozenset({"seq"}),
    "step_done": frozenset({"records", "completions", "state"}),
    "inject": frozenset({"instr", "seq"}),
    "inject_done": frozenset({"records", "completions", "state"}),
    "migrate_out": frozenset({"src", "dst", "pairs"}),
    "migrate_ack": frozenset({"n"}),
    "migrate_drop": frozenset({"src", "dst", "pairs", "seq", "live"}),
    "migrate_req": frozenset({"src", "dst", "count"}),
    "migrate_deliver": frozenset({"items"}),
    "migrate_map": frozenset({"dst", "mapped"}),
    "migrate_map_ack": frozenset({"n"}),
    "ping": frozenset(),
    "pong": frozenset({"state"}),
    "telemetry": frozenset(),
    "telemetry_snap": frozenset({"snapshot"}),
    "shutdown": frozenset(),
    "bye": frozenset(),
    "error": frozenset({"etype", "msg", "records", "completions",
                        "state"}),
    "frame": frozenset({"src", "dst", "items"}),
}


def pack_env(env: dict) -> bytes:
    """Serialize one envelope to its framed wire bytes (stamping ``v``)."""
    kind = env.get("kind")
    if kind not in ENVELOPE_FIELDS:
        raise WireError(f"unknown envelope kind {kind!r}; one of "
                        f"{sorted(ENVELOPE_FIELDS)}")
    doc = {"v": WIRE_VERSION, **env}
    body = json.dumps(doc, separators=(",", ":")).encode()
    return _LEN.pack(len(body)) + body


def _validate(doc: dict) -> dict:
    v = doc.get("v")
    if v not in WIRE_COMPAT:
        raise WireError(f"wire version {v!r} not in {WIRE_COMPAT} "
                        f"(peer speaks a different protocol)")
    kind = doc.get("kind")
    if v < 2 and kind in _V2_KINDS:
        raise WireError(f"v{v} envelope carries the v2-only kind "
                        f"{kind!r} (wire drift)")
    allowed = ENVELOPE_FIELDS.get(kind)
    if allowed is None:
        raise WireError(f"unknown envelope kind {kind!r}; one of "
                        f"{sorted(ENVELOPE_FIELDS)}")
    extra = set(doc) - allowed - {"v", "kind"}
    if extra:
        raise WireError(f"{kind} envelope has unknown fields "
                        f"{sorted(extra)} (wire drift? expected a subset "
                        f"of {sorted(allowed)})")
    return doc


def unpack_env(body: bytes) -> dict:
    """Parse and validate one envelope body (the bytes after the length
    prefix)."""
    try:
        doc = json.loads(body.decode())
    except (UnicodeDecodeError, json.JSONDecodeError) as e:
        raise WireError(f"undecodable envelope body: {e}") from None
    if not isinstance(doc, dict):
        raise WireError(f"envelope body is {type(doc).__name__}, "
                        f"not an object")
    return _validate(doc)


def write_env(f, env: dict) -> None:
    """Write one framed envelope to a binary file-like and flush."""
    f.write(pack_env(env))
    f.flush()


def read_env(f) -> dict:
    """Read one framed envelope from a binary file-like.  A clean EOF at
    the frame boundary (and a truncated frame) raise :class:`WireClosed`;
    anything malformed raises :class:`WireError`."""
    head = f.read(_LEN.size)
    if not head:
        raise WireClosed("peer closed the connection")
    if len(head) < _LEN.size:
        raise WireClosed(f"truncated length prefix "
                         f"({len(head)}/{_LEN.size} bytes)")
    (n,) = _LEN.unpack(head)
    if n > _MAX_FRAME:
        raise WireError(f"frame length {n} exceeds the {_MAX_FRAME}-byte "
                        f"cap (corrupt prefix?)")
    body = b""
    while len(body) < n:
        chunk = f.read(n - len(body))
        if not chunk:
            raise WireClosed(f"truncated frame ({len(body)}/{n} bytes)")
        body += chunk
    return unpack_env(body)


class Channel:
    """One framed-envelope connection over a socket.

    ``timeout_s`` is the read deadline — the coordinator's heartbeat: a
    worker that stays silent past it raises ``TimeoutError``, which the
    coordinator escalates to a pool crash."""

    obs = None      # optional repro.obs.Registry for net_* wall metrics

    def __init__(self, sock, *, timeout_s: float | None = None):
        sock.settimeout(timeout_s)
        self._sock = sock
        self._f = sock.makefile("rwb")

    def _count(self, direction: str, kind, nbytes: int = 0) -> None:
        obs = self.obs
        if obs is None or not obs.enabled:
            return
        # wall domain: what crossed this wire depends on transport and
        # timing, never on the instruction stream
        obs.counter("net_envelopes_total", "envelopes on the wire",
                    "wall").inc(labels={"dir": direction,
                                        "kind": str(kind)})
        if nbytes:
            obs.counter("net_bytes_total", "framed bytes sent",
                        "wall").inc(nbytes, labels={"dir": direction})

    def send(self, env: dict) -> None:
        """Write one envelope and flush."""
        buf = pack_env(env)
        self._f.write(buf)
        self._f.flush()
        self._count("out", env.get("kind"), len(buf))

    def recv(self) -> dict:
        """Read one envelope (blocking, up to the channel timeout)."""
        env = read_env(self._f)
        self._count("in", env.get("kind"))
        return env

    def close(self) -> None:
        """Close the file wrapper and the underlying socket."""
        for obj in (self._f, self._sock):
            try:
                obj.close()
            except OSError:
                pass


# --------------------------------------------------------------------------
# payload codec
# --------------------------------------------------------------------------
_ND_TAG = "__nd__"
_BYTES_TAG = "__b__"


def encode_value(x):
    """JSON-encodable form of a payload value: ndarrays (numpy or jax)
    and bytes are tagged + base64'd; containers recurse; scalars pass
    through; anything else is not wire-safe and raises."""
    if x is None or isinstance(x, (bool, int, float, str)):
        return x
    if isinstance(x, bytes):
        return {_BYTES_TAG: base64.b64encode(x).decode()}
    if isinstance(x, (list, tuple)):
        return [encode_value(v) for v in x]
    if isinstance(x, dict):
        for tag in (_ND_TAG, _BYTES_TAG):
            if tag in x:
                raise WireError(f"dict payload uses the reserved key "
                                f"{tag!r}")
        return {str(k): encode_value(v) for k, v in x.items()}
    if hasattr(x, "dtype") and hasattr(x, "shape"):
        a = np.asarray(x)
        return {_ND_TAG: [str(a.dtype), list(a.shape),
                          base64.b64encode(np.ascontiguousarray(a)
                                           .tobytes()).decode()]}
    raise WireError(f"payload value of type {type(x).__name__} is not "
                    f"wire-serializable")


def decode_value(x):
    """Inverse of :func:`encode_value` (ndarrays come back as numpy)."""
    if isinstance(x, list):
        return [decode_value(v) for v in x]
    if isinstance(x, dict):
        if _ND_TAG in x:
            dtype, shape, b64 = x[_ND_TAG]
            return np.frombuffer(base64.b64decode(b64),
                                 dtype=np.dtype(dtype)).reshape(shape)
        if _BYTES_TAG in x:
            return base64.b64decode(x[_BYTES_TAG])
        return {k: decode_value(v) for k, v in x.items()}
    return x


def encode_request(req: Request) -> dict:
    """Wire document for one request (rids never cross the wire — each
    side keeps its own request-id domain)."""
    return {"payload": encode_value(req.payload),
            "gen_steps": req.gen_steps,
            "model": req.model,
            "deadline": req.deadline,
            "priority": req.priority}


def decode_request(doc: dict) -> Request:
    """Inverse of :func:`encode_request`."""
    return Request(payload=decode_value(doc["payload"]),
                   gen_steps=doc["gen_steps"],
                   model=doc["model"],
                   deadline=doc["deadline"],
                   priority=doc["priority"])


def encode_completion(c: Completion) -> dict:
    """Wire document for one completion (member-rid domain)."""
    m = c.metrics
    return {"ticket": [c.ticket.rid, c.ticket.submitted_at],
            "output": encode_value(c.output),
            "metrics": {"rid": m.rid, "submitted_at": m.submitted_at,
                        "started_at": m.started_at,
                        "finished_at": m.finished_at, "model": m.model,
                        "status": m.status, "deadline": m.deadline,
                        "slo_ok": m.slo_ok}}


def decode_completion(doc: dict) -> Completion:
    """Inverse of :func:`encode_completion`."""
    rid, sub = doc["ticket"]
    return Completion(ticket=Ticket(rid=rid, submitted_at=sub),
                      output=decode_value(doc["output"]),
                      metrics=RequestMetrics(**doc["metrics"]))
