"""Worker process: one pool of the distributed fleet (DESIGN.md §14).

``python -m repro.fleet.worker --pool p1 --listen tcp:127.0.0.1:0``
hosts one ``FleetEngine`` + ``PoolExecutor`` behind a framed-envelope
control channel and executes what the coordinator streams at it:
``submit`` enqueues a request, ``step`` runs one fleet slot, ``inject``
runs one out-of-band instruction (SEND/RECV migration, REBALANCE,
SET_PARAM).  Every ``step``/``inject`` carries the router-wide seq
watermark; the worker stamps its records from it and ships them back,
so the coordinator's collected streams replay bitwise in-process.

SEND/RECV payloads never shortcut through worker memory: the executor's
transport is a :class:`~repro.fleet.net.transport.SocketTransport`,
whose ``migrate_*`` upcalls ride the same channel back to the
coordinator's mailbox — a worker only ever sees its own pool.

Members are either real CNN fleets (``--models``, built exactly like
``serve fleet`` builds them) or deterministic simulation members
(``--sim name:core:steps[:opaque]``) for transport tests and benches —
the sim twins of the test suite's StubEngine live here so an in-process
replay fleet can be built member-for-member identical to the workers'.
"""
from __future__ import annotations

import argparse
import json
import socket
import sys
import time

from repro.fleet.instructions import (SCHEMA_VERSION, instr_from_dict,
                                      stream_to_json)
from repro.fleet.faults import PoolCrash
from repro.fleet.net import wire
from repro.fleet.net.transport import SocketTransport
from repro.serving.api import (EngineBase, FixedRateAdmission, QueueFull,
                               ShedPolicy)

READY_PREFIX = "REPRO_WORKER_READY "


# --------------------------------------------------------------------------
# deterministic simulation members
# --------------------------------------------------------------------------
class SimEngine(EngineBase):
    """Batched simulation member: serves any payload in ``service_steps``
    slots with the CNN engine's two-phase advance/retire split and a
    fixed dominant core.  Deterministic by construction — the unit the
    transport tests and benches replay bitwise across processes."""

    def __init__(self, *, capacity: int = 2, service_steps: int = 1,
                 core: str = "c", max_queue: int | None = None,
                 service_cost_s: float = 0.0):
        super().__init__(max_queue=max_queue)
        self.policy = FixedRateAdmission(1)
        self.capacity = capacity
        self.service_steps = service_steps
        self.service_cost_s = service_cost_s
        self._core = core
        self._flight: list[list] = []       # [remaining, rid, payload]

    @property
    def in_flight(self) -> int:
        """Admitted, unfinished requests."""
        return len(self._flight)

    @property
    def has_work(self) -> bool:
        """Queued or in-flight work exists."""
        return bool(self._pending or self._flight)

    @property
    def next_core(self) -> str | None:
        """Dominant core of the next dispatch (None when idle)."""
        return self._core if self.has_work else None

    def advance(self) -> list:
        """Tick in-flight work one slot and admit into freed capacity."""
        self._start_clock()
        if self.service_cost_s and self._flight:
            time.sleep(self.service_cost_s)     # modeled compute per slot
        for f in self._flight:
            f[0] -= 1
        finished = [f for f in self._flight if f[0] <= 0]
        self._flight = [f for f in self._flight if f[0] > 0]
        n = self.policy.admit(queued=len(self._pending),
                              in_flight=len(self._flight),
                              capacity=self.capacity)
        for _ in range(max(0, min(n, len(self._pending),
                                  self.capacity - len(self._flight)))):
            popped = self._pop_admission()      # None: the rest was shed
            if popped is None:
                break
            req, _t = popped
            self._metrics[req.rid].started_at = time.perf_counter()
            self._flight.append([self.service_steps, req.rid,
                                 req.payload])
        return finished

    def retire(self, finished) -> list:
        """Materialize completions for finished flights (+ sheds)."""
        out = self._take_shed()
        out.extend(self._finish(rid, payload)
                   for _, rid, payload in finished)
        return out

    def step(self) -> list:
        """One fused slot (advance + retire)."""
        return self.retire(self.advance())


class OpaqueSimEngine(EngineBase):
    """Opaque simulation member: only ``step()`` exists (dispatch and
    block fused), the shape of the LM engine — the fleet compiles RUNs
    against it with ``fused=True`` and no deferred FREE."""

    def __init__(self, *, capacity: int = 2, service_steps: int = 1,
                 core: str = "p", max_queue: int | None = None,
                 service_cost_s: float = 0.0):
        super().__init__(max_queue=max_queue)
        self.policy = FixedRateAdmission(1)
        self._capacity = capacity
        self._steps = service_steps
        self.service_cost_s = service_cost_s
        self._core = core
        self._flight: list[list] = []

    @property
    def in_flight(self) -> int:
        """Admitted, unfinished requests."""
        return len(self._flight)

    @property
    def has_work(self) -> bool:
        """Queued or in-flight work exists."""
        return bool(self._pending or self._flight)

    @property
    def next_core(self) -> str | None:
        """Dominant core of the next dispatch (None when idle)."""
        return self._core if self.has_work else None

    def step(self) -> list:
        """One fused slot: tick, admit, retire."""
        self._start_clock()
        if self.service_cost_s and self._flight:
            time.sleep(self.service_cost_s)     # modeled compute per slot
        for f in self._flight:
            f[0] -= 1
        finished = [f for f in self._flight if f[0] <= 0]
        self._flight = [f for f in self._flight if f[0] > 0]
        n = self.policy.admit(queued=len(self._pending),
                              in_flight=len(self._flight),
                              capacity=self._capacity)
        for _ in range(max(0, min(n, len(self._pending),
                                  self._capacity - len(self._flight)))):
            popped = self._pop_admission()
            if popped is None:
                break
            req, _t = popped
            self._metrics[req.rid].started_at = time.perf_counter()
            self._flight.append([self._steps, req.rid, req.payload])
        out = self._take_shed()
        out.extend(self._finish(rid, payload)
                   for _, rid, payload in finished)
        return out


def parse_sim_spec(spec: str) -> list[tuple[str, str, int, bool]]:
    """Parse ``name:core:steps[:opaque]`` comma-list member specs."""
    out = []
    for tok in spec.split(","):
        parts = tok.strip().split(":")
        if len(parts) not in (3, 4) or (len(parts) == 4
                                        and parts[3] != "opaque"):
            raise ValueError(
                f"bad --sim member {tok!r}; want name:core:steps or "
                f"name:core:steps:opaque")
        name, core, steps = parts[0], parts[1], int(parts[2])
        if core not in ("c", "p"):
            raise ValueError(f"bad --sim core {core!r} in {tok!r}; "
                             f"'c' or 'p'")
        if steps < 1:
            raise ValueError(f"--sim steps must be >= 1 in {tok!r}")
        out.append((name, core, steps, len(parts) == 4))
    return out


def build_sim_fleet(spec: str, *, policy: str = "round_robin",
                    co_dispatch: int | None = None, burst: int = 1,
                    max_queue: int | None = None, shed: bool = False,
                    service_cost_s: float = 0.0):
    """Build a deterministic sim fleet from a ``--sim`` spec — the same
    function the in-process replay side calls, so worker and replay
    fleets are member-for-member identical.  ``service_cost_s`` adds a
    wall-clock sleep per occupied slot (modeled compute for throughput
    benches); it never changes scheduling decisions or records."""
    from repro.fleet.engine import FleetEngine
    from repro.fleet.router import make_policy

    members = {}
    for name, core, steps, opaque in parse_sim_spec(spec):
        cls = OpaqueSimEngine if opaque else SimEngine
        members[name] = cls(service_steps=steps, core=core,
                            max_queue=max_queue,
                            service_cost_s=service_cost_s)
    fleet = FleetEngine(members, policy=make_policy(policy),
                        co_dispatch=co_dispatch, burst=burst)
    if shed:
        for m in fleet.members:     # slot-clock SLO shedding at admission
            m.engine.policy = ShedPolicy(inner=m.engine.policy)
    return fleet


def build_cnn_worker_fleet(models: list[str], *, image_size: int,
                           use_pallas: bool, scheme: str,
                           policy: str, burst: int,
                           co_dispatch: int | None,
                           max_queue: int | None):
    """Build (and jit-warm) a real CNN fleet for this worker — the same
    construction ``serve fleet`` uses per pool."""
    import jax

    from repro.fleet.engine import build_cnn_fleet
    from repro.fleet.router import make_policy

    fleet, _pool = build_cnn_fleet(
        models, scheme=scheme, use_pallas=use_pallas,
        policy=make_policy(policy), burst=burst,
        co_dispatch=co_dispatch, max_queue=max_queue)
    img = jax.random.normal(jax.random.PRNGKey(0),
                            (1, image_size, image_size, 3),
                            dtype="float32")
    for m in fleet.members:         # pay every jit before READY
        m.engine.runner.run_sequential([img])
    return fleet


# --------------------------------------------------------------------------
# the serving loop
# --------------------------------------------------------------------------
class WorkerServer:
    """Serve one coordinator connection over one fleet."""

    def __init__(self, pool: str, fleet, chan: wire.Channel):
        self.pool = pool
        self.fleet = fleet
        self.chan = chan
        self.ex = fleet.executor
        self.ex.name = pool
        self.ex.transport = SocketTransport(chan)
        self.chan.obs = self.ex.obs      # worker-side net_* counters ship
        #                                  with the telemetry snapshot

    def _state(self) -> dict:
        f = self.fleet
        return {"queued": f.queued, "in_flight": f.in_flight,
                "has_work": f.has_work, "slot": f._slot,
                "dispatches": f._dispatches, "retries": self.ex.retries,
                "timeouts": self.ex.timeouts}

    def _error(self, etype: str, msg: str, **extra) -> None:
        self.chan.send({"kind": "error", "etype": etype, "msg": msg,
                        **extra})

    def serve(self) -> None:
        """Handshake, then answer RPCs until shutdown or disconnect."""
        env = self.chan.recv()
        if env["kind"] != "hello":
            self._error("WireError", f"expected hello, got "
                                     f"{env['kind']!r}")
            return
        if env["pool"] != self.pool:
            self._error("WireError", f"this worker is pool "
                                     f"{self.pool!r}, not "
                                     f"{env['pool']!r}")
            return
        self.chan.send({"kind": "hello_ack", "pool": self.pool,
                        "schema": SCHEMA_VERSION,
                        "members": [{"name": m.name, "weight": m.weight}
                                    for m in self.fleet.members],
                        "state": self._state()})
        while True:
            try:
                env = self.chan.recv()
            except wire.WireClosed:
                return              # coordinator went away: exit quietly
            kind = env["kind"]
            if kind == "shutdown":
                self.chan.send({"kind": "bye"})
                return
            if kind == "ping":
                self.chan.send({"kind": "pong", "state": self._state()})
            elif kind == "telemetry":
                # cumulative snapshot: the coordinator's absorb() replaces
                # the last one, so a kill loses at most this window
                self.chan.send({"kind": "telemetry_snap",
                                "snapshot": self.ex.obs.snapshot()})
            elif kind == "submit":
                self._submit(env)
            elif kind in ("step", "inject"):
                if not self._exec(env, step=(kind == "step")):
                    return          # the pool crashed: nothing to serve
            else:
                self._error("WireError",
                            f"unexpected envelope {kind!r}")

    def _submit(self, env: dict) -> None:
        try:
            ticket = self.fleet.submit(wire.decode_request(env["req"]))
        except QueueFull as e:
            self._error("QueueFull", str(e), state=self._state())
            return
        except KeyError as e:
            self._error("KeyError", str(e), state=self._state())
            return
        self.chan.send({"kind": "submit_ack", "rid": ticket.rid,
                        "records": [], "completions": [],
                        "state": self._state()})

    def _exec(self, env: dict, *, step: bool) -> bool:
        # the coordinator's seq watermark is the base every record this
        # RPC produces stamps from — the shared-counter contract that
        # keeps the collected streams replayable
        self.ex._seq.n = env["seq"]
        base = len(self.ex.records)
        seen = set(self.fleet._completions)
        try:
            if step:
                done = self.fleet.step()
            else:
                done = self.ex.inject(instr_from_dict(env["instr"]))
        except PoolCrash as e:
            # ship the fatal step's partial records and its unharvested
            # completions: the coordinator mirrors in-process crash
            # semantics (records stamped, completions harvestable)
            self._error(
                "PoolCrash", str(e),
                records=stream_to_json(self.ex.records[base:])["records"],
                completions=[wire.encode_completion(c)
                             for frid, c in self.fleet._completions.items()
                             if frid not in seen],
                state=self._state())
            return False
        except (KeyError, ValueError, TypeError, RuntimeError) as e:
            self._error(
                type(e).__name__, str(e),
                records=stream_to_json(self.ex.records[base:])["records"],
                state=self._state())
            return True
        self.chan.send({
            "kind": "step_done" if step else "inject_done",
            "records": stream_to_json(self.ex.records[base:])["records"],
            "completions": [wire.encode_completion(c) for c in done],
            "state": self._state()})
        return True


# --------------------------------------------------------------------------
# entrypoint
# --------------------------------------------------------------------------
def _listen(address: str) -> tuple[socket.socket, str]:
    """Bind a listening socket for ``tcp:HOST:PORT`` (port 0 picks an
    ephemeral port) or ``unix:PATH``; returns (socket, actual address)."""
    kind, _, rest = address.partition(":")
    if kind == "tcp":
        host, _, port = rest.rpartition(":")
        srv = socket.create_server((host, int(port)))
        got = srv.getsockname()
        return srv, f"tcp:{got[0]}:{got[1]}"
    if kind == "unix":
        srv = socket.socket(socket.AF_UNIX)
        srv.bind(rest)
        srv.listen(1)
        return srv, address
    raise ValueError(f"unknown --listen scheme in {address!r}; "
                     f"use tcp:HOST:PORT or unix:PATH")


def main(argv=None) -> int:
    """CLI: host one fleet pool behind a wire-protocol control channel."""
    p = argparse.ArgumentParser(
        prog="python -m repro.fleet.worker",
        description="Fleet worker process: hosts one pool and executes "
                    "the coordinator's instruction stream (DESIGN.md "
                    "§14).")
    p.add_argument("--pool", required=True,
                   help="this pool's name in the fleet topology")
    p.add_argument("--listen", default="tcp:127.0.0.1:0",
                   help="tcp:HOST:PORT (port 0 = ephemeral) or unix:PATH")
    kind = p.add_mutually_exclusive_group(required=True)
    kind.add_argument("--sim", metavar="SPEC",
                      help="simulation members, name:core:steps[:opaque] "
                           "comma-list (deterministic; for tests/benches)")
    kind.add_argument("--models", metavar="LIST",
                      help="comma-list of CNN members (mbv1,mbv2,sqz or "
                           "full names)")
    p.add_argument("--image-size", type=int, default=64)
    p.add_argument("--no-pallas", action="store_true",
                   help="reference conv path (CI-safe)")
    p.add_argument("--scheme", default="balanced")
    p.add_argument("--policy", default="round_robin")
    p.add_argument("--burst", type=int, default=1)
    p.add_argument("--co-dispatch", type=int, default=None)
    p.add_argument("--max-queue", type=int, default=None)
    p.add_argument("--shed", action="store_true",
                   help="wrap member admission in a slot-clock ShedPolicy "
                        "(sim fleets only)")
    p.add_argument("--sim-cost-us", type=int, default=0,
                   help="modeled compute: microseconds each sim member "
                        "sleeps per occupied slot (sim fleets only)")
    args = p.parse_args(argv)

    if args.sim:
        try:
            fleet = build_sim_fleet(args.sim, policy=args.policy,
                                    co_dispatch=args.co_dispatch,
                                    burst=args.burst,
                                    max_queue=args.max_queue,
                                    shed=args.shed,
                                    service_cost_s=args.sim_cost_us / 1e6)
        except ValueError as e:
            print(str(e), file=sys.stderr)
            return 2
    else:
        if args.shed or args.sim_cost_us:
            print("--shed/--sim-cost-us apply to --sim fleets only",
                  file=sys.stderr)
            return 2
        from repro.launch.serve import MODEL_ALIASES
        try:
            models = [MODEL_ALIASES[t.strip()]
                      for t in args.models.split(",")]
        except KeyError as e:
            print(f"unknown model {e.args[0]!r}; one of "
                  f"{sorted(MODEL_ALIASES)}", file=sys.stderr)
            return 2
        fleet = build_cnn_worker_fleet(
            models, image_size=args.image_size,
            use_pallas=not args.no_pallas, scheme=args.scheme,
            policy=args.policy, burst=args.burst,
            co_dispatch=args.co_dispatch, max_queue=args.max_queue)

    srv, address = _listen(args.listen)
    print(READY_PREFIX + json.dumps({"pool": args.pool,
                                     "address": address}), flush=True)
    conn, _peer = srv.accept()
    srv.close()
    WorkerServer(args.pool, fleet,
                 wire.Channel(conn)).serve()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
