"""Static co-scheduling planner for a multi-network traffic mix.

The paper's Table VII picks ONE PE configuration that serves a workload of
several networks well (its multi-CNN column beats the best single-CNN
config by ~2% on average throughput).  :func:`plan_fleet` reproduces that
flow for an arbitrary ``{model: qps share}`` mix by reusing the §V-B
design-space search (``core.search.search``) with the weighted-harmonic
objective: if model *m* is an ``s_m`` share of the request stream and runs
at ``fps_m`` when its groups occupy the cores, the steady-state aggregate
of time-multiplexing the networks is

    aggregate_fps = 1 / sum_m (s_m / fps_m)        (weighted harmonic mean)

— each unit of mixed work spends ``s_m / fps_m`` seconds in model *m*.
The unweighted case is exactly the paper's Table VII objective.  The
search picks theta (Eq.10) and the (n, v) PE shapes once for the whole
mix; per-model group merging falls out of ``best_schedule`` under that
shared config, and the resulting per-model ``Schedule``s are what
``fleet.engine.build_cnn_fleet`` executes.

:func:`plan_rows` renders the plan as the Table-VII-style
predicted-vs-measured rows that ``benchmarks/paper_tables.py`` prints and
``tests/test_fleet.py`` cross-checks against a live plan.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from repro.core.arch import BoardModel, DualCoreConfig, ResourceBudget
from repro.core.search import evaluate_config, harmonic_mean, search


@dataclasses.dataclass
class FleetPlan:
    """Output of the co-scheduling search for one traffic mix."""

    mix: dict[str, float]            # normalized qps shares, sum == 1
    config: DualCoreConfig           # shared PE configuration
    theta: float                     # its Eq.10 DSP split
    schedules: dict[str, object]     # per-model Schedule under config
    fps: dict[str, float]            # per-model fps while its groups run
    aggregate_fps: float             # weighted-harmonic aggregate
    predicted: dict[str, float]      # per-model *served* fps under the mix

    def summary(self) -> dict:
        # key is predicted_aggregate_fps, NOT aggregate_fps: the summary
        # lands in BENCH_fleet.json, where compare_bench gates the
        # aggregate_fps leaf — this is a deterministic cycle-domain
        # prediction, not a measurement, and must not be gated as one
        """JSON-ready summary of the planned config (lands in
        BENCH_fleet.json)."""
        return {"mix": {m: round(s, 4) for m, s in self.mix.items()},
                "config": str(self.config),
                "theta": round(self.theta, 4),
                "model_fps": {m: round(f, 2) for m, f in self.fps.items()},
                "predicted_fps": {m: round(f, 2)
                                  for m, f in self.predicted.items()},
                "predicted_aggregate_fps": round(self.aggregate_fps, 2)}


def normalize_mix(mix: Mapping[str, float]) -> dict[str, float]:
    """Normalize qps shares to sum 1; all shares must be positive (a model
    with zero traffic does not belong in the mix)."""
    if not mix:
        raise ValueError("empty traffic mix")
    if any(s <= 0 for s in mix.values()):
        raise ValueError(f"mix shares must be > 0 (got {dict(mix)}); drop "
                         f"zero-traffic models from the mix instead")
    total = float(sum(mix.values()))
    return {m: s / total for m, s in mix.items()}


def mix_schedule(mix: Mapping[str, float], n: int) -> list[str]:
    """Deterministic model-tag sequence of length ``n`` realizing the mix:
    at every position the model with the largest deficit (entitled count
    so far minus issued count) goes next — the same largest-deficit rule
    the weighted-fair step scheduler uses, so a replayed trace exercises
    the mix evenly instead of in model-sized bursts."""
    shares = normalize_mix(mix)
    counts = dict.fromkeys(shares, 0)
    out = []
    for i in range(n):
        m = max(shares, key=lambda k: (shares[k] * (i + 1) - counts[k],
                                       shares[k]))
        counts[m] += 1
        out.append(m)
    return out


def plan_fleet(mix: Mapping[str, float], *,
               board: BoardModel | None = None,
               budget: ResourceBudget | None = None,
               config: DualCoreConfig | None = None,
               max_evals: int = 8,
               with_load_balance: bool = True) -> FleetPlan:
    """Co-schedule the mix: pick (or evaluate) a shared PE config and the
    per-model schedules that maximize aggregate fps under the mix.

    With ``config`` given, skip the theta/(n,v) search and just schedule
    every model under it (the cheap path tests and the Table-VII
    cross-check use); otherwise run the §V-B branch-and-bound with the
    mix-weighted objective.
    """
    from repro.models.zoo import get_graph

    board = board or BoardModel()
    shares = normalize_mix(mix)
    models = list(shares)
    graphs = [get_graph(m) for m in models]
    weights = [shares[m] for m in models]
    if config is None:
        res = search(graphs, board, budget, max_evals=max_evals,
                     with_load_balance=with_load_balance, weights=weights)
        config, fps, schedules = res.config, res.fps, res.schedules
        aggregate = res.objective
    else:
        aggregate, fps, schedules = evaluate_config(
            config, graphs, board, with_load_balance, weights)
    predicted = {m: shares[m] * aggregate for m in models}
    return FleetPlan(mix=shares, config=config,
                     theta=config.theta(
                         (budget or ResourceBudget()).n_dsp),
                     schedules=schedules, fps=fps,
                     aggregate_fps=aggregate, predicted=predicted)


def plan_rows(plan: FleetPlan,
              measured: Mapping[str, float] | None = None,
              measured_aggregate: float | None = None
              ) -> list[tuple[str, float, float, float, float | None]]:
    """Table-VII-style rows: (model, share, model fps, predicted served
    fps, measured served fps) plus a final ``("aggregate", ...)`` row.
    ``measured`` maps model -> served fps from ``BENCH_fleet.json``
    (``None`` entries where the bench has not run)."""
    rows: list[tuple[str, float, float, float, float | None]] = []
    for m in plan.mix:
        rows.append((m, plan.mix[m], plan.fps[m], plan.predicted[m],
                     (measured or {}).get(m)))
    rows.append(("aggregate", 1.0,
                 harmonic_mean([plan.fps[m] for m in plan.mix],
                               [plan.mix[m] for m in plan.mix]),
                 plan.aggregate_fps, measured_aggregate))
    return rows
