"""Shared device pool for fleet serving (DESIGN.md §10).

One physical device pool backs every member of a fleet: the pool performs
the theta split into the c/p submeshes ONCE (``dualmesh.partition.
split_mesh`` — the Eq.10 DSP ratio, exactly as a single ``DualCoreRunner``
would) and *leases* that split to each member engine.  Members therefore
place their c-groups on the same c-submesh and their p-groups on the same
p-submesh, which is what lets a conv-heavy exec group of one network
overlap a dw-heavy group of another: the two dispatches land on disjoint
device queues, the multi-network generalization of the Fig.4b two-image
offset.

Leases are named and exclusive per name — double-leasing the same member
name is a wiring bug (two engines would account the same traffic), and
releasing frees the name for a replacement engine.  The pool never copies
or repartitions devices per member; it is bookkeeping over one split.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.dualmesh.partition import DualMesh, split_mesh


@dataclasses.dataclass(frozen=True)
class Lease:
    """One member's hold on the pool's submeshes."""

    name: str
    dual: DualMesh


class DevicePool:
    """Owns the device list and the single c/p split every member shares.

    theta is the c-share of the pool (Eq.10); with fewer than two devices
    the split is degenerate (both submeshes alias one device) but the fleet
    stays functional — same behavior as a standalone runner.
    """

    def __init__(self, devices=None, *, theta: float = 0.5):
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.theta = theta
        self.dual: DualMesh = split_mesh(self.devices, theta)
        self._leases: dict[str, Lease] = {}

    # ------------------------------------------------------------------
    @property
    def c_chips(self) -> int:
        """Devices currently leased to the c-submesh."""
        return self.dual.c_chips

    @property
    def p_chips(self) -> int:
        """Devices currently leased to the p-submesh."""
        return self.dual.p_chips

    @property
    def degenerate(self) -> bool:
        """True when both submeshes alias the same devices (single-device
        host): dispatches still serialize on one queue."""
        return self.dual.c_mesh is self.dual.p_mesh

    @property
    def leases(self) -> list[str]:
        """Names currently holding a lease on the shared split."""
        return list(self._leases)

    # ------------------------------------------------------------------
    def lease(self, name: str) -> DualMesh:
        """Lease the shared c/p split to member ``name`` (exclusive)."""
        if name in self._leases:
            raise ValueError(f"pool lease {name!r} already held; release "
                             f"it before re-leasing (one engine per name)")
        self._leases[name] = Lease(name=name, dual=self.dual)
        return self.dual

    def release(self, name: str) -> None:
        """Release ``name``'s lease; unknown names raise KeyError."""
        if name not in self._leases:
            raise KeyError(f"no lease named {name!r} "
                           f"(held: {sorted(self._leases)})")
        del self._leases[name]

    def revoke_all(self) -> list[str]:
        """Forcibly drop every lease (the pool-side half of a REBALANCE:
        the old split is about to stop existing, so no holder may keep
        dispatching onto it).  Returns the revoked names so the caller
        can re-lease and relocate each holder onto the new split."""
        revoked = sorted(self._leases)
        self._leases.clear()
        return revoked

    def resplit(self, theta: float) -> DualMesh:
        """Re-split the pool's c/p submeshes at a new ``theta`` (Eq.10).
        Refuses while leases are held — ``revoke_all`` first: engines
        holding the old ``DualMesh`` must relocate, not silently keep
        dispatching onto a split the pool no longer owns."""
        if self._leases:
            raise RuntimeError(f"resplit with leases held "
                               f"({sorted(self._leases)}); revoke_all() "
                               f"first and relocate the holders")
        self.theta = theta
        self.dual = split_mesh(self.devices, theta)
        return self.dual

    def stats(self) -> dict:
        """Pool summary: device count, theta, split sizes, lease holders."""
        return {"devices": len(self.devices),
                "theta": self.dual.theta,
                "c_chips": self.c_chips,
                "p_chips": self.p_chips,
                "degenerate": self.degenerate,
                "leases": sorted(self._leases)}
