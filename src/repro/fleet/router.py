"""Request routing and cross-engine step scheduling for the fleet.

Two decisions live here, both pluggable and both *outside* the member
engines (which stay single-network and unchanged):

  * **Routing** — which member serves a request.  Requests carry a
    ``model`` tag (``serving.api.Request.model``); the :class:`Router`
    maps tags to members and rejects unknown tags loudly.  A single-member
    fleet accepts untagged requests (there is only one place to go).

  * **Step scheduling** — which member's exec group the fleet dispatches
    next.  Every ``FleetEngine.step`` asks the :class:`SchedulingPolicy`
    to pick ONE primary member from the members that currently have work;
    the engine may then co-dispatch a second, core-complementary member
    (that part uses the latency model, see ``fleet.engine``).  Policies
    see a :class:`MemberView` per member — queue depth, in-flight count,
    traffic weight, dispatch deficit, earliest pending deadline, and the
    predicted dominant core — and nothing else, so they compose with any
    engine implementing the serving protocol — including a §14
    ``RemoteFleet``, whose view state is mirrored from the worker's
    ``step_done``/``pong`` envelopes rather than read in-process.

Policies:

  round_robin     cycle through members with work (stateless fairness)
  shortest_queue  least outstanding work first — keeps lightly-loaded
                  models' latency low (SJF flavor across networks)
  weighted_fair   largest dispatch deficit vs the traffic mix first
                  (weight w_m entitles a member to a w_m share of fleet
                  steps; deficit = entitlement - dispatches received)
  deadline_edf    earliest pending deadline first (requests without a
                  deadline sort last); FIFO tie-break by member order
"""
from __future__ import annotations

import dataclasses
from typing import Protocol, Sequence, runtime_checkable

from repro.serving.api import Request

POLICY_NAMES = ("round_robin", "shortest_queue", "weighted_fair",
                "deadline_edf")


@dataclasses.dataclass
class MemberView:
    """What a scheduling policy may observe about one member."""

    index: int                      # position in the fleet's member order
    name: str                       # model tag the member serves
    queued: int
    in_flight: int
    weight: float                   # traffic-mix share (normalized)
    dispatches: int                 # fleet steps this member has received
    head_deadline: float | None     # earliest deadline among queued reqs
    next_core: str | None           # 'c' | 'p' dominant core next step
    has_work: bool
    batched: bool = True            # has the advance/retire split (a RUN
    #                                 can defer its FREE); False = opaque,
    #                                 step() fuses dispatch and block

    @property
    def outstanding(self) -> int:
        """Queued plus in-flight work owned by this member."""
        return self.queued + self.in_flight


@runtime_checkable
class SchedulingPolicy(Protocol):
    """Picks which member the fleet steps next."""

    def pick(self, views: Sequence[MemberView],
             total_dispatches: int) -> int:
        """Return the ``index`` of the member to step.  ``views`` contains
        only members with work (never empty); ``total_dispatches`` is the
        fleet-wide step count so far (for deficit bookkeeping)."""
        ...


@dataclasses.dataclass
class RoundRobin:
    """Cycle through members with work, resuming after the last pick."""

    _last: int = -1

    def pick(self, views: Sequence[MemberView],
             total_dispatches: int) -> int:
        """Pick the first member with work after the last pick."""
        after = [v for v in views if v.index > self._last]
        v = (after or views)[0]
        self._last = v.index
        return v.index


@dataclasses.dataclass
class ShortestQueue:
    """Least outstanding (queued + in-flight) work first."""

    def pick(self, views: Sequence[MemberView],
             total_dispatches: int) -> int:
        """Pick the member with the least outstanding work."""
        return min(views, key=lambda v: (v.outstanding, v.index)).index


@dataclasses.dataclass
class WeightedFair:
    """Largest deficit vs the traffic mix: member m is entitled to
    ``w_m / sum(w)`` of all fleet steps; the member furthest below its
    entitlement goes next.  With equal weights this degrades to
    round-robin-like fairness; with a skewed mix, dispatch counts track
    the mix (a test drives this under skewed Poisson arrivals)."""

    def pick(self, views: Sequence[MemberView],
             total_dispatches: int) -> int:
        """Pick the member furthest below its weighted entitlement."""
        wsum = sum(v.weight for v in views)

        def deficit(v: MemberView) -> float:
            # all-zero weights degrade to equal shares, not index order
            share = v.weight / wsum if wsum > 0 else 1.0 / len(views)
            return share * (total_dispatches + 1) - v.dispatches

        return max(views, key=lambda v: (deficit(v), -v.index)).index


@dataclasses.dataclass
class DeadlineEDF:
    """Earliest pending deadline across members first; members whose head
    request has no deadline sort last (then FIFO by member order).  Pair
    with a per-member ``DeadlineAdmission`` so the member also admits its
    own queue in EDF order — fleet-level EDF picks the member, member-level
    EDF picks the request."""

    # tells the fleet to pay the per-slot pending-queue deadline scan;
    # policies without this flag get head_deadline=None for free
    uses_deadlines = True

    def pick(self, views: Sequence[MemberView],
             total_dispatches: int) -> int:
        """Pick the member whose head request expires first."""
        return min(views,
                   key=lambda v: (v.head_deadline is None,
                                  v.head_deadline
                                  if v.head_deadline is not None else 0.0,
                                  v.index)).index


def make_policy(name: str) -> SchedulingPolicy:
    """Policy registry for the CLI / bench (``POLICY_NAMES``)."""
    try:
        return {"round_robin": RoundRobin,
                "shortest_queue": ShortestQueue,
                "weighted_fair": WeightedFair,
                "deadline_edf": DeadlineEDF}[name]()
    except KeyError:
        raise ValueError(f"unknown scheduling policy {name!r}; "
                         f"one of {POLICY_NAMES}") from None


# --------------------------------------------------------------------------
# routing
# --------------------------------------------------------------------------
class Router:
    """Model-tag -> member routing table.

    The router also tallies arrivals per member (:attr:`routed`, counted
    at route time, before any admission decision) — the fleet's
    arrival-side view of the traffic mix, which the §13 control loop
    diffs between observations to estimate the live qps mix.  Queue
    depth alone cannot distinguish "more arrivals" from "slower
    service"; the arrival tally can.
    """

    def __init__(self, names: Sequence[str]):
        """Build the table over member ``names`` (model tags)."""
        if not names:
            raise ValueError("a fleet needs at least one member")
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate member names: {list(names)}")
        self.names = list(names)
        self.routed: dict[str, int] = {n: 0 for n in self.names}

    def route(self, request: Request) -> str:
        """Member name serving this request's model tag.  Untagged
        requests are only routable in a single-member fleet."""
        if request.model is None:
            if len(self.names) == 1:
                self.routed[self.names[0]] += 1
                return self.names[0]
            raise KeyError(f"untagged request in a {len(self.names)}-member "
                           f"fleet; set Request.model to one of "
                           f"{self.names}")
        if request.model not in self.names:
            raise KeyError(f"no member serves model {request.model!r} "
                           f"(members: {self.names})")
        self.routed[request.model] += 1
        return request.model
