"""Chrome-tracing export of executed fleet instruction streams.

Converts :class:`~repro.fleet.instructions.ExecRecord` streams into the
Chrome trace-event JSON format (the ``chrome://tracing`` / Perfetto
timeline — same target format as the Helium repo's tarmac converter):
one *process* row per pool, one *thread* track per submesh within it
('c-submesh', 'p-submesh'), a 'retire' track for FREEs, a 'control'
track for SEND/RECV/REBALANCE/SET_PARAM, and a 'bubbles' track marking
every submesh idle gap of >= 1 slot inside the pool's active window —
labeled with what the idle submesh could have run next, so a pipeline
bubble is a named event, not something to squint for.

With a ``roofline`` model (``{pool: {member: roofline_fps}}``, see
:func:`roofline_model`) every RUN slice additionally carries
``achieved_fps`` (advances over the slice's wall window),
``roofline_fps`` (the member's latency-model advance-rate ceiling), and
``roofline_util`` — their ratio clamped to 1.05, since wall clocks on a
host are not the board clock the model prices; the raw ratio is always
recoverable from the other two args.

Only executed records carry wall-clock stamps; compiled-only records
(``t0 is None``) are skipped and *counted* — the skip count comes back
from :func:`write_chrome_trace` so callers can report rather than
silently thin the timeline.  Timestamps are re-based to the earliest
``t0`` across every stream so the trace starts at 0.
"""
from __future__ import annotations

import json
from typing import Mapping, Sequence

from repro.fleet.instructions import (ExecRecord, Free, Rebalance, Recv,
                                      Run, Send, SetParam)

# track (tid) layout within each pool's process row; lower sorts first
_TRACKS = ("c-submesh", "p-submesh", "retire", "control", "bubbles")

#: clamp for the RUN-slice roofline utilization arg (host wall clocks
#: are not the board clock; see module docstring)
_UTIL_CLAMP = 1.05


def _track(instr) -> str:
    if isinstance(instr, Run):
        return {"c": "c-submesh", "p": "p-submesh"}.get(instr.core,
                                                        "control")
    if isinstance(instr, Free):
        return "retire"
    return "control"


def _label(instr, advances: int) -> str:
    if isinstance(instr, Run):
        tag = " primary" if instr.primary else ""
        fused = " fused" if instr.fused else ""
        return f"RUN {instr.member} x{advances}{tag}{fused}"
    if isinstance(instr, Free):
        return f"FREE {instr.member}"
    if isinstance(instr, Send):
        whom = instr.member or "*"
        return f"SEND {whom} -> {instr.peer} x{advances}"
    if isinstance(instr, Recv):
        return f"RECV <- {instr.peer} x{advances}"
    if isinstance(instr, Rebalance):
        return f"REBALANCE theta={instr.theta:.2f}"
    if isinstance(instr, SetParam):
        return f"SET {instr.member}.{instr.param}={instr.value}"
    return type(instr).__name__


def roofline_model(obj) -> dict[str, dict[str, float]]:
    """``{pool: {member: roofline_fps}}`` from live engines.

    Accepts a ``MultiPoolRouter`` (walks ``.executors``, taking each
    pool executor's local fleet), one ``FleetEngine`` (one pool), or an
    already-shaped mapping (passed through).  A member's ceiling is the
    latency model's advance rate: one slot advances a stream one exec
    group, and a group costs at least ``min(group_latencies)`` cycles,
    so ``roofline_fps = freq_mhz * 1e6 / min(group_latencies)``.
    Members without a pipeline latency model (service stubs, opaque
    engines, remote executors whose members live in another process)
    are skipped — their RUN slices carry no roofline args.
    """
    executors = getattr(obj, "executors", None)
    if executors is not None:                       # MultiPoolRouter
        fleets = {name: ex.fleet for name, ex in executors.items()
                  if getattr(ex, "fleet", None) is not None}
    elif isinstance(obj, Mapping):
        return dict(obj)
    else:                                           # one FleetEngine
        fleets = {getattr(obj.executor, "name", "pool0"): obj}
    out: dict[str, dict[str, float]] = {}
    for pool, fleet in fleets.items():
        per: dict[str, float] = {}
        for m in getattr(fleet, "members", ()):
            runner = getattr(m.engine, "runner", None)
            if runner is None or not hasattr(runner, "plan"):
                continue
            sched = runner.plan.exec_schedule
            lats = list(sched.group_latencies)
            if not lats or min(lats) <= 0:
                continue
            per[m.name] = sched.board.freq_mhz * 1e6 / min(lats)
        if per:
            out[pool] = per
    return out


def _bubbles(records: Sequence[ExecRecord]) -> list[dict]:
    """Idle-gap descriptors for one pool: for each core, every maximal
    run of >= 1 slot inside the pool's active slot range where that
    submesh ran nothing, stamped onto the per-slot wall windows."""
    slots = [r.slot for r in records]
    if not slots:
        return []
    lo, hi = min(slots), max(slots)
    # per-slot wall window across the whole pool (min t0, max t1)
    win: dict[int, list[float]] = {}
    for r in records:
        if r.t0 is None or r.t1 is None:
            continue
        w = win.setdefault(r.slot, [r.t0, r.t1])
        w[0] = min(w[0], r.t0)
        w[1] = max(w[1], r.t1)
    if not win:
        return []       # compiled-only: no wall clock to draw gaps on
    out: list[dict] = []
    for core in ("c", "p"):
        busy = {r.slot for r in records
                if isinstance(r.instr, Run) and r.instr.core == core}
        runs = sorted((r.slot, r.instr.member) for r in records
                      if isinstance(r.instr, Run) and r.instr.core == core)
        gap_start = None
        for slot in range(lo, hi + 2):          # hi+1 flushes a tail gap
            idle = slot <= hi and slot not in busy
            if idle and gap_start is None:
                gap_start = slot
            elif not idle and gap_start is not None:
                g0, g1 = gap_start, slot - 1
                gap_start = None
                nxt = next((m for s, m in runs if s > g1), None)
                could = (nxt if nxt is not None
                         else f"no {core}-core work")
                t0s = [win[s][0] for s in range(g0, g1 + 1) if s in win]
                t1s = [win[s][1] for s in range(g0, g1 + 1) if s in win]
                if t0s:
                    ts, te = min(t0s), max(t1s)
                else:       # a fully recordless gap: pin to neighbors
                    prev = [win[s][1] for s in win if s < g0]
                    after = [win[s][0] for s in win if s > g1]
                    ts = max(prev) if prev else 0.0
                    te = min(after) if after else ts
                out.append({"core": core, "slots": [g0, g1],
                            "could_have_run": could, "t0": ts, "t1": te})
    return out


def chrome_trace(streams: Mapping[str, Sequence[ExecRecord]], *,
                 roofline: Mapping[str, Mapping[str, float]] | None = None
                 ) -> dict:
    """``{pool name: records}`` -> a Chrome trace-event document.

    Every executed record becomes one complete ('X') event: ``ts``/``dur``
    in microseconds from the records' wall-clock window, filed under its
    pool's process and its submesh's thread, with slot / seq / advances
    in ``args`` for the details pane.  ``roofline`` adds per-RUN
    utilization args and is keyed like :func:`roofline_model`'s result.
    """
    stamped = [r for recs in streams.values() for r in recs
               if r.t0 is not None and r.t1 is not None]
    base = min((r.t0 for r in stamped), default=0.0)
    events: list[dict] = []
    for pid, (pool, records) in enumerate(sorted(streams.items())):
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name",
                       "args": {"name": pool}})
        for tid, track in enumerate(_TRACKS):
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": track}})
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_sort_index",
                           "args": {"sort_index": tid}})
        pool_roof = (roofline or {}).get(pool, {})
        for r in records:
            if r.t0 is None or r.t1 is None:
                continue
            args = {"slot": r.slot, "seq": r.seq,
                    "advances": r.advances}
            if isinstance(r.instr, Run) and r.advances > 0 \
                    and r.t1 > r.t0:
                roof = pool_roof.get(r.instr.member)
                if roof:
                    achieved = r.advances / (r.t1 - r.t0)
                    args["achieved_fps"] = round(achieved, 3)
                    args["roofline_fps"] = round(roof, 3)
                    args["roofline_util"] = round(
                        min(achieved / roof, _UTIL_CLAMP), 6)
            events.append({
                "ph": "X",
                "pid": pid,
                "tid": _TRACKS.index(_track(r.instr)),
                "name": _label(r.instr, r.advances),
                "cat": r.instr.op,
                "ts": (r.t0 - base) * 1e6,
                # sub-resolution slices still need nonzero width to render
                "dur": max((r.t1 - r.t0) * 1e6, 0.05),
                "args": args,
            })
        for b in _bubbles(records):
            events.append({
                "ph": "X",
                "pid": pid,
                "tid": _TRACKS.index("bubbles"),
                "name": (f"bubble {b['core']}-submesh "
                         f"x{b['slots'][1] - b['slots'][0] + 1}"),
                "cat": "bubble",
                "ts": (b["t0"] - base) * 1e6,
                "dur": max((b["t1"] - b["t0"]) * 1e6, 0.05),
                "args": {"core": b["core"], "slots": b["slots"],
                         "could_have_run": b["could_have_run"]},
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(streams: Mapping[str, Sequence[ExecRecord]],
                       path: str, *,
                       roofline: Mapping[str, Mapping[str, float]] |
                       None = None) -> tuple[int, int]:
    """Write :func:`chrome_trace` to ``path``; returns ``(events,
    skipped)`` — the event count and how many compiled-only (unstamped)
    records the export had to leave out."""
    doc = chrome_trace(streams, roofline=roofline)
    skipped = sum(1 for recs in streams.values() for r in recs
                  if r.t0 is None or r.t1 is None)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"]), skipped
