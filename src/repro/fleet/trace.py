"""Chrome-tracing export of executed fleet instruction streams.

Converts :class:`~repro.fleet.instructions.ExecRecord` streams into the
Chrome trace-event JSON format (the ``chrome://tracing`` / Perfetto
timeline — same target format as the Helium repo's tarmac converter):
one *process* row per pool, one *thread* track per submesh within it
('c-submesh', 'p-submesh'), plus a 'retire' track for FREEs and a
'control' track for SEND/RECV/REBALANCE/SET_PARAM — so pipeline bubbles (a submesh
track with a gap while the other is busy) are visible at a glance.

Only executed records carry wall-clock stamps; compiled-only records
(``t0 is None``) are skipped.  Timestamps are re-based to the earliest
``t0`` across every stream so the trace starts at 0.
"""
from __future__ import annotations

import json
from typing import Mapping, Sequence

from repro.fleet.instructions import (ExecRecord, Free, Rebalance, Recv,
                                      Run, Send, SetParam)

# track (tid) layout within each pool's process row; lower sorts first
_TRACKS = ("c-submesh", "p-submesh", "retire", "control")


def _track(instr) -> str:
    if isinstance(instr, Run):
        return {"c": "c-submesh", "p": "p-submesh"}.get(instr.core,
                                                        "control")
    if isinstance(instr, Free):
        return "retire"
    return "control"


def _label(instr, advances: int) -> str:
    if isinstance(instr, Run):
        tag = " primary" if instr.primary else ""
        fused = " fused" if instr.fused else ""
        return f"RUN {instr.member} x{advances}{tag}{fused}"
    if isinstance(instr, Free):
        return f"FREE {instr.member}"
    if isinstance(instr, Send):
        whom = instr.member or "*"
        return f"SEND {whom} -> {instr.peer} x{advances}"
    if isinstance(instr, Recv):
        return f"RECV <- {instr.peer} x{advances}"
    if isinstance(instr, Rebalance):
        return f"REBALANCE theta={instr.theta:.2f}"
    if isinstance(instr, SetParam):
        return f"SET {instr.member}.{instr.param}={instr.value}"
    return type(instr).__name__


def chrome_trace(streams: Mapping[str, Sequence[ExecRecord]]) -> dict:
    """``{pool name: records}`` -> a Chrome trace-event document.

    Every executed record becomes one complete ('X') event: ``ts``/``dur``
    in microseconds from the records' wall-clock window, filed under its
    pool's process and its submesh's thread, with slot / seq / advances
    in ``args`` for the details pane.
    """
    stamped = [r for recs in streams.values() for r in recs
               if r.t0 is not None and r.t1 is not None]
    base = min((r.t0 for r in stamped), default=0.0)
    events: list[dict] = []
    for pid, (pool, records) in enumerate(sorted(streams.items())):
        events.append({"ph": "M", "pid": pid, "tid": 0,
                       "name": "process_name",
                       "args": {"name": pool}})
        for tid, track in enumerate(_TRACKS):
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_name",
                           "args": {"name": track}})
            events.append({"ph": "M", "pid": pid, "tid": tid,
                           "name": "thread_sort_index",
                           "args": {"sort_index": tid}})
        for r in records:
            if r.t0 is None or r.t1 is None:
                continue
            events.append({
                "ph": "X",
                "pid": pid,
                "tid": _TRACKS.index(_track(r.instr)),
                "name": _label(r.instr, r.advances),
                "cat": r.instr.op,
                "ts": (r.t0 - base) * 1e6,
                # sub-resolution slices still need nonzero width to render
                "dur": max((r.t1 - r.t0) * 1e6, 0.05),
                "args": {"slot": r.slot, "seq": r.seq,
                         "advances": r.advances},
            })
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(streams: Mapping[str, Sequence[ExecRecord]],
                       path: str) -> int:
    """Write :func:`chrome_trace` to ``path``; returns the event count."""
    doc = chrome_trace(streams)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])
