"""``python -m repro.fleet.worker`` — the distributed-fleet worker
entrypoint (implementation: :mod:`repro.fleet.net.worker`)."""
from repro.fleet.net.worker import main

if __name__ == "__main__":
    raise SystemExit(main())
