"""Pallas TPU kernels for the perf-critical hot-spots.

Each kernel ships three files (repo convention):
  kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target;
             validated with interpret=True on this CPU container)
  ops.py     jit'd wrapper / dispatch
  ref.py     pure-jnp oracle used by the allclose test sweeps

Kernels:
  conv_gemm   c-core analogue — im2col GEMM, MXU 128x128 tiles, fused
              bias+ReLU6 epilogue
  depthwise   p-core analogue — VMEM halo tile (the line-buffer port)
  attention   flash attention (train/prefill) + split-K decode; int8-KV
              variants live in repro.lm.modules
  rmsnorm     fused norm used by every assigned arch
"""
