"""Pallas TPU kernels for the perf-critical hot-spots.

Each kernel ships three files (repo convention):
  kernel.py  pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target;
             validated in interpret mode on this CPU container — the
             ``interpret=None`` default auto-detects the backend)
  ops.py     dispatch wrapper (consults the block-shape autotune cache)
  ref.py     pure-jnp oracle used by the allclose test sweeps

Kernels (see DESIGN.md for the dual-OPU mapping):
  conv_gemm    c-core analogue — implicit-GEMM conv (patch tiles gathered
               in VMEM, no HBM im2col matrix) + tiled GEMM 1x1/fc fast
               path, fused bias+ReLU6 epilogue
  depthwise    p-core analogue — VMEM halo tile (the line-buffer port)
  fused_block  dw->pw and pw-expand->dw->pw-project in ONE pallas_call;
               the intermediate feature maps never leave VMEM
  attention    flash attention (train/prefill) + split-K decode; int8-KV
               variants live in repro.lm.modules
  rmsnorm      fused norm used by every assigned arch

Shared helpers: kernels/util.py (padding, grid cdiv, interpret default);
kernels/autotune.py (JSON-cached per-layer-signature block shapes).
"""
