"""FlashAttention Pallas kernel (train/prefill) + split-K decode variant.

This is the LM-side compute hot-spot.  The dual-OPU mapping (DESIGN.md §2):
prefill attention is compute-bound (c-class — MXU GEMMs over q/k blocks),
decode attention is memory-bound (p-class — streams the KV cache once,
exactly the line-buffer discipline: bring KV blocks to VMEM once, reuse for
all query heads of the group).

Layout: q (B, Hq, Sq, D), k/v (B, Hkv, Sk, D), GQA folds Hq = Hkv * G by
reindexing heads in the BlockSpec index maps (no KV duplication in HBM).

Grid (prefill): (B * Hq, Sq/bq, Sk/bk) with online-softmax running state
(m, l, acc) in VMEM scratch, carried across the contiguous k-grid dimension.
Causal masking is applied per tile; fully-masked tiles are cheap (the mask
zeroes p and alpha stays 1).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import resolve_interpret

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  nk: int, bq: int, bk: int, causal: bool, scale: float,
                  sk_valid: int):
    kv_idx = pl.program_id(2)

    @pl.when(kv_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                      # (bq, d)
    k = k_ref[0]                      # (bk, d)
    v = v_ref[0]                      # (bk, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    q_pos = pl.program_id(1) * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0)
    k_pos = kv_idx * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = k_pos < sk_valid           # padding mask
    if causal:
        mask = mask & (k_pos <= q_pos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]               # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kv_idx == nk - 1)
    def _finalize():
        l = l_ref[...]
        o_ref[0] = (acc_ref[...] / jnp.where(l == 0.0, 1.0, l)).astype(
            o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool | None = None) -> jax.Array:
    """q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D); Hq % Hkv == 0 (GQA).

    Returns (B, Hq, Sq, D).  KV is never materialised per-q-head: the
    BlockSpec index map folds the GQA group by integer division.
    """
    interpret = resolve_interpret(interpret)
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    assert hq % hkv == 0, (hq, hkv)
    g = hq // hkv
    scale = 1.0 / math.sqrt(d)
    bq = min(block_q, max(sq, 8))
    bk = min(block_k, max(sk, 8))
    sqp, skp = -sq % bq, -sk % bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sqp), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, skp), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, skp), (0, 0)))
    # fold batch & heads
    qf = qp.reshape(b * hq, sq + sqp, d)
    kf = kp.reshape(b * hkv, sk + skp, d)
    vf = vp.reshape(b * hkv, sk + skp, d)
    nq = (sq + sqp) // bq
    nk = (sk + skp) // bk
    grid = (b * hq, nq, nk)

    def q_map(h, i, j):
        return (h, i, 0)

    def kv_map(h, i, j):
        # fold GQA: query head h belongs to kv head (h % hq) // g of batch
        # h // hq
        return ((h // hq) * hkv + (h % hq) // g, j, 0)

    out = pl.pallas_call(
        functools.partial(_flash_kernel, nk=nk, bq=bq, bk=bk, causal=causal,
                          scale=scale, sk_valid=sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, d), q_map),
            pl.BlockSpec((1, bk, d), kv_map),
            pl.BlockSpec((1, bk, d), kv_map),
        ],
        out_specs=pl.BlockSpec((1, bq, d), q_map),
        out_shape=jax.ShapeDtypeStruct((b * hq, sq + sqp, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, hq, sq + sqp, d)[:, :, :sq]


@functools.partial(jax.jit, static_argnames=("block_k", "interpret"))
def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     kv_len: jax.Array | None = None, *,
                     block_k: int = 512,
                     interpret: bool | None = None) -> jax.Array:
    """Single-token decode: q (B, Hq, 1, D) against k/v (B, Hkv, S, D).

    The p-class kernel: streams the KV cache once through VMEM (split-K
    online softmax), memory-bound by design.  ``kv_len`` optionally masks
    the valid cache prefix per batch element (ragged decode).
    """
    b, hq, one, d = q.shape
    assert one == 1
    if kv_len is None:
        return flash_attention(q, k, v, causal=False, block_q=8,
                               block_k=block_k, interpret=interpret)
    # mask positions >= kv_len[b] by pre-masking k (set to NEG via bias on s
    # is cheaper, but reuse flash path for simplicity of the fallback)
    s = k.shape[2]
    pos = jnp.arange(s)[None, None, :, None]
    valid = pos < kv_len[:, None, None, None]
    k = jnp.where(valid, k, 0.0)
    # recompute with explicit mask via flash on the padded region: use the
    # sk_valid mechanism by slicing to max len (static) — positions beyond
    # kv_len contribute exp(-inf)=0 through the bias below.
    bias_mask = (~valid).squeeze(-1)  # (B, 1, S)
    out = masked_decode_ref(q, k, v, bias_mask)
    return out


def masked_decode_ref(q, k, v, bias_mask):
    """jnp fallback for ragged decode (used under jit; small q)."""
    g = q.shape[1] // k.shape[1]
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kr) / math.sqrt(q.shape[-1])
    s = jnp.where(bias_mask[:, :, None, :], NEG_INF, s)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vr).astype(q.dtype)
