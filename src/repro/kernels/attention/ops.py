"""jit'd attention entry points used by the LM substrate.

``attention(...)`` dispatches between the XLA einsum path (default — what the
multi-pod dry-run lowers, since Pallas TPU kernels cannot be compiled on this
CPU container) and the Pallas flash kernel (validated in interpret mode;
``use_pallas=True`` on real hardware).
"""
from __future__ import annotations

import functools

import jax

from repro.kernels.attention.kernel import decode_attention, flash_attention
from repro.kernels.attention.ref import attention_ref


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas",
                                             "interpret"))
def attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
              causal: bool = True, use_pallas: bool = False,
              interpret: bool | None = None) -> jax.Array:
    if use_pallas:
        return flash_attention(q, k, v, causal=causal, interpret=interpret)
    return attention_ref(q, k, v, causal=causal)


__all__ = ["attention", "flash_attention", "decode_attention",
           "attention_ref"]
