"""Pure-jnp oracle for the attention kernels (GQA, causal)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  causal: bool = True) -> jax.Array:
    """q: (B, Hq, Sq, D); k/v: (B, Hkv, Sk, D)."""
    b, hq, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    g = hq // hkv
    kr = jnp.repeat(k, g, axis=1)
    vr = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((sq, sk), bool), k=sk - sq)
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, vr.astype(jnp.float32))
    return out.astype(q.dtype)
