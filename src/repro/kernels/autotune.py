"""Block-shape autotuner for the Pallas kernels (DESIGN.md §4).

Ahn-style near-optimal tile geometry is shape-dependent: the best
(block_m, block_n, block_k) / channel-block / row-block for a 112x112x32
depthwise layer is not the best for a 7x7x1024 pointwise layer.  Rather than
bake one heuristic into every wrapper, each op consults this module with its
*layer signature*; the tuner benchmarks a small candidate set once per
signature, caches the winner in a JSON file, and every later call (same
process or a fresh one) gets the cached config with zero benchmark cost.

Cache format (``autotune_cache.json``)::

    {
      "version": 1,
      "entries": {
        "conv/h14.w14.ci32.co64.k3x3.s1.p1/f32": {
          "config": {"block_h": 9, "block_n": 64},
          "us": 1234.5,
          "backend": "cpu"
        },
        ...
      }
    }

Keys are ``kind/signature/dtype``; ``us`` is the winning median wall-clock in
microseconds on the machine that tuned.  The cache path defaults to
``results/autotune_cache.json`` (cwd-relative, matching the benchmarks'
results/ convention) and can be redirected with the
``REPRO_AUTOTUNE_CACHE`` env var (tests and CI point it at a temp file).

The lookup path (``get_config``) is pure python — cheap enough to run at
trace time inside the jit'd wrappers.  The benchmark path (``tune`` /
``tune_layer``) executes kernels eagerly and must only be called outside jit
(benchmarks/kernel_specs.py --smoke, tests, or an explicit warm-up).
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import os
from typing import Any, Callable

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
CACHE_VERSION = 1

_DTYPE_TAGS = {"float32": "f32", "bfloat16": "bf16", "float16": "f16"}

# in-memory mirror of the JSON files, keyed by resolved path
_MEM: dict[str, dict[str, Any]] = {}

# when not None, get_config appends every signature it is asked for —
# how the --sweep-zoo entry discovers exactly the signatures the op
# wrappers consult (see record_signatures / zoo_signatures)
_RECORDING: list["LayerSig"] | None = None


@dataclasses.dataclass(frozen=True)
class LayerSig:
    """Kernel-shape signature — the autotune cache key (DESIGN.md §4)."""

    kind: str                    # 'conv' | 'pointwise' | 'depthwise' |
                                 # 'fused_dw_pw' | 'fused_pw_dw_pw'
    H: int
    W: int
    C_i: int
    C_o: int
    K_h: int = 1
    K_w: int = 1
    stride: int = 1
    pad: int = 0
    dtype: str = "float32"

    def key(self) -> str:
        tag = _DTYPE_TAGS.get(self.dtype, self.dtype)
        return (f"{self.kind}/h{self.H}.w{self.W}.ci{self.C_i}.co{self.C_o}"
                f".k{self.K_h}x{self.K_w}.s{self.stride}.p{self.pad}/{tag}")


def cache_path(path: str | None = None) -> str:
    if path:
        return path
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    # repo-relative (matches the results/ convention of benchmarks/run.py)
    return os.path.join("results", "autotune_cache.json")


def load_cache(path: str | None = None) -> dict[str, Any]:
    p = cache_path(path)
    if p in _MEM:
        return _MEM[p]
    data: dict[str, Any] = {"version": CACHE_VERSION, "entries": {}}
    try:
        with open(p) as f:
            raw = json.load(f)
        if raw.get("version") == CACHE_VERSION:
            data = raw
    except (OSError, ValueError):
        pass
    _MEM[p] = data
    return data


def save_cache(data: dict[str, Any], path: str | None = None) -> None:
    p = cache_path(path)
    os.makedirs(os.path.dirname(p) or ".", exist_ok=True)
    tmp = p + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1, sort_keys=True)
    os.replace(tmp, p)
    _MEM[p] = data


def clear_memory_cache() -> None:
    """Drop the in-process mirror (tests use this to force a re-read)."""
    _MEM.clear()


# --------------------------------------------------------------------------
# lookup path (trace-time cheap)
# --------------------------------------------------------------------------
def get_config(sig: LayerSig, path: str | None = None) -> dict | None:
    """Cached winning config for ``sig``, or None on a miss.

    Entries tuned on a different backend are treated as misses: block
    shapes ranked by CPU interpret-mode wall-clock say nothing about MXU
    performance (and vice versa), so a TPU run must not inherit a cache
    populated by CPU CI.
    """
    if _RECORDING is not None:
        _RECORDING.append(sig)
    entry = load_cache(path)["entries"].get(sig.key())
    if not entry:
        return None
    import jax
    if entry.get("backend") != jax.default_backend():
        return None
    return dict(entry["config"])


def heuristic_config(sig: LayerSig) -> dict:
    """Default block shapes used on a cache miss — the pre-tuner behaviour."""
    if sig.kind == "conv":
        wo = max(1, (sig.W + 2 * sig.pad - sig.K_w) // sig.stride + 1)
        ho = max(1, (sig.H + 2 * sig.pad - sig.K_h) // sig.stride + 1)
        return {"block_h": max(1, min(ho, -(-256 // wo))),
                "block_n": min(128, max(sig.C_o, 8))}
    if sig.kind == "pointwise":
        return {"block": (128, 128, 128)}
    if sig.kind == "depthwise":
        # largest channel block whose halo tile fits half a core's VMEM
        tile = (sig.H + sig.K_h - 1) * (sig.W + sig.K_w - 1) * 4
        bc = max(8, (8 * 1024 * 1024) // max(tile, 1))
        bc = min(bc, sig.C_i)
        return {"block_c": max(8, bc - bc % 8) if bc >= 8 else max(1, bc)}
    if sig.kind in ("fused_dw_pw", "fused_pw_dw_pw"):
        return {"block_c": min(128, max(sig.C_i, 8)),
                "block_n": min(128, max(sig.C_o, 8))}
    raise ValueError(f"unknown kernel kind {sig.kind!r}")


def candidates(sig: LayerSig) -> list[dict]:
    """Small per-kind candidate sets (kept tiny: interpret mode is slow)."""
    out: list[dict] = [heuristic_config(sig)]
    if sig.kind == "conv":
        ho = max(1, (sig.H + 2 * sig.pad - sig.K_h) // sig.stride + 1)
        for bh in (1, 4, 8, 16):
            for bn in (64, 128):
                out.append({"block_h": min(bh, ho),
                            "block_n": min(bn, max(sig.C_o, 8))})
    elif sig.kind == "pointwise":
        for b in ((64, 64, 64), (128, 128, 128), (256, 128, 128)):
            out.append({"block": b})
    elif sig.kind == "depthwise":
        for bc in (32, 64, 128):
            out.append({"block_c": min(bc, max(sig.C_i, 1))})
    else:
        for bc in (64, 128):
            for bn in (64, 128):
                out.append({"block_c": min(bc, max(sig.C_i, 8)),
                            "block_n": min(bn, max(sig.C_o, 8))})
    # dedupe, preserving order
    seen: set[str] = set()
    uniq = []
    for c in out:
        k = json.dumps(c, sort_keys=True)
        if k not in seen:
            seen.add(k)
            uniq.append(c)
    return uniq


# --------------------------------------------------------------------------
# benchmark path (eager only)
# --------------------------------------------------------------------------
def _time_us(fn: Callable[[], Any], reps: int = 3) -> float:
    from repro.kernels.util import bench_best_us
    return bench_best_us(fn, reps=reps)


def tune(sig: LayerSig, run: Callable[[dict], Callable[[], Any]], *,
         path: str | None = None, reps: int = 3,
         force: bool = False) -> dict:
    """Benchmark ``candidates(sig)`` and cache the winner.

    ``run(config)`` returns a zero-arg callable executing the kernel with
    that config.  A cached entry short-circuits the benchmark (deterministic
    round-trips) unless ``force``.
    """
    if not force:
        hit = get_config(sig, path)
        if hit is not None:
            return hit
    import jax
    best_cfg, best_us = None, float("inf")
    for cfg in candidates(sig):
        try:
            us = _time_us(run(cfg), reps=reps)
        except Exception:            # a candidate may be invalid for a shape
            continue
        if us < best_us:
            best_cfg, best_us = cfg, us
    if best_cfg is None:
        # every candidate failed: cache the heuristic with no timing (null
        # keeps the JSON strict — NaN is not valid JSON)
        best_cfg, best_us = heuristic_config(sig), None
    data = load_cache(path)
    data["entries"][sig.key()] = {"config": best_cfg,
                                  "us": None if best_us is None
                                  else round(best_us, 1),
                                  "backend": jax.default_backend()}
    save_cache(data, path)
    return dict(best_cfg)


def tune_layer(sig: LayerSig, *, path: str | None = None, reps: int = 3,
               force: bool = False) -> dict:
    """Tune one layer signature end-to-end: builds dummy operands of the
    signature's shape and benchmarks the matching op wrapper."""
    import jax
    import jax.numpy as jnp

    dtype = jnp.dtype(sig.dtype)
    key = jax.random.PRNGKey(0)
    kx, kw, kw2, kw3 = jax.random.split(key, 4)
    x = (jax.random.normal(kx, (1, sig.H, sig.W, sig.C_i)) * 0.3
         ).astype(dtype)

    if sig.kind == "conv":
        from repro.kernels.conv_gemm.kernel import conv2d_implicit_gemm
        w = (jax.random.normal(kw, (sig.K_h, sig.K_w, sig.C_i, sig.C_o))
             * 0.2).astype(dtype)

        def run(cfg):
            return lambda: conv2d_implicit_gemm(
                x, w, stride=sig.stride, pad=sig.pad, **cfg)
    elif sig.kind == "pointwise":
        from repro.kernels.conv_gemm.kernel import matmul_bias_act
        xm = x.reshape(sig.H * sig.W, sig.C_i)
        w = (jax.random.normal(kw, (sig.C_i, sig.C_o)) * 0.2).astype(dtype)

        def run(cfg):
            block = tuple(cfg["block"])
            return lambda: matmul_bias_act(xm, w, block=block)
    elif sig.kind == "depthwise":
        from repro.kernels.depthwise.kernel import depthwise_conv2d
        w = (jax.random.normal(kw, (sig.K_h, sig.K_w, sig.C_i))
             * 0.3).astype(dtype)

        def run(cfg):
            return lambda: depthwise_conv2d(
                x, w, stride=sig.stride, pad=sig.pad, **cfg)
    elif sig.kind == "fused_dw_pw":
        from repro.kernels.fused_block.kernel import fused_dw_pw_conv
        dw_w = (jax.random.normal(kw, (sig.K_h, sig.K_w, sig.C_i))
                * 0.3).astype(dtype)
        pw_w = (jax.random.normal(kw2, (sig.C_i, sig.C_o)) * 0.2
                ).astype(dtype)

        def run(cfg):
            return lambda: fused_dw_pw_conv(
                x, dw_w, None, pw_w, None, stride=sig.stride, pad=sig.pad,
                **cfg)
    elif sig.kind == "fused_pw_dw_pw":
        # C_i in the signature is C_mid (the dw channel count, what the
        # block_c knob tiles); expand input is fixed at C_mid // 6 (the
        # common t=6 expansion) purely to exercise the expand GEMM.
        from repro.kernels.fused_block.kernel import fused_pw_dw_pw_conv
        cm = sig.C_i
        ci = max(8, cm // 6)
        x = (jax.random.normal(kx, (1, sig.H, sig.W, ci)) * 0.3
             ).astype(dtype)
        exp_w = (jax.random.normal(kw, (ci, cm)) * 0.2).astype(dtype)
        dw_w = (jax.random.normal(kw2, (sig.K_h, sig.K_w, cm))
                * 0.3).astype(dtype)
        proj_w = (jax.random.normal(kw3, (cm, sig.C_o)) * 0.2).astype(dtype)

        def run(cfg):
            return lambda: fused_pw_dw_pw_conv(
                x, exp_w, None, dw_w, None, proj_w, None,
                stride=sig.stride, pad=sig.pad, **cfg)
    else:
        raise ValueError(f"tune_layer: unsupported kind {sig.kind!r}")
    return tune(sig, run, path=path, reps=reps, force=force)


# --------------------------------------------------------------------------
# zoo sweep (python -m repro.kernels.autotune --sweep-zoo)
# --------------------------------------------------------------------------
ZOO_MODELS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")


@contextlib.contextmanager
def record_signatures():
    """Collect every LayerSig the op wrappers consult inside the block."""
    global _RECORDING
    prev, _RECORDING = _RECORDING, []
    try:
        yield _RECORDING
    finally:
        _RECORDING = prev


def zoo_signatures(image_size: int = 224,
                   models: tuple[str, ...] = ZOO_MODELS) -> list[LayerSig]:
    """Every layer signature the zoo forwards consult at ``image_size`` —
    per-layer and fused-block paths both — discovered by abstractly
    evaluating the real step programs with signature recording on, so the
    sweep can never drift from what the op wrappers actually ask for."""
    import jax
    import jax.numpy as jnp

    from repro.dualcore.program import build_program
    from repro.models.cnn import init_params
    from repro.models.zoo import get_graph

    sigs: list[LayerSig] = []
    seen: set[str] = set()
    x = jnp.zeros((1, image_size, image_size, 3), jnp.float32)
    for name in models:
        params = init_params(get_graph(name), jax.random.PRNGKey(0))
        for fuse in (False, True):
            prog = build_program(name, use_pallas=True, fuse=fuse)
            with record_signatures() as rec:
                jax.eval_shape(
                    lambda p, xx, prog=prog: prog.run(p, xx), params, x)
            for s in rec:
                if s.key() not in seen:
                    seen.add(s.key())
                    sigs.append(s)
    return sigs


def sweep_zoo(image_size: int = 224, *, reps: int = 3, limit: int = 0,
              force: bool = False, path: str | None = None) -> dict:
    """Warm the autotune cache over all zoo layer signatures (ROADMAP
    "autotune coverage").  ``limit`` bounds how many *missing* signatures
    get tuned this run (0 = all) so CI can warm incrementally inside its
    time budget; cached entries always short-circuit.  Returns a summary
    dict (total / cached / tuned / skipped)."""
    sigs = zoo_signatures(image_size)
    cached = [s for s in sigs if get_config(s, path) is not None]
    missing = [s for s in sigs if get_config(s, path) is None]
    if force:
        missing, cached = sigs, []
    todo = missing if limit <= 0 else missing[:limit]
    for i, sig in enumerate(todo):
        cfg = tune_layer(sig, path=path, reps=reps, force=force)
        entry = load_cache(path)["entries"][sig.key()]
        us = entry.get("us")
        print(f"[{i + 1:>3}/{len(todo)}] {sig.key():<48} -> {cfg} "
              f"({'n/a' if us is None else f'{us:.0f} us'})")
    summary = {"image_size": image_size, "total": len(sigs),
               "cached": len(cached), "tuned": len(todo),
               "skipped": len(missing) - len(todo),
               "cache_path": cache_path(path)}
    print(f"sweep: {summary['total']} signatures @ {image_size}px — "
          f"{summary['cached']} already cached, {summary['tuned']} tuned, "
          f"{summary['skipped']} deferred (limit) -> "
          f"{summary['cache_path']}")
    return summary


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="repro.kernels.autotune",
        description="Warm the block-shape autotune cache over the zoo.")
    ap.add_argument("--sweep-zoo", action="store_true", required=True,
                    help="tune every zoo layer signature into the cache")
    ap.add_argument("--image-size", type=int, default=None,
                    help="input H=W the signatures are taken at "
                         "(default: 224 paper size; 64 with --smoke, "
                         "matching the CI perf benches)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI bounds: 64px signatures, reps=1, --limit 12 "
                         "unless overridden (incremental warming via the "
                         "persisted cache)")
    ap.add_argument("--reps", type=int, default=None,
                    help="timing reps per candidate (default 3; 1 smoke)")
    ap.add_argument("--limit", type=int, default=None,
                    help="max missing signatures tuned this run "
                         "(0 = all; default 0, 12 with --smoke)")
    ap.add_argument("--force", action="store_true",
                    help="re-tune even cached signatures")
    ap.add_argument("--cache", default=None,
                    help=f"cache file (default: ${CACHE_ENV} or "
                         f"results/autotune_cache.json)")
    args = ap.parse_args(argv)

    image_size = args.image_size or (64 if args.smoke else 224)
    reps = args.reps if args.reps is not None else (1 if args.smoke else 3)
    limit = args.limit if args.limit is not None else (12 if args.smoke
                                                      else 0)
    sweep_zoo(image_size, reps=reps, limit=limit, force=args.force,
              path=args.cache)
    return 0


if __name__ == "__main__":
    import sys

    # run the *canonical* module instance: under ``python -m`` this file
    # executes as ``__main__``, whose module-level recording state would be
    # invisible to the op wrappers importing ``repro.kernels.autotune``
    from repro.kernels.autotune import main as _main

    sys.exit(_main())
