"""c-core analogue: im2col GEMM Pallas kernel with MXU-aligned VMEM tiling.

The dual-OPU c-core broadcasts one ifm pixel across the PE array and exploits
input/output channel parallelism — on TPU that is exactly a GEMM over the
im2col matrix, tiled (block_m x block_k) @ (block_k x block_n) so each step
feeds the 128x128 MXU from VMEM.  The k-grid dimension accumulates into a
float32 VMEM scratch accumulator (the overlay's output-buffer partial sums,
§III-A), with an optional fused bias + ReLU/ReLU6 epilogue (the overlay's
post-processing unit runs in the same pipeline).

Block shapes default to (128, 128, 128): MXU-native, and 3 * 128*128*4B =
192 KiB of VMEM per step — well inside the ~16 MiB/core budget while leaving
room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


DEFAULT_BLOCK = (128, 128, 128)  # (block_m, block_n, block_k)


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, nk: int,
                   fuse_bias: bool, act: str | None):
    """One (i, j, k) grid step: acc[i,j] += x[i,k] @ w[k,j]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        out = acc_ref[...]
        if fuse_bias:
            out = out + b_ref[...].astype(jnp.float32)
        if act == "relu":
            out = jnp.maximum(out, 0.0)
        elif act == "relu6":
            out = jnp.clip(out, 0.0, 6.0)
        o_ref[...] = out.astype(o_ref.dtype)


def _pad_to(x: jax.Array, mult: tuple[int, ...]) -> jax.Array:
    pads = [(0, -s % m) for s, m in zip(x.shape, mult)]
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


@functools.partial(jax.jit, static_argnames=("block", "act", "interpret"))
def matmul_bias_act(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
                    *, block: tuple[int, int, int] = DEFAULT_BLOCK,
                    act: str | None = None,
                    interpret: bool = True) -> jax.Array:
    """(M, K) @ (K, N) + bias with fused activation, Pallas-tiled.

    Shapes are padded up to the block grid; the result is sliced back.
    ``interpret=True`` runs the kernel body on CPU (this container); on a
    real TPU pass ``interpret=False``.
    """
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm = min(block[0], max(M, 8))
    bn = min(block[1], max(N, 8))
    bk = min(block[2], max(K, 8))
    xp = _pad_to(x, (bm, bk))
    wp = _pad_to(w, (bk, bn))
    fuse_bias = bias is not None
    b = bias if fuse_bias else jnp.zeros((N,), x.dtype)
    bp = _pad_to(b.reshape(1, N), (1, bn))
    Mp, Kp = xp.shape
    _, Np = wp.shape
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, fuse_bias=fuse_bias,
                          act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(xp, wp, bp)
    return out[:M, :N]
