"""c-core analogue: implicit-GEMM conv + tiled GEMM Pallas kernels.

The dual-OPU c-core broadcasts one ifm pixel across the PE array and exploits
input/output channel parallelism — on TPU that is a GEMM over conv patches.
The seed materialized the full im2col patch matrix in HBM (a K_h*K_w x
activation blow-up) before the GEMM ever ran; ``conv2d_implicit_gemm`` instead
keeps the NHWC feature map as-is and assembles each (block_m x block_k) patch
tile *inside the kernel* from a halo tile resident in VMEM, so HBM traffic is
~1x the ifm (DESIGN.md §1).  ``im2col`` survives only in ref.py as the test
oracle.

Grid: (N, C_o tiles, H_out tiles), with the H_out tiles innermost so the
image block (index map independent of the inner dims) stays VMEM-resident
across a whole output-channel pass.  Each step runs K_h*K_w MXU dots of
(block_h*W_out, C_i) @ (C_i, block_n) accumulated in a float32 VMEM scratch
(the overlay's output-buffer partial sums, §III-A), then a fused
bias + ReLU/ReLU6 epilogue (the overlay's post-processing unit).

``matmul_bias_act`` is the plain tiled GEMM used by the 1x1 (pointwise / fc)
fast path, where im2col is the identity.  Block shapes default to
(128, 128, 128): MXU-native, 3 * 128*128*4B = 192 KiB of VMEM per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import (apply_act, cdiv, pad_axis, pad_to,
                                resolve_interpret)


DEFAULT_BLOCK = (128, 128, 128)  # (block_m, block_n, block_k)


def _apply_epilogue(out, b_ref, act: str | None):
    if b_ref is not None:
        out = out + b_ref[...].astype(jnp.float32)
    return apply_act(out, act)


# --------------------------------------------------------------------------
# tiled GEMM (the 1x1 / fc fast path, and the building block of the tests)
# --------------------------------------------------------------------------
def _matmul_kernel(x_ref, w_ref, *rest, nk: int, fuse_bias: bool,
                   act: str | None):
    """One (i, j, k) grid step: acc[i,j] += x[i,k] @ w[k,j].

    The bias operand only exists when ``fuse_bias`` — no zeros block is
    allocated or streamed for bias-less GEMMs.
    """
    if fuse_bias:
        b_ref, o_ref, acc_ref = rest
    else:
        (o_ref, acc_ref), b_ref = rest, None

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], w_ref[...],
                            preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == nk - 1)
    def _epilogue():
        o_ref[...] = _apply_epilogue(acc_ref[...], b_ref,
                                     act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block", "act", "interpret"))
def matmul_bias_act(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
                    *, block: tuple[int, int, int] = DEFAULT_BLOCK,
                    act: str | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """(M, K) @ (K, N) + bias with fused activation, Pallas-tiled.

    Shapes are padded up to the block grid; the result is sliced back.
    ``interpret=None`` auto-detects: interpret on CPU, compiled on TPU.
    """
    interpret = resolve_interpret(interpret)
    M, K = x.shape
    K2, N = w.shape
    assert K == K2, (x.shape, w.shape)
    bm = min(block[0], max(M, 8))
    bn = min(block[1], max(N, 8))
    bk = min(block[2], max(K, 8))
    xp = pad_to(x, (bm, bk))
    wp = pad_to(w, (bk, bn))
    fuse_bias = bias is not None
    Mp, Kp = xp.shape
    _, Np = wp.shape
    nk = Kp // bk
    grid = (Mp // bm, Np // bn, nk)
    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
    ]
    operands = [xp, wp]
    if fuse_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, k: (0, j)))
        operands.append(pad_to(bias.reshape(1, N), (1, bn)))
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk, fuse_bias=fuse_bias,
                          act=act),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:M, :N]


# --------------------------------------------------------------------------
# implicit-GEMM conv (K > 1): no HBM patch matrix, ever
# --------------------------------------------------------------------------
def _implicit_gemm_kernel(x_ref, w_ref, *rest, kh: int, kw: int, stride: int,
                          bh: int, wo: int, fuse_bias: bool, act: str | None):
    """One (n, co, ht) grid step of the implicit GEMM.

    x_ref:   (1, Hp, Wp, C)  — the whole padded image, VMEM-resident (its
             index map ignores co/ht, so Pallas keeps it loaded across the
             inner grid dims: HBM traffic ~1x the ifm).
    w_ref:   (kh, kw, C, bn)
    b_ref:   (1, bn) — only present when ``fuse_bias``
    o_ref:   (1, bh, wo, bn)
    acc_ref: (bh*wo, bn) float32 VMEM scratch accumulator.

    The (bh*wo, C) patch tile for each window tap is gathered from the halo
    tile with strided VMEM slices — the in-kernel im2col — and fed straight
    to the MXU.
    """
    if fuse_bias:
        b_ref, o_ref, acc_ref = rest
    else:
        (o_ref, acc_ref), b_ref = rest, None
    ht = pl.program_id(2)
    x = x_ref[0]                       # (Hp, Wp, C)
    _, wp_, c = x.shape
    span_h = (bh - 1) * stride + kh
    # halo rows for this output-row block (dynamic start, static size)
    xs = jax.lax.dynamic_slice(x, (ht * bh * stride, 0, 0),
                               (span_h, wp_, c))
    acc_ref[...] = jnp.zeros_like(acc_ref)
    for i in range(kh):                # unrolled window taps: each gathers a
        for j in range(kw):            # patch tile from the same VMEM halo
            tap = jax.lax.slice(
                xs, (i, j, 0),
                (i + (bh - 1) * stride + 1, j + (wo - 1) * stride + 1, c),
                (stride, stride, 1))   # (bh, wo, c)
            acc_ref[...] += jnp.dot(tap.reshape(bh * wo, c),
                                    w_ref[i, j],
                                    preferred_element_type=jnp.float32)
    out = _apply_epilogue(acc_ref[...], b_ref, act)
    o_ref[0] = out.reshape(bh, wo, -1).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "pad", "act",
                                             "block_h", "block_n",
                                             "interpret"))
def conv2d_implicit_gemm(x: jax.Array, w: jax.Array,
                         bias: jax.Array | None = None, *, stride: int = 1,
                         pad: int = 0, act: str | None = None,
                         block_h: int = 0, block_n: int = 128,
                         interpret: bool | None = None) -> jax.Array:
    """NHWC conv as implicit GEMM: patch tiles assembled in VMEM, no
    (N*Ho*Wo, Kh*Kw*C) intermediate in HBM.

    x: (N, H, W, C_i); w: (K_h, K_w, C_i, C_o); bias: (C_o,) or None.
    ``block_h`` output rows per grid step (0 = auto: aim for a ~256-row
    GEMM M-tile); ``block_n`` output-channel tile.
    """
    interpret = resolve_interpret(interpret)
    n, h, wd, ci = x.shape
    kh, kw, ci2, co = w.shape
    assert ci == ci2, (x.shape, w.shape)
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (wd + 2 * pad - kw) // stride + 1
    bh = block_h if block_h > 0 else max(1, min(ho, cdiv(256, wo)))
    bh = min(bh, ho)
    bn = min(block_n, max(co, 8))
    n_ht = cdiv(ho, bh)
    # spatial padding: conv pad plus extra bottom rows so the last h-tile's
    # halo slice stays in bounds ((n_ht*bh - 1)*stride + kh rows needed)
    need_h = (n_ht * bh - 1) * stride + kh
    extra_h = max(0, need_h - (h + 2 * pad))
    xp = jnp.pad(x, ((0, 0), (pad, pad + extra_h), (pad, pad), (0, 0)))
    wp = pad_axis(w, 3, bn)
    cop = wp.shape[3]
    fuse_bias = bias is not None
    hp, wp_ = xp.shape[1], xp.shape[2]
    grid = (n, cop // bn, n_ht)
    in_specs = [
        pl.BlockSpec((1, hp, wp_, ci), lambda i, j, t: (i, 0, 0, 0)),
        pl.BlockSpec((kh, kw, ci, bn), lambda i, j, t: (0, 0, 0, j)),
    ]
    operands = [xp, wp]
    if fuse_bias:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, t: (0, j)))
        operands.append(pad_to(bias.reshape(1, co), (1, bn)))
    out = pl.pallas_call(
        functools.partial(_implicit_gemm_kernel, kh=kh, kw=kw, stride=stride,
                          bh=bh, wo=wo, fuse_bias=fuse_bias, act=act),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bh, wo, bn),
                               lambda i, j, t: (i, t, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, n_ht * bh, wo, cop), x.dtype),
        scratch_shapes=[pltpu.VMEM((bh * wo, bn), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[:, :ho, :, :co]
