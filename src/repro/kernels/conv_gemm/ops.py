"""Dispatch wrapper: 2D convolution via implicit GEMM (c-core analogue).

No im2col materialization anywhere on this path: 1x1 convs flatten pixels
(im2col is the identity) and run the tiled GEMM; K>1 convs run the
implicit-GEMM kernel whose patch tiles are gathered in VMEM (DESIGN.md §1).
Block shapes come from the autotune cache when a tuned entry exists for the
layer signature, else from the per-kind heuristic.
"""
from __future__ import annotations

import jax

from repro.kernels import autotune
from repro.kernels.conv_gemm.kernel import (DEFAULT_BLOCK,
                                            conv2d_implicit_gemm,
                                            matmul_bias_act)


def _sig(kind: str, x: jax.Array, kh: int, kw: int, ci: int, co: int,
         stride: int, pad: int) -> autotune.LayerSig:
    return autotune.LayerSig(kind=kind, H=x.shape[1], W=x.shape[2],
                             C_i=ci, C_o=co, K_h=kh, K_w=kw, stride=stride,
                             pad=pad, dtype=str(x.dtype))


def conv2d_gemm(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
                *, stride: int = 1, pad: int = 0, act: str | None = None,
                block=None, interpret: bool | None = None) -> jax.Array:
    """NHWC conv with fused bias/activation epilogue.

    x: (N, H, W, C_i); w: (K_h, K_w, C_i, C_o); bias: (C_o,) or None.
    ``block``: optional explicit (block_h, block_n) override for the
    implicit-GEMM path (autotuned / heuristic when None).
    """
    kh, kw, ci, co = w.shape
    if kh == 1 and kw == 1 and stride == 1 and pad == 0:
        return pointwise_conv(x, w.reshape(ci, co), bias, act=act,
                              interpret=interpret)
    if block is not None:
        bh, bn = block
    else:
        sig = _sig("conv", x, kh, kw, ci, co, stride, pad)
        cfg = autotune.get_config(sig) or autotune.heuristic_config(sig)
        bh, bn = cfg["block_h"], cfg["block_n"]
    return conv2d_implicit_gemm(x, w, bias, stride=stride, pad=pad, act=act,
                                block_h=bh, block_n=bn, interpret=interpret)


def pointwise_conv(x: jax.Array, w: jax.Array,
                   bias: jax.Array | None = None, *, act: str | None = None,
                   block=None, interpret: bool | None = None) -> jax.Array:
    """1x1 conv fast path: pure GEMM over flattened pixels.

    Accepts w as (C_i, C_o) or (1, 1, C_i, C_o).
    """
    n, h, wd, ci = x.shape
    if w.ndim == 4:
        w = w.reshape(w.shape[2], w.shape[3])
    co = w.shape[-1]
    if block is None:
        sig = _sig("pointwise", x, 1, 1, ci, co, 1, 0)
        cfg = autotune.get_config(sig)
        block = tuple(cfg["block"]) if cfg else DEFAULT_BLOCK
    out = matmul_bias_act(x.reshape(n * h * wd, ci), w, bias, block=block,
                          act=act, interpret=interpret)
    return out.reshape(n, h, wd, co)
