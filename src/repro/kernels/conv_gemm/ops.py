"""jit'd wrapper: 2D convolution as im2col + Pallas GEMM (c-core analogue)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.conv_gemm.kernel import DEFAULT_BLOCK, matmul_bias_act
from repro.kernels.conv_gemm.ref import im2col


@functools.partial(jax.jit,
                   static_argnames=("stride", "pad", "act", "block",
                                    "interpret"))
def conv2d_gemm(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
                *, stride: int = 1, pad: int = 0, act: str | None = None,
                block=DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    """NHWC conv: im2col then the tiled GEMM kernel with fused epilogue.

    x: (N, H, W, C_i); w: (K_h, K_w, C_i, C_o); bias: (C_o,) or None.
    """
    kh, kw, ci, co = w.shape
    patches, (n, ho, wo) = im2col(x, kh, kw, stride, pad)
    wm = w.reshape(kh * kw * ci, co)
    out = matmul_bias_act(patches, wm, bias, block=block, act=act,
                          interpret=interpret)
    return out.reshape(n, ho, wo, co)


@functools.partial(jax.jit, static_argnames=("act", "block", "interpret"))
def pointwise_conv(x: jax.Array, w: jax.Array,
                   bias: jax.Array | None = None, *, act: str | None = None,
                   block=DEFAULT_BLOCK, interpret: bool = True) -> jax.Array:
    """1x1 conv fast path: pure GEMM over flattened pixels."""
    n, h, wd, ci = x.shape
    co = w.shape[-1]
    out = matmul_bias_act(x.reshape(n * h * wd, ci),
                          w.reshape(ci, co), bias, block=block, act=act,
                          interpret=interpret)
    return out.reshape(n, h, wd, co)
