"""Pure-jnp oracles for the conv_gemm kernels.

``im2col`` lives here ONLY as the test oracle (and the baseline leg of the
--smoke benchmark): the execution path never materializes a patch matrix —
see conv2d_implicit_gemm (DESIGN.md §1)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_bias_act_ref(x: jax.Array, w: jax.Array,
                        bias: jax.Array | None = None,
                        act: str | None = None) -> jax.Array:
    out = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    return out.astype(x.dtype)


def im2col(x: jax.Array, kh: int, kw: int, stride: int,
           pad: int) -> jax.Array:
    """NHWC -> (N*Ho*Wo, kh*kw*C) patch matrix."""
    n, h, w, c = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (w + 2 * pad - kw) // stride + 1
    idx_h = jnp.arange(ho) * stride
    idx_w = jnp.arange(wo) * stride
    patches = []
    for i in range(kh):
        for j in range(kw):
            patches.append(xp[:, idx_h + i][:, :, idx_w + j])  # (n,ho,wo,c)
    # (n, ho, wo, kh*kw, c) -> (n*ho*wo, kh*kw*c)
    pm = jnp.stack(patches, axis=3)
    return pm.reshape(n * ho * wo, kh * kw * c), (n, ho, wo)


def conv2d_ref(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
               stride: int = 1, pad: int = 0,
               act: str | None = None) -> jax.Array:
    """Reference NHWC conv via jax.lax (oracle for the full conv op)."""
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    return out.astype(x.dtype)
