"""p-core analogue: depthwise conv Pallas kernel with VMEM sliding-window
reuse (the TPU port of the paper's line buffer, DESIGN.md §2).

The dual-OPU p-core keeps a T_w*(T_kh-1)+T_kw line buffer in BRAM so each ifm
pixel is read from DRAM once and reused across the K_h x K_w window.  On TPU
the analogue is: bring a (H+K-1, W+K-1, block_c) halo tile into VMEM once and
compute every window tap from it — HBM traffic is 1x the ifm instead of
K_h*K_w x.  Channel parallelism maps to the VPU lanes (channels-last, so the
per-tap multiply is a (Ho, Wo, block_c) vector op), mirroring the p-core's
per-PE-per-channel layout.

Grid: (N, C / block_c).  Each step holds x_tile + out tile in VMEM:
for 112x114x114 x 64ch x 4B ~ 3.3 MiB — fits; block_c shrinks for larger
maps (chosen by ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import apply_act, pad_axis, resolve_interpret


def _dw_kernel(x_ref, w_ref, *rest, kh: int, kw: int, stride: int,
               fuse_bias: bool, act: str | None):
    """x_ref: (1, Hp, Wp, bc) padded halo tile; w_ref: (kh, kw, bc);
    o_ref: (1, Ho, Wo, bc).  The bias operand only exists when
    ``fuse_bias`` — no zeros block is streamed for bias-less convs."""
    if fuse_bias:
        b_ref, o_ref = rest
    else:
        (o_ref,), b_ref = rest, None
    _, ho, wo, bc = o_ref.shape
    x = x_ref[0]
    acc = jnp.zeros((ho, wo, bc), jnp.float32)
    for i in range(kh):          # unrolled window taps — every tap reads the
        for j in range(kw):      # same VMEM tile (line-buffer reuse)
            tap = jax.lax.slice(
                x, (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, bc),
                (stride, stride, 1))
            acc = acc + tap.astype(jnp.float32) * w_ref[i, j, :].astype(
                jnp.float32)
    if fuse_bias:
        acc = acc + b_ref[...].astype(jnp.float32)
    o_ref[0] = apply_act(acc, act).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "pad", "act",
                                             "block_c", "interpret"))
def depthwise_conv2d(x: jax.Array, w: jax.Array,
                     bias: jax.Array | None = None, *, stride: int = 1,
                     pad: int = 1, act: str | None = None,
                     block_c: int = 64,
                     interpret: bool | None = None) -> jax.Array:
    """NHWC depthwise conv.  x: (N,H,W,C); w: (K_h,K_w,C); bias: (C,)."""
    interpret = resolve_interpret(interpret)
    n, h, wd, c = x.shape
    kh, kw, cw = w.shape
    assert cw == c, (w.shape, c)
    bc = min(block_c, c)
    # pad channels to a block multiple, spatial by the conv padding
    xp = pad_axis(jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))),
                  3, bc)
    wp = pad_axis(w, 2, bc)
    fuse_bias = bias is not None
    cp = xp.shape[3]
    hp, wp_ = h + 2 * pad, wd + 2 * pad
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (wd + 2 * pad - kw) // stride + 1
    grid = (n, cp // bc)
    in_specs = [
        pl.BlockSpec((1, hp, wp_, bc), lambda i, j: (i, 0, 0, j)),
        pl.BlockSpec((kh, kw, bc), lambda i, j: (0, 0, j)),
    ]
    operands = [xp, wp]
    if fuse_bias:
        in_specs.append(pl.BlockSpec((bc,), lambda i, j: (j,)))
        operands.append(pad_axis(bias, 0, bc))
    out = pl.pallas_call(
        functools.partial(_dw_kernel, kh=kh, kw=kw, stride=stride,
                          fuse_bias=fuse_bias, act=act),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ho, wo, bc), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cp), x.dtype),
        interpret=interpret,
    )(*operands)
    return out[..., :c]
