"""jit'd wrapper for the depthwise kernel with VMEM-aware channel blocking."""
from __future__ import annotations

import functools

import jax

from repro.kernels.depthwise.kernel import depthwise_conv2d

VMEM_BUDGET_BYTES = 8 * 1024 * 1024   # half of a v5e core's VMEM for x-tile


def pick_block_c(h: int, w: int, c: int, kh: int, kw: int,
                 bytes_per_elem: int = 4) -> int:
    """Largest channel block whose halo tile fits the VMEM budget — the
    Eq.2-style knob of the p-core port: T_c here plays the role of (n,v)."""
    tile = (h + kh - 1) * (w + kw - 1) * bytes_per_elem
    bc = max(8, VMEM_BUDGET_BYTES // max(tile, 1)) if tile else c
    bc = min(bc, c)
    # round down to a multiple of 8 (VPU sublane)
    return max(8, bc - bc % 8) if bc >= 8 else max(1, bc)


@functools.partial(jax.jit, static_argnames=("stride", "pad", "act",
                                             "interpret"))
def depthwise(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
              *, stride: int = 1, pad: int = 1, act: str | None = None,
              interpret: bool = True) -> jax.Array:
    n, h, wd, c = x.shape
    kh, kw, _ = w.shape
    bc = pick_block_c(h, wd, c, kh, kw)
    return depthwise_conv2d(x, w, bias, stride=stride, pad=pad, act=act,
                            block_c=bc, interpret=interpret)
