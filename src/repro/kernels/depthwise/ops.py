"""Dispatch wrapper for the depthwise kernel with VMEM-aware channel
blocking (autotuned per layer signature when a cache entry exists)."""
from __future__ import annotations

import jax

from repro.kernels import autotune
from repro.kernels.depthwise.kernel import depthwise_conv2d

VMEM_BUDGET_BYTES = 8 * 1024 * 1024   # half of a v5e core's VMEM for x-tile


def pick_block_c(h: int, w: int, c: int, kh: int, kw: int,
                 bytes_per_elem: int = 4) -> int:
    """Largest channel block whose halo tile fits the VMEM budget — the
    Eq.2-style knob of the p-core port: T_c here plays the role of (n,v)."""
    tile = (h + kh - 1) * (w + kw - 1) * bytes_per_elem
    bc = max(8, VMEM_BUDGET_BYTES // max(tile, 1)) if tile else c
    bc = min(bc, c)
    # round down to a multiple of 8 (VPU sublane)
    return max(8, bc - bc % 8) if bc >= 8 else max(1, bc)


def depthwise(x: jax.Array, w: jax.Array, bias: jax.Array | None = None,
              *, stride: int = 1, pad: int = 1, act: str | None = None,
              block_c: int | None = None,
              interpret: bool | None = None) -> jax.Array:
    n, h, wd, c = x.shape
    kh, kw, _ = w.shape
    if block_c is None:
        sig = autotune.LayerSig(kind="depthwise", H=h, W=wd, C_i=c, C_o=c,
                                K_h=kh, K_w=kw, stride=stride, pad=pad,
                                dtype=str(x.dtype))
        cfg = autotune.get_config(sig)
        block_c = cfg["block_c"] if cfg else pick_block_c(h, wd, c, kh, kw)
    return depthwise_conv2d(x, w, bias, stride=stride, pad=pad, act=act,
                            block_c=min(block_c, c), interpret=interpret)
