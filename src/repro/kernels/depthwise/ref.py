"""Pure-jnp oracle for the depthwise kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def depthwise_conv2d_ref(x: jax.Array, w: jax.Array,
                         bias: jax.Array | None = None, stride: int = 1,
                         pad: int = 1, act: str | None = None) -> jax.Array:
    """NHWC depthwise conv via lax with feature_group_count=C."""
    c = x.shape[-1]
    kh, kw, cw = w.shape
    assert cw == c
    w4 = w.reshape(kh, kw, 1, c)  # HWIO with I=1, groups=C
    out = jax.lax.conv_general_dilated(
        x.astype(jnp.float32), w4.astype(jnp.float32),
        window_strides=(stride, stride),
        padding=((pad, pad), (pad, pad)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c)
    if bias is not None:
        out = out + bias.astype(jnp.float32)
    if act == "relu":
        out = jnp.maximum(out, 0.0)
    elif act == "relu6":
        out = jnp.clip(out, 0.0, 6.0)
    return out.astype(x.dtype)
