"""Fused MobileNet-block kernels: dw(3x3) -> pw(1x1) (and pw-expand ->
dw -> pw-project) in a single pallas_call — the software analogue of the
dual-OPU's concurrent c-/p-core execution (DESIGN.md §3)."""
from repro.kernels.fused_block.ops import (fused_dw_pw,
                                           fused_inverted_residual)

__all__ = ["fused_dw_pw", "fused_inverted_residual"]
