"""Fused depthwise->pointwise Pallas kernels (DESIGN.md §3).

The paper's dual-OPU overlaps a communication-bound depthwise layer on the
p-core with the compute-bound pointwise layers on the c-core, keeping the
intermediate feature map on-chip.  The seed's software analogue did the
opposite: ``models/cnn.py`` round-tripped every activation through HBM
between the depthwise and pointwise kernels of a MobileNet block.  These
kernels run the whole block in ONE pallas_call per (image, C_out-tile):

  fused_dw_pw_conv      dw(KxK, stride s) -> pw(1x1)
  fused_pw_dw_pw_conv   pw-expand -> dw(KxK, stride s) -> pw-project
                        (MobileNet-v2 inverted residual, optional fused
                        residual add)

The depthwise result never leaves VMEM: at the first C_out tile of each
image the VPU computes the dw taps channel-block-by-channel-block from the
halo tile (p-core analogue) into a persistent float32 VMEM scratch; every
C_out tile then feeds that scratch to an MXU GEMM against its
pointwise-weight columns (c-core analogue).  The C_out grid dimension is
innermost, so the scratch survives across tiles and the dw pass runs once
per image.  HBM sees the block input once and the block output once.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.util import (apply_act as _act, pad_axis, pad_to,
                                resolve_interpret)


def _dw_tile(xc, w_ref, c0, bc, kh, kw, stride, ho, wo):
    """Depthwise conv of one VMEM channel block: (Hp, Wp, bc) -> f32
    (ho, wo, bc).  Every tap re-reads the same VMEM tile (line-buffer
    reuse, DESIGN.md §2)."""
    acc = jnp.zeros((ho, wo, bc), jnp.float32)
    for i in range(kh):
        for j in range(kw):
            tap = jax.lax.slice(
                xc, (i, j, 0),
                (i + (ho - 1) * stride + 1, j + (wo - 1) * stride + 1, bc),
                (stride, stride, 1))
            acc = acc + tap.astype(jnp.float32) * \
                w_ref[i, j, c0:c0 + bc].astype(jnp.float32)
    return acc


def _fused_dw_pw_kernel(x_ref, dw_w_ref, *rest, kh, kw, stride, bc, nc,
                        has_dw_b, has_pw_b, has_res, dw_act, pw_act):
    """Grid step (n, co): x_ref (1,Hp,Wp,Cp); dw_w_ref (kh,kw,Cp);
    optional dw_b (1,Cp) / pw_b (1,bn) / res (1,ho,wo,bn); pw_w (Cp,bn);
    o_ref (1,ho,wo,bn); dws_ref (ho*wo, Cp) f32 scratch.

    The depthwise result is computed channel-block-by-channel-block into
    the persistent VMEM scratch ONCE per image (co is the innermost grid
    dim, so the scratch survives across the C_out tiles) and every co step
    feeds it straight to the MXU — it never exists in HBM.
    """
    rest = list(rest)
    dw_b_ref = rest.pop(0) if has_dw_b else None
    pw_w_ref = rest.pop(0)
    pw_b_ref = rest.pop(0) if has_pw_b else None
    res_ref = rest.pop(0) if has_res else None
    o_ref, dws_ref = rest
    _, ho, wo, bn = o_ref.shape

    @pl.when(pl.program_id(1) == 0)
    def _compute_dw():
        x = x_ref[0]
        for cblk in range(nc):       # p-core analogue, one channel block
            c0 = cblk * bc           # of VMEM halo tile at a time
            xc = x[:, :, c0:c0 + bc]
            dw = _dw_tile(xc, dw_w_ref, c0, bc, kh, kw, stride, ho, wo)
            if dw_b_ref is not None:
                dw = dw + dw_b_ref[0, c0:c0 + bc].astype(jnp.float32)
            dws_ref[:, c0:c0 + bc] = _act(dw, dw_act).reshape(ho * wo, bc)

    out = jnp.dot(dws_ref[...], pw_w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if pw_b_ref is not None:
        out = out + pw_b_ref[...].astype(jnp.float32)
    out = _act(out, pw_act)
    out = out.reshape(ho, wo, bn)
    if res_ref is not None:
        out = out + res_ref[0].astype(jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "pad", "dw_act",
                                             "pw_act", "block_c", "block_n",
                                             "interpret"))
def fused_dw_pw_conv(x: jax.Array, dw_w: jax.Array,
                     dw_b: jax.Array | None, pw_w: jax.Array,
                     pw_b: jax.Array | None,
                     residual: jax.Array | None = None, *, stride: int = 1,
                     pad: int = 1, dw_act: str | None = "relu6",
                     pw_act: str | None = None, block_c: int = 64,
                     block_n: int = 128,
                     interpret: bool | None = None) -> jax.Array:
    """dw(KhxKw, stride) -> pw(1x1) in one pallas_call.

    x: (N,H,W,C); dw_w: (Kh,Kw,C); pw_w: (C,Co); biases (C,)/(Co,) or None;
    residual: (N,Ho,Wo,Co) or None (added after pw_act).
    """
    interpret = resolve_interpret(interpret)
    n, h, wd, c = x.shape
    kh, kw, cw = dw_w.shape
    assert cw == c and pw_w.shape[0] == c, (x.shape, dw_w.shape, pw_w.shape)
    co = pw_w.shape[1]
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (wd + 2 * pad - kw) // stride + 1
    bc = min(block_c, c)
    bn = min(block_n, max(co, 8))
    xp = pad_axis(jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0))),
                  3, bc)
    cp = xp.shape[3]
    hp, wp_ = xp.shape[1], xp.shape[2]
    dw_wp = pad_axis(dw_w, 2, bc)
    pw_wp = pad_to(pad_axis(pw_w, 0, bc), (cp, bn))
    cop = pw_wp.shape[1]
    grid = (n, cop // bn)
    in_specs = [
        pl.BlockSpec((1, hp, wp_, cp), lambda i, j: (i, 0, 0, 0)),
        pl.BlockSpec((kh, kw, cp), lambda i, j: (0, 0, 0)),
    ]
    operands: list[jax.Array] = [xp, dw_wp]
    if dw_b is not None:
        in_specs.append(pl.BlockSpec((1, cp), lambda i, j: (0, 0)))
        operands.append(pad_to(dw_b.reshape(1, c), (1, cp)))
    in_specs.append(pl.BlockSpec((cp, bn), lambda i, j: (0, j)))
    operands.append(pw_wp)
    if pw_b is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
        operands.append(pad_to(pw_b.reshape(1, co), (1, bn)))
    if residual is not None:
        assert residual.shape == (n, ho, wo, co), residual.shape
        in_specs.append(pl.BlockSpec((1, ho, wo, bn),
                                     lambda i, j: (i, 0, 0, j)))
        operands.append(pad_axis(residual, 3, bn))
    out = pl.pallas_call(
        functools.partial(_fused_dw_pw_kernel, kh=kh, kw=kw, stride=stride,
                          bc=bc, nc=cp // bc, has_dw_b=dw_b is not None,
                          has_pw_b=pw_b is not None,
                          has_res=residual is not None, dw_act=dw_act,
                          pw_act=pw_act),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ho, wo, bn), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cop), x.dtype),
        scratch_shapes=[pltpu.VMEM((ho * wo, cp), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[..., :co]


def _fused_pw_dw_pw_kernel(x_ref, exp_w_ref, *rest, kh, kw, stride, pad, bc,
                           nc, has_exp_b, has_dw_b, has_proj_b, has_res,
                           exp_act, dw_act, proj_act):
    """Grid step (n, co) of the inverted residual.

    x_ref (1,H,W,Ci); exp_w (Ci,Cmp); optional exp_b (1,Cmp);
    dw_w (kh,kw,Cmp); optional dw_b (1,Cmp); proj_w (Cmp,bn); optional
    proj_b (1,bn); optional res (1,ho,wo,bn); o_ref (1,ho,wo,bn);
    dws_ref (ho*wo,Cmp) f32 — expand+dw result, computed once per image
    (co innermost) and reused across C_out tiles; eb_ref (Hp,Wp,bc) f32 —
    the expanded map's halo tile, zero-padded in VMEM.  Neither the
    expanded map nor the dw result ever exists in HBM.
    """
    rest = list(rest)
    exp_b_ref = rest.pop(0) if has_exp_b else None
    dw_w_ref = rest.pop(0)
    dw_b_ref = rest.pop(0) if has_dw_b else None
    proj_w_ref = rest.pop(0)
    proj_b_ref = rest.pop(0) if has_proj_b else None
    res_ref = rest.pop(0) if has_res else None
    o_ref, dws_ref, eb_ref = rest
    _, ho, wo, bn = o_ref.shape
    _, h, wd, ci = x_ref.shape

    @pl.when(pl.program_id(1) == 0)
    def _compute_expand_dw():
        xm = x_ref[0].reshape(h * wd, ci)
        for cblk in range(nc):
            c0 = cblk * bc
            # pw-expand for this channel block (MXU), epilogue in f32
            e = jnp.dot(xm, exp_w_ref[:, c0:c0 + bc],
                        preferred_element_type=jnp.float32)
            if exp_b_ref is not None:
                e = e + exp_b_ref[0, c0:c0 + bc].astype(jnp.float32)
            e = _act(e, exp_act)
            # zero-padded halo tile of the expanded map, entirely in VMEM
            eb_ref[...] = jnp.zeros_like(eb_ref)
            eb_ref[pad:pad + h, pad:pad + wd, :] = e.reshape(h, wd, bc)
            dw = _dw_tile(eb_ref[...], dw_w_ref, c0, bc, kh, kw, stride,
                          ho, wo)
            if dw_b_ref is not None:
                dw = dw + dw_b_ref[0, c0:c0 + bc].astype(jnp.float32)
            dws_ref[:, c0:c0 + bc] = _act(dw, dw_act).reshape(ho * wo, bc)

    out = jnp.dot(dws_ref[...], proj_w_ref[...].astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    if proj_b_ref is not None:
        out = out + proj_b_ref[...].astype(jnp.float32)
    out = _act(out, proj_act)
    out = out.reshape(ho, wo, bn)
    if res_ref is not None:
        out = out + res_ref[0].astype(jnp.float32)
    o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("stride", "pad", "exp_act",
                                             "dw_act", "proj_act", "block_c",
                                             "block_n", "interpret"))
def fused_pw_dw_pw_conv(x: jax.Array, exp_w: jax.Array,
                        exp_b: jax.Array | None, dw_w: jax.Array,
                        dw_b: jax.Array | None, proj_w: jax.Array,
                        proj_b: jax.Array | None,
                        residual: jax.Array | None = None, *,
                        stride: int = 1, pad: int = 1,
                        exp_act: str | None = "relu6",
                        dw_act: str | None = "relu6",
                        proj_act: str | None = None, block_c: int = 64,
                        block_n: int = 128,
                        interpret: bool | None = None) -> jax.Array:
    """pw-expand -> dw(KhxKw, stride) -> pw-project in one pallas_call
    (MobileNet-v2 inverted residual; ``residual`` is fused into the
    epilogue when given).

    x: (N,H,W,Ci); exp_w: (Ci,Cm); dw_w: (Kh,Kw,Cm); proj_w: (Cm,Co).
    """
    interpret = resolve_interpret(interpret)
    n, h, wd, ci = x.shape
    cm = exp_w.shape[1]
    kh, kw, cmw = dw_w.shape
    assert exp_w.shape[0] == ci and cmw == cm and proj_w.shape[0] == cm
    co = proj_w.shape[1]
    ho = (h + 2 * pad - kh) // stride + 1
    wo = (wd + 2 * pad - kw) // stride + 1
    bc = min(block_c, cm)
    bn = min(block_n, max(co, 8))
    exp_wp = pad_axis(exp_w, 1, bc)
    cmp_ = exp_wp.shape[1]
    dw_wp = pad_axis(dw_w, 2, bc)
    proj_wp = pad_to(pad_axis(proj_w, 0, bc), (cmp_, bn))
    cop = proj_wp.shape[1]
    hp, wp_ = h + 2 * pad, wd + 2 * pad
    grid = (n, cop // bn)
    in_specs = [
        pl.BlockSpec((1, h, wd, ci), lambda i, j: (i, 0, 0, 0)),
        pl.BlockSpec((ci, cmp_), lambda i, j: (0, 0)),
    ]
    operands: list[jax.Array] = [x, exp_wp]
    if exp_b is not None:
        in_specs.append(pl.BlockSpec((1, cmp_), lambda i, j: (0, 0)))
        operands.append(pad_to(exp_b.reshape(1, cm), (1, cmp_)))
    in_specs.append(pl.BlockSpec((kh, kw, cmp_), lambda i, j: (0, 0, 0)))
    operands.append(dw_wp)
    if dw_b is not None:
        in_specs.append(pl.BlockSpec((1, cmp_), lambda i, j: (0, 0)))
        operands.append(pad_to(dw_b.reshape(1, cm), (1, cmp_)))
    in_specs.append(pl.BlockSpec((cmp_, bn), lambda i, j: (0, j)))
    operands.append(proj_wp)
    if proj_b is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j: (0, j)))
        operands.append(pad_to(proj_b.reshape(1, co), (1, bn)))
    if residual is not None:
        assert residual.shape == (n, ho, wo, co), residual.shape
        in_specs.append(pl.BlockSpec((1, ho, wo, bn),
                                     lambda i, j: (i, 0, 0, j)))
        operands.append(pad_axis(residual, 3, bn))
    out = pl.pallas_call(
        functools.partial(_fused_pw_dw_pw_kernel, kh=kh, kw=kw,
                          stride=stride, pad=pad, bc=bc, nc=cmp_ // bc,
                          has_exp_b=exp_b is not None,
                          has_dw_b=dw_b is not None,
                          has_proj_b=proj_b is not None,
                          has_res=residual is not None, exp_act=exp_act,
                          dw_act=dw_act, proj_act=proj_act),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, ho, wo, bn), lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cop), x.dtype),
        scratch_shapes=[pltpu.VMEM((ho * wo, cmp_), jnp.float32),
                        pltpu.VMEM((hp, wp_, bc), jnp.float32)],
        interpret=interpret,
    )(*operands)
    return out[..., :co]
