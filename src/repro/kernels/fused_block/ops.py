"""Dispatch wrappers for the fused MobileNet-block kernels.

Block shapes (channel k-block ``block_c``, output-channel tile ``block_n``)
come from the autotune cache when a tuned entry exists for the layer
signature, else from the per-kind heuristic (DESIGN.md §4).
"""
from __future__ import annotations

import jax

from repro.kernels import autotune
from repro.kernels.fused_block.kernel import (fused_dw_pw_conv,
                                              fused_pw_dw_pw_conv)


def _cfg(kind: str, x: jax.Array, c_i: int, c_o: int, kh: int, kw: int,
         stride: int, pad: int, block_c, block_n) -> tuple[int, int]:
    if block_c is not None and block_n is not None:
        return block_c, block_n
    sig = autotune.LayerSig(kind=kind, H=x.shape[1], W=x.shape[2], C_i=c_i,
                            C_o=c_o, K_h=kh, K_w=kw, stride=stride, pad=pad,
                            dtype=str(x.dtype))
    cfg = autotune.get_config(sig) or autotune.heuristic_config(sig)
    return (block_c or cfg["block_c"], block_n or cfg["block_n"])


def fused_dw_pw(x: jax.Array, dw_w: jax.Array, dw_b, pw_w: jax.Array, pw_b,
                residual=None, *, stride: int = 1, pad: int = 1,
                dw_act: str | None = "relu6", pw_act: str | None = None,
                block_c: int | None = None, block_n: int | None = None,
                interpret: bool | None = None) -> jax.Array:
    """dw(KhxKw) -> pw(1x1) fused block.  pw_w: (C,Co) or (1,1,C,Co)."""
    if pw_w.ndim == 4:
        pw_w = pw_w.reshape(pw_w.shape[2], pw_w.shape[3])
    kh, kw, c = dw_w.shape
    bc, bn = _cfg("fused_dw_pw", x, c, pw_w.shape[1], kh, kw, stride, pad,
                  block_c, block_n)
    return fused_dw_pw_conv(x, dw_w, dw_b, pw_w, pw_b, residual,
                            stride=stride, pad=pad, dw_act=dw_act,
                            pw_act=pw_act, block_c=bc, block_n=bn,
                            interpret=interpret)


def fused_inverted_residual(x: jax.Array, exp_w: jax.Array, exp_b,
                            dw_w: jax.Array, dw_b, proj_w: jax.Array,
                            proj_b, residual=None, *, stride: int = 1,
                            pad: int = 1, exp_act: str | None = "relu6",
                            dw_act: str | None = "relu6",
                            proj_act: str | None = None,
                            block_c: int | None = None,
                            block_n: int | None = None,
                            interpret: bool | None = None) -> jax.Array:
    """pw-expand -> dw -> pw-project fused block (MobileNet-v2 style).

    exp_w: (Ci,Cm) or (1,1,Ci,Cm); proj_w: (Cm,Co) or (1,1,Cm,Co).
    """
    if exp_w.ndim == 4:
        exp_w = exp_w.reshape(exp_w.shape[2], exp_w.shape[3])
    if proj_w.ndim == 4:
        proj_w = proj_w.reshape(proj_w.shape[2], proj_w.shape[3])
    kh, kw, cm = dw_w.shape
    bc, bn = _cfg("fused_pw_dw_pw", x, cm, proj_w.shape[1], kh, kw, stride,
                  pad, block_c, block_n)
    return fused_pw_dw_pw_conv(x, exp_w, exp_b, dw_w, dw_b, proj_w, proj_b,
                               residual, stride=stride, pad=pad,
                               exp_act=exp_act, dw_act=dw_act,
                               proj_act=proj_act, block_c=bc, block_n=bn,
                               interpret=interpret)
