"""Pure-jnp oracles for the fused-block kernels: the composed unfused
reference ops (depthwise then pointwise), exactly what the fused kernels
must reproduce."""
from __future__ import annotations

from repro.kernels.conv_gemm.ref import conv2d_ref
from repro.kernels.depthwise.ref import depthwise_conv2d_ref


def fused_dw_pw_ref(x, dw_w, dw_b, pw_w, pw_b, residual=None, *,
                    stride=1, pad=1, dw_act="relu6", pw_act=None):
    h = depthwise_conv2d_ref(x, dw_w, dw_b, stride=stride, pad=pad,
                             act=dw_act)
    c, co = pw_w.shape
    out = conv2d_ref(h, pw_w.reshape(1, 1, c, co), pw_b, stride=1, pad=0,
                     act=pw_act)
    if residual is not None:
        out = out + residual
    return out


def fused_pw_dw_pw_ref(x, exp_w, exp_b, dw_w, dw_b, proj_w, proj_b,
                       residual=None, *, stride=1, pad=1, exp_act="relu6",
                       dw_act="relu6", proj_act=None):
    ci, cm = exp_w.shape
    h = conv2d_ref(x, exp_w.reshape(1, 1, ci, cm), exp_b, stride=1, pad=0,
                   act=exp_act)
    h = depthwise_conv2d_ref(h, dw_w, dw_b, stride=stride, pad=pad,
                             act=dw_act)
    co = proj_w.shape[1]
    out = conv2d_ref(h, proj_w.reshape(1, 1, cm, co), proj_b, stride=1,
                     pad=0, act=proj_act)
    if residual is not None:
        out = out + residual
    return out
