"""Fused RMSNorm Pallas kernel (used by every assigned LM arch).

One pass over VMEM row blocks: mean-of-squares reduce + rsqrt + scale in a
single kernel, instead of XLA's reduce -> broadcast -> mul chain that round-
trips HBM.  Rows map to the grid, the feature dim stays whole in VMEM
(d_model <= 12288 -> 48 KiB/row fp32, fine).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.util import resolve_interpret


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w_ref[...]).astype(
        o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps", "block_rows",
                                             "interpret"))
def rmsnorm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
            block_rows: int = 256,
            interpret: bool | None = None) -> jax.Array:
    """x: (..., d); w: (d,)."""
    interpret = resolve_interpret(interpret)
    shape = x.shape
    d = shape[-1]
    rows = 1
    for s in shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)
    br = min(block_rows, max(rows, 1))
    rp = -rows % br
    xp = jnp.pad(x2, ((0, rp), (0, 0)))
    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=((rows + rp) // br,),
        in_specs=[pl.BlockSpec((br, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows + rp, d), x.dtype),
        interpret=interpret,
    )(xp, w.reshape(1, d))
    return out[:rows].reshape(shape)
