"""jit'd RMSNorm entry point with XLA fallback (dry-run path)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.rmsnorm.kernel import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.jit, static_argnames=("eps", "use_pallas",
                                             "interpret"))
def rms_norm(x: jax.Array, w: jax.Array, *, eps: float = 1e-6,
             use_pallas: bool = False,
             interpret: bool | None = None) -> jax.Array:
    if use_pallas:
        return rmsnorm(x, w, eps=eps, interpret=interpret)
    return rmsnorm_ref(x, w, eps)


__all__ = ["rms_norm", "rmsnorm", "rmsnorm_ref"]
