"""Pure-jnp oracle for the rmsnorm kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * w.astype(jnp.float32)).astype(
        x.dtype)
