"""Shared helpers for the Pallas kernel packages.

Every kernel wrapper needs the same three things: ceil-division for grids,
zero-padding up to block multiples (so BlockSpec grids divide evenly), and a
backend-aware default for Pallas ``interpret`` mode — interpret on CPU (this
container / CI), compiled on a real TPU.  They live here so conv_gemm /
depthwise / fused_block / attention / rmsnorm stay in sync (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def cdiv(a: int, b: int) -> int:
    """Ceiling division (grid sizing)."""
    return -(-a // b)


def pad_to(x: jax.Array, mult: tuple[int, ...]) -> jax.Array:
    """Zero-pad each leading axis of ``x`` up to a multiple of ``mult[i]``.

    ``mult`` may be shorter than ``x.ndim``; trailing axes are left alone.
    """
    pads = [(0, -s % m) for s, m in zip(x.shape, mult)]
    pads += [(0, 0)] * (x.ndim - len(pads))
    if any(p[1] for p in pads):
        x = jnp.pad(x, pads)
    return x


def pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    """Zero-pad a single axis of ``x`` up to a multiple of ``mult``."""
    extra = -x.shape[axis] % mult
    if not extra:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, extra)
    return jnp.pad(x, pads)


def apply_act(x: jax.Array, act: str | None) -> jax.Array:
    """The shared fused-epilogue activation (None | 'relu' | 'relu6')."""
    if act == "relu":
        return jnp.maximum(x, 0.0)
    if act == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    return x


def bench_best_us(fn, reps: int = 3) -> float:
    """Best-of-``reps`` wall-clock of ``fn`` in microseconds, after one
    warm-up call (compile).  The one timing rule shared by the autotuner
    and the --smoke benchmark, so both rank kernels identically."""
    import time
    jax.block_until_ready(fn())            # compile / warm-up
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def default_interpret() -> bool:
    """Pallas interpret-mode default: compiled on TPU, interpret elsewhere.

    All kernel wrappers take ``interpret: bool | None = None`` and resolve
    ``None`` through here, so a real-TPU run is fast by default while the
    CPU CI keeps validating the kernel bodies in interpret mode.  These
    kernels use TPU-specific scratch/memory spaces (pltpu.*), so any
    non-TPU backend (CPU *or* GPU) must interpret.
    """
    return jax.default_backend() != "tpu"


def resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else bool(interpret)
