"""Multi-pod dry-run (assignment deliverable e).

For every (architecture x input-shape x mesh) cell:
  jax.jit(step, in_shardings=..., out_shardings=...)
      .lower(**input_specs(arch, shape))  ->  .compile()
then record memory_analysis() (fits 16 GB/chip), cost_analysis() FLOPs /
bytes, and the collective bytes parsed from the compiled HLO (with while-
loop trip-count attribution) — the inputs to EXPERIMENTS.md §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2_5_14b --shape train_4k \
      --mesh single --out results/
  python -m repro.launch.dryrun --all --mesh both   # every live cell
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede the jax import (jax locks the device count on first init).
#   512 host devices back both the 16x16 single-pod and the 2x16x16
#   multi-pod production meshes.  Set here (and only here): smoke tests and
#   benches see 1 device.

import argparse
import json
import math
import sys
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCH_IDS, SHAPES, cells, get_arch
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_spec, cache_specs, param_specs,
                                   sanitize, to_shardings)
from repro.lm.config import ArchConfig
from repro.lm.model import decode_step, init_cache, init_params
from repro.lm.steps import TrainState, make_train_step
from repro.meshcompat import use_mesh
from repro.train.optimizer import AdamW

HBM_PER_CHIP = 16 * 1024 ** 3          # v5e
PEAK_FLOPS = 197e12                     # bf16 / chip
HBM_BW = 819e9                          # bytes/s / chip
ICI_BW = 50e9                           # bytes/s/link (~per chip effective)


# --------------------------------------------------------------------------
# Shape-policy helpers
# --------------------------------------------------------------------------
def microbatches_for(cfg: ArchConfig, batch: int,
                     data_size: int = 16) -> int:
    if cfg.d_model >= 8192:
        mb = 16
    elif cfg.d_model >= 4096:
        mb = 8
    elif cfg.d_model >= 2048:
        mb = 4
    else:
        mb = 2
    mb = min(mb, max(1, batch // data_size))   # keep b/mb shardable
    while batch % mb:
        mb //= 2
    return max(1, mb)


def sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def input_specs(cfg: ArchConfig, shape_name: str, mesh):
    """ShapeDtypeStruct stand-ins for every model input of the cell —
    weak-type-correct, shardable, no device allocation."""
    seq, batch, kind = SHAPES[shape_name]
    bspec = batch_spec(mesh, 2)
    bshard = NamedSharding(mesh, sanitize((batch, seq), bspec, mesh))
    out = {}
    if kind == "train":
        out["tokens"] = sds((batch, seq), jnp.int32, bshard)
        out["labels"] = sds((batch, seq), jnp.int32, bshard)
    else:
        s_tok = seq if kind == "prefill" else 1
        tshard = NamedSharding(
            mesh, sanitize((batch, s_tok), batch_spec(mesh, 2), mesh))
        out["tokens"] = sds((batch, s_tok), jnp.int32, tshard)
    if cfg.mrope:
        s_tok = seq if kind in ("train", "prefill") else 1
        p3 = sanitize((batch, 3, s_tok), batch_spec(mesh, 3), mesh)
        out["positions3"] = sds((batch, 3, s_tok), jnp.int32,
                                NamedSharding(mesh, p3))
    if cfg.encoder_decoder and kind == "train":
        es = sanitize((batch, cfg.enc_positions, cfg.d_model),
                      batch_spec(mesh, 3), mesh)
        out["enc_input"] = sds((batch, cfg.enc_positions, cfg.d_model),
                               jnp.bfloat16, NamedSharding(mesh, es))
    if cfg.frontend == "vision" and kind == "train":
        n_patch = 256        # stub: 256 patch embeddings per sample
        es = sanitize((batch, n_patch, cfg.d_model),
                      batch_spec(mesh, 3), mesh)
        out["extra_embeds"] = sds((batch, n_patch, cfg.d_model),
                                  jnp.bfloat16, NamedSharding(mesh, es))
    return out, kind, seq, batch


def abstract_state(cfg: ArchConfig, mesh, dtype=jnp.bfloat16, policy=None):
    """TrainState ShapeDtypeStructs with shardings attached."""
    from repro.launch.sharding import DEFAULT_POLICY
    policy = policy or DEFAULT_POLICY
    opt = AdamW()
    def init(key):
        p = init_params(cfg, key, dtype)
        return TrainState(p, opt.init(p), jnp.zeros((), jnp.int32))
    shape_tree = jax.eval_shape(init, jax.ShapeDtypeStruct((2,), jnp.uint32))
    fallbacks: list = []
    pspecs = param_specs(shape_tree.params, mesh, fallbacks, policy)
    from repro.launch.sharding import zero1_specs
    from repro.train.optimizer import AdamWState
    ospecs = (zero1_specs(shape_tree.params, mesh)
              if getattr(policy, "zero1", False) else pspecs)
    state_specs = TrainState(
        pspecs, AdamWState(step=P(), m=ospecs, v=ospecs), P())
    shardings = to_shardings(state_specs, mesh)
    with_sh = jax.tree.map(
        lambda s, sh: sds(s.shape, s.dtype, sh), shape_tree, shardings)
    return with_sh, shardings, fallbacks


def abstract_cache(cfg: ArchConfig, batch, max_len, mesh,
                   dtype=jnp.bfloat16, kv_dtype=None):
    act_dtype = jnp.bfloat16
    kvd = dtype if kv_dtype is None else kv_dtype

    def init(_):
        memory = params = None
        if cfg.encoder_decoder:
            # cross-KV needs params + memory; approximate with eval_shape
            from repro.lm.model import init_params as ip
            params = ip(cfg, jax.random.PRNGKey(0), act_dtype)
            memory = jnp.zeros((batch, cfg.enc_positions, cfg.d_model),
                               act_dtype)
        return init_cache(cfg, batch, max_len, act_dtype, memory=memory,
                          params=params, kv_dtype=kvd)
    shape_tree = jax.eval_shape(init, 0)
    fallbacks: list = []
    cspecs = cache_specs(shape_tree, mesh, fallbacks)
    shardings = to_shardings(cspecs, mesh)
    with_sh = jax.tree.map(
        lambda s, sh: sds(s.shape, s.dtype, sh) if s is not None else None,
        shape_tree, shardings, is_leaf=lambda x: x is None)
    return with_sh, shardings, fallbacks


# --------------------------------------------------------------------------
# Analytic MODEL_FLOPS
# --------------------------------------------------------------------------
def model_flops(cfg: ArchConfig, kind: str, seq: int, batch: int) -> float:
    n = cfg.active_param_count()
    if kind == "train":
        tokens = batch * seq
        base = 6.0 * n * tokens
        attn = 0.0
        if cfg.block_type == "transformer":
            attn = 12.0 * cfg.n_layers * batch * seq * seq * cfg.q_dim
        return base + attn
    if kind == "prefill":
        tokens = batch * seq
        base = 2.0 * n * tokens
        attn = 0.0
        if cfg.block_type == "transformer":
            attn = 4.0 * cfg.n_layers * batch * seq * seq * cfg.q_dim
        return base + attn
    # decode: one token per sequence + KV/state read
    base = 2.0 * n * batch
    attn = 0.0
    if cfg.block_type == "transformer":
        attn = 4.0 * cfg.n_layers * batch * seq * cfg.q_dim
    return base + attn


# --------------------------------------------------------------------------
# Cell runner
# --------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, policy=None,
             microbatches: int | None = None,
             kv_dtype=None) -> dict:
    """``policy`` / ``microbatches`` / ``kv_dtype`` are the §Perf hillclimb
    knobs; None selects the paper-baseline defaults."""
    cfg = get_arch(arch)
    from repro.lm import pshard
    pshard.set_dp_only(bool(policy and policy.dp_only))
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = math.prod(mesh.shape.values())
    inputs, kind, seq, batch = input_specs(cfg, shape_name, mesh)
    fallbacks: list = []
    t0 = time.time()

    if kind == "train":
        state_sds, state_sh, fb = abstract_state(cfg, mesh, policy=policy)
        fallbacks += fb
        mb = microbatches or microbatches_for(
            cfg, batch, 32 if multi_pod else 16)
        opt = AdamW()

        def constrain_mb(tree):
            def c(x):
                from repro.launch.sharding import batch_axes
                full = (None, batch_axes(mesh)) + (None,) * (x.ndim - 2)
                spec = sanitize(x.shape, P(*full), mesh)
                return jax.lax.with_sharding_constraint(
                    x, NamedSharding(mesh, spec))
            return jax.tree.map(c, tree)

        step = make_train_step(
            cfg, opt, microbatches=mb, constrain_mb=constrain_mb,
            grad_dtype=(jnp.bfloat16 if policy is not None
                        and getattr(policy, "grads_bf16", False)
                        else None))
        batch_tree = inputs
        jitted = jax.jit(step, donate_argnums=(0,))
        with use_mesh(mesh):            # ambient mesh for pshard hints
            lowered = jitted.lower(state_sds, batch_tree)
    else:
        max_len = seq if kind != "prefill" else seq
        cache_sds, cache_sh, fb = abstract_cache(
            cfg, batch, max_len, mesh, kv_dtype=kv_dtype)
        fallbacks += fb
        state_sds, state_sh, fb2 = abstract_state(cfg, mesh, policy=policy)
        fallbacks += fb2
        params_sds = state_sds.params

        if cfg.mrope:
            def step(params, token, cache, positions3):
                return decode_step(params, cfg, token, cache,
                                   positions3=positions3)
            args = (params_sds, inputs["tokens"], cache_sds,
                    inputs["positions3"])
        else:
            def step(params, token, cache):
                return decode_step(params, cfg, token, cache)
            args = (params_sds, inputs["tokens"], cache_sds)
        jitted = jax.jit(step, donate_argnums=(2,))
        with use_mesh(mesh):            # ambient mesh for pshard hints
            lowered = jitted.lower(*args)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    cost_flops = float(cost.get("flops", 0.0))
    cost_bytes = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = lowered.as_text()
    stats = analyze_hlo(hlo)

    # Per-device quantities (post-SPMD HLO shapes are shards).  cost_*
    # counts while bodies once; our parser attributes trips for dot flops
    # and collectives.  Bytes get the first-order loop correction by the
    # flops ratio (same bodies dominate both) — recorded as an estimate.
    flops_dev = stats["flops_per_device"]
    loop_corr = (flops_dev / cost_flops) if cost_flops > 0 else 1.0
    bytes_dev = cost_bytes * max(1.0, loop_corr)
    coll_dev = stats["collective_bytes_per_device"]

    per_dev_bytes = None
    if mem is not None:
        try:
            per_dev_bytes = int(mem.temp_size_in_bytes
                                + mem.argument_size_in_bytes
                                + mem.output_size_in_bytes
                                - mem.alias_size_in_bytes)
        except Exception:
            per_dev_bytes = None

    mf = model_flops(cfg, kind, seq, batch)
    t_comp = flops_dev / PEAK_FLOPS if flops_dev else None
    t_mem = bytes_dev / HBM_BW if bytes_dev else None
    t_coll = coll_dev / ICI_BW if coll_dev else 0.0

    result = {
        "arch": arch, "shape": shape_name, "kind": kind,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "chips": chips, "seq": seq, "batch": batch,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "hlo_flops_per_device": flops_dev,
        "hlo_flops_global": flops_dev * chips,
        "hlo_bytes_per_device": bytes_dev,
        "cost_analysis_flops": cost_flops,
        "cost_analysis_bytes": cost_bytes,
        "loop_correction": loop_corr,
        "collective_bytes_per_device": coll_dev,
        "collectives": stats["collective_bytes_by_op"],
        "collective_counts": stats["collective_counts"],
        "model_flops": mf,
        "useful_flops_ratio": (mf / (flops_dev * chips))
        if flops_dev else None,
        "per_device_bytes": per_dev_bytes,
        "fits_hbm": (per_dev_bytes is not None
                     and per_dev_bytes < HBM_PER_CHIP),
        "t_compute_s": t_comp, "t_memory_s": t_mem,
        "t_collective_s": t_coll,
        "sharding_fallbacks": sorted({f"{a}@{d}" for (s, a, d)
                                      in fallbacks})[:12],
        "ok": True,
    }
    if verbose:
        dom = max((k for k in ("t_compute_s", "t_memory_s",
                               "t_collective_s")
                   if result[k] is not None),
                  key=lambda k: result[k] or 0)
        print(f"[dryrun] {arch} {shape_name} {result['mesh']} "
              f"compile={t_compile:.0f}s flops/dev={flops_dev:.3e} "
              f"bytes/dev={bytes_dev:.3e} coll/dev={coll_dev:.3e} "
              f"dominant={dom} perdev_hbm={per_dev_bytes}")
        if mem is not None:
            print(f"  memory_analysis: {mem}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    todo = []
    if args.all:
        todo = cells()
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        todo = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for arch, shape in todo:
        for mp in meshes:
            tag = f"{arch}.{shape}.{'multi' if mp else 'single'}"
            path = os.path.join(args.out, tag + ".json")
            try:
                res = run_cell(arch, shape, mp)
            except Exception as e:  # noqa: BLE001 - record and continue
                failures += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": "multi" if mp else "single",
                       "ok": False, "error": f"{type(e).__name__}: {e}"}
                print(f"[dryrun] FAIL {tag}: {res['error']}",
                      file=sys.stderr)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
