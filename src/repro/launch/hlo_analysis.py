"""Post-SPMD HLO analysis for the roofline terms.

The CPU backend's ``compiled.cost_analysis()`` counts each while body ONCE
(scan trip counts are ignored), so we parse the compiled HLO text ourselves.

Attribution uses instruction metadata, which is exact:
  * every ``while`` op carries ``backend_config={"known_trip_count":
    {"n": "24"}}`` and an ``op_name`` path;
  * an instruction nested in that loop has an ``op_name`` that extends the
    while's path with ``/body``;
  * an instruction's execution count is the product of trip counts of all
    whiles whose ``op_name + "/body"`` prefixes its own op_name.

FLOPs: ``2 * out_elems * contracting_size`` per dot; operand shapes come
from a global symbol table (name -> shape) built from definition lines.
Collectives: output bytes of all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute.

Shapes in post-SPMD HLO are per-device shards, so everything here is
per-device; multiply by chip count for global numbers.
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
                "f32": 4, "s32": 4, "u32": 4, "f64": 8, "s64": 8,
                "u64": 8, "c64": 8, "c128": 16}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_OPNAME_RE = re.compile(r'op_name="([^"]+)"')
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def _dims(dims: str) -> list[int]:
    return [int(d) for d in dims.split(",") if d]


def _elems(dims: str) -> int:
    n = 1
    for d in _dims(dims):
        n *= d
    return n


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    return m.groups() if m else None


class HloIndex:
    def __init__(self, hlo: str):
        self.shapes: dict[str, tuple[str, str]] = {}
        self.lines: list[str] = hlo.splitlines()
        # op_name -> trip count.  Deduplicated: several while instructions
        # (e.g. parallel scans over k and v) share one op_name path; an
        # instruction nested in that path runs `trip` times total, not
        # trip^k (observed 96x flop over-attribution before the dedupe).
        wd: dict[str, int] = {}
        for ln in self.lines:
            d = _DEF_RE.match(ln)
            if d:
                sh = _first_shape(d.group(2))
                if sh:
                    self.shapes[d.group(1)] = sh
            if " while(" in ln:
                op = _OPNAME_RE.search(ln)
                trip = _TRIP_RE.search(ln)
                if op and trip:
                    t = int(trip.group(1))
                    wd[op.group(1)] = max(wd.get(op.group(1), 1), t)
        self.whiles: list[tuple[str, int]] = sorted(wd.items())

    def multiplier(self, op_name: str | None) -> int:
        if not op_name:
            return 1
        m = 1
        for wname, trip in self.whiles:
            if op_name.startswith(wname + "/body"):
                m *= trip
        return m


def _dot_flops(line: str, idx: HloIndex) -> float:
    rhs = line.partition("=")[2]
    out = _first_shape(rhs.split(" dot(")[0])
    if out is None:
        return 0.0
    out_elems = _elems(out[1])
    inside = rhs.split(" dot(", 1)[1]
    ops = re.findall(r"%([\w\.\-]+)", inside.split(")")[0])
    cdims = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", rhs)
    k = 1
    if ops and cdims and ops[0] in idx.shapes:
        dims = _dims(idx.shapes[ops[0]][1])
        for ci in _dims(cdims.group(1)):
            if ci < len(dims):
                k *= dims[ci]
    return 2.0 * out_elems * k


def analyze_hlo(hlo: str) -> dict:
    """Per-device dot flops, collective bytes/counts, loop-attributed."""
    idx = HloIndex(hlo)
    flops = 0.0
    conv_flops = 0.0
    coll_bytes = {op: 0.0 for op in COLLECTIVES}
    coll_count = {op: 0 for op in COLLECTIVES}
    for ln in idx.lines:
        interesting = " dot(" in ln or " convolution(" in ln or any(
            f" {op}(" in ln or f" {op}-start(" in ln for op in COLLECTIVES)
        if not interesting:
            continue
        op_name = None
        m = _OPNAME_RE.search(ln)
        if m:
            op_name = m.group(1)
        mult = idx.multiplier(op_name)
        if " dot(" in ln:
            flops += mult * _dot_flops(ln, idx)
            continue
        if " convolution(" in ln:
            # rough: 2 * out_elems * (kernel_elems_per_output); use output
            # elems * 2 * contracting estimated from operand 1 if known
            rhs = ln.partition("=")[2]
            out = _first_shape(rhs.split(" convolution(")[0])
            if out:
                ops = re.findall(r"%([\w\.\-]+)",
                                 rhs.split("convolution(", 1)[1])
                k = 1
                if len(ops) > 1 and ops[1] in idx.shapes:
                    kd = _dims(idx.shapes[ops[1]][1])
                    k = max(1, _elems(idx.shapes[ops[1]][1])
                            // max(1, kd[-1]))
                conv_flops += mult * 2.0 * _elems(out[1]) * k
            continue
        for op in COLLECTIVES:
            if f" {op}(" in ln or f" {op}-start(" in ln:
                lhs_type = ln.partition("=")[2].split(f" {op}")[0]
                b = 0
                for dt, dims in _SHAPE_RE.findall(lhs_type):
                    b += _elems(dims) * _DTYPE_BYTES.get(dt, 0)
                coll_bytes[op] += mult * b
                coll_count[op] += mult
                break
    return {"flops_per_device": flops + conv_flops,
            "collective_bytes_per_device": sum(coll_bytes.values()),
            "collective_bytes_by_op": coll_bytes,
            "collective_counts": coll_count,
            "n_whiles": len(idx.whiles)}
