"""Production mesh construction (assignment MULTI-POD DRY-RUN step 1).

A function, not a module-level constant, so importing this module never
touches jax device state.  Shapes:
  single pod:  (data=16, model=16)           — 256 chips (one v5e pod)
  multi pod:   (pod=2, data=16, model=16)    — 512 chips

The 'pod' axis carries only data parallelism (gradient all-reduce across the
slower inter-pod links); params are replicated across pods and FSDP-sharded
over 'data' within a pod (DESIGN.md §5).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: tuple[int, ...], axes: tuple[str, ...]):
    """Arbitrary mesh for tests / elastic re-meshing."""
    return jax.make_mesh(shape, axes)


def data_axes(mesh) -> tuple[str, ...]:
    """Axes that carry the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
