"""Analytic HBM-traffic model for the roofline memory term.

The CPU backend's ``cost_analysis()['bytes accessed']`` counts every
operand of every op as if nothing fused (observed ~1000x over true HBM
traffic), so the §Roofline memory term uses this analytic model instead;
the XLA number is kept in the dry-run JSON as ``cost_analysis_bytes``
(upper bound).  Counting discipline (per device, per step):

  train:   weights stream fwd + remat-recompute + bwd (3x) per microbatch;
           grads/optimizer state read+write in fp32; activation residual
           traffic ~ 12 bytes/token/layer/d_model (bf16 in+out per block,
           norm reads, remat saves).
  prefill: weights once; activations 6 B/token/layer/d; KV write.
  decode:  weights + whole KV/state read per token; activations negligible.
"""
from __future__ import annotations

from repro.lm.config import ArchConfig


def hbm_bytes_per_device(cfg: ArchConfig, kind: str, seq: int, batch: int,
                         chips: int, microbatches: int = 1,
                         kv_bytes_per_elem: float = 2.0) -> float:
    n_act = cfg.active_param_count()
    w_bf16 = 2.0 * n_act
    d, L = cfg.d_model, cfg.n_layers
    if kind == "train":
        tokens = batch * seq
        weights = 3.0 * w_bf16 * microbatches / chips
        opt = (2.0 + 3 * 4.0 + 2 * 4.0) * cfg.param_count() / chips
        acts = 12.0 * tokens * d * L / chips
        return weights + opt + acts
    if kind == "prefill":
        tokens = batch * seq
        weights = w_bf16 / chips
        acts = 6.0 * tokens * d * L / chips
        kv = (2.0 * L * batch * cfg.n_kv_heads * cfg.d_head * seq
              * kv_bytes_per_elem / chips
              if cfg.block_type == "transformer" else 0.0)
        return weights + acts + kv
    # decode / long-decode: one token per sequence
    weights = w_bf16 / chips
    kv = 0.0
    if cfg.block_type == "transformer" or cfg.attn_every:
        layers = (L if cfg.block_type == "transformer"
                  else L // max(1, cfg.attn_every))
        kv = (2.0 * layers * batch * cfg.n_kv_heads * cfg.d_head * seq
              * kv_bytes_per_elem) / chips
    if cfg.block_type in ("mamba2", "mlstm"):
        din = cfg.d_inner
        hp = din // max(1, cfg.ssm_heads)
        state = L * batch * cfg.ssm_heads * hp * max(cfg.ssm_state, hp) * 4
        kv += 2.0 * state / chips
    return weights + kv
