"""Serving launcher: dual-mesh (the paper's feature) or single-mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --requests 4 --prompt-len 16 --gen 8 [--theta 0.5 | --search]

With --search, the §V-B design flow picks theta and the TP widths for the
workload before launching; the realised schedule trace is printed.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_arch, get_smoke
from repro.dualmesh import (DualMeshRunner, TpuModel, request_stages,
                            search, split_mesh)
from repro.lm.model import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--search", action="store_true",
                    help="run the design-flow search for theta/tp first")
    ap.add_argument("--plan-chips", type=int, default=256,
                    help="pod size for the planning search")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    theta = args.theta
    if args.search:
        stages = request_stages(
            cfg, [(args.batch, args.prompt_len, args.gen)] * args.requests)
        res = search(stages, cfg, n_devices=args.plan_chips, max_evals=10)
        theta = res.theta
        print(f"[serve] design flow: theta={theta:.2f} "
              f"tp=({res.tp_c},{res.tp_p}) "
              f"planned makespan={res.makespan*1e3:.1f} ms "
              f"tokens/s={res.tokens_per_s:.0f} on {args.plan_chips} chips")

    params = init_params(cfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), theta)
    runner = DualMeshRunner(cfg, params, dual,
                            max_len=args.prompt_len + args.gen + 8)
    key = jax.random.PRNGKey(1)
    t0 = time.perf_counter()
    for r in range(0, max(1, args.requests), 2):
        pa = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
        pb = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                cfg.vocab)
        a, b, trace = runner.run_two_streams(pa, pb, gen_steps=args.gen)
    dt = time.perf_counter() - t0
    toks = args.requests * args.batch * (args.prompt_len + args.gen)
    print(f"[serve] {args.requests} requests x {args.batch} batch: "
          f"{dt*1e3:.0f} ms ({toks/dt:.0f} tok/s on "
          f"{len(jax.devices())} local device(s))")
    for kind, mesh_name, t in runner.trace:
        print(f"  {kind:<8} on {mesh_name}-mesh  {t*1e3:7.1f} ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
