"""Serving launcher: dual-mesh LM serving or the dual-core CNN pipeline.

LM (the paper's schedule generalized to N-stream continuous batching):

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --requests 8 --prompt-len 16 --gen 8 [--streams 8] \
      [--theta 0.5 | --search]

The request queue is served by the N-stream continuous-batching runtime:
chunked prefills on the c-submesh overlap fused decode batches on the
p-submesh, with the decode fusion width chosen by the makespan-aware
admission plan (override with --group-size).  With --search, the §V-B
design flow picks theta and the TP widths for the workload before
launching; the realised schedule trace is printed.

CNN (the paper's actual workload, executed on the schedule for real):

  PYTHONPATH=src python -m repro.launch.serve --dual-core mobilenet_v1 \
      --requests 4 --image-size 64 [--scheme balanced] [--no-pallas]

Builds the dual-core schedule, splits the local devices into c/p
submeshes, and pipelines the images through the alternating group chain
with the one-slot offset (Fig.4b); prints measured fps next to the
analytical/simulated two-batch latency.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs.registry import ARCH_IDS, get_arch, get_smoke
from repro.dualmesh import (DualMeshRunner, TpuModel, plan_admission,
                            request_stages, search, split_mesh)
from repro.lm.model import init_params

CNN_MODELS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")
CNN_SCHEMES = ("layer_type", "greedy", "round_robin", "balanced", "best")


def serve_dual_core(args) -> int:
    """--dual-core mode: pipelined CNN inference on the c/p submeshes."""
    from repro.core.arch import BoardModel, DUAL_BASELINE
    from repro.core.scheduler import best_schedule, build_schedule
    from repro.core.simulator import simulate_dual_core
    from repro.dualcore.runtime import DualCoreRunner
    from repro.models.cnn import build_model

    board = BoardModel()
    params, _, graph = build_model(args.dual_core)
    if args.scheme == "best":
        sched = best_schedule(graph, DUAL_BASELINE, board)
    else:
        sched = build_schedule(graph, DUAL_BASELINE, board, args.scheme)

    runner = DualCoreRunner(args.dual_core, params, sched,
                            use_pallas=not args.no_pallas)
    es = runner.plan.exec_schedule
    n = max(2, args.requests)
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    images = [jax.random.normal(k, (args.batch, args.image_size,
                                    args.image_size, 3)) for k in keys]
    runner.run_pipelined(images[:2])            # warm the per-group jits
    _, t_pipe = runner.timed(images, "pipelined", reps=2)
    _, t_seq = runner.timed(images, "sequential", reps=2)

    degenerate = runner.dual.c_mesh is runner.dual.p_mesh
    sim = simulate_dual_core(es)
    print(f"[dual-core] {args.dual_core} scheme={sched.scheme}: "
          f"{len(es.groups)} exec groups on "
          f"{runner.dual.c_chips}c+{runner.dual.p_chips}p devices"
          + (" (degenerate: both submeshes alias one device, no real "
             "overlap)" if degenerate else ""))
    print(f"[dual-core] model-side: T_b2={es.t_b2():,} cyc "
          f"(sim {sim.cycles_two_images:,} cyc, "
          f"{board.cycles_to_seconds(sim.cycles_two_images)*1e3:.2f} ms "
          f"@{board.freq_mhz:.0f}MHz), "
          f"pipeline speedup {2*sum(es.group_latencies)/es.t_b2():.2f}x")
    print(f"[dual-core] measured ({n} images x batch {args.batch} @ "
          f"{args.image_size}px): pipelined {t_pipe*1e3:.0f} ms "
          f"({n*args.batch/t_pipe:.2f} img/s), "
          f"sequential {t_seq*1e3:.0f} ms "
          f"({t_seq/t_pipe:.2f}x)")
    return 0


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--dual-core", choices=CNN_MODELS, default=None,
                    help="serve a CNN on the pipelined dual-core runtime "
                         "instead of the LM dual-mesh path")
    ap.add_argument("--scheme", choices=CNN_SCHEMES, default="balanced",
                    help="dual-core allocation scheme (--dual-core only)")
    ap.add_argument("--image-size", type=int, default=64,
                    help="input H=W for --dual-core (224 = paper size)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="use the XLA reference ops in --dual-core mode")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--streams", type=int, default=None,
                    help="concurrent streams the planner optimizes for "
                         "(default: --requests)")
    ap.add_argument("--group-size", type=int, default=None,
                    help="decode fusion width (default: makespan-aware)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill slice in tokens")
    ap.add_argument("--search", action="store_true",
                    help="run the design-flow search for theta/tp first")
    ap.add_argument("--plan-chips", type=int, default=256,
                    help="pod size for the planning search")
    args = ap.parse_args(argv)

    if args.dual_core is not None:
        return serve_dual_core(args)
    if args.arch is None:
        ap.error("--arch is required unless --dual-core is given")

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    n_streams = args.streams or max(1, args.requests)
    theta = args.theta
    if args.search:
        stages = request_stages(
            cfg, [(args.batch, args.prompt_len, args.gen)])
        res = search(stages, cfg, n_devices=args.plan_chips, max_evals=10,
                     n_streams=n_streams)
        theta = res.theta
        print(f"[serve] design flow: theta={theta:.2f} "
              f"tp=({res.tp_c},{res.tp_p}) n_streams={n_streams} "
              f"planned makespan={res.makespan*1e3:.1f} ms "
              f"tokens/s={res.tokens_per_s:.0f} on {args.plan_chips} chips")

    params = init_params(cfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), theta)
    plan = plan_admission(cfg, dual, TpuModel(), args.batch,
                          args.prompt_len, args.gen, n_streams,
                          max_group=args.group_size)
    print(f"[serve] admission plan: group_size="
          f"{args.group_size or plan.group_size} "
          f"(est {plan.est_tokens_per_s:.0f} tok/s model-side)")

    runner = DualMeshRunner(cfg, params, dual,
                            max_len=args.prompt_len + args.gen + 8)
    keys = jax.random.split(jax.random.PRNGKey(1), max(1, args.requests))
    prompts = [jax.random.randint(k, (args.batch, args.prompt_len), 0,
                                  cfg.vocab) for k in keys]
    res = runner.serve(prompts, gen_steps=args.gen,
                       group_size=args.group_size or plan.group_size,
                       prefill_chunk=args.prefill_chunk)
    s = res.stats
    print(f"[serve] {args.requests} requests x {args.batch} batch: "
          f"{s['wall_s']*1e3:.0f} ms ({s['tokens_per_s']:.0f} tok/s, "
          f"{s['total_tokens']} tokens, fused decode batches "
          f"{s['fused_sizes']}, on {len(jax.devices())} local device(s))")
    for kind, mesh_name, t in res.trace:
        print(f"  {kind:<8} on {mesh_name}-mesh  {t*1e3:7.1f} ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
