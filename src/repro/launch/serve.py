"""Serving launcher: both runtimes behind the shared streaming engine API.

Two subcommands, one engine interface (``repro.serving.Engine`` —
submit/step/drain with per-request latency metrics, bounded-queue
backpressure, and a pluggable admission policy):

LM (dual-mesh N-stream continuous batching):

  PYTHONPATH=src python -m repro.launch.serve lm --arch qwen2_0_5b --smoke \
      --requests 8 --prompt-len 16 --gen 8 [--streams 8] \
      [--theta 0.5 | --search] [--arrival-rate 1.0] [--max-queue 64]

  Requests are submitted to a ``DualMeshEngine`` on a fixed Poisson-ish
  arrival trace (``--arrival-rate``, in requests per scheduler slot;
  ``inf`` submits everything up front): chunked prefills on the c-submesh
  overlap fused decode batches on the p-submesh, the decode fusion width
  defaults to the makespan-aware admission plan (``--group-size``
  overrides), and with ``--search`` the §V-B design flow picks theta and
  the TP widths first.

CNN (dual-core pipeline with online slot-refill admission):

  PYTHONPATH=src python -m repro.launch.serve cnn mobilenet_v1 \
      --requests 4 --image-size 64 [--scheme balanced] [--no-pallas] \
      [--arrival-rate 1.0] [--max-queue 64]

  Builds the dual-core schedule, splits the local devices into c/p
  submeshes, and streams the requests through a ``DualCoreEngine``: each
  scheduler slot advances every in-flight image one exec group (the
  Fig.4b one-slot offset) and refills the drained group-0 slot from the
  request queue.  ``--requests 1`` is honored as the degenerate
  single-image run (no silent workload bump).  Prints steady-state fps and
  p50/p95 request latency next to the analytical/simulated two-batch
  latency.

Fleet (several CNNs multiplexed over one device pool, DESIGN.md §10):

  PYTHONPATH=src python -m repro.launch.serve fleet \
      --models mbv1,mbv2,squeezenet --mix 0.4,0.35,0.25 --requests 9 \
      [--policy weighted_fair] [--plan] [--scheme balanced] [--no-pallas] \
      [--no-interleave] [--image-size 64] [--arrival-rate] [--max-queue] \
      [--pools 2] [--trace trace.json]

  One ``DevicePool`` leases the shared c/p split to a ``DualCoreEngine``
  per model; requests tagged per the traffic mix stream through the
  ``FleetEngine``, whose scheduling policy picks which member's exec
  group dispatches first each slot, with up to ``--co-dispatch`` further
  members following core-complementary-first per the latency model —
  conv-heavy and dw-heavy groups from different networks overlap on the
  two submeshes.  ``--plan`` first
  runs the §V-B co-scheduling search over the mix and serves under the
  planned PE config, printing the predicted Table-VII-style throughput
  next to the measured one.  Prints aggregate fps and per-model p50/p95.

  ``--pools N`` stands up N process-local pools (one fleet each) behind a
  ``MultiPoolRouter`` — requests place onto the least outstanding pool,
  and the executed per-pool instruction streams interleave by router
  sequence number.  ``--trace PATH`` exports the executed stream as
  Chrome-tracing JSON (one track per submesh per pool).

  ``--slo-ms X`` serves every member under a ``ShedPolicy`` with an
  ``X``-millisecond wall-clock deadline per request — past-deadline queue
  entries are shed instead of served, and the summary reports goodput
  (served AND within SLO) next to raw throughput.  ``--faults PLAN.json``
  arms a seeded ``repro.fleet.FaultPlan`` on the executors: deterministic
  injected RUN errors / pool crashes / dropped SENDs / latency skew,
  retried and recovered per DESIGN.md §12 (crash recovery needs
  ``--pools >= 2``).  A malformed plan or a non-positive SLO is a usage
  error (exit 2).

  ``--adapt`` attaches a closed-loop controller (DESIGN.md §13,
  ``repro.fleet.ControlLoop``) to each pool's fleet: every
  ``--control-interval`` slots it observes the sliding completion window
  and injects SET_PARAM / REBALANCE instructions — re-weighting member
  shares toward the observed arrival mix, narrowing/widening retunable
  engines' fusion width on p95 SLO breaches (needs ``--slo-ms``), and
  re-leasing theta on sustained shedding.  The summary reports the
  decisions taken; the injected instructions land in the recorded
  stream, so ``--trace`` shows them on the control track and the run
  replays bitwise without the controller.
"""
from __future__ import annotations

import argparse
import sys

import jax

from repro.configs.registry import ARCH_IDS, get_arch, get_smoke
from repro.dualmesh import (DualMeshRunner, TpuModel, plan_admission,
                            request_stages, search, split_mesh)
from repro.lm.model import init_params
from repro.serving import (DualCoreEngine, DualMeshEngine, Request,
                           poisson_arrivals, replay)

CNN_MODELS = ("mobilenet_v1", "mobilenet_v2", "squeezenet")
CNN_SCHEMES = ("layer_type", "greedy", "round_robin", "balanced", "best")
MODEL_ALIASES = {"mbv1": "mobilenet_v1", "mbv2": "mobilenet_v2",
                 "sqz": "squeezenet",
                 **{m: m for m in CNN_MODELS}}


def _fail(msg: str) -> None:
    """CLI usage error: clear one-line message on stderr, exit code 2
    (argparse's convention for bad arguments) — never a raw traceback."""
    print(f"repro.launch.serve: error: {msg}", file=sys.stderr)
    raise SystemExit(2)


def _arrivals(n: int, rate: float) -> list[int]:
    """Arrival trace for n requests: Poisson-ish at ``rate`` per slot, or
    everything at slot 0 when the rate is infinite."""
    if rate == float("inf"):
        return [0] * n
    return poisson_arrivals(n, rate=rate, seed=0)


def _print_latency(metrics) -> None:
    print(f"[serve] latency: p50 {metrics.p50_ms():.1f} ms, "
          f"p95 {metrics.p95_ms():.1f} ms over "
          f"{metrics.completed} requests")


def serve_cnn(args) -> int:
    """``cnn`` subcommand: streaming CNN serving on the c/p submeshes."""
    from repro.core.arch import BoardModel, DUAL_BASELINE
    from repro.core.scheduler import best_schedule, build_schedule
    from repro.core.simulator import simulate_dual_core
    from repro.dualcore.runtime import DualCoreRunner
    from repro.models.cnn import build_model

    board = BoardModel()
    params, _, graph = build_model(args.model)
    if args.scheme == "best":
        sched = best_schedule(graph, DUAL_BASELINE, board)
    else:
        sched = build_schedule(graph, DUAL_BASELINE, board, args.scheme)

    runner = DualCoreRunner(args.model, params, sched,
                            use_pallas=not args.no_pallas)
    es = runner.plan.exec_schedule
    n = args.requests
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    images = [jax.random.normal(k, (args.batch, args.image_size,
                                    args.image_size, 3)) for k in keys]
    runner.run_sequential(images[:1])           # warm the per-group jits

    engine = DualCoreEngine(runner, max_queue=args.max_queue)
    res = replay(engine, [Request(x) for x in images],
                 _arrivals(n, args.arrival_rate))
    _, t_seq = runner.timed(images, "sequential", reps=2)

    degenerate = runner.dual.c_mesh is runner.dual.p_mesh
    sim = simulate_dual_core(es)
    print(f"[serve] cnn {args.model} scheme={sched.scheme}: "
          f"{len(es.groups)} exec groups on "
          f"{runner.dual.c_chips}c+{runner.dual.p_chips}p devices"
          + (" (degenerate: both submeshes alias one device, no real "
             "overlap)" if degenerate else ""))
    print(f"[serve] model-side: T_b2={es.t_b2():,} cyc "
          f"(sim {sim.cycles_two_images:,} cyc, "
          f"{board.cycles_to_seconds(sim.cycles_two_images)*1e3:.2f} ms "
          f"@{board.freq_mhz:.0f}MHz), "
          f"pipeline speedup {2*sum(es.group_latencies)/es.t_b2():.2f}x")
    s = res.stats
    print(f"[serve] streamed {n} request(s) x batch {args.batch} @ "
          f"{args.image_size}px in {s['slots']} slots: "
          f"{s['wall_s']*1e3:.0f} ms "
          f"({n*args.batch/s['wall_s']:.2f} img/s), "
          f"sequential {t_seq*1e3:.0f} ms "
          f"({t_seq/s['wall_s']:.2f}x)")
    _print_latency(res.metrics)
    return 0


class _MetricsSink:
    """``--metrics PATH`` / ``--metrics-every K`` plumbing shared by the
    three fleet paths.  Without ``--metrics-every`` the final registry
    snapshot is written once (``-`` = Prometheus text on stdout, ``.json``
    = JSON, else Prometheus text).  With it, one compact
    ``{"step": s, "snapshot": ...}`` JSON line is appended every K steps
    plus a final line — a replayable time series."""

    def __init__(self, args):
        self.path = getattr(args, "metrics", None)
        self.every = getattr(args, "metrics_every", None)
        self.registry = None      # set once the engine/router exists
        self._started = False

    def on_step(self, step: int) -> None:
        if self.registry is None or not self.every:
            return
        if (step + 1) % self.every == 0:
            self._append(step)

    def _append(self, step: int) -> None:
        import json

        line = json.dumps({"step": step,
                           "snapshot": self.registry.snapshot()},
                          sort_keys=True)
        if self.path == "-":
            print(line)
            return
        with open(self.path, "a" if self._started else "w") as f:
            f.write(line + "\n")
        self._started = True

    def finish(self, steps: int) -> None:
        if self.registry is None or self.path is None:
            return
        if self.every:
            self._append(steps)
            if self.path != "-":
                print(f"[serve] appended metric snapshots every "
                      f"{self.every} step(s) to {self.path}")
            return
        from repro.obs import write_metrics

        fmt = write_metrics(self.registry, self.path)
        if self.path != "-":
            print(f"[serve] wrote {fmt} metrics to {self.path}")


def _parse_fleet_mix(args) -> dict[str, float]:
    """--models/--mix -> normalized {model: share} (aliases expanded).
    Malformed values are usage errors: message + exit 2 via :func:`_fail`,
    not a traceback."""
    names = []
    for tok in args.models.split(","):
        tok = tok.strip()
        if tok not in MODEL_ALIASES:
            _fail(f"unknown model {tok!r} in --models; one of "
                  f"{sorted(MODEL_ALIASES)}")
        names.append(MODEL_ALIASES[tok])
    if len(set(names)) != len(names):
        _fail(f"duplicate models in --models: {names}")
    if args.mix is None:
        shares = [1.0] * len(names)
    else:
        try:
            shares = [float(t) for t in args.mix.split(",")]
        except ValueError:
            _fail(f"--mix must be comma-separated numbers "
                  f"(got {args.mix!r})")
        if len(shares) != len(names):
            _fail(f"{len(names)} models in --models but {len(shares)} "
                  f"shares in --mix")
    from repro.fleet import normalize_mix

    try:
        return normalize_mix(dict(zip(names, shares)))
    except ValueError as e:
        _fail(str(e))


def _serve_fleet_workers(args, mix, build, requests, arrivals) -> int:
    """``fleet --workers N --transport socket``: each pool is a real
    worker process (``python -m repro.fleet.worker``) hosting the same
    CNN fleet; the coordinator drives them over ``SocketTransport``
    through the standard ``MultiPoolRouter`` placement / migration /
    crash-recovery logic (DESIGN.md §14)."""
    from repro.fleet import MultiPoolRouter, RecoveryConfig
    from repro.fleet.net.coordinator import (connect, start_workers,
                                             stop_workers)
    from repro.serving import QueueFull

    kill = None
    if args.kill_worker is not None:
        pool_name, sep, at = args.kill_worker.partition("@")
        if not sep or not at.isdigit():
            _fail(f"--kill-worker wants POOL@STEP (e.g. pool1@3), got "
                  f"{args.kill_worker!r}")
        kill = (pool_name, int(at))
    pools = [f"pool{p}" for p in range(args.workers)]
    if kill is not None and kill[0] not in pools:
        _fail(f"--kill-worker pool {kill[0]!r} is not one of {pools}")

    wargs = ["--models", ",".join(mix),
             "--image-size", str(args.image_size),
             "--scheme", args.scheme, "--policy", args.policy,
             "--burst", str(args.burst)]
    if args.no_pallas:
        wargs.append("--no-pallas")
    co = 0 if args.no_interleave else args.co_dispatch
    if co is not None:
        wargs += ["--co-dispatch", str(co)]
    if args.max_queue is not None:
        wargs += ["--max-queue", str(args.max_queue)]

    recovery = RecoveryConfig()
    print(f"[serve] spawning {args.workers} worker process(es): "
          f"python -m repro.fleet.worker --pool <name> {' '.join(wargs)}")
    procs = start_workers({p: list(wargs) for p in pools})
    fleets = {}
    try:
        fleets = connect(procs, heartbeat_s=recovery.heartbeat_s)
        router = MultiPoolRouter(fleets, recovery=recovery)
        sink = _MetricsSink(args)
        sink.registry = router.obs

        def collect_telemetry():
            for ex in router.executors.values():
                handle = getattr(ex, "_handle", None)
                if handle is not None and handle.lost is None:
                    handle.collect(ex)

        addrs = ", ".join(f"{p}={procs[p].address}" for p in pools)
        print(f"[serve] fleet {'+'.join(mix)} x {args.workers} workers "
              f"over SocketTransport ({addrs})")
        # replay()'s open loop, plus the mid-run SIGKILL hook
        order = sorted(range(len(requests)), key=lambda i: arrivals[i])
        refused, nxt, step = [], 0, 0
        while nxt < len(order) or refused or router.has_work:
            if kill is not None and step >= kill[1]:
                print(f"[serve] SIGKILL worker {kill[0]} at router "
                      f"step {step}")
                procs[kill[0]].kill()
                kill = None
            due, refused = refused, []
            while nxt < len(order) and arrivals[order[nxt]] <= step:
                due.append(order[nxt])
                nxt += 1
            for i in due:
                try:
                    router.submit(requests[i])
                except QueueFull:
                    refused.append(i)
            router.step()
            if args.metrics:
                # pull each worker's cumulative snapshot every step so a
                # SIGKILL loses at most the last unshipped window
                collect_telemetry()
                sink.on_step(step)
            step += 1
        if args.metrics:
            collect_telemetry()
        res = router.result()
        st = res.stats
        streams = {name: list(ex.records)
                   for name, ex in router.executors.items()}
        placements = list(router.placements)
        events = list(router.events)
    finally:
        stop_workers(fleets, procs)

    n = len(requests)
    sink.finish(st["steps"])
    print(f"[serve] streamed {n} request(s) over {args.workers} workers "
          f"in {st['steps']} router steps: {st['wall_s']*1e3:.0f} ms, "
          f"aggregate {st['aggregate_fps']:.2f} fps")
    for pname, pp in st["pools"].items():
        served = ", ".join(f"{m}:{c}" for m, c in pp["served"].items())
        print(f"  {pname:<8} {pp['slots']} slots "
              f"{pp['dispatches']} dispatches  served {served or '-'}")
    for name, pm in st["per_model"].items():
        print(f"  {name:<14} {pm['completed']} done  "
              f"p50 {pm['p50_ms']:.1f} ms  p95 {pm['p95_ms']:.1f} ms  "
              f"{pm['requests_per_s']:.2f} fps")
    done = len(res.completions)
    print(f"[serve] exactly-once: {done}/{n} retired, "
          f"{st['duplicates_dropped']} duplicates dropped, "
          f"{st['failed']} failed, {st['recovered']} recovered, "
          f"dead workers {st['dead'] or '-'}")
    if done != n or st["duplicates_dropped"] or st["failed"]:
        print("repro.launch.serve: error: exactly-once retirement "
              "violated", file=sys.stderr)
        return 1
    if args.verify_replay:
        from repro.fleet.compiler import stream_signature

        fresh = MultiPoolRouter({p: build()[0] for p in pools})
        fresh.replay(streams, placements, requests, events)
        for p, recs in streams.items():
            if stream_signature(recs) != stream_signature(
                    fresh.executors[p].records):
                print(f"repro.launch.serve: error: replay diverged on "
                      f"{p}", file=sys.stderr)
                return 1
        print(f"[serve] replay verified: "
              f"{sum(len(r) for r in streams.values())} records across "
              f"{len(streams)} pool(s) replay bitwise on fresh "
              f"in-process fleets")
    if args.trace:
        import json

        from repro.fleet.trace import chrome_trace

        doc = chrome_trace(streams)
        with open(args.trace, "w") as f:
            json.dump(doc, f)
        print(f"[serve] wrote {len(doc['traceEvents'])} trace events to "
              f"{args.trace} (open in chrome://tracing)")
    return 0


def serve_fleet(args) -> int:
    """``fleet`` subcommand: multi-network serving over one device pool —
    or over ``--pools N`` process-local pools (hosts stand-in) behind a
    ``MultiPoolRouter``, each pool replaying its own compiled instruction
    stream — or over ``--workers N`` real worker processes behind
    ``--transport socket`` (DESIGN.md §14)."""
    from repro.fleet import (FaultInjector, FaultPlan, MultiPoolRouter,
                             build_cnn_fleet, make_policy, mix_schedule,
                             plan_fleet, plan_rows)
    from repro.serving import ShedPolicy

    mix = _parse_fleet_mix(args)
    if args.pools < 1:
        _fail(f"--pools must be >= 1, got {args.pools}")
    if args.workers < 0:
        _fail(f"--workers must be >= 0, got {args.workers}")
    if args.workers:
        if args.transport != "socket":
            _fail(f"--workers {args.workers} puts each pool in its own "
                  f"process; only --transport socket crosses process "
                  f"boundaries ({args.transport!r} is an in-process "
                  f"mailbox binding — use --pools for it)")
        if args.pools != 1:
            _fail("--workers and --pools are mutually exclusive: "
                  "workers are real processes, pools are process-local")
        if args.faults is not None:
            _fail("--faults is in-process fault injection; with "
                  "--workers, kill a real process instead "
                  "(--kill-worker POOL@STEP)")
        if args.adapt:
            _fail("--adapt runs a per-pool in-process controller; it is "
                  "not supported over --workers")
        if args.slo_ms is not None:
            _fail("--slo-ms attaches in-process shed policies; it is "
                  "not supported over --workers")
        if args.plan:
            _fail("--plan is not supported over --workers (each worker "
                  "builds its own fleet from the model list)")
    elif args.transport == "socket":
        _fail("--transport socket needs --workers N (worker processes "
              "to talk to)")
    elif args.transport == "file" and args.pools < 2:
        _fail("--transport file is the multi-pool spool mailbox; it "
              "needs --pools >= 2")
    if args.spool is not None and args.transport != "file":
        _fail("--spool only applies to --transport file")
    if args.kill_worker is not None and not args.workers:
        _fail("--kill-worker needs --workers")
    if args.verify_replay and not args.workers:
        _fail("--verify-replay needs --workers (the in-process paths "
              "have replay tests of their own)")
    if args.slo_ms is not None and not args.slo_ms > 0:
        _fail(f"--slo-ms must be > 0, got {args.slo_ms}")
    if args.control_interval < 1:
        _fail(f"--control-interval must be >= 1, got "
              f"{args.control_interval}")
    if args.metrics_every is not None and not args.metrics:
        _fail("--metrics-every needs --metrics PATH (where would the "
              "snapshots go?)")
    if args.metrics_every is not None and args.metrics_every < 1:
        _fail(f"--metrics-every must be >= 1, got {args.metrics_every}")
    fault_plan = None
    if args.faults is not None:
        try:
            fault_plan = FaultPlan.load(args.faults)
        except (OSError, ValueError) as e:
            _fail(f"--faults {args.faults!r}: {e}")
    admission = None
    if args.slo_ms is not None:
        admission = {m: ShedPolicy(slo_s=args.slo_ms / 1e3, clock="wall")
                     for m in mix}
    plan = None
    if args.plan:
        plan = plan_fleet(mix, max_evals=args.plan_evals)
        print(f"[serve] fleet plan: config={plan.config} "
              f"theta={plan.theta:.2f} predicted aggregate "
              f"{plan.aggregate_fps:.1f} fps")

    def build():
        return build_cnn_fleet(
            list(mix), plan=plan, scheme=args.scheme,
            use_pallas=not args.no_pallas, policy=make_policy(args.policy),
            weights=mix, admission=admission, max_queue=args.max_queue,
            co_dispatch=0 if args.no_interleave else args.co_dispatch,
            burst=args.burst)

    n = args.requests
    tags = mix_schedule(mix, n)
    keys = jax.random.split(jax.random.PRNGKey(0), n)
    images = [jax.random.normal(k, (args.batch, args.image_size,
                                    args.image_size, 3)) for k in keys]
    requests = [Request(x, model=t) for x, t in zip(images, tags)]
    arrivals = _arrivals(n, args.arrival_rate)

    if args.workers:
        return _serve_fleet_workers(args, mix, build, requests, arrivals)

    sink = _MetricsSink(args)

    def attach_controller(fleet_engine):
        if not args.adapt:
            return None
        from repro.fleet import ControlLoop

        return ControlLoop(fleet_engine, interval=args.control_interval,
                           slo_ms=args.slo_ms, plan_evals=args.plan_evals)

    if args.pools == 1:
        engine, pool = build()
        controller = attach_controller(engine)
        if fault_plan is not None:
            engine.executor.injector = FaultInjector(fault_plan)
        for m in engine.members:         # warm each member's per-group jits
            # any image warms a member — a skewed mix or --requests <
            # number of models can leave a member with no tagged request
            m.engine.runner.run_sequential(images[:1])
        s = pool.stats()
        print(f"[serve] fleet {'+'.join(mix)} policy={args.policy} "
              f"({s['c_chips']}c+{s['p_chips']}p devices"
              + (", degenerate: both submeshes alias one device"
                 if s["degenerate"] else "") + ")")
        sink.registry = engine.executor.obs
        res = replay(engine, requests, arrivals, on_step=sink.on_step)
        st = res.stats
        print(f"[serve] streamed {n} request(s) in {st['slots']} fleet "
              f"slots ({st['dispatches']} member dispatches): "
              f"{st['wall_s']*1e3:.0f} ms, aggregate "
              f"{st['aggregate_fps']:.2f} fps")
        for name, pm in st["per_model"].items():
            d = st["per_member"][name]
            print(f"  {name:<14} {pm['completed']} done "
                  f"({d['dispatches']} dispatches)  "
                  f"p50 {pm['p50_ms']:.1f} ms  p95 {pm['p95_ms']:.1f} ms  "
                  f"{pm['requests_per_s']:.2f} fps")
        if plan is not None:
            measured = {m: v["requests_per_s"]
                        for m, v in st["per_model"].items()}
            print("[serve] predicted (Table-VII-style) vs measured fps:")
            for name, share, fps, pred, meas in plan_rows(
                    plan, measured, st["aggregate_fps"]):
                print(f"  {name:<14} share={share:.2f} "
                      f"model-side={fps:8.1f} predicted={pred:8.1f} "
                      f"measured="
                      + (f"{meas:8.2f}" if meas is not None else "     n/a"))
        if args.slo_ms is not None or fault_plan is not None:
            print(f"[serve] goodput {st['goodput_fps']:.2f} fps "
                  f"(shed {res.metrics.count('shed')}, "
                  f"retries {engine.executor.retries})")
        if controller is not None:
            cs = controller.stats()
            weights = ", ".join(f"{m.name}={m.weight:.2f}"
                                for m in engine.members)
            print(f"[serve] control: {cs['observations']} observations, "
                  f"{cs['decisions']} decisions {cs['by_kind'] or '{}'}; "
                  f"final weights {weights}")
        streams = {"pool0": engine.stream}
        roof_src, steps_done = engine, st["slots"]
    else:
        fleets = {f"pool{p}": build()[0] for p in range(args.pools)}
        controllers = {name: attach_controller(fl)
                       for name, fl in fleets.items()} if args.adapt else {}
        transport = None
        if args.transport == "file":
            import tempfile

            from repro.fleet.net import FileTransport

            spool = args.spool or tempfile.mkdtemp(prefix="repro_spool_")
            transport = FileTransport(spool)
            print(f"[serve] inter-pool migration spooled through "
                  f"{spool} (FileTransport)")
        router = MultiPoolRouter(
            fleets, injector=(FaultInjector(fault_plan)
                              if fault_plan is not None else None),
            transport=transport)
        for fleet_engine in fleets.values():
            for m in fleet_engine.members:
                m.engine.runner.run_sequential(images[:1])
        print(f"[serve] fleet {'+'.join(mix)} x {args.pools} pools "
              f"policy={args.policy} (requests placed on the least "
              f"outstanding pool)")
        sink.registry = router.obs
        res = replay(router, requests, arrivals, on_step=sink.on_step)
        st = res.stats
        print(f"[serve] streamed {n} request(s) over {args.pools} pools "
              f"in {st['steps']} router steps: {st['wall_s']*1e3:.0f} ms, "
              f"aggregate {st['aggregate_fps']:.2f} fps")
        for pname, pp in st["pools"].items():
            served = ", ".join(f"{m}:{c}" for m, c in pp["served"].items())
            print(f"  {pname:<8} {pp['slots']} slots "
                  f"{pp['dispatches']} dispatches  served {served or '-'}")
        for name, pm in st["per_model"].items():
            print(f"  {name:<14} {pm['completed']} done  "
                  f"p50 {pm['p50_ms']:.1f} ms  p95 {pm['p95_ms']:.1f} ms  "
                  f"{pm['requests_per_s']:.2f} fps")
        if args.slo_ms is not None or fault_plan is not None:
            print(f"[serve] goodput {st['goodput_fps']:.2f} fps "
                  f"(shed {st['shed']}, failed {st['failed']}, "
                  f"recovered {st['recovered']}, dead pools "
                  f"{st['dead'] or '-'}, duplicates dropped "
                  f"{st['duplicates_dropped']})")
        for pname, ctl in controllers.items():
            if ctl is not None:
                cs = ctl.stats()
                print(f"[serve] control {pname}: {cs['observations']} "
                      f"observations, {cs['decisions']} decisions "
                      f"{cs['by_kind'] or '{}'}")
        streams = {name: ex.records
                   for name, ex in router.executors.items()}
        roof_src, steps_done = router, st["steps"]
    sink.finish(steps_done)
    if args.trace:
        import json

        from repro.fleet.trace import chrome_trace, roofline_model

        doc = chrome_trace(streams, roofline=roofline_model(roof_src))
        with open(args.trace, "w") as f:
            json.dump(doc, f)
        print(f"[serve] wrote {len(doc['traceEvents'])} trace events to "
              f"{args.trace} (roofline-annotated; open in "
              f"chrome://tracing)")
    return 0


def serve_lm(args) -> int:
    """``lm`` subcommand: dual-mesh continuous batching."""
    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    n_streams = args.streams or max(1, args.requests)
    theta = args.theta
    if args.search:
        stages = request_stages(
            cfg, [(args.batch, args.prompt_len, args.gen)])
        res = search(stages, cfg, n_devices=args.plan_chips, max_evals=10,
                     n_streams=n_streams)
        theta = res.theta
        print(f"[serve] design flow: theta={theta:.2f} "
              f"tp=({res.tp_c},{res.tp_p}) n_streams={n_streams} "
              f"planned makespan={res.makespan*1e3:.1f} ms "
              f"tokens/s={res.tokens_per_s:.0f} on {args.plan_chips} chips")

    params = init_params(cfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), theta)
    plan = plan_admission(cfg, dual, TpuModel(), args.batch,
                          args.prompt_len, args.gen, n_streams,
                          max_group=args.group_size)
    group_size = args.group_size or plan.group_size
    print(f"[serve] admission plan: group_size={group_size} "
          f"(est {plan.est_tokens_per_s:.0f} tok/s model-side)")

    runner = DualMeshRunner(cfg, params, dual,
                            max_len=args.prompt_len + args.gen + 8)
    n = max(1, args.requests)
    keys = jax.random.split(jax.random.PRNGKey(1), n)
    prompts = [jax.random.randint(k, (args.batch, args.prompt_len), 0,
                                  cfg.vocab) for k in keys]
    engine = DualMeshEngine(runner, group_size=group_size,
                            prefill_chunk=args.prefill_chunk,
                            max_queue=args.max_queue)
    res = replay(engine,
                 [Request(p, gen_steps=args.gen) for p in prompts],
                 _arrivals(n, args.arrival_rate))
    s = res.stats
    print(f"[serve] {n} requests x {args.batch} batch: "
          f"{s['wall_s']*1e3:.0f} ms ({s['tokens_per_s']:.0f} tok/s, "
          f"{s['total_tokens']} tokens, fused decode batches "
          f"{s['fused_sizes']}, on {len(jax.devices())} local device(s))")
    _print_latency(res.metrics)
    for kind, mesh_name, t in res.trace:
        print(f"  {kind:<8} on {mesh_name}-mesh  {t*1e3:7.1f} ms")
    return 0


def _add_common(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--requests", type=int, default=2,
                    help="number of requests to serve (>= 1)")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--arrival-rate", type=float, default=float("inf"),
                    help="Poisson-ish arrivals per scheduler slot "
                         "(default inf: everything at slot 0)")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="bounded request queue (backpressure beyond it)")


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="repro.launch.serve",
        description="Serve the LM or the CNN through the shared "
                    "repro.serving engine API.")
    sub = ap.add_subparsers(dest="cmd", required=True)

    lm = sub.add_parser("lm", help="dual-mesh LM continuous batching")
    lm.add_argument("--arch", choices=ARCH_IDS, required=True)
    lm.add_argument("--smoke", action="store_true")
    _add_common(lm)
    lm.add_argument("--prompt-len", type=int, default=16)
    lm.add_argument("--gen", type=int, default=8)
    lm.add_argument("--theta", type=float, default=0.5)
    lm.add_argument("--streams", type=int, default=None,
                    help="concurrent streams the planner optimizes for "
                         "(default: --requests)")
    lm.add_argument("--group-size", type=int, default=None,
                    help="decode fusion width (default: makespan-aware)")
    lm.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill slice in tokens")
    lm.add_argument("--search", action="store_true",
                    help="run the design-flow search for theta/tp first")
    lm.add_argument("--plan-chips", type=int, default=256,
                    help="pod size for the planning search")
    lm.set_defaults(func=serve_lm)

    cnn = sub.add_parser("cnn", help="dual-core CNN streaming pipeline")
    cnn.add_argument("model", choices=CNN_MODELS)
    cnn.add_argument("--scheme", choices=CNN_SCHEMES, default="balanced",
                     help="dual-core allocation scheme")
    cnn.add_argument("--image-size", type=int, default=64,
                     help="input H=W (224 = paper size)")
    cnn.add_argument("--no-pallas", action="store_true",
                     help="use the XLA reference ops")
    _add_common(cnn)
    cnn.set_defaults(func=serve_cnn)

    from repro.fleet import POLICY_NAMES

    fleet = sub.add_parser(
        "fleet", help="multi-CNN fleet over one device pool")
    fleet.add_argument("--models", default="mbv1,mbv2,squeezenet",
                       help="comma-separated member models "
                            "(aliases: mbv1, mbv2, sqz)")
    fleet.add_argument("--mix", default=None,
                       help="comma-separated qps shares aligned with "
                            "--models (default: equal)")
    fleet.add_argument("--policy", choices=POLICY_NAMES,
                       default="weighted_fair",
                       help="cross-engine step scheduling policy")
    fleet.add_argument("--scheme", choices=CNN_SCHEMES, default="balanced",
                       help="per-model allocation scheme (without --plan)")
    fleet.add_argument("--plan", action="store_true",
                       help="co-schedule the mix through the §V-B search "
                            "first and serve under the planned PE config")
    fleet.add_argument("--plan-evals", type=int, default=8,
                       help="search budget for --plan")
    fleet.add_argument("--image-size", type=int, default=64,
                       help="input H=W (224 = paper size)")
    fleet.add_argument("--no-pallas", action="store_true",
                       help="use the XLA reference ops")
    fleet.add_argument("--co-dispatch", type=int, default=None,
                       help="max members co-dispatched per slot beyond "
                            "the primary (default: all with work)")
    fleet.add_argument("--burst", type=int, default=4,
                       help="consecutive slots each batched member "
                            "advances per fleet step (locality "
                            "amortization; raises other members' "
                            "queueing by up to burst-1 slots; default 4 "
                            "matches the BENCH_fleet configuration — 1 "
                            "is strict slot-granular interleaving)")
    fleet.add_argument("--no-interleave", action="store_true",
                       help="disable co-dispatch entirely (same as "
                            "--co-dispatch 0): one policy-picked member "
                            "per slot")
    fleet.add_argument("--pools", type=int, default=1,
                       help="process-local device pools (hosts stand-in); "
                            "> 1 serves through a MultiPoolRouter that "
                            "places requests on the least outstanding "
                            "pool")
    fleet.add_argument("--workers", type=int, default=0, metavar="N",
                       help="serve over N real worker processes (python "
                            "-m repro.fleet.worker), one pool each, "
                            "behind --transport socket; mutually "
                            "exclusive with --pools > 1")
    fleet.add_argument("--transport", default="local",
                       choices=("local", "socket", "file"),
                       help="inter-pool request transport: 'local' "
                            "(in-memory mailbox, the --pools default), "
                            "'socket' (length-prefixed wire envelopes to "
                            "--workers processes), 'file' (spool-"
                            "directory mailbox between --pools, see "
                            "--spool)")
    fleet.add_argument("--spool", default=None, metavar="DIR",
                       help="spool directory for --transport file "
                            "(default: a fresh temp dir)")
    fleet.add_argument("--kill-worker", default=None, metavar="POOL@STEP",
                       help="SIGKILL the named worker process at the "
                            "given router step (crash-recovery demo; "
                            "needs --workers)")
    fleet.add_argument("--verify-replay", action="store_true",
                       help="after a --workers run, replay the collected "
                            "per-worker streams + placement log on fresh "
                            "in-process fleets and assert they match "
                            "bitwise")
    fleet.add_argument("--trace", default=None, metavar="PATH",
                       help="write the executed instruction stream as "
                            "Chrome-tracing JSON to PATH (one track per "
                            "submesh per pool, roofline args on RUN "
                            "slices, labeled bubble events; open in "
                            "chrome://tracing)")
    fleet.add_argument("--metrics", default=None, metavar="PATH",
                       help="write the telemetry registry at the end of "
                            "the run: '-' = Prometheus text on stdout, "
                            "*.json = JSON, else Prometheus text "
                            "(docs/observability.md)")
    fleet.add_argument("--metrics-every", type=int, default=None,
                       metavar="K",
                       help="with --metrics: append one JSON snapshot "
                            "line every K engine/router steps (a metric "
                            "time series) instead of one final "
                            "exposition")
    fleet.add_argument("--faults", default=None, metavar="PLAN.json",
                       help="arm a seeded FaultPlan (repro.fleet.faults) "
                            "on the executors: deterministic RUN errors, "
                            "pool crashes, dropped SENDs, latency skew")
    fleet.add_argument("--slo-ms", type=float, default=None,
                       help="per-request wall-clock SLO in ms: serve "
                            "every member under a ShedPolicy that drops "
                            "past-deadline queue entries and report "
                            "goodput (served AND within SLO)")
    fleet.add_argument("--adapt", action="store_true",
                       help="attach a closed-loop controller (DESIGN.md "
                            "§13) to each pool: observe the completion "
                            "window every --control-interval slots and "
                            "inject SET_PARAM/REBALANCE — reweight "
                            "members toward the observed mix, retune "
                            "fusion width on p95 breaches (with "
                            "--slo-ms), re-lease theta on sustained "
                            "shedding")
    fleet.add_argument("--control-interval", type=int, default=8,
                       metavar="K",
                       help="fleet slots between controller observations "
                            "(with --adapt; default 8)")
    _add_common(fleet)
    fleet.set_defaults(func=serve_fleet)

    args = ap.parse_args(argv)
    if args.requests < 1:
        ap.error(f"--requests must be >= 1, got {args.requests}")
    if args.max_queue is not None and args.max_queue < 1:
        ap.error(f"--max-queue must be >= 1, got {args.max_queue}")
    if not args.arrival_rate > 0:
        ap.error(f"--arrival-rate must be > 0, got {args.arrival_rate}")
    return args.func(args)


if __name__ == "__main__":
    sys.exit(main())
