"""Serving launcher: dual-mesh (the paper's feature) or single-mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2_0_5b --smoke \
      --requests 8 --prompt-len 16 --gen 8 [--streams 8] \
      [--theta 0.5 | --search]

The request queue is served by the N-stream continuous-batching runtime:
chunked prefills on the c-submesh overlap fused decode batches on the
p-submesh, with the decode fusion width chosen by the makespan-aware
admission plan (override with --group-size).  With --search, the §V-B
design flow picks theta and the TP widths for the workload before
launching; the realised schedule trace is printed.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax

from repro.configs.registry import ARCH_IDS, get_arch, get_smoke
from repro.dualmesh import (DualMeshRunner, TpuModel, plan_admission,
                            request_stages, search, split_mesh)
from repro.lm.model import init_params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=2)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=8)
    ap.add_argument("--theta", type=float, default=0.5)
    ap.add_argument("--streams", type=int, default=None,
                    help="concurrent streams the planner optimizes for "
                         "(default: --requests)")
    ap.add_argument("--group-size", type=int, default=None,
                    help="decode fusion width (default: makespan-aware)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="chunked-prefill slice in tokens")
    ap.add_argument("--search", action="store_true",
                    help="run the design-flow search for theta/tp first")
    ap.add_argument("--plan-chips", type=int, default=256,
                    help="pod size for the planning search")
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    n_streams = args.streams or max(1, args.requests)
    theta = args.theta
    if args.search:
        stages = request_stages(
            cfg, [(args.batch, args.prompt_len, args.gen)])
        res = search(stages, cfg, n_devices=args.plan_chips, max_evals=10,
                     n_streams=n_streams)
        theta = res.theta
        print(f"[serve] design flow: theta={theta:.2f} "
              f"tp=({res.tp_c},{res.tp_p}) n_streams={n_streams} "
              f"planned makespan={res.makespan*1e3:.1f} ms "
              f"tokens/s={res.tokens_per_s:.0f} on {args.plan_chips} chips")

    params = init_params(cfg, jax.random.PRNGKey(0))
    dual = split_mesh(jax.devices(), theta)
    plan = plan_admission(cfg, dual, TpuModel(), args.batch,
                          args.prompt_len, args.gen, n_streams,
                          max_group=args.group_size)
    print(f"[serve] admission plan: group_size="
          f"{args.group_size or plan.group_size} "
          f"(est {plan.est_tokens_per_s:.0f} tok/s model-side)")

    runner = DualMeshRunner(cfg, params, dual,
                            max_len=args.prompt_len + args.gen + 8)
    keys = jax.random.split(jax.random.PRNGKey(1), max(1, args.requests))
    prompts = [jax.random.randint(k, (args.batch, args.prompt_len), 0,
                                  cfg.vocab) for k in keys]
    res = runner.serve(prompts, gen_steps=args.gen,
                       group_size=args.group_size or plan.group_size,
                       prefill_chunk=args.prefill_chunk)
    s = res.stats
    print(f"[serve] {args.requests} requests x {args.batch} batch: "
          f"{s['wall_s']*1e3:.0f} ms ({s['tokens_per_s']:.0f} tok/s, "
          f"{s['total_tokens']} tokens, fused decode batches "
          f"{s['fused_sizes']}, on {len(jax.devices())} local device(s))")
    for kind, mesh_name, t in res.trace:
        print(f"  {kind:<8} on {mesh_name}-mesh  {t*1e3:7.1f} ms")
    return 0


if __name__ == "__main__":
    sys.exit(main())
