"""Sharding rules (DESIGN.md §5): MaxText-style logical rules with a
divisibility guard.

Plan (mesh axes: optional 'pod', 'data', 'model'):
  * TP over 'model': attention heads / q-dim, FFN hidden, vocab.
  * FSDP over 'data': the d_model dim of every weight matrix.
  * 'pod' carries pure data parallelism (batch); params replicated across
    pods (inter-pod links are the slow tier).
  * KV caches: batch over 'data', then heads over 'model' when divisible,
    else sequence over 'model' (split-K decode; DESIGN.md §5).

``sanitize`` drops a mesh axis from a spec whenever the corresponding dim is
not divisible (e.g. qwen2-0.5b's 14 heads, granite-moe's vocab 49155) —
recorded in the dry-run output as a fallback, not a failure.
"""
from __future__ import annotations


import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _axsize(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _axsize(mesh, n)
        return out
    return mesh.shape[name] if name in mesh.axis_names else 0


def sanitize(shape: tuple[int, ...], spec: P, mesh: Mesh,
             fallbacks: list | None = None) -> P:
    """Drop axes that don't exist in the mesh or don't divide the dim.
    A tuple axis that fails degrades to its largest working member."""
    out = []
    for dim, ax in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                       - len(spec))):
        size = _axsize(mesh, ax)
        if ax is not None and size and dim % size == 0:
            out.append(ax)
            continue
        if isinstance(ax, tuple):
            pick = None
            for member in sorted(ax, key=lambda a: -_axsize(mesh, a)):
                ms = _axsize(mesh, member)
                if ms and dim % ms == 0:
                    pick = member
                    break
            if pick is not None:
                if fallbacks is not None:
                    fallbacks.append((shape, ax, dim))
                out.append(pick)
                continue
        if ax is not None and fallbacks is not None and size != 0:
            fallbacks.append((shape, ax, dim))
        out.append(None)
    return P(*out)


def batch_axes(mesh: Mesh):
    from repro.lm import pshard
    names = (("pod", "data", "model") if pshard.dp_only()
             else ("pod", "data"))
    axes = tuple(a for a in names if a in mesh.axis_names)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Hillclimb knobs (EXPERIMENTS.md §Perf).

    fsdp: shard weight d_model dims over 'data' (ZeRO-3 style).  Worth it
      only when params+opt exceed HBM; for small models the per-microbatch
      weight all-gathers dominate the step (observed 227x the compute term
      on qwen2-0.5b train).
    feature_2d: serving-only — shard weight *feature* dims over
      ('data','model') combined (256-way TP).  Removes the per-layer
      weight all-gathers from decode at the cost of tiny per-layer
      activation all-reduces.
    """
    fsdp: bool = True
    feature_2d: bool = False
    dp_only: bool = False   # pure data parallelism: weights replicated,
    #                         batch over every mesh axis (small models)
    zero1: bool = False     # shard optimizer moments over 'model' even
    #                         when params are replicated (ZeRO-1)
    embed_fsdp: bool = False  # shard the embedding table (vocab over
    #                           'data'); costs one table all-gather per
    #                           microbatch, saves ~2.6 GB/device at 104B
    grads_bf16: bool = False  # accumulate microbatch grads in bf16


DEFAULT_POLICY = ShardingPolicy()


def auto_policy(param_count: int, kind: str, model_axis: int = 16,
                hbm_bytes: float = 16 * 1024 ** 3) -> ShardingPolicy:
    """Pick the sharding policy from the model's memory needs (the
    optimized path; baseline uses DEFAULT_POLICY)."""
    if kind == "train":
        # params bf16 + grads f32 + adam m,v f32, TP-sharded only
        need = param_count * (2 + 4 + 8) / model_axis
        return ShardingPolicy(fsdp=need > 0.45 * hbm_bytes)
    # serving: no optimizer state; 2D feature sharding when TP-only
    # weights would crowd out the KV cache
    need = param_count * 2 / model_axis
    return ShardingPolicy(fsdp=False, feature_2d=need > 0.2 * hbm_bytes)


# --------------------------------------------------------------------------
# Parameter rules, keyed by pytree path
# --------------------------------------------------------------------------
def _apply_policy(spec: P, policy: ShardingPolicy) -> P:
    if policy.dp_only:
        return P(*([None] * len(spec)))
    out = []
    for ax in spec:
        if ax == "data" and not policy.fsdp:
            out.append(None)
        elif ax == "model" and policy.feature_2d:
            out.append(("data", "model"))
        else:
            out.append(ax)
    return P(*out)


def _param_rule(path: str, ndim: int) -> P:
    """Logical spec by leaf name; leading 'L' (stacked layers) is never
    sharded.  Written for unstacked rank; a stacked leaf gets None prepended
    by the caller."""
    name = path.split("/")[-1]
    stacked = path.startswith("blocks") or path.startswith("enc_blocks") \
        or path.startswith("cross_blocks")
    lead = (None,) if stacked else ()
    moe = "/mlp/" in path and name in ("wg", "wu", "wd") and \
        ndim == len(lead) + 3
    shared_moe = "/shared/" in path
    if name == "embed":
        # vocab replicated (keeps the token gather local), d_model over
        # 'model' so the gather output (batch->data, d->model) lines up
        # with the activation layout — FSDP'ing d over 'data' here collides
        # with the batch axis and forces involuntary rematerialization.
        # (policy.embed_fsdp shards vocab over 'data' instead: one table
        # all-gather per microbatch, applied in _apply_policy2.)
        return P(None, "model")
    if name == "lm_head":
        return P("data", "model")
    if name in ("wq", "wk", "wv") and not moe:
        return P(*lead, "data", "model")
    if name == "wo":
        return P(*lead, "model", "data")
    if name in ("bq", "bk", "bv"):
        return P(*lead, "model")
    if name == "router":
        return P(*lead, "data", None)
    if (moe or shared_moe) and name in ("wg", "wu"):
        return P(*lead, "model", "data", None)   # experts over model (EP)
    if (moe or shared_moe) and name == "wd":
        return P(*lead, "model", None, "data")
    if name in ("wg", "wu"):                      # dense mlp
        return P(*lead, "data", "model")
    if name == "wd":
        return P(*lead, "model", "data")
    if name == "in_proj":                         # mamba2
        return P(*lead, "data", "model")
    if name == "out_proj":
        return P(*lead, "model", "data")
    if name == "w_gates":                         # mlstm
        return P(*lead, "data", None)
    if name == "enc_pos":
        return P(None, "data")
    return P()                                    # norms, biases, conv_w


def _ep_fallback(spec: P, shape, mesh) -> P:
    """MoE fallback: if experts don't divide 'model', shard the FFN dim
    instead (granite-moe: 40 experts on a 16-way axis)."""
    if len(shape) >= 3 and spec and spec[len(spec) - 3] == "model":
        e_dim = shape[-3]
        if e_dim % _axsize(mesh, "model") != 0:
            # move 'model' to the F dim: (..., E, D, F) or (..., E, F, D)
            lead = (None,) * (len(shape) - 3)
            if spec[-1] is None:      # (E, D, F) case: wg/wu
                return P(*lead, None, "data", "model")
            return P(*lead, None, "model", "data")
    return spec


def param_specs(params_tree, mesh: Mesh, fallbacks: list | None = None,
                policy: ShardingPolicy = DEFAULT_POLICY):
    """PartitionSpec tree matching ``params_tree`` (arrays or SDS)."""
    def visit(path_parts, leaf):
        path = "/".join(str(p) for p in path_parts)
        spec = _param_rule(path, leaf.ndim)
        if path.endswith("embed") and policy.embed_fsdp:
            spec = P("data", "model")
        spec = _ep_fallback(spec, leaf.shape, mesh)
        spec = _apply_policy(spec, policy)
        # pad/truncate spec to rank
        spec = P(*(tuple(spec) + (None,) * leaf.ndim)[:leaf.ndim])
        return sanitize(leaf.shape, spec, mesh, fallbacks)

    return jax.tree_util.tree_map_with_path(
        lambda kp, l: visit([_key(k) for k in kp], l), params_tree)


def _key(k):
    if hasattr(k, "key"):
        return k.key
    if hasattr(k, "idx"):
        return k.idx
    return str(k)


# --------------------------------------------------------------------------
# Activation / state rules
# --------------------------------------------------------------------------
def batch_spec(mesh: Mesh, ndim: int) -> P:
    return P(*((batch_axes(mesh),) + (None,) * (ndim - 1)))


def cache_specs(cache_tree, mesh: Mesh, fallbacks=None):
    """DecodeCache sharding: KV (L,B,H,S,D): batch->data(+pod), heads->model
    if divisible else sequence->model; SSM state (L,B,H,P,N): heads->model.
    """
    b_ax = batch_axes(mesh)
    msize = _axsize(mesh, "model")

    def visit(path_parts, leaf):
        name = "/".join(str(_key(k)) for k in path_parts)
        if leaf is None:
            return None
        if leaf.ndim == 5 and ("kv" in name or "shared" in name
                               or "cross" in name):
            L, B, H, S, D = leaf.shape
            if H % msize == 0:
                spec = P(None, b_ax, "model", None, None)
            else:
                spec = P(None, b_ax, None, "model", None)
            return sanitize(leaf.shape, spec, mesh, fallbacks)
        if leaf.ndim == 5 and "ssm" in name:
            spec = P(None, b_ax, "model", None, None)
            return sanitize(leaf.shape, spec, mesh, fallbacks)
        if leaf.ndim >= 2:
            return sanitize(leaf.shape, P(None, b_ax), mesh, fallbacks)
        return P()

    return jax.tree_util.tree_map_with_path(
        lambda kp, l: visit(kp, l), cache_tree,
        is_leaf=lambda x: x is None)


def opt_state_specs(opt_state, pspecs, mesh: Mesh):
    """Adam moments shard like their parameters; step scalar replicated."""
    def visit(leaf, ref_tree=None):
        return leaf
    from repro.train.optimizer import AdamWState
    return AdamWState(step=P(), m=pspecs, v=pspecs)


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: None if s is None else NamedSharding(mesh, s),
        spec_tree, is_leaf=lambda x: isinstance(x, P) or x is None)


def zero1_specs(params_tree, mesh: Mesh):
    """ZeRO-1: optimizer-moment specs — shard each leaf's largest divisible
    dim over 'model' (params themselves stay replicated)."""
    msize = _axsize(mesh, "model")

    def visit(leaf):
        if leaf.ndim == 0 or not msize:
            return P()
        dims = list(leaf.shape)
        order = sorted(range(len(dims)), key=lambda i: -dims[i])
        for i in order:
            if dims[i] % msize == 0:
                spec = [None] * len(dims)
                spec[i] = "model"
                return P(*spec)
        return P()

    return jax.tree.map(visit, params_tree)
