"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2_0_5b --smoke \
      --steps 100 --ckpt-dir /tmp/ckpt [--resume]

Wires the fault-tolerant TrainRunner (checkpoints, recovery, straggler
accounting) to any registered architecture; ``--smoke`` selects the reduced
config (CPU-runnable), otherwise the full config is used (requires a pod).
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.configs.registry import ARCH_IDS, get_arch, get_smoke
from repro.data.pipeline import DataConfig
from repro.train.optimizer import AdamW
from repro.train.runner import RunnerConfig, TrainRunner


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_arch(args.arch)
    rcfg = RunnerConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                        max_steps=args.steps,
                        microbatches=args.microbatches)
    opt = AdamW(lr=args.lr, total_steps=args.steps,
                warmup_steps=max(1, args.steps // 10))
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq_len,
                          global_batch=args.global_batch)
    runner = TrainRunner(cfg, rcfg, optimizer=opt, data_cfg=data_cfg)
    if not args.resume:
        import shutil
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    out = runner.run()
    print(f"[train] arch={cfg.name} steps={out['final_step']} "
          f"loss={out['final_loss']:.4f} recoveries={out['recoveries']} "
          f"stragglers={out['stragglers']}")
    for m in out["metrics"][:: max(1, len(out["metrics"]) // 10)]:
        print(f"  step {m['step']:>5}  loss {m['loss']:.4f}  "
              f"lr {m['lr']:.2e}  {m['step_time_s']*1e3:.0f} ms")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(out, f, indent=1)
    return 0


if __name__ == "__main__":
    sys.exit(main())
