"""Architecture config for the assigned LM-family models.

One frozen dataclass covers all five families (dense / moe / hybrid / enc-dec
/ recurrent); family-specific fields are zero/None when unused.  The exact
instances live in ``repro.configs.<arch_id>`` and are registered in
``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                 # dense | moe | hybrid | audio | vlm | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0             # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    rope_theta: float = 1e4
    # --- MoE ---------------------------------------------------------------
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_shared: int = 0         # always-on shared experts (DeepSeek/Qwen-MoE)
    moe_capacity_factor: float = 1.25
    # token counts <= this use the dense all-experts path (decode: reading
    # every expert's weights dominates anyway, so dense compute is free)
    moe_dense_threshold: int = 512
    # --- SSM / hybrid ------------------------------------------------------
    block_type: str = "transformer"   # transformer | mamba2 | mlstm
    ssm_state: int = 0
    ssm_conv: int = 4
    d_inner_mult: int = 2       # d_inner = mult * d_model for ssm blocks
    attn_every: int = 0         # hybrid: shared attn block every k layers
    # --- enc-dec (whisper) ---------------------------------------------------
    encoder_decoder: bool = False
    enc_layers: int = 0
    enc_positions: int = 1500   # whisper: 1500 frames after the conv stem
    # --- multimodal ----------------------------------------------------------
    mrope: bool = False         # qwen2-vl M-RoPE (3 rotary sections)
    mrope_sections: tuple = (16, 24, 24)   # t/h/w split of d_head//2
    frontend: str | None = None  # 'audio' | 'vision' stub (input_specs emits
    #                              precomputed frame/patch embeddings)
    notes: str = ""

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert self.n_heads % 1 == 0
        if self.family == "moe":
            assert self.moe_experts > 0 and self.moe_top_k > 0

    # ---- derived ----------------------------------------------------------
    @property
    def padded_vocab(self) -> int:
        """LM-head vocab padded to a TP-shardable multiple (Megatron-style):
        keeps logits (vocab -> 'model')-sharded even for vocabs like
        whisper's 51865 or granite-moe's 49155.  Padded logit columns are
        masked to -inf in the loss / argmax."""
        mult = 2048
        return -(-self.vocab // mult) * mult

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    @property
    def d_inner(self) -> int:
        return self.d_inner_mult * self.d_model

    @property
    def ssm_heads(self) -> int:
        """Mamba2/mLSTM head count over d_inner (headdim 64 convention)."""
        return max(1, self.d_inner // 64)

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS=6ND)."""
        d, v = self.d_model, self.vocab
        total = v * d                      # embedding
        if not self.tie_embeddings:
            total += d * v                 # lm head
        per_layer = 0
        if self.block_type == "transformer":
            per_layer += d * self.q_dim + 2 * d * self.kv_dim \
                + self.q_dim * d           # qkvo
            if self.qkv_bias:
                per_layer += self.q_dim + 2 * self.kv_dim
            per_layer += 2 * d             # norms
            if self.family == "moe":
                per_layer += d * self.moe_experts        # router
                per_layer += 3 * d * self.d_ff * (self.moe_experts
                                                  + self.moe_shared)
            else:
                per_layer += 3 * d * self.d_ff           # swiglu
        elif self.block_type == "mamba2":
            din, st, nh = self.d_inner, self.ssm_state, self.ssm_heads
            per_layer += d * (2 * din + 2 * st + nh)     # in_proj
            per_layer += self.ssm_conv * (din + 2 * st)  # conv1d
            per_layer += nh * 2 + din                    # A, D, dt_bias-ish
            per_layer += din * d + d                     # out_proj + norm
        elif self.block_type == "mlstm":
            din = self.d_inner
            per_layer += d * 3 * din + d * 2 * self.ssm_heads  # qkv + i/f
            per_layer += din * d + 2 * d                       # out + norms
        total += self.n_layers * per_layer
        if self.attn_every:                # zamba2 shared attn+mlp block
            total += (d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
                      + 3 * d * self.d_ff + 2 * d)
        if self.encoder_decoder:
            enc_per = (4 * d * d + 3 * d * self.d_ff + 2 * d)
            dec_cross = self.n_layers * (4 * d * d + d)
            total += self.enc_layers * enc_per + dec_cross
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE uses top_k + shared experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.n_layers * 3 * d * self.d_ff * (
            self.moe_experts + self.moe_shared)
        active = self.n_layers * 3 * d * self.d_ff * (self.moe_top_k
                                                      + self.moe_shared)
        return int(dense + active)

    def scaled(self, **overrides) -> "ArchConfig":
        """Reduced-config clone for smoke tests."""
        return dataclasses.replace(self, **overrides)
