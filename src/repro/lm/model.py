"""Model assembly for the 10 assigned architectures.

One ``init_params(cfg, key)`` + ``forward(params, batch, cfg)`` pair covers
all families; layer stacks are scanned (stacked leading L axis) so the HLO
is O(1) in depth — essential for the 64/80-layer dry-run compiles.

Decode (``decode_step``) carries an explicit cache pytree:
  transformer: stacked (L, B, Hkv, S_max, Dh) K/V
  mamba2/mlstm: stacked SSM state (+ conv tail)
  zamba2 hybrid: SSM stack + per-application shared-attention KV
  whisper: decoder self-KV + precomputed cross-KV
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.lm.config import ArchConfig
from repro.lm.modules import (KVCache, attention_scores, cross_attention,
                              gqa_attention, moe_block, rms_norm,
                              swiglu_mlp)
from repro.lm.pshard import BATCH, MODEL, hint
from repro.lm.ssm import SSMState, mamba2_block, mamba2_dims, mlstm_block

INIT_SCALE = 0.02


# ==========================================================================
# Parameter initialisation
# ==========================================================================
def _dense(key, shape, dtype, scale=INIT_SCALE):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def _attn_params(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {"wq": _dense(ks[0], (cfg.d_model, cfg.q_dim), dtype),
         "wk": _dense(ks[1], (cfg.d_model, cfg.kv_dim), dtype),
         "wv": _dense(ks[2], (cfg.d_model, cfg.kv_dim), dtype),
         "wo": _dense(ks[3], (cfg.q_dim, cfg.d_model), dtype)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.q_dim,), dtype)
        p["bk"] = jnp.zeros((cfg.kv_dim,), dtype)
        p["bv"] = jnp.zeros((cfg.kv_dim,), dtype)
    return p


def _mlp_params(key, cfg: ArchConfig, dtype, d_ff=None):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {"wg": _dense(ks[0], (cfg.d_model, d_ff), dtype),
            "wu": _dense(ks[1], (cfg.d_model, d_ff), dtype),
            "wd": _dense(ks[2], (d_ff, cfg.d_model), dtype)}


def _moe_params(key, cfg: ArchConfig, dtype):
    ks = jax.random.split(key, 7)
    e, f, d = cfg.moe_experts, cfg.d_ff, cfg.d_model
    p = {"router": _dense(ks[0], (d, e), dtype),
         "wg": _dense(ks[1], (e, d, f), dtype),
         "wu": _dense(ks[2], (e, d, f), dtype),
         "wd": _dense(ks[3], (e, f, d), dtype)}
    if cfg.moe_shared:
        s = cfg.moe_shared
        p["shared"] = {"wg": _dense(ks[4], (s, d, f), dtype),
                       "wu": _dense(ks[5], (s, d, f), dtype),
                       "wd": _dense(ks[6], (s, f, d), dtype)}
    return p


def _block_params(key, cfg: ArchConfig, dtype):
    """One layer's params (unstacked)."""
    if cfg.block_type == "transformer":
        ka, km = jax.random.split(key)
        p = {"ln1": jnp.ones((cfg.d_model,), dtype),
             "ln2": jnp.ones((cfg.d_model,), dtype),
             "attn": _attn_params(ka, cfg, dtype)}
        p["mlp"] = (_moe_params(km, cfg, dtype) if cfg.family == "moe"
                    else _mlp_params(km, cfg, dtype))
        return p
    if cfg.block_type == "mamba2":
        din, nh, hp, ns = mamba2_dims(cfg)
        ks = jax.random.split(key, 3)
        zdim = 2 * din + 2 * ns + nh
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "in_proj": _dense(ks[0], (cfg.d_model, zdim), dtype),
                "conv_w": _dense(ks[1], (cfg.ssm_conv, din + 2 * ns), dtype,
                                 0.2),
                "dt_bias": jnp.zeros((nh,), dtype),
                "a_log": jnp.zeros((nh,), jnp.float32),
                "d_skip": jnp.ones((din,), dtype),
                "out_proj": _dense(ks[2], (din, cfg.d_model), dtype)}
    if cfg.block_type == "mlstm":
        din, nh = cfg.d_inner, cfg.ssm_heads
        ks = jax.random.split(key, 5)
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "wq": _dense(ks[0], (cfg.d_model, din), dtype),
                "wk": _dense(ks[1], (cfg.d_model, din), dtype),
                "wv": _dense(ks[2], (cfg.d_model, din), dtype),
                "w_gates": _dense(ks[3], (cfg.d_model, 2 * nh), dtype),
                "wo": _dense(ks[4], (din, cfg.d_model), dtype)}
    raise ValueError(cfg.block_type)


def _stacked_blocks(key, cfg: ArchConfig, n: int, dtype):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_params(k, cfg, dtype))(keys)


def init_params(cfg: ArchConfig, key: jax.Array, dtype=jnp.float32):
    ks = jax.random.split(key, 8)
    p: dict[str, Any] = {
        "embed": _dense(ks[0], (cfg.vocab, cfg.d_model), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
        "blocks": _stacked_blocks(ks[1], cfg, cfg.n_layers, dtype),
    }
    # Execution always carries a separate lm_head, even for tied configs
    # (initialised from the same key as the embedding).  A literal
    # ``embed.T`` head inherits the gather-friendly (vocab-replicated,
    # d->model) embedding sharding, whose transpose forces replicated
    # full-vocab logits (~20 GB/device observed).  Tying still counts once
    # in cfg.param_count(); deviation recorded in DESIGN.md §7.
    p["lm_head"] = _dense(ks[2] if not cfg.tie_embeddings else ks[0],
                          (cfg.d_model, cfg.padded_vocab), dtype)
    if cfg.attn_every:                       # zamba2 shared attn+mlp block
        p["shared_attn"] = {"ln1": jnp.ones((cfg.d_model,), dtype),
                            "ln2": jnp.ones((cfg.d_model,), dtype),
                            "attn": _attn_params(ks[3], cfg, dtype),
                            "mlp": _mlp_params(ks[4], cfg, dtype)}
    if cfg.encoder_decoder:
        enc_cfg = dataclasses.replace(cfg, qkv_bias=False,
                                      block_type="transformer",
                                      family="dense")
        p["enc_blocks"] = _stacked_blocks(ks[5], enc_cfg, cfg.enc_layers,
                                          dtype)
        p["enc_pos"] = _dense(ks[6], (cfg.enc_positions, cfg.d_model), dtype)
        p["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        cross = jax.vmap(lambda k: {
            "ln": jnp.ones((cfg.d_model,), dtype),
            "attn": _attn_params(k, cfg, dtype)})(
                jax.random.split(ks[7], cfg.n_layers))
        p["cross_blocks"] = cross
    return p


# ==========================================================================
# Forward (training / prefill)
# ==========================================================================
def _transformer_layer(lp, x, cfg, positions, positions3, causal=True):
    h, _ = gqa_attention(lp["attn"], rms_norm(x, lp["ln1"], cfg.norm_eps),
                         cfg, positions, positions3=positions3,
                         causal=causal)
    x = x + h
    inner = rms_norm(x, lp["ln2"], cfg.norm_eps)
    if cfg.family == "moe":
        x = x + moe_block(lp["mlp"], inner, cfg)
    else:
        x = x + swiglu_mlp(lp["mlp"], inner)
    return x


def _ssm_layer(lp, x, cfg, state=None):
    block = mamba2_block if cfg.block_type == "mamba2" else mlstm_block
    h, new_state = block(lp, rms_norm(x, lp["ln"], cfg.norm_eps), cfg, state)
    return x + h, new_state


def _shared_attn_apply(sp, x, cfg, positions):
    h, _ = gqa_attention(sp["attn"], rms_norm(x, sp["ln1"], cfg.norm_eps),
                         cfg, positions)
    x = x + h
    x = x + swiglu_mlp(sp["mlp"], rms_norm(x, sp["ln2"], cfg.norm_eps))
    return x


def encode(params, cfg: ArchConfig, enc_input: jax.Array) -> jax.Array:
    """Whisper encoder over precomputed frame embeddings (frontend stub)."""
    x = enc_input + params["enc_pos"][None, :enc_input.shape[1]]
    positions = jnp.arange(x.shape[1])

    def body(h, lp):
        return _transformer_layer(lp, h, cfg, positions, None,
                                  causal=False), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _inner_group_size(L: int) -> int:
    """Largest divisor of L not exceeding ~sqrt(L) (2-level remat)."""
    best = 1
    d = 1
    while d * d <= L * 4:
        if L % d == 0 and d * d <= L * 2:
            best = d
        d += 1
    return best


def scan_layers(body, x, xs, L: int, remat: bool):
    """Scan over L layers with 2-level (sqrt-L) rematerialisation.

    A flat rematted scan saves every layer's input — (L, B, S, D) ~6.4 GB
    per device for the 64-layer 12288-wide config.  Grouping layers into
    ~sqrt(L) chunks and checkpointing the *group* keeps only group-boundary
    carries plus one group's transient residuals (~6x smaller there)."""
    inner = _inner_group_size(L) if (remat and L >= 16) else 0
    if not inner or inner < 2:
        fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(fn, x, xs)
        return x
    outer = L // inner
    xs2 = jax.tree.map(
        lambda a: a.reshape((outer, inner) + a.shape[1:]), xs)
    inner_body = jax.checkpoint(body)   # nested: per-layer residuals are
    #                                     recomputed, only carries saved

    @jax.checkpoint
    def group(h, chunk):
        h, _ = jax.lax.scan(inner_body, h, chunk)
        return h, None

    x, _ = jax.lax.scan(group, x, xs2)
    return x


def forward(params, cfg: ArchConfig, tokens: jax.Array,
            positions3: jax.Array | None = None,
            enc_input: jax.Array | None = None,
            extra_embeds: jax.Array | None = None,
            remat: bool = True) -> jax.Array:
    """tokens: (B, S) -> logits (B, S, vocab)."""
    B, S = tokens.shape
    x = hint(params["embed"][tokens], BATCH, None, None)
    if extra_embeds is not None:              # vlm stub: patch embeddings
        n = extra_embeds.shape[1]
        x = x.at[:, :n].add(extra_embeds.astype(x.dtype))
    positions = jnp.arange(S)
    memory = (encode(params, cfg, enc_input)
              if cfg.encoder_decoder else None)

    if cfg.block_type == "transformer" and not cfg.encoder_decoder:
        def body(h, lp):
            return _transformer_layer(lp, h, cfg, positions, positions3), None
        x = scan_layers(body, x, params["blocks"], cfg.n_layers, remat)
    elif cfg.encoder_decoder:
        def body(h, lps):
            lp, cp = lps
            att, _ = gqa_attention(
                lp["attn"], rms_norm(h, lp["ln1"], cfg.norm_eps), cfg,
                positions)
            h = h + att
            h = h + cross_attention(cp["attn"],
                                    rms_norm(h, cp["ln"], cfg.norm_eps),
                                    memory, cfg)
            h = h + swiglu_mlp(lp["mlp"],
                               rms_norm(h, lp["ln2"], cfg.norm_eps))
            return h, None
        x = scan_layers(body, x, (params["blocks"], params["cross_blocks"]),
                        cfg.n_layers, remat)
    else:                                     # mamba2 / mlstm / hybrid
        k_every = cfg.attn_every
        sp = params.get("shared_attn")

        def body(carry, inp):
            h = carry
            li, lp = inp
            h, _ = _ssm_layer(lp, h, cfg)
            if k_every:
                h = jax.lax.cond(
                    (li + 1) % k_every == 0,
                    lambda hh: _shared_attn_apply(sp, hh, cfg, positions),
                    lambda hh: hh, h)
            return h, None
        x = scan_layers(body, x, (jnp.arange(cfg.n_layers),
                                  params["blocks"]), cfg.n_layers, remat)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return hint(jnp.einsum("bsd,dv->bsv", x, params["lm_head"]),
                BATCH, None, MODEL)


# ==========================================================================
# Decode (single new token against a cache)
# ==========================================================================
class DecodeCache(NamedTuple):
    kv_k: jax.Array | None      # (L, B, Hkv, S_max, Dh)
    kv_v: jax.Array | None
    ssm: jax.Array | None       # (L, B, H, P, N)
    conv: jax.Array | None      # (L, B, K-1, C)
    shared_k: jax.Array | None  # (n_apps, B, Hkv, S_max, Dh)  (zamba2)
    shared_v: jax.Array | None
    cross_k: jax.Array | None   # (L, B, H, M, Dh)  (whisper)
    cross_v: jax.Array | None
    pos: jax.Array              # scalar int32: tokens already cached


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.float32, memory: jax.Array | None = None,
               params=None, kv_dtype=None) -> DecodeCache:
    """``kv_dtype=jnp.int8`` stores the self-attention KV quantized
    (static-scale, see modules.quantize_kv); activations/cross/shared
    caches stay in ``dtype``."""
    L = cfg.n_layers
    mk = lambda *s: jnp.zeros(s, dtype)
    kv_k = kv_v = ssm = conv = sk = sv = ck = cv = None
    if cfg.block_type == "transformer":
        kvd = kv_dtype or dtype
        kv_k = jnp.zeros((L, batch, cfg.n_kv_heads, max_len, cfg.d_head),
                         kvd)
        kv_v = jnp.zeros((L, batch, cfg.n_kv_heads, max_len, cfg.d_head),
                         kvd)
    else:
        din, nh, hp, ns = mamba2_dims(cfg)
        if cfg.block_type == "mlstm":
            nh, hp, ns = cfg.ssm_heads, cfg.d_inner // cfg.ssm_heads + 1, \
                cfg.d_inner // cfg.ssm_heads
            ssm = jnp.zeros((L, batch, nh, hp, ns), jnp.float32)
        else:
            ssm = jnp.zeros((L, batch, nh, hp, ns), jnp.float32)
            conv = mk(L, batch, cfg.ssm_conv - 1, din + 2 * ns)
    if cfg.attn_every:
        n_apps = cfg.n_layers // cfg.attn_every
        sk = mk(n_apps, batch, cfg.n_kv_heads, max_len, cfg.d_head)
        sv = mk(n_apps, batch, cfg.n_kv_heads, max_len, cfg.d_head)
    if cfg.encoder_decoder:
        assert memory is not None and params is not None
        m = memory.shape[1]

        def one(cp):
            k = jnp.einsum("bmd,dk->bmk", memory, cp["attn"]["wk"]).reshape(
                batch, m, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            v = jnp.einsum("bmd,dk->bmk", memory, cp["attn"]["wv"]).reshape(
                batch, m, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            return k.astype(dtype), v.astype(dtype)
        ck, cv = jax.vmap(one)(params["cross_blocks"])
    return DecodeCache(kv_k, kv_v, ssm, conv, sk, sv, ck, cv,
                       jnp.zeros((), jnp.int32))


def decode_step(params, cfg: ArchConfig, token: jax.Array,
                cache: DecodeCache,
                positions3: jax.Array | None = None):
    """token: (B, S) with S >= 1 -> (logits (B, S, V), new cache).

    S == 1 is the serve_step; S > 1 is chunked prefill (the dual-mesh
    load-balance knob, DESIGN.md §2)."""
    B, S = token.shape
    x = hint(params["embed"][token], BATCH, None, None)
    pos = cache.pos
    positions = pos + jnp.arange(S)

    # NOTE on cache plumbing: the stacked KV cache flows through the layer
    # scan as xs/ys.  A carried-buffer + in-place-DUS variant was tried and
    # reverted: GSPMD loses the carry's sharding through the while loop and
    # replicates the whole cache (+80 GB/device).  The xs/ys form keeps the
    # sharding but double-buffers the stack on the CPU backend's memory
    # analysis; see EXPERIMENTS.md §Perf (KV-int8 hillclimb).
    if cfg.block_type == "transformer" and not cfg.encoder_decoder:
        def body(h, lps):
            lp, ck, cv = lps
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            att, nc = gqa_attention(lp["attn"], hn, cfg, positions,
                                    cache=KVCache(ck, cv), cache_pos=pos,
                                    positions3=positions3)
            h = h + att
            inner = rms_norm(h, lp["ln2"], cfg.norm_eps)
            h = h + (moe_block(lp["mlp"], inner, cfg)
                     if cfg.family == "moe" else swiglu_mlp(lp["mlp"], inner))
            return h, (nc.k, nc.v)
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], cache.kv_k, cache.kv_v))
        cache = cache._replace(kv_k=nk, kv_v=nv)
    elif cfg.encoder_decoder:
        def body(h, lps):
            lp, cp, ck, cv, xk, xv = lps
            hn = rms_norm(h, lp["ln1"], cfg.norm_eps)
            att, nc = gqa_attention(lp["attn"], hn, cfg, positions,
                                    cache=KVCache(ck, cv), cache_pos=pos)
            h = h + att
            # cross attention against precomputed encoder K/V
            hq = rms_norm(h, cp["ln"], cfg.norm_eps)
            q = jnp.einsum("bsd,dq->bsq", hq, cp["attn"]["wq"]).reshape(
                B, S, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
            xo = attention_scores(q, xk, xv, causal=False, q_offset=0)
            xo = xo.transpose(0, 2, 1, 3).reshape(B, S, cfg.q_dim)
            h = h + jnp.einsum("bsq,qd->bsd", xo, cp["attn"]["wo"])
            inner = rms_norm(h, lp["ln2"], cfg.norm_eps)
            h = h + swiglu_mlp(lp["mlp"], inner)
            return h, (nc.k, nc.v)
        x, (nk, nv) = jax.lax.scan(
            body, x, (params["blocks"], params["cross_blocks"],
                      cache.kv_k, cache.kv_v, cache.cross_k, cache.cross_v))
        cache = cache._replace(kv_k=nk, kv_v=nv)
    else:
        k_every = cfg.attn_every
        sp = params.get("shared_attn")
        sk, sv = cache.shared_k, cache.shared_v

        def body(carry, inp):
            h, sk, sv = carry
            li, lp, s, cv_ = inp
            st = SSMState(s, cv_)
            h, ns = _ssm_layer(lp, h, cfg, st)
            if k_every:
                app = li // k_every

                def apply(args):
                    hh, sk, sv = args
                    hn = rms_norm(hh, sp["ln1"], cfg.norm_eps)
                    att, nc = gqa_attention(
                        sp["attn"], hn, cfg, positions,
                        cache=KVCache(sk[app], sv[app]), cache_pos=pos)
                    hh = hh + att
                    hh = hh + swiglu_mlp(sp["mlp"], rms_norm(
                        hh, sp["ln2"], cfg.norm_eps))
                    return (hh, sk.at[app].set(nc.k), sv.at[app].set(nc.v))

                h, sk, sv = jax.lax.cond(
                    (li + 1) % k_every == 0, apply,
                    lambda a: a, (h, sk, sv))
            return (h, sk, sv), (ns.s, ns.conv if ns.conv is not None
                                 else jnp.zeros((B, 0, 0)))
        conv_in = (cache.conv if cache.conv is not None
                   else jnp.zeros((cfg.n_layers, B, 0, 0)))
        (x, sk, sv), (ns, nconv) = jax.lax.scan(
            body, (x, sk if sk is not None else jnp.zeros((1,)),
                   sv if sv is not None else jnp.zeros((1,))),
            (jnp.arange(cfg.n_layers), params["blocks"], cache.ssm,
             conv_in))
        cache = cache._replace(
            ssm=ns, conv=nconv if cache.conv is not None else None,
            shared_k=sk if cache.shared_k is not None else None,
            shared_v=sv if cache.shared_v is not None else None)

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = hint(jnp.einsum("bsd,dv->bsv", x, params["lm_head"]),
                  BATCH, None, MODEL)
    return logits, cache._replace(pos=cache.pos + S)
