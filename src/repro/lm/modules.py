"""Transformer building blocks shared by all assigned architectures.

Pure functions over explicit param pytrees (no framework): GQA attention
(with optional QKV bias and KV cache), RoPE / M-RoPE, SwiGLU MLP, and the
scatter-dispatch MoE (shared + routed top-k experts).

All matmuls keep weights in the layout (d_in, d_out) so TP sharding rules
('model' on d_out for up-projections, on d_in for down-projections) apply
uniformly (see repro.launch.sharding).
"""
from __future__ import annotations

import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.lm.pshard import BATCH, MODEL, hint


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------
def rms_norm(x, w, eps=1e-6):
    return rmsnorm_ref(x, w, eps)


def layer_norm(x, w, b, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(x.dtype)


# --------------------------------------------------------------------------
# Rotary embeddings (RoPE + qwen2-vl M-RoPE)
# --------------------------------------------------------------------------
def rope_freqs(d_head: int, theta: float, positions: jax.Array) -> tuple:
    """positions: (..., S) int -> cos/sin (..., S, d_head//2) f32."""
    half = d_head // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, H, S, D); cos/sin: (B, S, D//2) or (S, D//2)."""
    if cos.ndim == 2:
        cos = cos[None]
        sin = sin[None]
    cos = cos[:, None]          # (B, 1, S, D/2)
    sin = sin[:, None]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin],
                          axis=-1)
    return out.astype(x.dtype)


def mrope_freqs(d_head: int, theta: float, positions3: jax.Array,
                sections: tuple[int, ...]) -> tuple:
    """qwen2-vl M-RoPE: positions3 (B, 3, S) (t/h/w); the d_head//2 rotary
    dims are split into ``sections`` bands, each driven by one position
    stream.  For text-only input all three streams are equal and M-RoPE
    reduces to RoPE (property-tested)."""
    half = d_head // 2
    assert sum(sections) == half, (sections, half)
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    cos_parts, sin_parts = [], []
    start = 0
    for band, sec in enumerate(sections):
        pos = positions3[:, band].astype(jnp.float32)          # (B, S)
        ang = pos[..., None] * inv[start:start + sec]          # (B, S, sec)
        cos_parts.append(jnp.cos(ang))
        sin_parts.append(jnp.sin(ang))
        start += sec
    return (jnp.concatenate(cos_parts, -1), jnp.concatenate(sin_parts, -1))


# --------------------------------------------------------------------------
# Attention (GQA, optional bias, optional KV cache)
# --------------------------------------------------------------------------
class KVCache(NamedTuple):
    k: jax.Array            # (B, Hkv, S_max, Dh)
    v: jax.Array


# Query-block size of the chunked attention — the Eq.2 "input size per PE"
# analogue on the LM side: bounds score memory at O(Q_CHUNK x Sk).
# Env-tunable for §Perf sweeps.
Q_CHUNK = int(os.environ.get("REPRO_Q_CHUNK", "512"))

# Static symmetric scales for int8 KV quantization (KIVI-style, but with
# calibration folded to a constant: post-rope k/v are ~N(0,1) at our init;
# production would calibrate per channel).  q and p are quantized on the
# fly so the dots run int8 x int8 -> s32 — no bf16 dequantised copy of the
# cache is ever materialised (the point of the optimization).
KV_SCALE = 32.0
Q_SCALE = 32.0
P_SCALE = 127.0


def quantize_kv(x: jax.Array) -> jax.Array:
    return jnp.clip(jnp.round(x.astype(jnp.float32) * KV_SCALE),
                    -127, 127).astype(jnp.int8)


def _attn_block(qg, k, v, q_pos, causal, kv_valid):
    """One query block: qg (B,Hkv,G,bq,D) vs full k/v (B,Hkv,Sk,D).

    K/V stay in their storage dtype with fp32/s32 accumulation via
    preferred_element_type — upcasting the whole KV cache materialises a
    fp32 copy of it per layer (observed 5.4 GB/device on the 32k decode
    cells).  int8 caches run both dots in int8."""
    sk = k.shape[2]
    int8_kv = k.dtype == jnp.int8
    if int8_kv:
        qq = jnp.clip(jnp.round(qg.astype(jnp.float32) * Q_SCALE),
                      -127, 127).astype(jnp.int8)
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qq, k,
                       preferred_element_type=jnp.int32)
        s = s.astype(jnp.float32) / (Q_SCALE * KV_SCALE)
    else:
        s = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(k.dtype), k,
                       preferred_element_type=jnp.float32)
    k_pos = jnp.arange(sk)
    mask = jnp.ones((qg.shape[3], sk), bool)
    if causal:
        mask = k_pos[None, :] <= q_pos[:, None]
    if kv_valid is not None:
        mask = mask[None] & (k_pos[None, None, :] < kv_valid[:, None, None])
        s = jnp.where(mask[:, None, None], s, -1e30)
    else:
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    if int8_kv:
        pq = jnp.round(p * P_SCALE).astype(jnp.int8)
        out = jnp.einsum("bhgqk,bhkd->bhgqd", pq, v,
                         preferred_element_type=jnp.int32)
        return out.astype(jnp.float32) / (P_SCALE * KV_SCALE)
    return jnp.einsum("bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32)


def attention_scores(q, k, v, causal: bool, q_offset=None,
                     kv_valid: jax.Array | None = None):
    """GQA attention used by the lowered (XLA) path.

    q: (B,Hq,Sq,D), k/v: (B,Hkv,Sk,D).  ``q_offset`` positions the query
    block inside the kv sequence (decode / chunked prefill); ``kv_valid``
    masks the cache tail.

    Long query sequences are processed in Q_CHUNK blocks via lax.scan (the
    flash-attention discipline in pure XLA): peak score memory is
    O(bq x Sk) instead of O(Sq x Sk), which is what makes the 32k train /
    prefill cells fit (the Pallas flash kernel is the on-hardware path;
    this is its XLA twin for the CPU-backend dry-run)."""
    b, hq, sq, d = q.shape
    hkv, sk = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32) / (d ** 0.5)
    qg = qf.reshape(b, hkv, g, sq, d)   # grouped: no KV duplication
    off = q_offset if q_offset is not None else sk - sq
    if sq <= Q_CHUNK:
        out = _attn_block(qg, k, v, jnp.arange(sq) + off, causal, kv_valid)
        return out.reshape(b, hq, sq, d).astype(q.dtype)
    nq = -(-sq // Q_CHUNK)
    pad = nq * Q_CHUNK - sq
    qp = jnp.pad(qg, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    qp = qp.reshape(b, hkv, g, nq, Q_CHUNK, d)

    # checkpoint each block: without it the scan's AD saves the softmax
    # probs (O(S^2) f32) as residuals — recompute them in the backward.
    blk_fn = jax.checkpoint(
        lambda blk, pos: _attn_block(blk, k, v, pos, causal, kv_valid))

    def body(_, i):
        blk = jax.lax.dynamic_index_in_dim(qp, i, axis=3, keepdims=False)
        pos = i * Q_CHUNK + jnp.arange(Q_CHUNK) + off
        return None, blk_fn(blk, pos)

    _, outs = jax.lax.scan(body, None, jnp.arange(nq))
    out = jnp.moveaxis(outs, 0, 3).reshape(b, hkv, g, nq * Q_CHUNK, d)
    return out[:, :, :, :sq].reshape(b, hq, sq, d).astype(q.dtype)


def gqa_attention(params: dict, x: jax.Array, cfg, positions: jax.Array,
                  cache: KVCache | None = None,
                  cache_pos: jax.Array | None = None,
                  positions3: jax.Array | None = None,
                  causal: bool = True):
    """Full attention block: qkv proj -> rope -> attention -> out proj.

    Returns (out, new_cache).  With a cache, k/v of the current block are
    written at ``cache_pos`` and attention runs over the whole cache.
    """
    b, s, d = x.shape
    x = hint(x, BATCH, None, None)
    q = hint(jnp.einsum("bsd,dq->bsq", x, params["wq"]), BATCH, None, MODEL)
    k = hint(jnp.einsum("bsd,dk->bsk", x, params["wk"]), BATCH, None, MODEL)
    v = hint(jnp.einsum("bsd,dk->bsk", x, params["wv"]), BATCH, None, MODEL)
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    if cfg.mrope and positions3 is not None:
        cos, sin = mrope_freqs(cfg.d_head, cfg.rope_theta, positions3,
                               cfg.mrope_sections)
    else:
        cos, sin = rope_freqs(cfg.d_head, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        assert cache_pos is not None
        k_store = (quantize_kv(k) if cache.k.dtype == jnp.int8
                   else k.astype(cache.k.dtype))
        v_store = (quantize_kv(v) if cache.v.dtype == jnp.int8
                   else v.astype(cache.v.dtype))
        ck = jax.lax.dynamic_update_slice(
            cache.k, k_store, (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(
            cache.v, v_store, (0, 0, cache_pos, 0))
        new_cache = KVCache(ck, cv)
        kv_valid = jnp.full((b,), cache_pos + s, jnp.int32)
        out = attention_scores(q, ck, cv, causal=causal, q_offset=cache_pos,
                               kv_valid=kv_valid)
    else:
        out = attention_scores(q, k, v, causal=causal, q_offset=0)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    out = hint(out, BATCH, None, MODEL)
    return hint(jnp.einsum("bsq,qd->bsd", out, params["wo"]),
                BATCH, None, None), new_cache


def cross_attention(params: dict, x: jax.Array, memory: jax.Array, cfg):
    """Whisper decoder cross-attention (memory = encoder output)."""
    b, s, d = x.shape
    m = memory.shape[1]
    q = jnp.einsum("bsd,dq->bsq", x, params["wq"]).reshape(
        b, s, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = jnp.einsum("bmd,dk->bmk", memory, params["wk"]).reshape(
        b, m, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = jnp.einsum("bmd,dk->bmk", memory, params["wv"]).reshape(
        b, m, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    out = attention_scores(q, k, v, causal=False, q_offset=0)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, cfg.q_dim)
    return jnp.einsum("bsq,qd->bsd", out, params["wo"])


# --------------------------------------------------------------------------
# MLP (SwiGLU) and MoE
# --------------------------------------------------------------------------
def swiglu_mlp(params: dict, x: jax.Array) -> jax.Array:
    x = hint(x, BATCH, None, None)
    gate = jax.nn.silu(hint(jnp.einsum("bsd,df->bsf", x, params["wg"]),
                            BATCH, None, MODEL))
    up = hint(jnp.einsum("bsd,df->bsf", x, params["wu"]), BATCH, None, MODEL)
    return hint(jnp.einsum("bsf,fd->bsd", gate * up, params["wd"]),
                BATCH, None, None)


def gelu_mlp(params: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, params["wu"])
                    + params.get("bu", 0.0))
    return jnp.einsum("bsf,fd->bsd", h, params["wd"]) + params.get("bd", 0.0)


def moe_block(params: dict, x: jax.Array, cfg) -> jax.Array:
    """MoE with shared experts (always on) + routed top-k.

    Two dispatch modes:
      * dense (t <= cfg.moe_dense_threshold, i.e. decode): every expert runs
        on every token, combined by the gate.  At decode batch sizes the
        step is bound by reading all expert weights from HBM once, so dense
        compute costs nothing extra and is drop-free (exactly matches the
        training router semantics) — the p-class discipline of DESIGN.md §2.
      * scatter (train/prefill): avoids the O(T*E*C) GShard combine tensor —
        token ranks within each expert come from a (T, E) cumsum, tokens
        scatter into an (E, C, D) buffer, experts run a grouped einsum, and
        results gather back weighted by the router prob.  Memory is
        O(T*E + E*C*D), sharding over ('data' on T/C, 'model' on E).
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.moe_experts, cfg.moe_top_k
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, k)                    # (t, k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)

    if t <= cfg.moe_dense_threshold:
        # dense path: (t, e, f) activations, no drops
        g_ = jax.nn.silu(jnp.einsum("td,edf->tef", xt, params["wg"]))
        u_ = jnp.einsum("td,edf->tef", xt, params["wu"])
        y_all = jnp.einsum("tef,efd->ted", g_ * u_, params["wd"])
        onehot = jax.nn.one_hot(idx, e, dtype=gate.dtype)  # (t, k, e)
        weights = jnp.einsum("tk,tke->te", gate, onehot)
        out = jnp.einsum("te,ted->td", weights, y_all)
        if cfg.moe_shared:
            sh = params["shared"]
            gs = jax.nn.silu(jnp.einsum("td,sdf->tsf", xt, sh["wg"]))
            us = jnp.einsum("td,sdf->tsf", xt, sh["wu"])
            out = out + jnp.einsum("tsf,sfd->td", gs * us, sh["wd"])
        return out.reshape(b, s, d).astype(x.dtype)

    cap = max(8, int(cfg.moe_capacity_factor * t * k / e))
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)       # (t, k, e)
    flat = onehot.reshape(t * k, e)
    rank = jnp.cumsum(flat, axis=0) - flat                 # (t*k, e)
    rank = jnp.sum(rank * flat, axis=-1).reshape(t, k)     # position in expert
    keep = rank < cap                                       # capacity drop
    gate = gate * keep

    buf = jnp.zeros((e, cap, d), xt.dtype)
    buf = buf.at[idx.reshape(-1), jnp.where(keep, rank, cap - 1).reshape(-1)
                 ].add((xt[:, None, :] * keep[..., None]).reshape(t * k, d))
    # routed experts: grouped SwiGLU
    g_ = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["wg"]))
    u_ = jnp.einsum("ecd,edf->ecf", buf, params["wu"])
    y = jnp.einsum("ecf,efd->ecd", g_ * u_, params["wd"])  # (e, cap, d)
    out = (y[idx.reshape(-1), jnp.where(keep, rank, 0).reshape(-1)]
           .reshape(t, k, d) * gate[..., None]).sum(axis=1)

    # shared experts (dense, always on)
    if cfg.moe_shared:
        sh = params["shared"]
        gs = jax.nn.silu(jnp.einsum("td,sdf->tsf", xt, sh["wg"]))
        us = jnp.einsum("td,sdf->tsf", xt, sh["wu"])
        out = out + jnp.einsum("tsf,sfd->td", gs * us, sh["wd"])
    return out.reshape(b, s, d).astype(x.dtype)


def moe_aux_loss(params: dict, x: jax.Array, cfg) -> jax.Array:
    """Load-balance auxiliary loss (Switch-style)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = jnp.einsum("td,de->te", xt, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top1 = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top1, cfg.moe_experts), axis=0)
    imp = jnp.mean(probs, axis=0)
    return cfg.moe_experts * jnp.sum(frac * imp)
