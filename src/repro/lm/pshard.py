"""Activation sharding hints (MaxText-style logical constraints).

``hint(x, 'batch', None, 'model')`` applies a with_sharding_constraint
resolved against the ambient mesh (repro.meshcompat.use_mesh /
current_mesh, portable across the jax.set_mesh API move).  Outside any
mesh (CPU
smoke tests) it is a no-op; axes that are missing from the mesh or do not
divide the dimension are dropped (same fallback policy as
repro.launch.sharding).

These hints pin the canonical layout — activations (batch->data, d
replicated), projections (batch->data, features->model) — so GSPMD
all-gathers the FSDP-sharded *weights* instead of partial-summing
activations over the data axis (which costs an all-reduce of a full
activation tensor per matmul; observed 10 TB/step before the hints).
"""
from __future__ import annotations

import math

import jax
from jax.sharding import PartitionSpec as P

from repro.meshcompat import current_mesh

BATCH = "batch"
MODEL = "model"

# dp_only mode (hillclimb knob): batch spans every mesh axis and 'model'
# resolves to nothing — pure data parallelism with replicated weights.
_DP_ONLY = False


def set_dp_only(flag: bool):
    global _DP_ONLY
    _DP_ONLY = flag


def dp_only() -> bool:
    return _DP_ONLY


def _mesh():
    return current_mesh()


def hint(x, *logical):
    mesh = _mesh()
    if mesh is None:
        return x
    names = mesh.axis_names
    shape = dict(zip(names, (mesh.shape[n] for n in names)))
    spec = []
    for dim, want in zip(x.shape, logical):
        ax = None
        if want == BATCH:
            cand = (tuple(n for n in ("pod", "data", "model")
                          if n in names) if _DP_ONLY else
                    tuple(n for n in ("pod", "data") if n in names))
            while cand:
                size = math.prod(shape[n] for n in cand)
                if dim % size == 0:
                    ax = cand if len(cand) > 1 else cand[0]
                    break
                cand = cand[:-1]
        elif want == MODEL and not _DP_ONLY:
            if "model" in names and dim % shape["model"] == 0:
                ax = "model"
        spec.append(ax)
    # pad remaining dims with None
    spec += [None] * (x.ndim - len(spec))
    return jax.lax.with_sharding_constraint(x, P(*spec))
