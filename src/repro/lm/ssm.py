"""Recurrent blocks: Mamba2 (SSD) and mLSTM (xLSTM), sharing one chunked
gated-linear scan.

Both are state-space recurrences of the form
    S_t = a_t * S_{t-1} + b_t (x) u_t          (state:  H x P x N)
    y_t = <S_t, c_t> (+ D * u_t)
with per-head scalar decay a_t.  Training/prefill uses a chunked scan:
within a chunk the contribution is a masked quadratic (attention-like)
einsum, across chunks a lax.scan carries the state — O(S * chunk) compute,
which is what makes the ``long_500k`` shape lowerable (DESIGN.md §4).
Decode is the plain one-step recurrence on a carried state.

Simplifications recorded in DESIGN.md §7:
  * mLSTM uses the GLA form (sigmoid forget, exp input gate clipped to
    [-10, 10] instead of the running-max stabiliser; the normaliser is the
    augmented-v row trick so it shares the SSD scan).
  * xlstm-350m is built from mLSTM blocks only (the 350M xLSTM is
    predominantly mLSTM; sLSTM's strictly sequential recurrence does not map
    to TPU training parallelism).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.lm.pshard import BATCH, MODEL, hint

import os
# SSD chunk length: the intra-chunk quadratic costs O(S*CHUNK) flops while
# the cross-chunk scan costs O(S/CHUNK) sequential steps — a §Perf knob
# (EXPERIMENTS.md, zamba2 chunk sweep).  Env-tunable for the dry-run.
CHUNK = int(os.environ.get("REPRO_SSD_CHUNK", "128"))


class SSMState(NamedTuple):
    s: jax.Array          # (B, H, P, N) state
    conv: jax.Array | None  # (B, K-1, C) conv tail (mamba2 only)


# --------------------------------------------------------------------------
# Shared chunked gated-linear scan
# --------------------------------------------------------------------------
def chunked_gla_scan(log_a, u, b, c, s0):
    """log_a: (B,S,H) per-head log decay (<= 0 for mamba2);
    u: (B,S,H,P) inputs; b: (B,S,H,N) write keys; c: (B,S,H,N) read keys;
    s0: (B,H,P,N) initial state.
    Returns y: (B,S,H,P), s_final.
    """
    B, S, H = log_a.shape
    P, N = u.shape[-1], b.shape[-1]
    Lc = min(CHUNK, S)
    pad = -S % Lc
    if pad:
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // Lc

    def reshape_chunks(x):
        return x.reshape((B, nc, Lc) + x.shape[2:]).swapaxes(0, 1)

    la, uc, bc, cc = map(reshape_chunks, (log_a, u, b, c))

    def chunk_step(s_prev, inp):
        la_, u_, b_, c_ = inp                       # (B,Lc,H,...)
        cum = jnp.cumsum(la_, axis=1)               # (B,Lc,H)
        total = cum[:, -1]                          # (B,H)
        # intra-chunk: y_i += sum_{j<=i} exp(cum_i - cum_j) <c_i, b_j> u_j
        decay = cum[:, :, None, :] - cum[:, None, :, :]     # (B,i,j,H)
        mask = (jnp.arange(Lc)[:, None] >= jnp.arange(Lc)[None, :])
        w = jnp.where(mask[None, :, :, None], jnp.exp(decay), 0.0)
        scores = jnp.einsum("bihn,bjhn->bijh", c_, b_) * w
        y = jnp.einsum("bijh,bjhp->bihp", scores, u_)
        # inter-chunk: y_i += exp(cum_i) <c_i, s_prev>
        y = y + jnp.einsum("bihn,bhpn,bih->bihp", c_, s_prev,
                           jnp.exp(cum))
        # state update: s = exp(total) s_prev + sum_j exp(total - cum_j) b_j u_j
        wj = jnp.exp(total[:, None] - cum)          # (B,Lc,H)
        s_new = (jnp.exp(total)[:, :, None, None] * s_prev
                 + jnp.einsum("bjhp,bjhn,bjh->bhpn", u_, b_, wj))
        return s_new, y

    s_final, ys = jax.lax.scan(chunk_step, s0, (la, uc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, nc * Lc, H, P)[:, :S]
    return y, s_final


def gla_step(s, log_a, u, b, c):
    """One-token recurrence (decode).  Shapes: log_a (B,H), u (B,H,P),
    b/c (B,H,N)."""
    a = jnp.exp(log_a)[..., None, None]
    s_new = a * s + jnp.einsum("bhp,bhn->bhpn", u, b)
    y = jnp.einsum("bhn,bhpn->bhp", c, s_new)
    return s_new, y


# --------------------------------------------------------------------------
# Mamba2 block
# --------------------------------------------------------------------------
def mamba2_dims(cfg):
    din = cfg.d_inner
    nh = cfg.ssm_heads
    return din, nh, din // nh, cfg.ssm_state


def causal_conv1d(x, w, tail=None):
    """x: (B,S,C); w: (K,C) depthwise causal conv.  ``tail`` is the carried
    (B,K-1,C) suffix for decode."""
    K = w.shape[0]
    if tail is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([tail, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_tail = xp[:, -(K - 1):, :] if K > 1 else xp[:, :0]
    return jax.nn.silu(out), new_tail


def mamba2_block(params, x, cfg, state: SSMState | None = None):
    """x: (B,S,D) -> (B,S,D).  With ``state`` given, runs incrementally
    (decode) and returns the new state."""
    B, S, D = x.shape
    din, nh, hp, ns = mamba2_dims(cfg)
    x = hint(x, BATCH, None, None)
    proj = hint(jnp.einsum("bsd,dz->bsz", x, params["in_proj"]),
                BATCH, None, MODEL)
    z, xin, bmat, cmat, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + ns, 2 * din + 2 * ns], axis=-1)
    conv_in = jnp.concatenate([xin, bmat, cmat], axis=-1)
    tail = state.conv if state is not None else None
    conv_out, new_tail = causal_conv1d(conv_in, params["conv_w"], tail)
    xin, bmat, cmat = jnp.split(conv_out, [din, din + ns], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])           # (B,S,H)
    log_a = -jnp.exp(params["a_log"])[None, None] * dt     # (B,S,H) <= 0
    u = (xin.reshape(B, S, nh, hp)
         * dt[..., None])                                  # dt-scaled input
    b = jnp.broadcast_to(bmat[:, :, None, :], (B, S, nh, ns))
    c = jnp.broadcast_to(cmat[:, :, None, :], (B, S, nh, ns))
    s0 = (state.s if state is not None
          else jnp.zeros((B, nh, hp, ns), jnp.float32))
    if state is not None and S == 1:
        s_new, y = gla_step(s0, log_a[:, 0], u[:, 0], b[:, 0], c[:, 0])
        y = y[:, None]
    else:
        y, s_new = chunked_gla_scan(log_a, u, b, c, s0)
    y = y.reshape(B, S, din) + xin * params["d_skip"][None, None]
    y = y * jax.nn.silu(z)
    out = hint(jnp.einsum("bsz,zd->bsd", y.astype(x.dtype),
                          params["out_proj"]), BATCH, None, None)
    return out, SSMState(s_new, new_tail)


# --------------------------------------------------------------------------
# mLSTM block (xLSTM)
# --------------------------------------------------------------------------
def mlstm_block(params, x, cfg, state: SSMState | None = None):
    """mLSTM as gated linear attention with normaliser-augmented values."""
    B, S, D = x.shape
    din = cfg.d_inner
    nh = cfg.ssm_heads
    hp = din // nh
    x = hint(x, BATCH, None, None)
    q = jnp.einsum("bsd,dz->bsz", x, params["wq"]).reshape(B, S, nh, hp)
    k = jnp.einsum("bsd,dz->bsz", x, params["wk"]).reshape(B, S, nh, hp)
    v = jnp.einsum("bsd,dz->bsz", x, params["wv"]).reshape(B, S, nh, hp)
    k = k / (hp ** 0.5)
    gates = jnp.einsum("bsd,dg->bsg", x, params["w_gates"])  # (B,S,2H)
    i_t = jnp.exp(jnp.clip(gates[..., :nh], -10.0, 10.0))
    log_f = jax.nn.log_sigmoid(gates[..., nh:])              # (B,S,H) <= 0
    # augment v with a ones-column: row P of the state is the normaliser n_t
    v_aug = jnp.concatenate(
        [v * i_t[..., None], i_t[..., None] * jnp.ones_like(v[..., :1])],
        axis=-1)                                             # (B,S,H,P+1)
    s0 = (state.s if state is not None
          else jnp.zeros((B, nh, hp + 1, hp), jnp.float32))
    if state is not None and S == 1:
        s_new, y = gla_step(s0, log_f[:, 0], v_aug[:, 0], k[:, 0], q[:, 0])
        y = y[:, None]
    else:
        y, s_new = chunked_gla_scan(log_f, v_aug, k, q, s0)
    num, den = y[..., :hp], y[..., hp:]
    y = num / jnp.maximum(jnp.abs(den), 1.0)
    y = y.reshape(B, S, din).astype(x.dtype)
    return hint(jnp.einsum("bsz,zd->bsd", y, params["wo"]),
                BATCH, None, None), SSMState(s_new, None)
