"""train_step / serve_step factories for every assigned architecture.

``make_train_step``: cross-entropy LM loss with microbatched gradient
accumulation (scan) — the activation-memory knob that keeps the 104B
train_4k cells inside 16 GB/chip (DESIGN.md §5) — plus AdamW update.

``make_prefill`` / ``make_serve_step``: inference entry points lowered by
the decode_* / long_* dry-run shapes.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.lm.config import ArchConfig
from repro.lm.model import DecodeCache, decode_step, encode, forward
from repro.train.optimizer import AdamW, AdamWState


def lm_loss(params, cfg: ArchConfig, batch: dict, remat: bool = True):
    """Next-token cross entropy; logits in fp32 for the reduction."""
    logits = forward(params, cfg, batch["tokens"],
                     positions3=batch.get("positions3"),
                     enc_input=batch.get("enc_input"),
                     extra_embeds=batch.get("extra_embeds"),
                     remat=remat)
    labels = batch["labels"]
    logits = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab:
        pad_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                            logits.ndim - 1)
        logits = jnp.where(pad_iota < cfg.vocab, logits, -1e30)
    logz = jax.nn.logsumexp(logits, axis=-1)
    # gold logit via a one-hot-masked sum: take_along_axis gathers across
    # the (vocab -> 'model')-sharded dim and forces GSPMD to replicate the
    # full logits tensor; the iota-compare fuses into the reduction and
    # partitions cleanly.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    gold = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                   axis=-1)
    mask = batch.get("mask", jnp.ones_like(labels, jnp.float32))
    nll = (logz - gold) * mask
    return nll.sum() / jnp.maximum(mask.sum(), 1.0)


class TrainState(NamedTuple):
    params: Any
    opt: AdamWState
    step: jax.Array


def make_train_step(cfg: ArchConfig, optimizer: AdamW,
                    microbatches: int = 1, remat: bool = True,
                    constrain_mb=None, grad_dtype=None):
    """Returns train_step(state, batch) -> (state, metrics).

    With microbatches > 1, the global batch is split along axis 0 and
    gradients are accumulated through a lax.scan — activations for only one
    microbatch are ever live.  ``constrain_mb`` (optional) applies a
    sharding constraint to the split (mb, b/mb, ...) batch so GSPMD keeps
    the per-microbatch batch dim on the data axis instead of resharding.
    """

    def loss_fn(params, mb):
        return lm_loss(params, cfg, mb, remat=remat)

    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: TrainState, batch: dict):
        params = state.params
        if microbatches == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def split(x):
                b = x.shape[0]
                assert b % microbatches == 0, (b, microbatches)
                return x.reshape((microbatches, b // microbatches)
                                 + x.shape[1:])
            mbs = jax.tree.map(split, batch)
            if constrain_mb is not None:
                mbs = constrain_mb(mbs)
            gdt = grad_dtype or jnp.float32
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, gdt),
                                params)

            def acc(carry, mb):
                l, g = grad_fn(params, mb)
                return (carry[0] + l,
                        jax.tree.map(lambda a, b: a + b.astype(a.dtype),
                                     carry[1], g)), None

            (loss, grads), _ = jax.lax.scan(acc, (jnp.zeros(()), zero), mbs)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        new_params, new_opt, gnorm = optimizer.apply(grads, state.opt,
                                                     params)
        metrics = {"loss": loss, "grad_norm": gnorm,
                   "lr": optimizer.schedule(state.opt.step)}
        return TrainState(new_params, new_opt, state.step + 1), metrics

    return train_step


def make_init_state(cfg: ArchConfig, optimizer: AdamW, dtype=jnp.float32):
    def init(key):
        from repro.lm.model import init_params
        params = init_params(cfg, key, dtype)
        return TrainState(params, optimizer.init(params),
                          jnp.zeros((), jnp.int32))
    return init


# --------------------------------------------------------------------------
# Serving
# --------------------------------------------------------------------------
def make_prefill(cfg: ArchConfig):
    """prefill(params, tokens, cache) -> (last-token logits, filled cache).

    Transformer archs fill the KV cache by running ``forward`` with cache
    writes folded in; recurrent archs run the chunked scan and keep the
    state.  Implemented as chunk-of-sequence decode for cache-correctness
    across every family: one call of the underlying block code per chunk.
    """

    def prefill(params, tokens, cache: DecodeCache,
                positions3=None, enc_input=None):
        B, S = tokens.shape
        if cfg.encoder_decoder and enc_input is not None:
            memory = encode(params, cfg, enc_input)
            cache = cache._replace()  # cross K/V precomputed in init_cache
        # run the whole prompt as one "step" of length S: decode_step
        # generalises to S>1 because gqa_attention writes S positions and
        # masks causally inside the cache window.
        logits, cache = _multi_token_step(params, cfg, tokens, cache,
                                          positions3)
        return logits[:, -1:], cache

    return prefill


def _multi_token_step(params, cfg, tokens, cache, positions3=None):
    """decode_step for S >= 1 tokens (used by prefill and speculative
    verification)."""
    # decode_step is written for S tokens at position cache.pos; reuse it.
    return decode_step(params, cfg, tokens, cache, positions3=positions3)


def make_serve_step(cfg: ArchConfig):
    """serve_step(params, token, cache) -> (logits, cache): one new token
    with greedy sampling helper."""

    def serve_step(params, token, cache: DecodeCache, positions3=None):
        logits, cache = decode_step(params, cfg, token, cache,
                                    positions3=positions3)
        next_token = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]
        return logits, next_token, cache

    return serve_step


def make_generate(cfg: ArchConfig, steps: int):
    """Greedy autoregressive generation loop (lax.scan over decode steps)."""
    serve = make_serve_step(cfg)

    def generate(params, prompt_tokens, cache: DecodeCache):
        prefill = make_prefill(cfg)
        logits, cache = prefill(params, prompt_tokens, cache)
        tok = jnp.argmax(logits[:, -1, :cfg.vocab], axis=-1)[:, None]

        def body(carry, _):
            tok, cache = carry
            _, nxt, cache = serve(params, tok, cache)
            return (nxt, cache), tok[:, 0]

        (_, cache), toks = jax.lax.scan(body, (tok, cache), None,
                                        length=steps)
        return toks.T, cache   # (B, steps)

    return generate
