"""Version-portable mesh accessors.

JAX moved the ambient-mesh API twice across the versions this repo meets:

  >= 0.5    jax.sharding.get_abstract_mesh() / jax.set_mesh(mesh)
  0.4.x     the ambient mesh lives in jax.interpreters.pxla
            .thread_resources.env.physical_mesh and is entered with the
            ``with mesh:`` context manager

Everything in the repo that needs the ambient mesh (pshard hints, the
dry-run lowering path) routes through the two helpers here so the rest of
the code is version-agnostic.
"""
from __future__ import annotations

import contextlib

import jax


def _nonempty(mesh) -> bool:
    if mesh is None:
        return False
    if getattr(mesh, "empty", False):
        return False
    return bool(getattr(mesh, "axis_names", ()))


def current_mesh():
    """The ambient (abstract or physical) mesh, or ``None`` outside any
    mesh context.  Tries the new API first, then the 0.4.x thread-local."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        try:
            m = get_abstract()
        except Exception:
            m = None
        if _nonempty(m):
            return m
    try:
        from jax.interpreters import pxla
        m = pxla.thread_resources.env.physical_mesh
    except Exception:
        return None
    return m if _nonempty(m) else None


@contextlib.contextmanager
def use_mesh(mesh):
    """``with use_mesh(m):`` — ambient-mesh context on any JAX version.

    New JAX: ``jax.set_mesh`` (itself a context manager).  0.4.x: the Mesh
    object's own context manager, which populates ``thread_resources``."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is None:
        with mesh:
            yield
        return
    ctx = set_mesh(mesh)
    if hasattr(ctx, "__enter__"):
        with ctx:
            yield
    else:                        # set_mesh mutated global state; undo after
        try:
            yield
        finally:
            set_mesh(None)
