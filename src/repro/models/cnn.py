"""End-to-end JAX implementations of the paper's CNN workloads.

Every model is driven by its ``LayerGraph`` from ``repro.models.zoo`` — the
graph IS the single source of truth for layer characteristics, so the JAX
execution, the dual-OPU scheduler and the latency model can never diverge
(a test asserts per-layer activation shapes match the graph).

Execution is expressed once, as a step program (``repro.dualcore.program``),
and consumed two ways:

  * sequential forward (this module): run the steps in order on one device.
    ``use_pallas`` selects XLA reference ops vs the Pallas kernels;
    ``fuse=True`` (Pallas path) runs dw->pw / pw-expand->dw->pw-project
    chains as single fused_block pallas_calls (DESIGN.md §3); ``fuse=False``
    forces the per-layer kernels.
  * pipelined dual-core (``run_pipelined`` -> ``repro.dualcore.runtime``):
    the same steps partitioned into the alternating c/p-core groups of a
    scheduler ``Schedule`` and executed on the two submeshes with the
    paper's one-slot image offset (DESIGN.md §8).

Because both paths execute the same step objects, they agree bitwise.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.graph import LayerGraph
from repro.dualcore.program import build_program, run_layer as _run_layer
from repro.models.zoo import get_graph

__all__ = ["FORWARDS", "build_model", "init_params", "run_pipelined",
           "_run_layer"]

Params = dict[str, dict[str, jax.Array]]


def init_params(graph: LayerGraph, key: jax.Array,
                dtype=jnp.float32) -> Params:
    """He-init weights for every conv/dwconv/fc layer in the graph."""
    params: Params = {}
    for l in graph.layers:
        key, sub = jax.random.split(key)
        if l.op == "dwconv":
            shape = (l.K_h, l.K_w, l.C_i)
            fan_in = l.K_h * l.K_w
        else:
            shape = (l.K_h, l.K_w, l.C_i, l.C_o)
            fan_in = l.K_h * l.K_w * l.C_i
        w = jax.random.normal(sub, shape) * (2.0 / fan_in) ** 0.5
        params[l.name] = {"w": w.astype(dtype),
                         "b": jnp.zeros((l.C_o,), dtype)}
    return params


def _make_forward(name: str) -> Callable:
    def forward(params: Params, x: jax.Array, use_pallas: bool = False,
                collect: dict | None = None, fuse: bool = True) -> jax.Array:
        prog = build_program(name, use_pallas=use_pallas, fuse=fuse)
        return prog.run(params, x, collect)

    forward.__name__ = f"{name}_forward"
    forward.__qualname__ = forward.__name__
    forward.__doc__ = (f"Sequential forward pass of {name} "
                       f"(step program in repro.dualcore.program).")
    return forward


mobilenet_v1_forward = _make_forward("mobilenet_v1")
mobilenet_v2_forward = _make_forward("mobilenet_v2")
squeezenet_forward = _make_forward("squeezenet")

FORWARDS: dict[str, Callable] = {
    "mobilenet_v1": mobilenet_v1_forward,
    "mobilenet_v2": mobilenet_v2_forward,
    "squeezenet": squeezenet_forward,
}


def build_model(name: str, key=None, dtype=jnp.float32):
    """Return (params, forward_fn, graph) for one of the paper workloads."""
    g = get_graph(name)
    key = key if key is not None else jax.random.PRNGKey(0)
    params = init_params(g, key, dtype)
    return params, FORWARDS[name], g


def run_pipelined(name: str, params: Params, schedule, images, *,
                  devices=None, use_pallas: bool = True,
                  fuse: bool | str = "group", jit_groups: bool = True,
                  record: list | None = None):
    """Execute ``schedule`` for real: pipeline ``images`` through the
    alternating c/p-core group chain on the split device mesh with the
    paper's one-slot offset (Fig.4b).  Returns the per-image logits in
    submission order.  Compatibility wrapper: continuous serving goes
    through ``repro.serving.DualCoreEngine`` (submit/step/drain with
    online slot-refill admission); this submits a ready list and drains.
    See ``repro.dualcore.runtime.DualCoreRunner`` for the knobs; pass
    ``record=[]`` to capture the execution trace."""
    from repro.dualcore.runtime import DualCoreRunner

    runner = DualCoreRunner(name, params, schedule, devices=devices,
                            use_pallas=use_pallas, fuse=fuse,
                            jit_groups=jit_groups)
    return runner.run_pipelined(images, record=record)
