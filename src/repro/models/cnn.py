"""End-to-end JAX implementations of the paper's CNN workloads.

Every model is driven by its ``LayerGraph`` from ``repro.models.zoo`` — the
graph IS the single source of truth for layer characteristics, so the JAX
execution, the dual-OPU scheduler and the latency model can never diverge
(a test asserts per-layer activation shapes match the graph).

Execution paths per layer:
  * XLA (default): jax.lax convolutions — this is what the dry-run lowers.
  * Pallas (use_pallas=True): the fusion pass (repro.core.fusion) groups
    dw->pw / pw-expand->dw->pw-project chains and runs each group as ONE
    fused_block pallas_call — the intermediate feature maps stay in VMEM,
    the software analogue of the dual-OPU's concurrent c-/p-core execution
    (DESIGN.md §3).  Unmatched layers fall back to the implicit-GEMM /
    depthwise kernels.  ``fuse=False`` forces the per-layer kernels (the
    unfused baseline the benchmarks compare against).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.fusion import plan_fusion
from repro.core.graph import LayerGraph, LayerSpec
from repro.kernels.conv_gemm.ops import conv2d_gemm
from repro.kernels.conv_gemm.ref import conv2d_ref
from repro.kernels.depthwise.ops import depthwise
from repro.kernels.depthwise.ref import depthwise_conv2d_ref
from repro.kernels.fused_block.ops import (fused_dw_pw,
                                           fused_inverted_residual)
from repro.models.zoo import get_graph

Params = dict[str, dict[str, jax.Array]]


def init_params(graph: LayerGraph, key: jax.Array,
                dtype=jnp.float32) -> Params:
    """He-init weights for every conv/dwconv/fc layer in the graph."""
    params: Params = {}
    for l in graph.layers:
        key, sub = jax.random.split(key)
        if l.op == "dwconv":
            shape = (l.K_h, l.K_w, l.C_i)
            fan_in = l.K_h * l.K_w
        else:
            shape = (l.K_h, l.K_w, l.C_i, l.C_o)
            fan_in = l.K_h * l.K_w * l.C_i
        w = jax.random.normal(sub, shape) * (2.0 / fan_in) ** 0.5
        params[l.name] = {"w": w.astype(dtype),
                         "b": jnp.zeros((l.C_o,), dtype)}
    return params


def _run_layer(l: LayerSpec, x: jax.Array, p: dict[str, jax.Array],
               act: str | None, use_pallas: bool) -> jax.Array:
    if l.op == "dwconv":
        if use_pallas:
            return depthwise(x, p["w"], p["b"], stride=l.stride, pad=l.pad,
                             act=act)
        return depthwise_conv2d_ref(x, p["w"], p["b"], stride=l.stride,
                                    pad=l.pad, act=act)
    if use_pallas:
        return conv2d_gemm(x, p["w"], p["b"], stride=l.stride, pad=l.pad,
                           act=act)
    return conv2d_ref(x, p["w"], p["b"], stride=l.stride, pad=l.pad, act=act)


def _avgpool_all(x):
    return jnp.mean(x, axis=(1, 2), keepdims=True)


def _mbv1_act(name: str) -> str | None:
    return None if name == "fc" else "relu6"


def _mbv2_act(name: str) -> str | None:
    if name in ("fc",) or name.endswith("_project"):
        return None                 # linear bottleneck / classifier head
    return "relu6"


def _forward_fused_chain(g: LayerGraph, params: Params, x: jax.Array,
                         act_of: Callable[[str], str | None],
                         collect: dict | None) -> jax.Array:
    """Pallas path for the (almost) sequential nets: run the fusion plan,
    one fused_block pallas_call per dw->pw / pw->dw->pw group.

    ``collect`` only records feature maps that actually materialize in HBM
    (the fused groups' outputs) — the whole point of fusion is that the
    intermediates never exist.
    """
    h = x
    for grp in plan_fusion(g):
        first = g.layer(grp.layers[0])
        last = g.layer(grp.layers[-1])
        if first.op == "fc" and "avgpool" in first.fused:
            h = _avgpool_all(h)
        if grp.kind == "dw_pw":
            d, p = (g.layer(nm) for nm in grp.layers)
            pd, pp = params[d.name], params[p.name]
            h = fused_dw_pw(h, pd["w"], pd["b"], pp["w"], pp["b"],
                            stride=d.stride, pad=d.pad,
                            dw_act=act_of(d.name), pw_act=act_of(p.name))
        elif grp.kind == "pw_dw_pw":
            e, d, p = (g.layer(nm) for nm in grp.layers)
            res = h if ("add" in p.fused and d.stride == 1
                        and e.C_i == p.C_o) else None
            pe, pd, pp = params[e.name], params[d.name], params[p.name]
            h = fused_inverted_residual(
                h, pe["w"], pe["b"], pd["w"], pd["b"], pp["w"], pp["b"],
                res, stride=d.stride, pad=d.pad, exp_act=act_of(e.name),
                dw_act=act_of(d.name), proj_act=act_of(p.name))
        else:
            h = _run_layer(first, h, params[first.name], act_of(first.name),
                           use_pallas=True)
        if collect is not None:
            collect[last.name] = h.shape
    return h.reshape(h.shape[0], -1)


def _maxpool(x, window=3, stride=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, window, window, 1),
        (1, stride, stride, 1), "VALID")


# --------------------------------------------------------------------------
# MobileNet v1
# --------------------------------------------------------------------------
def mobilenet_v1_forward(params: Params, x: jax.Array,
                         use_pallas: bool = False,
                         collect: dict | None = None,
                         fuse: bool = True) -> jax.Array:
    g = get_graph("mobilenet_v1")
    if use_pallas and fuse:
        return _forward_fused_chain(g, params, x, _mbv1_act, collect)
    h = x
    for l in g.layers[:-1]:
        h = _run_layer(l, h, params[l.name], "relu6", use_pallas)
        if collect is not None:
            collect[l.name] = h.shape
    h = _avgpool_all(h)
    fc = g.layers[-1]
    h = _run_layer(fc, h, params[fc.name], None, use_pallas)
    if collect is not None:
        collect[fc.name] = h.shape
    return h.reshape(h.shape[0], -1)


# --------------------------------------------------------------------------
# MobileNet v2 (inverted residuals + linear bottlenecks)
# --------------------------------------------------------------------------
def mobilenet_v2_forward(params: Params, x: jax.Array,
                         use_pallas: bool = False,
                         collect: dict | None = None,
                         fuse: bool = True) -> jax.Array:
    g = get_graph("mobilenet_v2")
    if use_pallas and fuse:
        return _forward_fused_chain(g, params, x, _mbv2_act, collect)
    h = x
    residual: jax.Array | None = None
    for l in g.layers:
        if l.name == "fc":
            h = _avgpool_all(h)
            h = _run_layer(l, h, params[l.name], None, use_pallas)
            if collect is not None:
                collect[l.name] = h.shape
            return h.reshape(h.shape[0], -1)
        if l.name.endswith("_expand") or l.name in ("conv1", "conv_last"):
            act = "relu6"
        elif l.name.endswith("_dw"):
            act = "relu6"
        else:                       # _project: linear bottleneck
            act = None
        if l.name.endswith("_expand") or (l.name.endswith("_dw")
                                          and "expand" not in l.name):
            if l.name.endswith("_expand"):
                residual = h        # block input (for the residual add)
        out = _run_layer(l, h, params[l.name], act, use_pallas)
        if l.name.endswith("_project") and "add" in l.fused \
                and residual is not None and residual.shape == out.shape:
            out = out + residual
        h = out
        if collect is not None:
            collect[l.name] = h.shape
    raise AssertionError("fc layer missing")


# --------------------------------------------------------------------------
# SqueezeNet v1.1
# --------------------------------------------------------------------------
def squeezenet_forward(params: Params, x: jax.Array,
                       use_pallas: bool = False,
                       collect: dict | None = None,
                       fuse: bool = True) -> jax.Array:
    # no dwconv layers -> the fusion plan is all singletons; the per-layer
    # kernels are already the fastest Pallas path here
    g = get_graph("squeezenet")
    l = g.layer("conv1")
    h = _run_layer(l, x, params["conv1"], "relu", use_pallas)
    if collect is not None:
        collect["conv1"] = h.shape
    h = _maxpool(jnp.pad(h, ((0, 0), (0, 1), (0, 1), (0, 0)),
                         constant_values=-jnp.inf))
    pool_after = {"fire3_e3x3", "fire5_e3x3"}   # v1.1 pool placement
    for i in range(2, 10):
        name = f"fire{i}"
        sq = _run_layer(g.layer(f"{name}_squeeze"), h,
                        params[f"{name}_squeeze"], "relu", use_pallas)
        e1 = _run_layer(g.layer(f"{name}_e1x1"), sq,
                        params[f"{name}_e1x1"], "relu", use_pallas)
        e3 = _run_layer(g.layer(f"{name}_e3x3"), sq,
                        params[f"{name}_e3x3"], "relu", use_pallas)
        h = jnp.concatenate([e1, e3], axis=-1)
        if collect is not None:
            collect[f"{name}_squeeze"] = sq.shape
            collect[f"{name}_e1x1"] = e1.shape
            collect[f"{name}_e3x3"] = e3.shape
        if f"{name}_e3x3" in pool_after:
            h = _maxpool(jnp.pad(h, ((0, 0), (0, 1), (0, 1), (0, 0)),
                                 constant_values=-jnp.inf))
    h = _run_layer(g.layer("conv10"), h, params["conv10"], "relu",
                   use_pallas)
    if collect is not None:
        collect["conv10"] = h.shape
    return _avgpool_all(h).reshape(h.shape[0], -1)


FORWARDS: dict[str, Callable] = {
    "mobilenet_v1": mobilenet_v1_forward,
    "mobilenet_v2": mobilenet_v2_forward,
    "squeezenet": squeezenet_forward,
}


def build_model(name: str, key=None, dtype=jnp.float32):
    """Return (params, forward_fn, graph) for one of the paper workloads."""
    g = get_graph(name)
    key = key if key is not None else jax.random.PRNGKey(0)
    params = init_params(g, key, dtype)
    return params, FORWARDS[name], g
