"""Layer-graph definitions of the paper's workloads (§VI-A b).

MobileNet v1 [arXiv:1704.04861], MobileNet v2 [arXiv:1801.04381] and
SqueezeNet (interpreted as v1.1 — the Table IV cycle count of 447k on a
1152-multiplier core is only consistent with v1.1's ~360M MACs; v1.0's ~860M
would exceed 100% PE efficiency; recorded in DESIGN.md §7).

These produce the same LayerGraph IR as ``repro.models.extract`` does from the
JAX model definitions; a test asserts the two paths agree.
"""
from __future__ import annotations

from repro.core.graph import LayerGraph, LayerSpec, chain_graph


# --------------------------------------------------------------------------
# MobileNet v1 (224x224x3, width multiplier 1.0)
# --------------------------------------------------------------------------
def mobilenet_v1_graph() -> LayerGraph:
    layers = [LayerSpec("conv1", "conv", 224, 224, 3, 32, 3, 3, 2, pad=1)]
    # (stride, C_out) per depthwise-separable block
    cfg = [(1, 64), (2, 128), (1, 128), (2, 256), (1, 256), (2, 512),
           (1, 512), (1, 512), (1, 512), (1, 512), (1, 512),
           (2, 1024), (1, 1024)]
    h, w, c = 112, 112, 32
    for i, (s, c_out) in enumerate(cfg, start=1):
        layers.append(LayerSpec(f"dw{i}", "dwconv", h, w, c, c, 3, 3, s,
                                pad=1))
        h, w = -(-h // s), -(-w // s)
        layers.append(LayerSpec(f"pw{i}", "conv", h, w, c, c_out, 1, 1, 1))
        c = c_out
    layers.append(LayerSpec("fc", "fc", 1, 1, 1024, 1000, 1, 1, 1,
                            fused=("avgpool",)))
    return chain_graph("mobilenet_v1", layers)


# --------------------------------------------------------------------------
# MobileNet v2 (224x224x3, width multiplier 1.0)
# --------------------------------------------------------------------------
MBV2_BLOCKS = [
    # (expansion t, C_out, repeats n, stride s) — Table 2 of the v2 paper
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
]


def mobilenet_v2_graph() -> LayerGraph:
    layers = [LayerSpec("conv1", "conv", 224, 224, 3, 32, 3, 3, 2, pad=1)]
    h, w, c = 112, 112, 32
    bi = 0
    for t, c_out, n, s in MBV2_BLOCKS:
        for r in range(n):
            stride = s if r == 0 else 1
            bi += 1
            c_mid = c * t
            if t != 1:
                layers.append(LayerSpec(f"b{bi}_expand", "conv",
                                        h, w, c, c_mid, 1, 1, 1))
            layers.append(LayerSpec(f"b{bi}_dw", "dwconv",
                                    h, w, c_mid, c_mid, 3, 3, stride, pad=1))
            h, w = -(-h // stride), -(-w // stride)
            fused = ("add",) if (stride == 1 and c == c_out and r > 0) else ()
            layers.append(LayerSpec(f"b{bi}_project", "conv",
                                    h, w, c_mid, c_out, 1, 1, 1, fused=fused))
            c = c_out
    layers.append(LayerSpec("conv_last", "conv", h, w, c, 1280, 1, 1, 1))
    layers.append(LayerSpec("fc", "fc", 1, 1, 1280, 1000, 1, 1, 1,
                            fused=("avgpool",)))
    return chain_graph("mobilenet_v2", layers)


# --------------------------------------------------------------------------
# SqueezeNet v1.1 (224x224x3)
# --------------------------------------------------------------------------
SQZ_FIRE = [
    # (name, H, W, C_in, squeeze, expand) after the preceding pool
    ("fire2", 56, 56, 64, 16, 64),
    ("fire3", 56, 56, 128, 16, 64),
    ("fire4", 28, 28, 128, 32, 128),
    ("fire5", 28, 28, 256, 32, 128),
    ("fire6", 14, 14, 256, 48, 192),
    ("fire7", 14, 14, 384, 48, 192),
    ("fire8", 14, 14, 384, 64, 256),
    ("fire9", 14, 14, 512, 64, 256),
]


def squeezenet_graph() -> LayerGraph:
    layers = [LayerSpec("conv1", "conv", 224, 224, 3, 64, 3, 3, 2, pad=1,
                        fused=("maxpool",))]
    edges: list[tuple[str, str]] = []
    prev = "conv1"
    for name, h, w, c_in, sq, ex in SQZ_FIRE:
        squeeze = LayerSpec(f"{name}_squeeze", "conv", h, w, c_in, sq, 1, 1, 1)
        e1 = LayerSpec(f"{name}_e1x1", "conv", h, w, sq, ex, 1, 1, 1)
        e3 = LayerSpec(f"{name}_e3x3", "conv", h, w, sq, ex, 3, 3, 1, pad=1,
                       fused=("concat",))
        layers += [squeeze, e1, e3]
        edges += [(prev, squeeze.name), (squeeze.name, e1.name),
                  (squeeze.name, e3.name)]
        prev = e3.name  # concat(e1, e3) feeds the next fire/conv
        edges.append((e1.name, e3.name))  # concat dependency marker
    layers.append(LayerSpec("conv10", "conv", 14, 14, 512, 1000, 1, 1, 1,
                            fused=("avgpool",)))
    edges.append((prev, "conv10"))
    return LayerGraph("squeezenet", layers, edges)


PAPER_WORKLOADS = {
    "mobilenet_v1": mobilenet_v1_graph,
    "mobilenet_v2": mobilenet_v2_graph,
    "squeezenet": squeezenet_graph,
}


def get_graph(name: str) -> LayerGraph:
    try:
        return PAPER_WORKLOADS[name]()
    except KeyError:
        raise KeyError(f"unknown workload {name!r}; "
                       f"choices: {sorted(PAPER_WORKLOADS)}") from None
