"""Unified telemetry: replay-deterministic metrics + exposition.

``repro.obs`` is dependency-free (stdlib only) and safe to import from
every layer — the executor, router, control loop, serving API, and the
§14 wire stack all instrument through one :class:`Registry` per
top-level engine.  See ``docs/observability.md`` for the metric table
and the slot/wall domain contract.
"""
from repro.obs.export import to_json, to_prometheus, write_metrics
from repro.obs.registry import (DEFAULT_COUNT_BOUNDS,
                                DEFAULT_SECONDS_BOUNDS, Counter, Gauge,
                                Histogram, Registry, parse_label_key)

__all__ = ["Counter", "Gauge", "Histogram", "Registry",
           "DEFAULT_COUNT_BOUNDS", "DEFAULT_SECONDS_BOUNDS",
           "parse_label_key", "to_json", "to_prometheus", "write_metrics"]
