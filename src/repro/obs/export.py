"""Exposition: registry snapshots as Prometheus text or JSON.

Both formats render the same :meth:`repro.obs.registry.Registry.snapshot`
dict, so a scraped file and a shipped §14 ``telemetry_snap`` payload are
the same data.  The ``domain`` of every metric rides along (Prometheus:
a ``# HELP``-line suffix; JSON: the ``domain`` field) so a reader can
tell replay-deterministic values from wall-clock ones.
"""
from __future__ import annotations

import json
import sys

from repro.obs.registry import Registry, parse_label_key


def _prom_labels(key: str, extra: dict | None = None) -> str:
    labels = parse_label_key(key)
    if extra:
        labels.update(extra)
    if not labels:
        return ""
    inner = ",".join(f'{k}="{labels[k]}"' for k in sorted(labels))
    return "{" + inner + "}"


def to_prometheus(snapshot: dict) -> str:
    """Render a snapshot in the Prometheus text exposition format
    (text/plain version 0.0.4): counters and gauges one sample per label
    set, histograms as cumulative ``_bucket{le=...}`` + ``_sum`` +
    ``_count`` families."""
    lines: list[str] = []
    for name, entry in snapshot.get("counters", {}).items():
        lines.append(f"# HELP {name} {entry.get('help', '')} "
                     f"[domain={entry.get('domain', '?')}]")
        lines.append(f"# TYPE {name} counter")
        for key, v in entry.get("series", {}).items():
            lines.append(f"{name}{_prom_labels(key)} {_num(v)}")
    for name, entry in snapshot.get("gauges", {}).items():
        lines.append(f"# HELP {name} {entry.get('help', '')} "
                     f"[domain={entry.get('domain', '?')}]")
        lines.append(f"# TYPE {name} gauge")
        for key, v in entry.get("series", {}).items():
            lines.append(f"{name}{_prom_labels(key)} {_num(v)}")
    for name, entry in snapshot.get("histograms", {}).items():
        lines.append(f"# HELP {name} {entry.get('help', '')} "
                     f"[domain={entry.get('domain', '?')}]")
        lines.append(f"# TYPE {name} histogram")
        bounds = entry.get("bounds", [])
        for key, s in entry.get("series", {}).items():
            cum = 0
            for b, c in zip(bounds, s["counts"]):
                cum += c
                lines.append(f"{name}_bucket"
                             f"{_prom_labels(key, {'le': _num(b)})} {cum}")
            cum += s["counts"][len(bounds)] if len(s["counts"]) > \
                len(bounds) else 0
            lines.append(f"{name}_bucket"
                         f"{_prom_labels(key, {'le': '+Inf'})} {cum}")
            lines.append(f"{name}_sum{_prom_labels(key)} {_num(s['sum'])}")
            lines.append(f"{name}_count{_prom_labels(key)} {s['n']}")
    return "\n".join(lines) + "\n"


def _num(v) -> str:
    if isinstance(v, float) and v.is_integer():
        return str(int(v))
    return str(v)


def to_json(snapshot: dict) -> str:
    """Render a snapshot as deterministic JSON (sorted keys)."""
    return json.dumps(snapshot, indent=1, sort_keys=True)


def write_metrics(registry_or_snapshot, path: str,
                  domain: str | None = None) -> str:
    """Write one exposition of ``registry_or_snapshot`` to ``path``:
    ``-`` streams Prometheus text to stdout, a ``.json`` suffix selects
    JSON, anything else Prometheus text.  Returns the format used
    (``"prom"`` or ``"json"``)."""
    snap = (registry_or_snapshot.snapshot(domain)
            if isinstance(registry_or_snapshot, Registry)
            else registry_or_snapshot)
    if path == "-":
        sys.stdout.write(to_prometheus(snap))
        return "prom"
    if path.endswith(".json"):
        with open(path, "w") as f:
            f.write(to_json(snap) + "\n")
        return "json"
    with open(path, "w") as f:
        f.write(to_prometheus(snap))
    return "prom"
