"""Zero-dependency metrics registry: counters, gauges, histograms.

One :class:`Registry` instance is owned per top-level engine — the
``PoolExecutor`` creates its own and a ``MultiPoolRouter`` re-homes every
pool executor onto one shared registry, the same move it makes with the
seq counter — so two runs in one process (a live run and its replay)
never bleed into each other.

Every metric lives in one of two **domains**, the contract that keeps
replay honest (DESIGN.md §11-§12 extended to telemetry):

  * ``"slot"`` — a pure function of the instruction stream.  Incremented
    only on paths both live execution and ``router.replay`` pass through
    (``PoolExecutor.execute``, ``_submit_to``, the recovery-event log),
    from values the stream signature already pins (op, core, advances,
    slot).  ``registry.snapshot(domain="slot")`` of a replay is
    dict-equal to the live run's (tested, including crash recovery).
  * ``"wall"`` — observational: wall-clock durations, injector retries,
    envelope bytes, RTTs, heartbeat misses, controller decisions.  Never
    compared across replay; confined to its own channel so it cannot
    contaminate the deterministic one.

Labels are frozen ``(key, value)`` tuples internally and canonical
``"k=v,k2=v2"`` strings in snapshots (keys sorted); label values must
not contain ``','`` or ``'='``.  Snapshots are plain JSON-able dicts —
what ships over the wire (§14 ``telemetry_snap`` envelopes), merges
across processes (:meth:`Registry.absorb`), and exports
(:mod:`repro.obs.export`).
"""
from __future__ import annotations

from typing import Mapping

# seconds-scaled bounds: instruction execution on this stack spans
# ~0.1 ms (stub slots) to seconds (cold-jit CNN slots)
DEFAULT_SECONDS_BOUNDS = (1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
                          0.1, 0.3, 1.0, 3.0, 10.0)
# count-scaled bounds (advances per RUN, payloads per SEND)
DEFAULT_COUNT_BOUNDS = (0.0, 1.0, 2.0, 4.0, 8.0, 16.0, 32.0)

DOMAINS = ("slot", "wall")


def _label_key(labels: Mapping[str, str] | None) -> str:
    if not labels:
        return ""
    parts = []
    for k in sorted(labels):
        v = str(labels[k])
        if "," in v or "=" in v:
            raise ValueError(f"label value {v!r} for {k!r} may not "
                             f"contain ',' or '='")
        parts.append(f"{k}={v}")
    return ",".join(parts)


def parse_label_key(key: str) -> dict[str, str]:
    """Invert :func:`_label_key`: ``"a=1,b=2"`` -> ``{"a": "1", "b": "2"}``."""
    if not key:
        return {}
    return dict(p.split("=", 1) for p in key.split(","))


class Counter:
    """Monotonic counter; one value per label set."""

    kind = "counter"

    def __init__(self, registry: "Registry", name: str, help: str,
                 domain: str):
        self.registry = registry
        self.name = name
        self.help = help
        self.domain = domain
        self.series: dict[str, float] = {}

    def inc(self, n: float = 1,
            labels: Mapping[str, str] | None = None) -> None:
        """Add ``n`` (default 1) to the series named by ``labels``."""
        if not self.registry.enabled or n == 0:
            return
        key = _label_key(labels)
        self.series[key] = self.series.get(key, 0) + n


class Gauge:
    """Last-write-wins instantaneous value; one per label set."""

    kind = "gauge"

    def __init__(self, registry: "Registry", name: str, help: str,
                 domain: str):
        self.registry = registry
        self.name = name
        self.help = help
        self.domain = domain
        self.series: dict[str, float] = {}

    def set(self, value: float,
            labels: Mapping[str, str] | None = None) -> None:
        """Set the series named by ``labels`` to ``value``."""
        if not self.registry.enabled:
            return
        self.series[_label_key(labels)] = value


class Histogram:
    """Fixed-bound histogram: per-bucket counts (bucket i counts
    observations ``<= bounds[i]``, non-cumulative internally; the last
    implicit bucket is +Inf), plus sum and count."""

    kind = "histogram"

    def __init__(self, registry: "Registry", name: str, help: str,
                 domain: str, bounds: tuple[float, ...]):
        if list(bounds) != sorted(bounds) or len(set(bounds)) != len(bounds):
            raise ValueError(f"histogram bounds must be strictly "
                             f"increasing (got {bounds})")
        self.registry = registry
        self.name = name
        self.help = help
        self.domain = domain
        self.bounds = tuple(float(b) for b in bounds)
        self.series: dict[str, dict] = {}

    def observe(self, value: float,
                labels: Mapping[str, str] | None = None) -> None:
        """File ``value`` into its bucket for the ``labels`` series."""
        if not self.registry.enabled:
            return
        key = _label_key(labels)
        s = self.series.get(key)
        if s is None:
            s = self.series[key] = {
                "counts": [0] * (len(self.bounds) + 1), "sum": 0.0, "n": 0}
        i = len(self.bounds)                  # +Inf bucket by default
        for j, b in enumerate(self.bounds):
            if value <= b:
                i = j
                break
        s["counts"][i] += 1
        s["sum"] += value
        s["n"] += 1


class Registry:
    """A process-local metric namespace (module docstring).

    ``enabled=False`` turns every ``inc``/``set``/``observe`` into a
    no-op — the bare leg of ``benchmarks/obs_bench.py`` measures the
    instrumentation overhead against exactly this switch.
    """

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}
        self._absorbed: dict[str, dict] = {}     # source -> last snapshot

    # ------------------------------------------------------------------
    def _get(self, cls, name: str, help: str, domain: str, **kw):
        if domain not in DOMAINS:
            raise ValueError(f"unknown metric domain {domain!r}; "
                             f"one of {DOMAINS}")
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(self, name, help, domain, **kw)
            return m
        if not isinstance(m, cls) or m.domain != domain:
            raise ValueError(
                f"metric {name!r} re-registered as {cls.__name__.lower()}/"
                f"{domain}, but it is a {m.kind}/{m.domain}")
        return m

    def counter(self, name: str, help: str = "",
                domain: str = "slot") -> Counter:
        """Get or create the counter ``name``."""
        return self._get(Counter, name, help, domain)

    def gauge(self, name: str, help: str = "",
              domain: str = "slot") -> Gauge:
        """Get or create the gauge ``name``."""
        return self._get(Gauge, name, help, domain)

    def histogram(self, name: str, help: str = "", domain: str = "wall",
                  bounds: tuple[float, ...] = DEFAULT_SECONDS_BOUNDS
                  ) -> Histogram:
        """Get or create the histogram ``name`` (fixed ``bounds``)."""
        return self._get(Histogram, name, help, domain, bounds=bounds)

    # ------------------------------------------------------------------
    def snapshot(self, domain: str | None = None, *,
                 sources: bool = True) -> dict:
        """Plain-dict view of every metric (optionally one ``domain``),
        merged with the latest absorbed per-source snapshots (cumulative,
        so counters add and histograms sum; ``sources=False`` restricts
        to this process).  Deterministically ordered: dict-equality of
        two snapshots is the replay-determinism acceptance check."""
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name in sorted(self._metrics):
            m = self._metrics[name]
            if domain is not None and m.domain != domain:
                continue
            entry = {"help": m.help, "domain": m.domain,
                     "series": {k: m.series[k] for k in sorted(m.series)}}
            if isinstance(m, Histogram):
                entry["bounds"] = list(m.bounds)
                entry["series"] = {
                    k: {"counts": list(s["counts"]), "sum": s["sum"],
                        "n": s["n"]}
                    for k, s in sorted(m.series.items())}
                out["histograms"][name] = entry
            elif isinstance(m, Gauge):
                out["gauges"][name] = entry
            else:
                out["counters"][name] = entry
        if sources:
            for source in sorted(self._absorbed):
                _merge_into(out, self._absorbed[source], domain)
        return out

    def absorb(self, snapshot: dict, *, source: str) -> None:
        """Adopt a remote registry's cumulative ``snapshot`` (a §14
        ``telemetry_snap`` payload).  The latest snapshot per ``source``
        *replaces* its predecessor — each ships cumulative totals, so a
        killed worker loses at most the window since its last ship,
        never double-counts."""
        self._absorbed[source] = snapshot

    @property
    def sources(self) -> list[str]:
        """Names of remote registries absorbed so far."""
        return sorted(self._absorbed)


def _merge_into(out: dict, snap: dict, domain: str | None) -> None:
    """Merge one absorbed snapshot into ``out`` (counters/histograms add,
    gauges last-write-wins, absent metrics adopted whole)."""
    for name, entry in snap.get("counters", {}).items():
        if domain is not None and entry.get("domain") != domain:
            continue
        dst = out["counters"].setdefault(
            name, {"help": entry.get("help", ""),
                   "domain": entry.get("domain", "wall"), "series": {}})
        for k, v in entry.get("series", {}).items():
            dst["series"][k] = dst["series"].get(k, 0) + v
        dst["series"] = {k: dst["series"][k]
                         for k in sorted(dst["series"])}
    for name, entry in snap.get("gauges", {}).items():
        if domain is not None and entry.get("domain") != domain:
            continue
        dst = out["gauges"].setdefault(
            name, {"help": entry.get("help", ""),
                   "domain": entry.get("domain", "wall"), "series": {}})
        dst["series"].update(entry.get("series", {}))
        dst["series"] = {k: dst["series"][k]
                         for k in sorted(dst["series"])}
    for name, entry in snap.get("histograms", {}).items():
        if domain is not None and entry.get("domain") != domain:
            continue
        dst = out["histograms"].setdefault(
            name, {"help": entry.get("help", ""),
                   "domain": entry.get("domain", "wall"),
                   "bounds": list(entry.get("bounds", [])), "series": {}})
        for k, s in entry.get("series", {}).items():
            d = dst["series"].get(k)
            if d is None:
                dst["series"][k] = {"counts": list(s["counts"]),
                                    "sum": s["sum"], "n": s["n"]}
            else:
                d["counts"] = [a + b
                               for a, b in zip(d["counts"], s["counts"])]
                d["sum"] += s["sum"]
                d["n"] += s["n"]
        dst["series"] = {k: dst["series"][k]
                         for k in sorted(dst["series"])}
