"""repro.serving — one streaming engine API for both runtimes (DESIGN.md §9).

The LM dual-mesh runner and the CNN dual-core runner serve through the same
``Engine`` protocol (``submit`` / ``step`` / ``drain``), with shared
``Request``/``Ticket``/``Completion`` lifecycle objects, per-request
latency ``Metrics``, and a pluggable ``AdmissionPolicy``.  ``replay`` drives
any engine with a fixed arrival trace (``poisson_arrivals`` builds one).
"""
from repro.serving.api import (STATUSES, AdmissionPolicy, Completion,
                               DeadlineAdmission, Engine, EngineBase,
                               FixedRateAdmission, GreedyAdmission, Metrics,
                               PriorityAdmission, QueueFull, Request,
                               RequestMetrics, ServeResult, ShedPolicy,
                               Ticket, percentile, poisson_arrivals, replay)
from repro.serving.cnn import DualCoreEngine, stream_images
from repro.serving.lm import DualMeshEngine

__all__ = [
    "AdmissionPolicy",
    "Completion",
    "DeadlineAdmission",
    "DualCoreEngine",
    "DualMeshEngine",
    "Engine",
    "EngineBase",
    "FixedRateAdmission",
    "GreedyAdmission",
    "Metrics",
    "PriorityAdmission",
    "QueueFull",
    "Request",
    "RequestMetrics",
    "STATUSES",
    "ServeResult",
    "ShedPolicy",
    "Ticket",
    "percentile",
    "poisson_arrivals",
    "replay",
    "stream_images",
]
