"""Workload-agnostic streaming engine API (DESIGN.md §9).

The repo grew two serving stacks — ``dualmesh.DualMeshRunner.serve`` for the
LM and ``dualcore.DualCoreRunner.run_pipelined`` for the CNN — that shared no
interface despite both implementing the paper's keep-both-cores-busy story.
This module is the single surface both now serve through:

  Request / Ticket / Completion    one unit of work and its lifecycle
  Metrics / RequestMetrics         per-request latency + aggregate throughput
  AdmissionPolicy                  how many queued requests enter per step
  Engine (protocol)                submit / step / drain / result
  replay                           drive an engine with a fixed arrival trace

Lifecycle: ``submit`` enqueues a :class:`Request` onto the engine's bounded
queue and returns a :class:`Ticket` (raising :class:`QueueFull` when the
queue is at capacity — backpressure is the caller's signal to slow down).
``step`` advances the engine by exactly one scheduler slot: it services
in-flight work, retires finished requests (returned as :class:`Completion`
objects), and asks the :class:`AdmissionPolicy` how many queued requests to
admit into freed capacity.  ``drain`` steps until no work remains and
returns a :class:`ServeResult`; ``result`` snapshots what has completed so
far without stepping.  Engines never spin a thread — the caller owns the
loop, which is what lets ``replay`` interleave submissions mid-flight and
tests drive slot-by-slot.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Protocol, Sequence, runtime_checkable


class QueueFull(RuntimeError):
    """``submit`` refused: the engine's bounded request queue is full.

    This is backpressure, not an error state — the caller should retry after
    ``step`` has drained capacity (``replay`` does exactly that)."""


@dataclasses.dataclass
class Request:
    """One unit of serving work.

    ``payload`` is workload-defined: a ``(B, P)`` token prompt for the LM
    engine, an ``(N, H, W, 3)`` image for the CNN engine.  ``gen_steps`` is
    the LM decode budget (total generated tokens; the prefill emits the
    first) and is ignored by the CNN engine.  ``rid`` is assigned by the
    engine at submit time.

    ``model`` tags the request with the network it targets — the fleet
    front-end routes on it and :meth:`Metrics.by_model` breaks latency
    percentiles down by it.  ``deadline`` is any caller-defined comparable
    (absolute wall-clock, a slot index, ...) that
    :class:`DeadlineAdmission` orders admissions by; ``priority`` (higher
    is more urgent) is what :class:`PriorityAdmission` orders by.  Both are
    inert under the FIFO policies.
    """

    payload: Any
    gen_steps: int = 0
    rid: int | None = None
    model: str | None = None
    deadline: float | None = None
    priority: int = 0


@dataclasses.dataclass(frozen=True)
class Ticket:
    """Receipt for a submitted request: its id and submission wall-time."""

    rid: int
    submitted_at: float


#: terminal request states: served normally / dropped past-deadline by a
#: ShedPolicy / lost with no surviving pool to serve it / re-routed off a
#: crashed pool and served elsewhere
STATUSES = ("ok", "shed", "failed", "recovered")


@dataclasses.dataclass
class RequestMetrics:
    """Wall-clock lifecycle of one request (perf_counter timestamps)."""

    rid: int
    submitted_at: float
    started_at: float | None = None     # admitted into the engine
    finished_at: float | None = None    # output materialized
    model: str | None = None            # Request.model tag, if any
    status: str = "ok"                  # one of STATUSES
    deadline: float | None = None       # Request.deadline, for SLO checks
    slo_ok: bool = True                 # finished within its deadline
    #                                     (vacuously True with none set)

    @property
    def wait_s(self) -> float:
        """Queue wait before admission, in seconds."""
        return (self.started_at or self.submitted_at) - self.submitted_at

    @property
    def service_s(self) -> float:
        """Admission-to-finish service time, in seconds."""
        if self.finished_at is None or self.started_at is None:
            return float("nan")
        return self.finished_at - self.started_at

    @property
    def latency_s(self) -> float:
        """Submit-to-finish latency, in seconds."""
        if self.finished_at is None:
            return float("nan")
        return self.finished_at - self.submitted_at


@dataclasses.dataclass
class Completion:
    """A finished request: its ticket, output, and measured lifecycle.
    ``output`` is None for shed/failed requests — check :attr:`status`
    before using it."""

    ticket: Ticket
    output: Any
    metrics: RequestMetrics

    @property
    def status(self) -> str:
        """Terminal status of the underlying request."""
        return self.metrics.status


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolated percentile (numpy semantics, no numpy import)."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    pos = (len(s) - 1) * q / 100.0
    lo = int(pos)
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


@dataclasses.dataclass
class Metrics:
    """Aggregate view over completed requests."""

    requests: list[RequestMetrics] = dataclasses.field(default_factory=list)
    wall_s: float = 0.0
    slots_observed: int = 0      # engine slots (or router steps) elapsed

    @property
    def completed(self) -> int:
        """Requests that reached a terminal status."""
        return len(self.requests)

    def latencies_ms(self, model: str | None = None) -> list[float]:
        """Latencies of *served* requests (ok/recovered) — shed and
        failed requests produced no output, so they do not belong in
        service-latency percentiles (they are counted separately)."""
        return [m.latency_s * 1e3 for m in self.requests
                if m.finished_at is not None
                and m.status in ("ok", "recovered")
                and (model is None or m.model == model)]

    def count(self, status: str) -> int:
        """Completions with the given terminal status."""
        return sum(1 for m in self.requests if m.status == status)

    def goodput(self) -> int:
        """Served requests that met their deadline (no deadline = met)."""
        return sum(1 for m in self.requests
                   if m.status in ("ok", "recovered") and m.slo_ok)

    def goodput_fps(self) -> float:
        """Within-SLO completions per second — the metric overload
        protection optimizes (serving a request late counts for
        nothing; shedding it early at least frees the capacity)."""
        return self.goodput() / self.wall_s if self.wall_s else 0.0

    def p50_ms(self) -> float:
        """Median served latency, in milliseconds."""
        return percentile(self.latencies_ms(), 50)

    def p95_ms(self) -> float:
        """95th-percentile served latency, in milliseconds."""
        return percentile(self.latencies_ms(), 95)

    def requests_per_s(self) -> float:
        """Completions per wall-clock second."""
        if not self.wall_s:
            return float("inf") if self.completed else 0.0
        return self.completed / self.wall_s

    def models(self) -> list[str]:
        """Distinct request model tags, in first-seen order."""
        seen: dict[str, None] = {}
        for m in self.requests:
            if m.model is not None:
                seen.setdefault(m.model, None)
        return list(seen)

    def by_model(self) -> dict[str, dict]:
        """Latency breakdown keyed by request model tag: the per-model
        completed count, p50/p95 latency, and served fps over the shared
        wall clock (what the fleet bench and the Table-VII comparison
        report per network).  Zero completions / zero wall clock yield
        None / 0.0, not NaN / inf — these dicts land in BENCH JSONs,
        which must stay valid JSON."""
        out: dict[str, dict] = {}
        for model in self.models():
            lats = self.latencies_ms(model)
            out[model] = {
                "completed": len(lats),
                "p50_ms": round(percentile(lats, 50), 3) if lats else None,
                "p95_ms": round(percentile(lats, 95), 3) if lats else None,
                "requests_per_s": round(len(lats) / self.wall_s, 3)
                if self.wall_s else 0.0,
            }
            shed = sum(1 for m in self.requests
                       if m.model == model and m.status == "shed")
            if shed:
                out[model]["shed"] = shed
        return out

    def summary(self) -> dict:
        """Aggregate snapshot, JSON-safe in the zero-completions and
        everything-shed cases (empty percentiles report None, an
        unstarted clock and an empty goodput 0.0)."""
        lats = self.latencies_ms()
        out = {"completed": self.completed,
               "wall_s": round(self.wall_s, 6),
               "slots_observed": self.slots_observed,
               "requests_per_s": round(len(lats) / self.wall_s, 3)
               if self.wall_s else 0.0,
               "goodput_fps": round(self.goodput_fps(), 3),
               "shed": self.count("shed"),
               "failed": self.count("failed"),
               "recovered": self.count("recovered"),
               "p50_ms": round(percentile(lats, 50), 3) if lats else None,
               "p95_ms": round(percentile(lats, 95), 3) if lats else None}
        per_model = self.by_model()
        if per_model:
            out["per_model"] = per_model
        return out


class MetricsWindow:
    """Sliding window over the last ``size`` request completions.

    :class:`Metrics` aggregates a whole run; a controller needs the
    *recent* picture — a mix that flipped five minutes ago should not be
    averaged against the hour before it.  The window keeps the last
    ``size`` terminal :class:`RequestMetrics` (fed via :meth:`observe`
    from each step's completions) and answers the per-model questions
    the §13 control loop asks: completion share, shed rate, p95 latency.
    """

    def __init__(self, size: int = 64):
        """Create a window keeping the most recent ``size`` completions."""
        if size < 1:
            raise ValueError(f"window size must be >= 1 (got {size})")
        self.size = size
        self._buf: deque[RequestMetrics] = deque(maxlen=size)

    def __len__(self) -> int:
        """Number of completions currently held (<= ``size``)."""
        return len(self._buf)

    def observe(self, completions: Sequence[Completion]) -> None:
        """Absorb one step's completions (oldest entries fall out)."""
        for c in completions:
            self._buf.append(c.metrics)

    def clear(self) -> None:
        """Forget everything (e.g. after a REBALANCE changed the world)."""
        self._buf.clear()

    def models(self) -> list[str]:
        """Distinct model tags in the window, in first-seen order."""
        seen: dict[str, None] = {}
        for m in self._buf:
            if m.model is not None:
                seen.setdefault(m.model, None)
        return list(seen)

    def stats(self, model: str | None = None) -> dict:
        """Window stats, optionally restricted to one model tag.

        Returns ``{"n", "served", "shed", "shed_rate", "p95_ms"}`` where
        ``served`` counts ok/recovered completions, ``shed_rate`` is
        shed / n (0.0 on an empty slice), and ``p95_ms`` is the served
        p95 latency (None with nothing served — JSON-safe).
        """
        ms = [m for m in self._buf
              if model is None or m.model == model]
        lats = [m.latency_s * 1e3 for m in ms
                if m.finished_at is not None
                and m.status in ("ok", "recovered")]
        shed = sum(1 for m in ms if m.status == "shed")
        return {
            "n": len(ms),
            "served": len(lats),
            "shed": shed,
            "shed_rate": shed / len(ms) if ms else 0.0,
            "p95_ms": percentile(lats, 95) if lats else None,
        }

    def by_model(self) -> dict[str, dict]:
        """Per-model :meth:`stats`, keyed by model tag."""
        return {m: self.stats(m) for m in self.models()}


@dataclasses.dataclass
class ServeResult:
    """What ``drain``/``result`` hand back: outputs in submission order,
    per-request completions, aggregate metrics, and engine-specific stats
    (token counts for the LM engine, slot counts for the CNN engine)."""

    outputs: list[Any]
    completions: list[Completion]
    metrics: Metrics
    stats: dict = dataclasses.field(default_factory=dict)
    trace: list = dataclasses.field(default_factory=list)


# --------------------------------------------------------------------------
# admission policies
# --------------------------------------------------------------------------
class AdmissionPolicy(Protocol):
    """Decides, once per ``step``, how many queued requests to admit.

    A policy may additionally define ``select(pending) -> int`` returning
    the index of the queued request to admit next — engines that find it
    (via :meth:`EngineBase._pop_admission`) admit out of FIFO order, which
    is how the latency-aware policies (:class:`DeadlineAdmission`,
    :class:`PriorityAdmission`) reorder the queue without the engines
    knowing anything about deadlines."""

    def admit(self, *, queued: int, in_flight: int, capacity: int) -> int:
        """Number of requests to move from the queue into the engine.  The
        engine clamps the answer to what is actually admissible (free
        capacity, queue length, and any structural per-step limit such as
        the CNN pipeline's one-entry-per-slot offset)."""
        ...


@dataclasses.dataclass
class GreedyAdmission:
    """Fill all free capacity every step — maximum occupancy."""

    def admit(self, *, queued: int, in_flight: int, capacity: int) -> int:
        """Admit everything the engine has capacity for."""
        return max(0, min(queued, capacity - in_flight))


@dataclasses.dataclass
class FixedRateAdmission:
    """At most ``per_step`` admissions per step — the paper's staggered
    entry (one stream per slot) is ``per_step=1``."""

    per_step: int = 1

    def admit(self, *, queued: int, in_flight: int, capacity: int) -> int:
        """Admit at most ``per_step`` requests per scheduler step."""
        return max(0, min(queued, self.per_step, capacity - in_flight))


@dataclasses.dataclass
class DeadlineAdmission:
    """Earliest-deadline-first: admit the queued request with the smallest
    ``Request.deadline`` next (``None`` deadlines sort last, FIFO among
    themselves).  Rate-wise identical to :class:`FixedRateAdmission` —
    EDF changes *which* request enters a freed slot, not how many."""

    per_step: int = 1

    def admit(self, *, queued: int, in_flight: int, capacity: int) -> int:
        """Admit at most ``per_step`` requests per scheduler step."""
        return max(0, min(queued, self.per_step, capacity - in_flight))

    def select(self, pending: Sequence[Request]) -> int:
        """Select the earliest-deadline pending request."""
        return min(range(len(pending)),
                   key=lambda i: (pending[i].deadline is None,
                                  pending[i].deadline
                                  if pending[i].deadline is not None
                                  else 0.0, i))


@dataclasses.dataclass
class PriorityAdmission:
    """Highest ``Request.priority`` first, FIFO within a priority class."""

    per_step: int = 1

    def admit(self, *, queued: int, in_flight: int, capacity: int) -> int:
        """Admit at most ``per_step`` requests per scheduler step."""
        return max(0, min(queued, self.per_step, capacity - in_flight))

    def select(self, pending: Sequence[Request]) -> int:
        """Select the highest-priority pending request."""
        return min(range(len(pending)),
                   key=lambda i: (-pending[i].priority, i))


@dataclasses.dataclass
class ShedPolicy:
    """SLO enforcement: drop queued requests already past their deadline
    instead of serving them late.

    Wraps an inner :class:`AdmissionPolicy` (default
    ``FixedRateAdmission(1)``) for the how-many/which decisions; the shed
    decision happens at two points: engines sweep their queue at the
    start of every dispatch (``EngineBase.shed_expired`` — the fleet
    executor calls it with the fleet slot before each RUN) and
    :meth:`EngineBase._pop_admission` re-checks the selected request at
    admission, so a request can never enter the pipeline already dead.
    Shed requests complete with ``status="shed"`` and no output —
    explicitly accounted, never silently lost.

    ``clock`` picks the deadline domain: ``"slot"`` (default) compares
    deadlines against the engine's scheduler-slot counter — fully
    deterministic, so faulted runs replay bitwise with the same shed
    set; ``"wall"`` compares against ``time.perf_counter()`` — the
    production mode (``serve fleet --slo-ms``), not replay-deterministic
    by nature.  With ``slo_s`` set (wall clock only), requests submitted
    without a deadline get one stamped at ``submit + slo_s``.
    """

    inner: AdmissionPolicy | None = None
    slo_s: float | None = None
    clock: str = "slot"

    sheds = True        # engines detect shedding support via this attr

    def __post_init__(self):
        if self.clock not in ("slot", "wall"):
            raise ValueError(f"ShedPolicy clock must be 'slot' or 'wall' "
                             f"(got {self.clock!r})")
        if self.slo_s is not None:
            if not self.slo_s > 0:
                raise ValueError(f"slo_s must be > 0 (got {self.slo_s})")
            if self.clock != "wall":
                raise ValueError("slo_s auto-stamps wall-clock deadlines; "
                                 "with clock='slot' set Request.deadline "
                                 "to a slot index explicitly")
        if self.inner is None:
            self.inner = FixedRateAdmission(1)

    def now(self, slot_clock: float) -> float:
        """Current time in the policy's clock domain."""
        return (time.perf_counter() if self.clock == "wall"
                else float(slot_clock))

    def expired(self, deadline: float | None, now: float) -> bool:
        """True when ``deadline`` has passed at ``now``."""
        return deadline is not None and now > deadline

    def admit(self, *, queued: int, in_flight: int, capacity: int) -> int:
        """Delegate the how-many decision to the inner policy."""
        return self.inner.admit(queued=queued, in_flight=in_flight,
                                capacity=capacity)

    def select(self, pending: Sequence[Request]) -> int:
        """Delegate selection to the inner policy (FIFO default)."""
        sel = getattr(self.inner, "select", None)
        return 0 if sel is None else int(sel(pending))


# --------------------------------------------------------------------------
# the engine protocol
# --------------------------------------------------------------------------
@runtime_checkable
class Engine(Protocol):
    """The shared serving surface (see module docstring for the contract)."""

    def submit(self, request: Request | Any) -> Ticket:
        """Enqueue one request and return its ticket."""
        ...

    def step(self) -> list[Completion]:
        """Advance the pipeline one slot; return newly finished work."""
        ...

    def drain(self) -> ServeResult:
        """Step until idle, then return the full result."""
        ...

    def result(self) -> ServeResult:
        """Snapshot of completions and metrics so far."""
        ...

    @property
    def has_work(self) -> bool:
        """True while any queued or in-flight work remains."""
        ...


# --------------------------------------------------------------------------
# shared engine bookkeeping
# --------------------------------------------------------------------------
class EngineBase:
    """Queue / ticket / metrics bookkeeping shared by every engine.

    Subclasses own the scheduling (``step`` and ``has_work``); this base
    owns the request lifecycle: the bounded pending queue, rid assignment,
    ticket + metrics stamping at submit, completion stamping (with the
    materializing block) in :meth:`_finish`, and the :meth:`result`
    snapshot — so submit semantics and accounting can never diverge
    between workloads.
    """

    obs = None           # optional repro.obs.Registry (fleet wires it;
    #                      standalone engines run uninstrumented)

    def __init__(self, *, max_queue: int | None = None):
        if max_queue is not None and max_queue < 1:
            raise ValueError(f"max_queue must be >= 1 (got {max_queue}); "
                             f"a 0-capacity queue could never admit work")
        self.max_queue = max_queue
        self._pending: deque[tuple[Request, Ticket]] = deque()
        self._completions: dict[int, Completion] = {}
        self._order: list[int] = []            # rids in submission order
        self._metrics: dict[int, RequestMetrics] = {}
        self._next_rid = 0
        self._t0: float | None = None
        self._ext_clock: float | None = None   # last externally-supplied
        #                                        shed clock (fleet slot)
        self._shed_buf: list[Completion] = []  # sheds found mid-admission,
        #                                        drained by the next sweep

    @property
    def queued(self) -> int:
        """Requests waiting for admission."""
        return len(self._pending)

    def pending_requests(self) -> list[Request]:
        """Queued-but-unadmitted requests, in queue order (a read-only
        view — the fleet scheduler inspects deadlines through this)."""
        return [req for req, _ in self._pending]

    def submit(self, request: Request | Any) -> Ticket:
        """Enqueue one request; raises :class:`QueueFull` at the bound."""
        if self.max_queue is not None \
                and len(self._pending) >= self.max_queue:
            raise QueueFull(f"request queue at max_queue={self.max_queue}")
        req = request if isinstance(request, Request) else Request(request)
        rid = self._next_rid
        self._next_rid += 1
        req.rid = rid
        ticket = Ticket(rid=rid, submitted_at=time.perf_counter())
        pol = getattr(self, "policy", None)
        if (getattr(pol, "sheds", False) and req.deadline is None
                and pol.slo_s is not None):
            req.deadline = ticket.submitted_at + pol.slo_s
        self._metrics[rid] = RequestMetrics(rid=rid,
                                            submitted_at=ticket.submitted_at,
                                            model=req.model,
                                            deadline=req.deadline)
        self._order.append(rid)
        self._pending.append((req, ticket))
        return ticket

    def _pop_admission(self) -> tuple[Request, Ticket] | None:
        """Pop the next request to admit: FIFO unless the engine's
        admission policy orders the queue via ``select`` (EDF/priority).
        Under a :class:`ShedPolicy` a selected request already past its
        deadline is shed instead of admitted (buffered on
        ``_shed_buf``); returns None when shedding emptied the queue."""
        pol = getattr(self, "policy", None)
        sheds = getattr(pol, "sheds", False)
        select = getattr(pol, "select", None)
        while self._pending:
            if select is None or len(self._pending) <= 1:
                item = self._pending.popleft()
            else:
                i = int(select([req for req, _ in self._pending]))
                if not 0 <= i < len(self._pending):
                    raise ValueError(f"admission policy {self.policy!r} "
                                     f"selected index {i}, outside the "
                                     f"queue [0, {len(self._pending)})")
                item = self._pending[i]
                del self._pending[i]
            req, _ticket = item
            if sheds and pol.expired(req.deadline, pol.now(self._clock())):
                self._shed_buf.append(self._shed(req))
                continue
            return item
        return None

    # -- SLO shedding ---------------------------------------------------
    def _clock(self) -> float:
        """The slot-domain shed clock: the last externally supplied slot
        (the fleet executor clocks members with the fleet slot — the
        domain the replayable deadlines live in), else the engine's own
        slot counter."""
        if self._ext_clock is not None:
            return self._ext_clock
        return float(getattr(self, "_slot", 0))

    def _shed(self, req: Request) -> Completion:
        """File one past-deadline request as a ``status="shed"``
        completion (no output) — explicitly dropped, never lost."""
        m = self._metrics[req.rid]
        m.status = "shed"
        m.finished_at = time.perf_counter()
        c = Completion(ticket=Ticket(rid=req.rid,
                                     submitted_at=m.submitted_at),
                       output=None, metrics=m)
        self._completions[req.rid] = c
        return c

    def _take_shed(self) -> list[Completion]:
        out, self._shed_buf = self._shed_buf, []
        return out

    def shed_expired(self, now: float | None = None) -> list[Completion]:
        """Sweep the queue for requests past deadline under the engine's
        :class:`ShedPolicy` (no-op without one).  ``now`` sets the
        slot-domain clock (the fleet executor passes the fleet slot
        before each RUN — live and replayed runs shed identically);
        None uses the engine's own counter.  Returns the shed
        completions, including any buffered by admission-time checks."""
        pol = getattr(self, "policy", None)
        if not getattr(pol, "sheds", False):
            return self._take_shed()
        if now is not None:
            self._ext_clock = float(now)
        now_v = pol.now(self._clock())
        out = self._take_shed()
        kept: deque[tuple[Request, Ticket]] = deque()
        for req, ticket in self._pending:
            if pol.expired(req.deadline, now_v):
                out.append(self._shed(req))
            else:
                kept.append((req, ticket))
        self._pending = kept
        return out

    def withdraw_pending(self, max_n: int | None = None
                         ) -> list[tuple[int, Request]]:
        """Remove up to ``max_n`` queued (unadmitted) requests — newest
        first, so the longest-waiting requests keep their place — and
        un-account them (their rids vanish from the metrics and the
        submission order; the tickets are dead).  Returned pairs are in
        original queue order, ready for re-submission elsewhere: this is
        the executor-facing hook behind the SEND instruction (cross-pool
        migration).  In-flight work is never withdrawn — it finishes
        where it was dispatched."""
        n = (len(self._pending) if max_n is None
             else max(0, min(max_n, len(self._pending))))
        taken = [self._pending.pop() for _ in range(n)][::-1]
        out: list[tuple[int, Request]] = []
        for req, _ticket in taken:
            del self._metrics[req.rid]
            self._order.remove(req.rid)
            out.append((req.rid, req))
        return out

    def _start_clock(self) -> None:
        if self._t0 is None:
            self._t0 = time.perf_counter()

    def _finish(self, rid: int, output) -> Completion:
        """Materialize ``output``, stamp the finish time, file the
        completion."""
        import jax

        jax.block_until_ready(output)
        m = self._metrics[rid]
        m.finished_at = time.perf_counter()
        pol = getattr(self, "policy", None)
        if m.deadline is not None and getattr(pol, "sheds", False):
            m.slo_ok = not pol.expired(m.deadline, pol.now(self._clock()))
        c = Completion(ticket=Ticket(rid=rid, submitted_at=m.submitted_at),
                       output=output, metrics=m)
        self._completions[rid] = c
        return c

    def _extra_stats(self, metrics: Metrics) -> dict:
        """Engine-specific stats merged into ``result().stats``."""
        return {}

    def _trace_snapshot(self) -> list:
        return []

    def result(self) -> ServeResult:
        """Snapshot of everything completed so far, in submission order."""
        wall = ((time.perf_counter() - self._t0) if self._t0 is not None
                else 0.0)
        completions = [self._completions[r] for r in self._order
                       if r in self._completions]
        metrics = Metrics(requests=[c.metrics for c in completions],
                          wall_s=wall,
                          slots_observed=int(getattr(self, "_slot", 0)
                                             or getattr(self, "_steps", 0)))
        stats = {"wall_s": wall}
        stats.update(self._extra_stats(metrics))
        return ServeResult(outputs=[c.output for c in completions],
                           completions=completions, metrics=metrics,
                           stats=stats, trace=self._trace_snapshot())

    def drain(self) -> ServeResult:
        """Step until no queued or in-flight work remains."""
        while self.has_work:
            self.step()
        return self.result()


# --------------------------------------------------------------------------
# arrival-trace driving
# --------------------------------------------------------------------------
def poisson_arrivals(n: int, rate: float = 1.0, seed: int = 0) -> list[int]:
    """Fixed Poisson-ish arrival trace: ``n`` step-indexed arrival times
    with exponential inter-arrival gaps of mean ``1/rate`` steps, from a
    seeded generator (deterministic across runs — benchmarks diff it)."""
    import random

    if not rate > 0:
        raise ValueError(f"arrival rate must be > 0 (got {rate}); use an "
                         f"all-zeros arrival list for everything-at-once")
    rng = random.Random(seed)
    t, out = 0.0, []
    for _ in range(n):
        out.append(int(t))
        t += rng.expovariate(rate)
    return out


def replay(engine: Engine, requests: Sequence[Request | Any],
           arrivals: Sequence[int] | None = None,
           on_step=None) -> ServeResult:
    """Drive ``engine`` with requests arriving at the given step indices.

    Requests whose arrival step has passed are submitted before each step;
    a :class:`QueueFull` pushes that request to later steps (backpressure
    in action) but never blocks the requests behind it — against a
    single-queue engine the distinction is moot (the queue that refused
    request i refuses i+1 too), while against a fleet front end it is the
    per-member isolation: one model's full queue must not starve another
    model's traffic that arrived the same step.  Refused requests retry
    first next step, so per-queue FIFO order is preserved.  ``on_step``
    (if given) fires after every engine step with the step index — the
    periodic-telemetry hook.  Returns the engine's final result once
    every request has been submitted and served.
    """
    arrivals = list(arrivals) if arrivals is not None else [0] * len(requests)
    if len(arrivals) != len(requests):
        raise ValueError(f"{len(requests)} requests but "
                         f"{len(arrivals)} arrival times")
    order = sorted(range(len(requests)), key=lambda i: arrivals[i])
    refused: list[int] = []
    nxt, step = 0, 0
    while nxt < len(order) or refused or engine.has_work:
        due, refused = refused, []
        while nxt < len(order) and arrivals[order[nxt]] <= step:
            due.append(order[nxt])
            nxt += 1
        for i in due:
            try:
                engine.submit(requests[i])
            except QueueFull:
                refused.append(i)       # retry after the next step frees room
        engine.step()
        if on_step is not None:
            on_step(step)
        step += 1
    return engine.result()
