"""Streaming CNN engine: online admission for the dual-core pipeline.

``DualCoreRunner.run_pipelined`` took a static image list and never refilled
a drained slot — the pipeline wound down as streams finished even when more
work was waiting.  :class:`DualCoreEngine` closes that gap (the ROADMAP
"online admission loop" item): requests queue up (bounded, with
:class:`~repro.serving.api.QueueFull` backpressure), and every scheduler
slot the engine

  1. advances each in-flight stream by one exec group, oldest stream first
     (stream admitted at slot ``s`` runs group ``k - s`` at slot ``k`` — the
     paper's one-slot offset, so neighbouring streams always occupy
     different cores by the alternation invariant);
  2. admits at most one queued request into the freed group-0 slot (the
     structural per-step limit — two streams entering the same slot would
     double-book a core; the :class:`AdmissionPolicy` can only throttle
     below that);
  3. retires streams that cleared the last group, materializing their
     output (the per-request latency the metrics record) — only after every
     dispatch of the slot is in flight, so the block never serializes the
     cross-core overlap.

With every request available up front this reproduces the
``run_pipelined`` dispatch trace exactly (a test asserts it); under bursty
arrivals, empty-queue slots become pipeline bubbles that later admissions
refill.  Capacity equals the number of exec groups — the deepest the
one-slot-offset pipeline can be — so in-flight work is bounded by
construction and the queue bound covers the rest.
"""
from __future__ import annotations

import dataclasses
import time
from typing import TYPE_CHECKING

from repro.serving.api import (AdmissionPolicy, Completion, EngineBase,
                               FixedRateAdmission, Metrics, RequestMetrics,
                               ServeResult, Ticket)

if TYPE_CHECKING:
    from repro.dualcore.runtime import DualCoreRunner


@dataclasses.dataclass
class _Flight:
    """One in-flight stream: its env and the next group it will run."""

    rid: int
    env: dict
    next_group: int
    ticket: Ticket
    metrics: RequestMetrics


class DualCoreEngine(EngineBase):
    """Continuous-streaming front end over a :class:`DualCoreRunner`.

    ``record``, when given, receives ``(slot, rid, group, core)`` tuples in
    dispatch order — the same trace ``run_pipelined`` produced, now with
    admission slots determined online by arrivals instead of statically.
    """

    def __init__(self, runner: "DualCoreRunner", *,
                 policy: AdmissionPolicy | None = None,
                 max_queue: int | None = None,
                 record: list | None = None):
        super().__init__(max_queue=max_queue)
        self.runner = runner
        self.policy = policy or FixedRateAdmission(1)
        self.capacity = len(runner.groups)
        self._handles = runner.handles
        self._record = record
        self._flight: list[_Flight] = []      # admission order: oldest first
        self._slot = 0

    # ------------------------------------------------------------------
    @property
    def in_flight(self) -> int:
        """Streams currently in the pipeline."""
        return len(self._flight)

    @property
    def has_work(self) -> bool:
        """True while any queued or in-flight work remains."""
        return bool(self._pending or self._flight)

    def next_dispatch_cycles(self) -> tuple[float, float]:
        """Predicted (c-cycles, p-cycles) the *next* ``step`` will dispatch,
        from the per-group latency model the schedule carries
        (``core.scheduler.Schedule.group_latencies`` of the exec schedule):
        every in-flight stream contributes its next group's latency on
        that group's core, plus group 0 if an admission would land.  The
        fleet front-end reads this to co-dispatch a member whose slot is
        conv-heavy with one whose slot is dw-heavy."""
        lat = self.runner.plan.exec_schedule.group_latencies
        groups = self.runner.groups
        cyc = {"c": 0.0, "p": 0.0}
        for f in self._flight:
            cyc[groups[f.next_group].core] += lat[f.next_group]
        if self._pending and len(self._flight) < self.capacity:
            cyc[groups[0].core] += lat[0]
        return cyc["c"], cyc["p"]

    @property
    def next_core(self) -> str | None:
        """Core carrying the dominant share of the next step's dispatches
        (``None`` when the engine has no work)."""
        if not self.has_work:
            return None
        c, p = self.next_dispatch_cycles()
        return "c" if c >= p else "p"

    # ------------------------------------------------------------------
    def _dispatch(self, f: _Flight) -> None:
        """Run flight ``f``'s next group via the runner's group handle
        (cross-core env hop included)."""
        gi = f.next_group
        h = self._handles[gi]
        f.env = h(f.env, prev_core=self._handles[gi - 1].core
                  if gi > 0 else None)
        if self._record is not None:
            self._record.append((self._slot, f.rid, gi, h.core))
        f.next_group = gi + 1

    def relocate(self, dual) -> None:
        """Move the engine onto a re-split pool (REBALANCE): relocate the
        runner's params/shardings, then re-place every in-flight env on
        its next group's core — a stream mid-chain resumes on the new
        submeshes without losing its position."""
        self.runner.relocate(dual)
        self._handles = self.runner.handles
        for f in self._flight:
            f.env = self.runner._place(f.env,
                                       self._handles[f.next_group].core)

    def step(self) -> list[Completion]:
        """Advance the pipeline by one slot (see module docstring)."""
        return self.retire(self.advance())

    def advance(self) -> list["_Flight"]:
        """Dispatch phase of one slot: advance every in-flight stream and
        admit into the freed group-0 slot, returning the flights that
        cleared the last group WITHOUT materializing them.  Callers that
        own more dispatches for the same wall-clock window (the fleet's
        cross-engine co-dispatch) issue those first and call
        :meth:`retire` after — the same block-last rule ``step`` applies
        within one engine, extended across engines."""
        self._start_clock()
        # 0. shed past-deadline queue entries (ShedPolicy only) against
        #    the engine's own slot counter — unless an external clock
        #    (the fleet executor's slot) already swept this dispatch
        if self._ext_clock is None:
            self._shed_buf.extend(self.shed_expired())
        finished: list[_Flight] = []
        # 1. advance in-flight streams, oldest (deepest group) first
        kept: list[_Flight] = []
        for f in self._flight:
            self._dispatch(f)
            (finished if f.next_group >= self.capacity else kept).append(f)
        self._flight = kept
        # 2. admit into the freed group-0 slot — at most one per slot, or
        #    the one-slot offset (one group per core per slot) breaks
        n = self.policy.admit(queued=len(self._pending),
                              in_flight=len(self._flight),
                              capacity=self.capacity)
        n = max(0, min(n, 1, self.capacity - len(self._flight),
                       len(self._pending)))
        if n:
            popped = self._pop_admission()      # None: everything left in
            if popped is not None:              # the queue was shed
                req, ticket = popped
                self._metrics[req.rid].started_at = time.perf_counter()
                f = _Flight(rid=req.rid,
                            env=self.runner.place_input(req.payload),
                            next_group=0, ticket=ticket,
                            metrics=self._metrics[req.rid])
                self._dispatch(f)
                if f.next_group >= self.capacity:   # single-group chain
                    finished.append(f)
                else:
                    self._flight.append(f)
        self._slot += 1
        return finished

    def retire(self, finished: list["_Flight"]) -> list[Completion]:
        """Materialize the outputs of flights returned by
        :meth:`advance` — only after every dispatch of the slot is in
        flight; blocking earlier would serialize the cross-core overlap.
        Shed completions buffered during the dispatch phase ride out
        here too."""
        out = self._take_shed()
        out.extend(self._finish(f.rid, f.env["out"]) for f in finished)
        return out

    # ------------------------------------------------------------------
    def _extra_stats(self, metrics: Metrics) -> dict:
        return {"engine": "dualcore", "slots": self._slot,
                "capacity": self.capacity,
                "exec_groups": self.capacity,
                "completed": metrics.completed,
                "queued": len(self._pending),
                "in_flight": len(self._flight),
                "fps": metrics.requests_per_s()}


def stream_images(runner: "DualCoreRunner", images, *,
                  policy: AdmissionPolicy | None = None,
                  max_queue: int | None = None,
                  record: list | None = None) -> ServeResult:
    """Serve a ready list of images through a fresh engine (the engine-API
    equivalent of the old ``run_pipelined`` call shape: everything arrives
    at slot 0, the admission loop staggers entry one slot apart)."""
    eng = DualCoreEngine(runner, policy=policy, max_queue=max_queue,
                         record=record)
    for x in images:
        eng.submit(x)
    return eng.drain()
