"""Streaming LM engine: the dual-mesh serve loop behind the engine API.

``DualMeshRunner.serve`` was a monolithic method — queue, admission,
prefill, decode-group bookkeeping and metrics all in one while-loop.
:class:`DualMeshEngine` factors that loop into the shared
submit/step/drain surface: the runner keeps the mechanics (chunked prefill
on the c-submesh, fused decode groups on the p-submesh, eviction), the
engine owns the policy, and :class:`~repro.serving.api.EngineBase` owns
the request lifecycle.  One ``step`` is one scheduler slot:

  1. advance every active decode group by a quantum of fused steps on the
     p-submesh (retiring members that hit their generation target);
  2. ask the :class:`AdmissionPolicy` how many queued requests to admit and
     run their chunked prefills on the c-submesh (default: one per slot,
     the paper's stagger — the prefill dispatch overlaps the decode
     dispatched just before);
  3. fuse position-aligned prefilled streams into decode groups once
     ``group_size`` of them are ready (or the queue has drained).

``DualMeshRunner.serve`` survives as a thin compatibility shim: submit
everything, drain, repackage.  Requests can also arrive mid-flight —
``submit`` between ``step`` calls joins the live queue, and the bounded
queue raises :class:`~repro.serving.api.QueueFull` as backpressure.
"""
from __future__ import annotations

import time
from typing import TYPE_CHECKING

import jax

from repro.serving.api import (AdmissionPolicy, Completion, EngineBase,
                               FixedRateAdmission, Metrics)

if TYPE_CHECKING:
    from repro.dualmesh.runtime import DualMeshRunner


class DualMeshEngine(EngineBase):
    """Continuous-batching LM serving over a :class:`DualMeshRunner`.

    group_size      decode fusion width; None fuses every position-aligned
                    ready stream once the queue drains (callers wanting the
                    makespan-aware width pass
                    ``runner.planned_group_size(...)``)
    prefill_chunk   chunked-prefill slice in tokens (None = whole prompt)
    quantum         fused decode steps per slot (None = run a group until
                    its earliest member finishes)
    policy          admissions per slot (default one per slot, the stagger)
    max_queue       bounded request queue; submit raises QueueFull beyond it
    max_in_flight   cap on admitted-but-unfinished streams (None = no cap)
    """

    def __init__(self, runner: "DualMeshRunner", *,
                 group_size: int | None = None,
                 prefill_chunk: int | None = None,
                 quantum: int | None = None,
                 policy: AdmissionPolicy | None = None,
                 max_queue: int | None = None,
                 max_in_flight: int | None = None):
        super().__init__(max_queue=max_queue)
        self.runner = runner
        self.group_size = None if group_size is None else max(1, group_size)
        self.prefill_chunk = prefill_chunk
        # a 0-quantum would never progress a decode group
        self.quantum = None if quantum is None else max(1, quantum)
        self.policy = policy or FixedRateAdmission(1)
        self.max_in_flight = max_in_flight
        self._ready: list = []                 # prefilled StreamStates
        self._groups: list = []                # active DecodeGroups
        self._trace_start = len(runner.trace)
        self.prefill_tokens = 0
        self.decode_tokens = 0
        self.fused_sizes: list[int] = []
        self.retunes: list[tuple[int, dict]] = []   # mid-run knob changes

    # ------------------------------------------------------------------
    @property
    def in_flight(self) -> int:
        """Requests currently prefilling or decoding."""
        return len(self._ready) + sum(len(g.members) for g in self._groups)

    @property
    def has_work(self) -> bool:
        """True while any queued or in-flight work remains."""
        return bool(self._pending or self._ready or self._groups)

    def next_dispatch_cycles(self) -> tuple[float, float]:
        """Predicted (c-submesh, p-submesh) work of the next ``step``, in
        tokens (the LM analog of the CNN engine's cycle estimate): queued
        prompts prefill on the c-submesh, active decode groups advance on
        the p-submesh.  Units differ from the CNN engine's cycles — the
        fleet only compares the two sides of one engine to find its
        dominant core, never cycles across engines."""
        c = float(sum(getattr(req.payload, "size", 1)
                      for req, _ in self._pending))
        p = float(sum(g.batch for g in self._groups))
        return c, p

    @property
    def next_core(self) -> str | None:
        """Dominant core of the next dispatch (None when idle)."""
        if not self.has_work:
            return None
        c, p = self.next_dispatch_cycles()
        return "c" if c >= p else "p"

    # ------------------------------------------------------------------
    def step(self) -> list[Completion]:
        """One scheduler slot (see module docstring)."""
        self._start_clock()
        # shed past-deadline queue entries (ShedPolicy only), unless an
        # external clock (the fleet executor's slot) already swept
        shed = (self.shed_expired() if self._ext_clock is None
                else self._take_shed())
        r = self.runner
        done: list[tuple[int, jax.Array]] = []
        # 1. p-submesh: advance active decode groups (async dispatch —
        #    overlaps with the prefills dispatched right after)
        for g in list(self._groups):
            q = min(m.remaining for m in g.members)
            if self.quantum is not None:
                q = min(q, self.quantum)
            if q > 0:
                r._decode_group(g, q)
                self.decode_tokens += q * g.batch
            finished: dict[int, jax.Array] = {}
            if r._evict(g, finished) is None:
                self._groups.remove(g)
            done.extend(finished.items())
        # 2. c-submesh: admit queued requests, chunked prefill each
        capacity = (self.max_in_flight if self.max_in_flight is not None
                    else len(self._pending) + self.in_flight)
        n = self.policy.admit(queued=len(self._pending),
                              in_flight=self.in_flight, capacity=capacity)
        for _ in range(max(0, min(n, len(self._pending)))):
            popped = self._pop_admission()      # None: the rest was shed
            if popped is None:
                break
            req, _ticket = popped
            self._metrics[req.rid].started_at = time.perf_counter()
            st = r.new_stream(req.payload, int(req.gen_steps), rid=req.rid)
            want = st.gen_target
            plen = st.tokens.shape[1]
            self.prefill_tokens += st.tokens.size
            st = r.run_prefill(st, self.prefill_chunk)
            if want <= 0:               # prefill-only request: no emit
                done.append((req.rid, st.tokens[:, :plen]))
                continue
            self.decode_tokens += st.tokens.shape[0]    # the prefill emit
            st.gen_target -= 1
            if st.gen_target <= 0:
                done.append((req.rid, st.tokens))
            else:
                self._ready.append(st)
        # 3. fuse position-aligned ready streams into decode groups once
        #    group_size are waiting — or no further prefills can arrive
        #    right now, because the queue drained or admission is stalled
        #    at the in-flight cap (waiting for group_size would livelock:
        #    the cap blocks the very admissions the gate is waiting for)
        stalled = (self.max_in_flight is not None
                   and self.in_flight >= self.max_in_flight)
        buckets: dict[tuple, list] = {}
        for st in self._ready:
            buckets.setdefault((st.tokens.shape[1],), []).append(st)
        self._ready = []
        for bucket in buckets.values():
            while (self.group_size is not None
                   and len(bucket) >= self.group_size) \
                    or (bucket and (not self._pending or stalled)):
                width = (self.group_size if self.group_size is not None
                         else len(bucket))
                take, bucket = bucket[:width], bucket[width:]
                self.fused_sizes.append(len(take))
                self._groups.append(r._fuse(take))
            self._ready.extend(bucket)
        # 4. materialize completions only now, after every dispatch of the
        #    slot is in flight — blocking inside the loops above would
        #    serialize the c/p-submesh overlap (same rule as the CNN
        #    engine's retire phase)
        return shed + [self._finish(rid, out) for rid, out in done]

    # ------------------------------------------------------------------
    def retune(self, *, group_size: int | None = None,
               quantum: int | None = None,
               prefill_chunk: int | None = None) -> dict:
        """Adjust serving knobs mid-run (the SET_PARAM / §13 hook).

        Only the knobs passed change; each affects work scheduled *after*
        the call — in-flight decode groups keep the width they were fused
        at (re-fusing a live group would re-jit mid-request), so a
        ``group_size`` change takes effect at the next fuse.  Returns the
        knobs' new values.  Every retune is logged on :attr:`retunes` as
        ``(slot-ordinal, {knob: value})`` for the stats breakdown.
        """
        changed: dict[str, int | None] = {}
        if group_size is not None:
            gs = int(group_size)
            if gs < 1:
                raise ValueError(f"group_size must be >= 1 (got {gs})")
            self.group_size = gs
            changed["group_size"] = gs
        if quantum is not None:
            q = int(quantum)
            if q < 1:
                raise ValueError(f"quantum must be >= 1 (got {q})")
            self.quantum = q
            changed["quantum"] = q
        if prefill_chunk is not None:
            pc = int(prefill_chunk)
            if pc < 1:
                raise ValueError(f"prefill_chunk must be >= 1 (got {pc})")
            self.prefill_chunk = pc
            changed["prefill_chunk"] = pc
        if changed:
            self.retunes.append((len(self.fused_sizes), changed))
        return {"group_size": self.group_size, "quantum": self.quantum,
                "prefill_chunk": self.prefill_chunk}

    # ------------------------------------------------------------------
    def _extra_stats(self, metrics: Metrics) -> dict:
        total = self.prefill_tokens + self.decode_tokens
        wall = metrics.wall_s
        return {"engine": "dualmesh",
                "n_streams": len(self._order),
                "group_size": self.group_size,
                "retunes": [{"at_fuse": i, **kv}
                            for i, kv in self.retunes],
                "fused_sizes": list(self.fused_sizes),
                "prefill_tokens": self.prefill_tokens,
                "decode_tokens": self.decode_tokens,
                "total_tokens": total,
                "tokens_per_s": total / wall if wall else float("inf")}

    def _trace_snapshot(self) -> list:
        return self.runner.trace[self._trace_start:]
