"""Distributed checkpointing with atomic commits and elastic restore.

Layout (one directory per step):
    ckpt_dir/
      step_000100/
        manifest.json        # step, tree structure, shapes/dtypes, mesh
        shard_<host>.npz     # this host's param/optimizer shards
      LATEST                 # atomically-updated pointer

Fault-tolerance properties:
  * atomic commit: shards + manifest land in step_NNN.tmp, then one rename;
    a crash mid-save never corrupts LATEST.
  * keep-last-k garbage collection.
  * elastic restore: arrays are re-sharded onto whatever mesh the restarted
    job brings up (jax.device_put with the new sharding) — a 16-host job
    can resume a 32-host checkpoint and vice versa.
"""
from __future__ import annotations

import json
import os
import shutil
import time

import jax
import numpy as np

def _flatten(state) -> tuple[list, object]:
    leaves, treedef = jax.tree.flatten(state)
    return leaves, treedef


def save(ckpt_dir: str, state, step: int, keep: int = 3,
         host_id: int = 0, blocking: bool = True) -> str:
    """Atomically write a checkpoint for ``step``."""
    tmp = os.path.join(ckpt_dir, f"step_{step:08d}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(state)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(l))
              for i, l in enumerate(leaves)}
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **arrays)
    manifest = {
        "step": step,
        "time": time.time(),
        "n_leaves": len(leaves),
        "shapes": [list(np.shape(a)) for a in arrays.values()],
        "dtypes": [str(np.asarray(a).dtype) for a in arrays.values()],
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    os.replace(tmp, final) if not os.path.exists(final) else None
    if os.path.exists(tmp):
        shutil.rmtree(tmp, ignore_errors=True)
    with open(os.path.join(ckpt_dir, "LATEST.tmp"), "w") as f:
        f.write(os.path.basename(final))
    os.replace(os.path.join(ckpt_dir, "LATEST.tmp"),
               os.path.join(ckpt_dir, "LATEST"))
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for d in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    p = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        name = f.read().strip()
    if not os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
        return None
    return int(name.split("_")[1])


def restore(ckpt_dir: str, reference_state, step: int | None = None,
            shardings=None, host_id: int = 0):
    """Restore into the structure of ``reference_state`` (elastic: arrays
    are placed with ``shardings`` of the *current* mesh if given)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, f"shard_{host_id}.npz")) as z:
        arrays = [z[f"leaf_{i}"] for i in range(len(z.files))]
    leaves, treedef = _flatten(reference_state)
    assert len(arrays) == len(leaves), (len(arrays), len(leaves))
    cast = [np.asarray(a).astype(l.dtype) if hasattr(l, "dtype") else a
            for a, l in zip(arrays, leaves)]
    if shardings is not None:
        sh_leaves = jax.tree.leaves(
            shardings, is_leaf=lambda x: x is None or hasattr(x, "spec"))
        placed = [jax.device_put(a, s) if s is not None else jax.numpy.asarray(a)
                  for a, s in zip(cast, sh_leaves)]
    else:
        placed = [jax.numpy.asarray(a) for a in cast]
    return jax.tree.unflatten(treedef, placed)
