"""Optimizers in pure JAX (no optax dependency): AdamW + SGD-momentum,
with global-norm clipping and a warmup-cosine schedule."""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return AdamWState(step=jnp.zeros((), jnp.int32),
                          m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params))

    def schedule(self, step) -> jax.Array:
        warm = jnp.minimum(1.0, (step + 1) / max(1, self.warmup_steps))
        t = jnp.clip((step - self.warmup_steps)
                     / max(1, self.total_steps - self.warmup_steps), 0.0, 1.0)
        cos = self.min_lr_ratio + (1 - self.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(jnp.pi * t))
        return self.lr * warm * cos

    def apply(self, grads, state: AdamWState, params):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / (gnorm + 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
        step = state.step + 1
        b1, b2 = self.b1, self.b2
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g.astype(
            jnp.float32), state.m, grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state.v, grads)
        lr = self.schedule(state.step)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + self.eps)
            u = u + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, AdamWState(step, m, v), gnorm


class SGDMState(NamedTuple):
    step: jax.Array
    mom: Any


@dataclasses.dataclass(frozen=True)
class SGDM:
    lr: float = 0.1
    momentum: float = 0.9

    def init(self, params) -> SGDMState:
        return SGDMState(jnp.zeros((), jnp.int32),
                         jax.tree.map(lambda p: jnp.zeros(p.shape,
                                                          jnp.float32),
                                      params))

    def apply(self, grads, state: SGDMState, params):
        mom = jax.tree.map(lambda m, g: self.momentum * m
                           + g.astype(jnp.float32), state.mom, grads)
        new_params = jax.tree.map(
            lambda p, m: (p.astype(jnp.float32) - self.lr * m).astype(
                p.dtype), params, mom)
        return new_params, SGDMState(state.step + 1, mom), global_norm(grads)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))
