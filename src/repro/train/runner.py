"""Fault-tolerant training runner.

Wraps the jitted train_step with the operational machinery a 1000-node job
needs (DESIGN.md §5):
  * periodic atomic checkpoints + automatic resume (--resume);
  * failure recovery: a step that raises (device loss, injected fault) rolls
    back to the last checkpoint and replays — data is step-indexed, so
    replays are bit-identical;
  * straggler mitigation: per-step deadline watchdog; steps that exceed
    ``straggler_factor`` x the rolling median are logged and counted, and
    the dualmesh scheduler's Alg.1 rebalancer can be re-run on the live
    latency profile (hook);
  * elastic re-mesh: ``remesh()`` re-shards the state onto a new mesh
    (grown or shrunk data axis) between steps.
"""
from __future__ import annotations

import dataclasses
import statistics
import time

import jax

from repro.data.pipeline import DataConfig, SyntheticLM
from repro.lm.config import ArchConfig
from repro.lm.steps import TrainState, make_init_state, make_train_step
from repro.train import checkpoint as ckpt
from repro.train.optimizer import AdamW


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_steps: int = 200
    microbatches: int = 1
    straggler_factor: float = 3.0
    max_retries: int = 3
    seed: int = 0


class FaultInjector:
    """Test hook: raise at chosen steps to exercise recovery."""

    def __init__(self, fail_at=()):
        self.fail_at = set(fail_at)
        self.fired = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected fault at step {step}")


class TrainRunner:
    def __init__(self, cfg: ArchConfig, rcfg: RunnerConfig,
                 optimizer: AdamW | None = None,
                 fault_injector: FaultInjector | None = None,
                 data_cfg: DataConfig | None = None):
        self.cfg = cfg
        self.rcfg = rcfg
        self.opt = optimizer or AdamW(total_steps=rcfg.max_steps)
        self.fault = fault_injector
        self.data_cfg = data_cfg or DataConfig(
            vocab=cfg.vocab, seq_len=64, global_batch=8, seed=rcfg.seed)
        self.data = SyntheticLM(self.data_cfg)
        self.train_step = jax.jit(
            make_train_step(cfg, self.opt, rcfg.microbatches))
        self.step_times: list[float] = []
        self.stragglers = 0
        self.recoveries = 0
        self.metrics_log: list[dict] = []

    # ---- state ------------------------------------------------------------
    def init_state(self) -> TrainState:
        return make_init_state(self.cfg, self.opt)(
            jax.random.PRNGKey(self.rcfg.seed))

    def resume_or_init(self) -> tuple[TrainState, int]:
        ref = jax.eval_shape(lambda: self.init_state())
        last = ckpt.latest_step(self.rcfg.ckpt_dir)
        if last is None:
            return self.init_state(), 0
        state = ckpt.restore(self.rcfg.ckpt_dir, ref)
        return state, last

    # ---- main loop --------------------------------------------------------
    def run(self, steps: int | None = None) -> dict:
        import os
        os.makedirs(self.rcfg.ckpt_dir, exist_ok=True)
        state, start = self.resume_or_init()
        if start == 0:
            ckpt.save(self.rcfg.ckpt_dir, state, 0)
        target = steps or self.rcfg.max_steps
        step = start
        retries = 0
        while step < target:
            batch = self.data.batch_at(step)
            t0 = time.perf_counter()
            try:
                if self.fault is not None:
                    self.fault.maybe_fail(step)
                state, metrics = self.train_step(state, batch)
                metrics = {k: float(v) for k, v in metrics.items()}
            except Exception as e:  # noqa: BLE001 — node failure path
                self.recoveries += 1
                retries += 1
                if retries > self.rcfg.max_retries:
                    raise
                state, step = self.resume_or_init()
                continue
            retries = 0
            dt = time.perf_counter() - t0
            self.step_times.append(dt)
            if len(self.step_times) >= 8:
                med = statistics.median(self.step_times[-32:])
                if dt > self.rcfg.straggler_factor * med:
                    self.stragglers += 1
            step += 1
            metrics["step"] = step
            metrics["step_time_s"] = dt
            self.metrics_log.append(metrics)
            if step % self.rcfg.ckpt_every == 0 or step == target:
                ckpt.save(self.rcfg.ckpt_dir, state, step)
        return {"final_step": step,
                "final_loss": self.metrics_log[-1]["loss"]
                if self.metrics_log else None,
                "recoveries": self.recoveries,
                "stragglers": self.stragglers,
                "metrics": self.metrics_log}

    # ---- elastic ----------------------------------------------------------
    def remesh(self, state: TrainState, new_mesh, param_specs_fn):
        """Re-shard the live state onto a new mesh (elastic scale up/down)."""
        from repro.launch.sharding import param_specs, to_shardings
        specs = param_specs(state.params, new_mesh)
        shardings = to_shardings(specs, new_mesh)
        new_params = jax.tree.map(jax.device_put, state.params, shardings)
        new_m = jax.tree.map(jax.device_put, state.opt.m, shardings)
        new_v = jax.tree.map(jax.device_put, state.opt.v, shardings)
        from repro.train.optimizer import AdamWState
        return TrainState(new_params,
                          AdamWState(state.opt.step, new_m, new_v),
                          state.step)
