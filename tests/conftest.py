"""Test-session bootstrap.

The property tests use ``hypothesis``.  CI installs the real package
(requirements-dev.txt); hermetic containers that cannot pip-install get a
minimal deterministic stand-in registered here *before* test collection,
so the property tests still run (seeded example sweep) instead of being
skipped.  Only the strategy surface the test-suite actually uses is
implemented: integers / sampled_from / booleans / tuples / lists / builds.
"""
from __future__ import annotations

import random
import sys
import types


def _install_hypothesis_fallback():
    class _Strategy:
        def __init__(self, sample):
            self.sample = sample            # sample(rng) -> value

    def integers(min_value, max_value):
        return _Strategy(lambda r: r.randint(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(lambda r: r.choice(elements))

    def booleans():
        return _Strategy(lambda r: r.random() < 0.5)

    def tuples(*strats):
        return _Strategy(lambda r: tuple(s.sample(r) for s in strats))

    def lists(elem, min_size=0, max_size=10, **_):
        return _Strategy(
            lambda r: [elem.sample(r)
                       for _ in range(r.randint(min_size, max_size))])

    def builds(target, *strats, **kw_strats):
        return _Strategy(lambda r: target(
            *(s.sample(r) for s in strats),
            **{k: s.sample(r) for k, s in kw_strats.items()}))

    def just(value):
        return _Strategy(lambda r: value)

    def given(*strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0)      # deterministic example sweep
                n = getattr(wrapper, "_fallback_max_examples", 10)
                for _ in range(n):
                    fn(*args, *(s.sample(rng) for s in strats), **kwargs)
            # NOTE: no functools.wraps — copying __wrapped__ would make
            # pytest read the original signature and demand its sampled
            # parameters as fixtures.
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper.hypothesis_fallback = True
            return wrapper
        return deco

    def settings(max_examples=10, deadline=None, **_):
        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn
        return deco

    st_mod = types.ModuleType("hypothesis.strategies")
    for f in (integers, sampled_from, booleans, tuples, lists, builds,
              just):
        setattr(st_mod, f.__name__, f)

    hyp = types.ModuleType("hypothesis")
    hyp.given = given
    hyp.settings = settings
    hyp.strategies = st_mod
    hyp.HealthCheck = types.SimpleNamespace(too_slow=None, filter_too_much=None)
    hyp.__fallback__ = True
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401  (real package wins when installed)
except ImportError:
    _install_hypothesis_fallback()


# Keep test runs hermetic: never read (or write) the developer's autotune
# cache — block shapes must come from the deterministic heuristics unless a
# test tunes into its own tmp_path explicitly.  Hard assignment on purpose:
# an exported REPRO_AUTOTUNE_CACHE must not leak into the suite either.
import os
import tempfile

os.environ["REPRO_AUTOTUNE_CACHE"] = os.path.join(
    tempfile.mkdtemp(prefix="repro_autotune_test_"), "autotune.json")
