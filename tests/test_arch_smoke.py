"""Per-architecture smoke tests (assignment requirement): reduced config of
the same family, one forward + one train step on CPU, asserting output
shapes and finiteness; plus decode-vs-forward consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_arch, get_smoke
from repro.lm.model import (decode_step, encode, forward, init_cache,
                            init_params)
from repro.lm.steps import make_init_state, make_train_step
from repro.train.optimizer import AdamW

KEY = jax.random.PRNGKey(0)

# published total-parameter sanity bands (B params) for the full configs
PARAM_BANDS = {
    "command_r_plus_104b": (95, 115),
    "granite_20b": (18, 30),        # SwiGLU vs the original's GELU MLP
    "qwen2_0_5b": (0.3, 0.7),
    "qwen2_5_14b": (12, 17),
    "qwen2_moe_a2_7b": (12, 16),    # 14.3B total / 2.7B active
    "granite_moe_3b_a800m": (2.5, 4.5),
    "zamba2_2_7b": (2.0, 3.5),
    "whisper_small": (0.15, 0.45),
    "qwen2_vl_72b": (65, 80),
    "xlstm_350m": (0.2, 0.5),
}


def _batch(cfg, B=2, S=16):
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    if cfg.mrope:
        batch["positions3"] = jnp.tile(jnp.arange(S)[None, None], (B, 3, 1))
    if cfg.encoder_decoder:
        batch["enc_input"] = jax.random.normal(
            KEY, (B, cfg.enc_positions, cfg.d_model)) * 0.1
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_arch(arch)
    lo, hi = PARAM_BANDS[arch]
    n = cfg.param_count() / 1e9
    assert lo < n < hi, (arch, n)
    if cfg.family == "moe":
        assert cfg.active_param_count() < cfg.param_count()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke(arch)
    p = init_params(cfg, KEY)
    b = _batch(cfg)
    logits = forward(p, cfg, b["tokens"], positions3=b.get("positions3"),
                     enc_input=b.get("enc_input"))
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    opt = AdamW(lr=1e-3, warmup_steps=2, total_steps=10)
    state = make_init_state(cfg, opt)(KEY)
    ts = jax.jit(make_train_step(cfg, opt, microbatches=1))
    b = _batch(cfg)
    l0 = None
    for _ in range(3):
        state, m = ts(state, b)
        l0 = float(m["loss"]) if l0 is None else l0
    assert np.isfinite(float(m["loss"]))
    assert float(m["loss"]) < l0          # memorising a fixed batch
    assert np.isfinite(float(m["grad_norm"]))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    """Incremental decode with a cache reproduces the full forward —
    catches cache indexing / position / state-carry bugs in every family."""
    cfg = get_smoke(arch)
    p = init_params(cfg, KEY)
    B, S = 2, 12
    b = _batch(cfg, B, S)
    toks = b["tokens"]
    p3 = b.get("positions3")
    full = forward(p, cfg, toks, positions3=p3,
                   enc_input=b.get("enc_input"), remat=False)
    memory = (encode(p, cfg, b["enc_input"])
              if cfg.encoder_decoder else None)
    cache = init_cache(cfg, B, S + 4, memory=memory,
                       params=p if cfg.encoder_decoder else None)
    l1, cache = decode_step(p, cfg, toks[:, :7],
                            cache, positions3=None if p3 is None
                            else p3[:, :, :7])
    outs = [l1]
    for i in range(7, S):
        li, cache = decode_step(p, cfg, toks[:, i:i + 1], cache,
                                positions3=None if p3 is None
                                else p3[:, :, i:i + 1])
        outs.append(li)
    inc = jnp.concatenate(outs, axis=1)
    rel = float(jnp.max(jnp.abs(inc - full))) / float(
        jnp.max(jnp.abs(full)))
    assert rel < 2e-5, (arch, rel)


def test_grad_accumulation_equivalence():
    """microbatches=4 must give the same loss/grads as microbatches=1."""
    cfg = get_smoke("qwen2_0_5b")
    opt = AdamW(lr=1e-3)
    state = make_init_state(cfg, opt)(KEY)
    b = _batch(cfg, B=4, S=16)
    ts1 = jax.jit(make_train_step(cfg, opt, microbatches=1))
    ts4 = jax.jit(make_train_step(cfg, opt, microbatches=4))
    _, m1 = ts1(state, b)
    _, m4 = ts4(state, b)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m4["grad_norm"]), rtol=1e-4)


def test_mrope_reduces_to_rope_for_text():
    """M-RoPE with equal t/h/w position streams == plain RoPE."""
    from repro.lm.modules import mrope_freqs, rope_freqs
    pos = jnp.arange(32)
    cfg = get_smoke("qwen2_vl_72b")
    c1, s1 = rope_freqs(cfg.d_head, cfg.rope_theta, pos)
    p3 = jnp.tile(pos[None, None], (1, 3, 1))
    c3, s3 = mrope_freqs(cfg.d_head, cfg.rope_theta, p3,
                         cfg.mrope_sections)
    # bands are permuted relative to rope (sections are contiguous), so
    # compare sorted magnitudes per position
    np.testing.assert_allclose(np.sort(np.asarray(c3[0]), axis=-1),
                               np.sort(np.asarray(c1), axis=-1), rtol=1e-6)


def test_moe_router_load_balance_loss():
    from repro.lm.modules import moe_aux_loss
    cfg = get_smoke("qwen2_moe_a2_7b")
    p = init_params(cfg, KEY)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model)) * 0.1
    blk = jax.tree.map(lambda a: a[0], p["blocks"])
    aux = moe_aux_loss(blk["mlp"], x, cfg)
    assert float(aux) >= 1.0 - 1e-3      # >= 1 by Cauchy-Schwarz; = 1 ideal
