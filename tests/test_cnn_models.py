"""JAX CNN model tests: forward shapes, graph consistency, Pallas parity."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.cnn import FORWARDS, build_model, _run_layer

X = jax.random.normal(jax.random.PRNGKey(7), (1, 224, 224, 3), jnp.float32)


@pytest.mark.parametrize("name", sorted(FORWARDS))
def test_forward_shape_and_finite(name):
    params, fwd, g = build_model(name)
    out = fwd(params, X)
    assert out.shape == (1, 1000)
    assert bool(jnp.all(jnp.isfinite(out)))


@pytest.mark.parametrize("name", sorted(FORWARDS))
def test_activations_match_graph(name):
    """The JAX execution and the dual-OPU latency model consume the same
    LayerGraph: per-layer activation shapes must equal the graph's
    (H_out, W_out, C_o)."""
    params, fwd, g = build_model(name)
    collect = {}
    fwd(params, X, collect=collect)
    for l in g.layers:
        if l.name not in collect or l.name in ("fc",):
            continue
        got = tuple(collect[l.name][1:])
        exp = (l.H_out, l.W_out, l.C_o)
        if l.name == "conv10":     # global avgpool output handled outside
            exp = (l.H_out, l.W_out, l.C_o)
        assert got == exp, (name, l.name, got, exp)


def test_params_match_graph_counts():
    for name in FORWARDS:
        params, _, g = build_model(name)
        n_params = sum(int(np.prod(v["w"].shape)) + int(np.prod(
            v["b"].shape)) for v in params.values())
        assert n_params == g.total_params


def test_pallas_layer_parity_in_model():
    """Run representative layers of MobileNet v1 through both execution
    paths (XLA vs Pallas interpret) on real activations."""
    params, fwd, g = build_model("mobilenet_v1")
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 28, 28, 256))
    for lname in ("dw5", "pw5"):
        l = g.layer(lname)
        xs = x[..., :l.C_i] if l.C_i <= 256 else jnp.tile(
            x, (1, 1, 1, l.C_i // 256))
        a = _run_layer(l, xs, params[lname], "relu6", use_pallas=False)
        b = _run_layer(l, xs, params[lname], "relu6", use_pallas=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=3e-4, atol=3e-4)
