"""The CI perf-regression gate must pass on identical reports and trip on
an injected slowdown (ISSUE-3 satellite)."""
import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))            # repo root -> benchmarks pkg

from benchmarks.compare_bench import compare, extract_metrics, main  # noqa: E402

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

REPORT = {
    "conv_implicit_gemm": [
        {"shape": "56x56x16->64 k3 s1", "im2col_ms": 120.0,
         "implicit_ms": 10.0},
        {"shape": "28x28x32->128 k3 s1", "im2col_ms": 80.0,
         "implicit_ms": 8.0},
    ],
    "fused_dw_pw": [
        {"shape": "14x14x256->256 s1", "unfused_ms": 30.0,
         "fused_ms": 12.0},
    ],
    "measured": {
        "mobilenet_v1": {"pipelined_ms": 350.0, "sequential_ms": 360.0},
    },
    "fleet": {"aggregate_fps": 7.0,
              "baseline": {"best_fps": 5.0}},
}


def test_extract_gates_only_our_legs():
    m = extract_metrics(REPORT)
    # shape-labelled, stable keys; baseline legs (im2col/unfused/sequential
    # timings, best_fps baseline throughput) are not gated
    assert "conv_implicit_gemm/56x56x16->64 k3 s1/implicit_ms" in m
    assert "measured/mobilenet_v1/pipelined_ms" in m
    assert "fleet/aggregate_fps" in m
    assert len(m) == 5
    assert not any("im2col" in k or "unfused" in k or "sequential" in k
                   or "best_fps" in k for k in m)


def test_identical_reports_pass():
    regs, _ = compare(REPORT, copy.deepcopy(REPORT))
    assert regs == []


def test_gate_trips_on_injected_3x_regression():
    fresh = copy.deepcopy(REPORT)
    fresh["conv_implicit_gemm"][0]["implicit_ms"] *= 3.0
    regs, _ = compare(REPORT, fresh, threshold=2.0)
    assert len(regs) == 1
    assert regs[0].key == "conv_implicit_gemm/56x56x16->64 k3 s1/implicit_ms"
    assert regs[0].ratio == pytest.approx(3.0)


def test_gate_tolerates_sub_threshold_noise_and_new_entries():
    fresh = copy.deepcopy(REPORT)
    fresh["conv_implicit_gemm"][0]["implicit_ms"] *= 1.9   # < 2x: noise
    fresh["fused_dw_pw"].append(
        {"shape": "7x7x1024->1024 s1", "fused_ms": 99.0})  # new: not gated
    del fresh["measured"]["mobilenet_v1"]                  # gone: not gated
    regs, notes = compare(REPORT, fresh)
    assert regs == []
    assert any("new entry" in n for n in notes)
    assert any("disappeared" in n for n in notes)


def test_higher_better_gate_trips_on_throughput_drop():
    """aggregate_fps gates in the opposite direction: fresh falling below
    baseline / threshold fails; a latency-style doubling does not."""
    fresh = copy.deepcopy(REPORT)
    fresh["fleet"]["aggregate_fps"] = 3.0          # 7.0 -> 3.0: > 2x drop
    regs, _ = compare(REPORT, fresh, threshold=2.0)
    assert [r.key for r in regs] == ["fleet/aggregate_fps"]
    assert regs[0].ratio == pytest.approx(3.0 / 7.0)


def test_higher_better_gate_tolerates_gains_and_noise():
    fresh = copy.deepcopy(REPORT)
    fresh["fleet"]["aggregate_fps"] = 14.0         # 2x GAIN: never a fail
    regs, _ = compare(REPORT, fresh, threshold=2.0)
    assert regs == []
    fresh["fleet"]["aggregate_fps"] = 4.0          # 1.75x drop < threshold
    regs, notes = compare(REPORT, fresh, threshold=2.0)
    assert regs == []
    assert any("higher-better" in n for n in notes)


def test_noise_floor_skips_micro_timings():
    base = {"fused_dw_pw": [{"shape": "tiny", "fused_ms": 0.05}]}
    fresh = {"fused_dw_pw": [{"shape": "tiny", "fused_ms": 0.5}]}   # 10x!
    regs, notes = compare(base, fresh, min_ms=1.0)
    assert regs == []
    assert any("noise floor" in n for n in notes)


def test_main_exit_codes(tmp_path):
    base_p = tmp_path / "base.json"
    fresh_p = tmp_path / "fresh.json"
    base_p.write_text(json.dumps(REPORT))
    fresh = copy.deepcopy(REPORT)
    fresh["measured"]["mobilenet_v1"]["pipelined_ms"] *= 3.0
    fresh_p.write_text(json.dumps(fresh))
    assert main(["--baseline", str(base_p), "--fresh", str(base_p)]) == 0
    assert main(["--baseline", str(base_p), "--fresh", str(fresh_p)]) == 1


def test_committed_baselines_have_gated_entries():
    """The gate is only meaningful if the committed artifacts expose gated
    metrics — guard against silently renaming the fields."""
    for fname in ("BENCH_kernels.json", "BENCH_dualcore.json",
                  "BENCH_serving.json", "BENCH_fleet.json"):
        with open(os.path.join(REPO, fname)) as f:
            report = json.load(f)
        assert extract_metrics(report), f"{fname} has no gated entries"
    with open(os.path.join(REPO, "BENCH_fleet.json")) as f:
        fleet = json.load(f)
    assert "fleet/aggregate_fps" in extract_metrics(fleet)
