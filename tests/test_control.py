"""Closed-loop SLO adaptation (ISSUE-8 / DESIGN.md §13): SET_PARAM schema
v2 + v1 compat, MetricsWindow stats, mix-flip reweight convergence, p95
breach retune + recovery, hysteresis (deadband / band gap / shed arm +
cooldown + §12 interlock), decision-log audit, and bitwise live-vs-replay
of a controlled run with no controller attached."""
import os
import sys
from types import SimpleNamespace

import pytest

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from test_fleet import StubEngine, _stub_fleet  # noqa: E402

from repro.fleet import (ControlLoop, Decision, ExecRecord,  # noqa: E402
                         FleetEngine, Rebalance, RebalanceTheta, Retune,
                         Reweight, SetParam, WeightedFair, compile_fleet,
                         decisions_from_json, decisions_to_json,
                         lower_action, stream_from_json, stream_signature,
                         stream_to_json, verify_decisions)
from repro.fleet.compiler import CompileError  # noqa: E402
from repro.fleet.control import Observation  # noqa: E402
from repro.fleet.instructions import Run  # noqa: E402
from repro.serving import Request, replay  # noqa: E402
from repro.serving.api import Completion, MetricsWindow, RequestMetrics  # noqa: E402


class StubTunable(StubEngine):
    """A stub member exposing the LM engine's retune surface."""

    def __init__(self, **kw):
        super().__init__(**kw)
        self.group_size = 8
        self.retunes = []

    def retune(self, *, group_size=None):
        if group_size is not None:
            if group_size < 1:
                raise ValueError(f"group_size must be >= 1 (got "
                                 f"{group_size})")
            self.group_size = int(group_size)
            self.retunes.append(int(group_size))
        return {"group_size": self.group_size}


def _obs(slot=0, arrivals=None, queued=None, window=None, shed_rate=0.0,
         weights=None):
    return Observation(slot=slot, arrivals=arrivals or {},
                       queued=queued or {}, window=window or {},
                       shed_rate=shed_rate, weights=weights or {})


def _win(p95):
    return {"n": 8, "served": 8, "shed": 0, "shed_rate": 0.0, "p95_ms": p95}


# --------------------------------------------------------------------------
# the mix-flip trace shared by the convergence and replay tests
# --------------------------------------------------------------------------
_W0 = {"a": 0.75, "b": 0.25}


def _flip_fleet(trace=None):
    return _stub_fleet(cores=("c", "p"), names=list(_W0), weights=_W0,
                       policy=WeightedFair(), co_dispatch=0, trace=trace)


def _flip_trace():
    """48 one-per-slot arrivals whose mix flips 3:1 -> 1:3 at step 24."""
    tags = ["a", "a", "a", "b"] * 6 + ["b", "b", "b", "a"] * 6
    reqs = [Request(i, model=t) for i, t in enumerate(tags)]
    return reqs, list(range(len(reqs)))


# --------------------------------------------------------------------------
# SET_PARAM schema + executor semantics
# --------------------------------------------------------------------------
def test_set_param_round_trip_and_v1_compat():
    rec = [ExecRecord(instr=SetParam(member="a", param="weight",
                                     value=0.6),
                      slot=1, seq=0, advances=0)]
    rt = stream_from_json(stream_to_json(rec))
    assert rt[0].instr == rec[0].instr
    # v1 streams (no SET_PARAM) still load...
    v1 = stream_to_json([ExecRecord(instr=Run(member="a"), slot=0, seq=0)])
    v1["version"] = 1
    assert stream_from_json(v1)[0].instr == Run(member="a")
    # ...but a v1 doc carrying a v2-only op is schema drift, not data
    drift = stream_to_json(rec)
    drift["version"] = 1
    with pytest.raises(ValueError, match="schema drift"):
        stream_from_json(drift)


def test_set_param_execution_paths():
    fleet = _flip_fleet()
    fleet.executor.inject(SetParam(member="b", param="weight", value=0.9))
    assert fleet._by_name["b"].weight == pytest.approx(0.9)
    with pytest.raises(KeyError, match="unknown member"):
        fleet.executor.inject(SetParam(member="zz", param="weight",
                                       value=0.5))
    with pytest.raises(RuntimeError, match="retune"):
        fleet.executor.inject(SetParam(member="a", param="group_size",
                                       value=4))   # StubEngine: no retune


def test_metrics_window_stats():
    win = MetricsWindow(4)
    def done(model, status, lat_s):
        m = RequestMetrics(rid=0, model=model, submitted_at=0.0,
                           status=status)
        if status in ("ok", "recovered"):
            m.finished_at = lat_s
        return Completion(ticket=SimpleNamespace(rid=0), output=None,
                          metrics=m)
    win.observe([done("a", "ok", 0.010), done("a", "shed", 0.0),
                 done("b", "ok", 0.020)])
    assert win.stats("a") == {"n": 2, "served": 1, "shed": 1,
                              "shed_rate": 0.5, "p95_ms": 10.0}
    assert win.stats()["n"] == 3
    assert set(win.by_model()) == {"a", "b"}
    # bounded: a 4th + 5th entry evict the oldest two
    win.observe([done("b", "ok", 0.030), done("b", "ok", 0.030)])
    assert len(win) == 4 and win.stats("a")["n"] == 1
    assert win.stats("zzz") == {"n": 0, "served": 0, "shed": 0,
                                "shed_rate": 0.0, "p95_ms": None}
    with pytest.raises(ValueError, match="window size"):
        MetricsWindow(0)


# --------------------------------------------------------------------------
# reweight: convergence on a seeded mix flip, deadband hysteresis
# --------------------------------------------------------------------------
def test_mix_flip_reweights_to_new_mix():
    fleet = _flip_fleet()
    ctl = ControlLoop(fleet, interval=8, reweight_deadband=0.15)
    reqs, arr = _flip_trace()
    res = replay(fleet, reqs, arr)
    assert res.metrics.completed == len(reqs)

    rw = [d for d in ctl.decisions if d.action.kind == "reweight"]
    # one clean flip: exactly one reweight per member, at the first
    # observation whose window saw the new mix, none before or after
    assert len(rw) == 2
    assert {m.name: m.weight for m in fleet.members} == \
        pytest.approx({"a": 0.25, "b": 0.75})
    # the evidence in the log is the flipped arrival window
    for d in rw:
        assert d.observed["arrivals"] == {"a": 2, "b": 6}
    # post-decision dispatch share follows the new entitlement: b, now
    # owed 3x a, wins the primary pick strictly more often
    seq0 = max(d.seq for d in rw)
    picks = [r.instr.member for r in fleet.stream
             if r.seq > seq0 and isinstance(r.instr, Run) and r.instr.primary]
    assert picks.count("b") > picks.count("a")
    # controller summary surfaces through the engine's result stats
    assert res.stats["control"]["by_kind"] == {"reweight": 2}
    assert res.stats["control"]["decisions"] == 2


def test_reweight_deadband_rides_out_wobble():
    """A mix oscillating inside the deadband must emit nothing."""
    fleet = _stub_fleet(cores=("c", "p"), names=["a", "b"],
                        weights={"a": 0.5, "b": 0.5},
                        policy=WeightedFair(), co_dispatch=0)
    ctl = ControlLoop(fleet, interval=5, reweight_deadband=0.2)
    # each 5-arrival window is 0.6/0.4 or 0.4/0.6: TV distance 0.1 from
    # the 0.5/0.5 weights, inside the deadband every observation
    tags = (["a", "a", "a", "b", "b"] + ["b", "b", "b", "a", "a"]) * 4
    reqs = [Request(i, model=t) for i, t in enumerate(tags)]
    replay(fleet, reqs, list(range(len(reqs))))
    assert ctl.decisions == []
    assert ctl.observations > 0
    assert {m.name: m.weight for m in fleet.members} == {"a": 0.5,
                                                         "b": 0.5}


# --------------------------------------------------------------------------
# retune: p95 breach narrows the fusion width, recovery widens it back
# --------------------------------------------------------------------------
def _tunable_fleet():
    members = {"lm": StubTunable(core="c", name="lm"),
               "cnn": StubEngine(core="p", name="cnn")}
    return FleetEngine(members, policy=WeightedFair(), co_dispatch=0)


def test_p95_breach_retunes_and_recovers():
    fleet = _tunable_fleet()
    ctl = ControlLoop(fleet, interval=4, slo_ms=100.0, band=(0.5, 1.0))
    lm = fleet._by_name["lm"].engine
    hot, cool = _obs(window={"lm": _win(150.0)}), \
        _obs(window={"lm": _win(40.0)})

    def run(obs):
        acts = ctl.decide(obs)
        for a, r in acts:
            ctl._apply(a, r, obs)
        return [a for a, _ in acts]

    # hot: one halving per observation, down to the floor, then nothing
    assert run(hot) == [Retune(member="lm", param="group_size", value=4)]
    assert lm.group_size == 4
    assert run(hot) == [Retune(member="lm", param="group_size", value=2)]
    assert run(hot) == [Retune(member="lm", param="group_size", value=1)]
    assert run(hot) == [] and lm.group_size == 1       # min_group floor
    # mid-band: the gap is the hysteresis — nothing moves either way
    assert run(_obs(window={"lm": _win(70.0)})) == []
    # cool: one doubling per observation back to the configured width
    assert run(cool) == [Retune(member="lm", param="group_size", value=2)]
    assert run(cool) == [Retune(member="lm", param="group_size", value=4)]
    assert run(cool) == [Retune(member="lm", param="group_size", value=8)]
    assert lm.group_size == 8 and lm.retunes == [4, 2, 1, 2, 4, 8]
    # fully recovered: further cool observations are not a breach exit
    assert run(cool) == []
    # every retune was injected into the stream and the log matches it
    assert [r.instr for r in fleet.executor.records] == \
        [SetParam(member="lm", param="group_size", value=v)
         for v in (4, 2, 1, 2, 4, 8)]
    verify_decisions(fleet.executor.records, ctl.decisions)


# --------------------------------------------------------------------------
# shed-rate rebalance: sustain, re-arm, cooldown, and the §12 interlock
# --------------------------------------------------------------------------
def test_shed_rebalance_hysteresis_and_cooldown(monkeypatch):
    import repro.fleet.planner as planner
    monkeypatch.setattr(planner, "plan_fleet",
                        lambda mix, max_evals=4:
                        SimpleNamespace(theta=0.625))
    fleet = _flip_fleet()
    fleet.pool = object()           # decide() only checks for a pool
    ctl = ControlLoop(fleet, interval=4, shed_high=0.25, shed_low=0.05,
                      sustain=2, cooldown=3)
    hot = _obs(shed_rate=0.4, weights={"a": 0.5, "b": 0.5})
    cool, mid = _obs(shed_rate=0.01), _obs(shed_rate=0.15)

    assert ctl.decide(hot) == []                     # streak 1 < sustain
    fired = ctl.decide(hot)                          # streak 2: fires
    assert fired == [(RebalanceTheta(theta=0.625), fired[0][1])]
    assert "shed rate 0.400" in fired[0][1]
    # disarmed: sustained shedding alone must not fire again...
    assert ctl.decide(hot) == [] and ctl.decide(hot) == []
    assert ctl.decide(mid) == []                     # between the bands
    ctl._cooldown_left = 0                           # cooldown elapsed
    assert ctl.decide(hot) == []                     # still disarmed
    # ...until the rate drops below shed_low (re-arm), and sustains again
    assert ctl.decide(cool) == []
    assert ctl.decide(hot) == []
    assert len(ctl.decide(hot)) == 1

    # cooldown blocks even an armed, sustained trigger
    ctl._shed_armed, ctl._shed_streak = True, 5
    ctl._cooldown_left = 2
    assert ctl.decide(hot) == []


def test_foreign_rebalance_restarts_cooldown():
    """A §12 recovery (or drift) REBALANCE in the stream must push the
    controller's own rebalance trigger into cooldown."""
    fleet = _flip_fleet()
    ctl = ControlLoop(fleet, interval=4, cooldown=3)
    ex = fleet.executor
    assert ctl._cooldown_left == 0
    ex.records.append(ExecRecord(instr=Rebalance(theta=0.5),
                                 slot=fleet._slot, seq=next(ex._seq),
                                 advances=0))
    ctl.observe()
    assert ctl._cooldown_left == 3


# --------------------------------------------------------------------------
# the decision log
# --------------------------------------------------------------------------
def test_decision_log_round_trip_and_errors():
    ds = [Decision(seq=3, slot=2,
                   action=Reweight(member="a", weight=0.25),
                   reason="drift", observed={"shed_rate": 0.0}),
          Decision(seq=9, slot=8, action=RebalanceTheta(theta=0.7),
                   reason="shed")]
    rt = decisions_from_json(decisions_to_json(ds))
    assert rt == ds
    with pytest.raises(ValueError, match="decision log version"):
        decisions_from_json({"version": 99, "decisions": []})
    with pytest.raises(ValueError, match="unknown decision kind"):
        decisions_from_json({"version": 1, "decisions":
                             [{"seq": 0, "slot": 0, "kind": "overclock",
                               "action": {}}]})
    # verify: seq must exist and must hold exactly the lowered action
    recs = [ExecRecord(instr=lower_action(ds[0].action), slot=2, seq=3)]
    verify_decisions(recs, ds[:1])
    with pytest.raises(ValueError, match="no matching stream record"):
        verify_decisions(recs, ds[1:])
    bad = [ExecRecord(instr=SetParam(member="a", param="weight",
                                     value=0.99), slot=2, seq=3)]
    with pytest.raises(ValueError, match="lowered to"):
        verify_decisions(bad, ds[:1])


# --------------------------------------------------------------------------
# replay: controlled runs replay bitwise with no controller attached
# --------------------------------------------------------------------------
def test_controlled_run_replays_bitwise():
    trace_live = []
    live = _flip_fleet(trace_live)
    ctl = ControlLoop(live, interval=8, reweight_deadband=0.15)
    reqs, arr = _flip_trace()
    res_live = replay(live, reqs, arr)
    assert any(isinstance(r.instr, SetParam) for r in live.stream)
    verify_decisions(live.stream, ctl.decisions)

    # serialize stream + decision log, replay on a fresh uncontrolled fleet
    rt = stream_from_json(stream_to_json(live.stream, pool="pool0"))
    log = decisions_from_json(decisions_to_json(ctl.decisions))
    trace_rep = []
    fresh = _flip_fleet(trace_rep)
    assert fresh.controller is None
    res_rep = fresh.executor.replay(rt, _flip_trace()[0], arr)

    assert stream_signature(fresh.stream) == stream_signature(live.stream)
    assert trace_rep == trace_live
    assert res_rep.outputs == res_live.outputs
    assert [c.ticket.rid for c in res_rep.completions] == \
        [c.ticket.rid for c in res_live.completions]
    # the decision log audits the replayed stream too (same seqs), and
    # the replayed SET_PARAMs re-applied the reweight without a controller
    verify_decisions(fresh.stream, log)
    assert {m.name: m.weight for m in fresh.members} == \
        pytest.approx({"a": 0.25, "b": 0.75})


def test_v1_stream_replays_bitwise():
    """Pre-§13 (schema v1) recorded streams stay loadable + replayable."""
    trace_live = []
    live = _flip_fleet(trace_live)          # no controller: v1-shaped run
    reqs, arr = _flip_trace()
    res_live = replay(live, reqs, arr)
    doc = stream_to_json(live.stream)
    doc["version"] = 1
    rt = stream_from_json(doc)
    trace_rep = []
    fresh = _flip_fleet(trace_rep)
    res_rep = fresh.executor.replay(rt, _flip_trace()[0], arr)
    assert stream_signature(fresh.stream) == stream_signature(live.stream)
    assert trace_rep == trace_live
    assert res_rep.outputs == res_live.outputs


def test_compile_refuses_controlled_fleet():
    fleet = _flip_fleet()
    ControlLoop(fleet, interval=8)
    with pytest.raises(CompileError, match="ControlLoop"):
        compile_fleet(fleet, _flip_trace()[0])


def test_control_loop_validates_args():
    with pytest.raises(ValueError, match="interval"):
        ControlLoop(_flip_fleet(), interval=0)
    with pytest.raises(ValueError, match="band"):
        ControlLoop(_flip_fleet(), band=(1.0, 0.5))
    with pytest.raises(ValueError, match="shed_low"):
        ControlLoop(_flip_fleet(), shed_high=0.1, shed_low=0.2)
