"""Paper-model anchors: graph stats, area model vs Tables I/III/VI/VIII,
latency calibration vs Table IV."""

import pytest

from repro.core import (BoardModel, CoreConfig,
                        P128_9, DUAL_BASELINE, DUAL_MBV1, DUAL_MBV2,
                        DUAL_SQZ, DUAL_MULTI, core_area, dual_core_area,
                        pe_structure_lut_equiv, simulate_single_core,
                        graph_latency_report)
from repro.models.zoo import get_graph

TABLE_IV = {  # board-level cycle counts
    "mobilenet_v1": 755_857,
    "mobilenet_v2": 637_551,
    "squeezenet": 447_457,
}


# --------------------------------------------------------------------------
# Graph construction
# --------------------------------------------------------------------------
def test_mobilenet_v1_shape():
    g = get_graph("mobilenet_v1")
    assert len(g) == 28                       # conv1 + 13*(dw+pw) + fc
    # canonical MACs ~569M (1.0x, 224x224)
    assert 550e6 < g.total_macs < 580e6
    # ~4.2M weights
    assert 3.9e6 < g.total_params < 4.5e6


def test_mobilenet_v2_shape():
    g = get_graph("mobilenet_v2")
    # 1 stem + 17 blocks (2 or 3 convs each) + conv_last + fc = 53
    assert len(g) == 53
    assert 290e6 < g.total_macs < 320e6      # ~300M canonical


def test_squeezenet_shape():
    g = get_graph("squeezenet")
    assert len(g) == 26                       # conv1 + 8 fires * 3 + conv10
    assert 340e6 < g.total_macs < 400e6      # v1.1 ~360-390M
    order = [l.name for l in g.topological_order()]
    assert order.index("fire2_squeeze") < order.index("fire2_e1x1")
    assert order.index("fire2_e1x1") < order.index("fire2_e3x3")


def test_dwconv_requires_equal_channels():
    from repro.core import LayerSpec
    with pytest.raises(ValueError):
        LayerSpec("bad", "dwconv", 8, 8, 16, 32, 3, 3)


# --------------------------------------------------------------------------
# Area model anchors
# --------------------------------------------------------------------------
def test_dsp_counts_match_paper_exactly():
    # Table I / IV / VI / VIII published DSP counts
    assert P128_9.n_dsp + 1 == 577            # P(128,9) incl. invariant
    assert DUAL_MBV1.n_dsp == 832             # C(128,12)+P(8,16)
    assert DUAL_MBV2.n_dsp == 832             # C(160,8)+P(48,8)
    assert DUAL_SQZ.n_dsp == 840              # C(130,8)+P(64,10)


def test_table_iii_equivalent_lut():
    p = pe_structure_lut_equiv(CoreConfig("p", 64, 9))
    c = pe_structure_lut_equiv(CoreConfig("c", 128, 8))
    # paper: P(64,9): LB 39868, mult 40896, adders 17859, total 98623
    assert abs(p["multipliers"] - 40_896) < 1
    assert abs(p["line_buffer"] - 39_868) / 39_868 < 0.01
    assert abs(p["adders"] - 17_859) / 17_859 < 0.01
    assert abs(p["total"] - 98_623) / 98_623 < 0.01
    # paper: C(128,8): mult 72704, adders 31749, total 104453
    assert abs(c["multipliers"] - 72_704) < 1
    assert abs(c["adders"] - 31_749) / 31_749 < 0.01
    assert c["line_buffer"] == 0
    assert abs(c["total"] - 104_453) / 104_453 < 0.01
    # "similar total equivalent cost indicates similar area"
    assert abs(p["total"] - c["total"]) / c["total"] < 0.10


def test_table_i_resource_model():
    a = core_area(P128_9, include_invariant=True)
    # paper's own model: LUT 137,149 / FF 234,046 / DSP 577 / BRAM 237
    assert a.dsp == 577
    assert abs(a.lut - 137_149) / 137_149 < 0.03
    assert abs(a.ff - 234_046) / 234_046 < 0.03
    assert abs(a.bram18k - 237) / 237 < 0.20   # BRAM banking approximated


def test_dual_area_within_budget():
    from repro.core import ResourceBudget
    budget = ResourceBudget()
    for cfg in (DUAL_BASELINE, DUAL_MBV1, DUAL_MBV2, DUAL_SQZ, DUAL_MULTI):
        a = dual_core_area(cfg)
        assert budget.fits(a.dsp, a.bram18k, a.lut, a.ff), str(cfg)


# --------------------------------------------------------------------------
# Latency calibration (Table IV)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("model,target", sorted(TABLE_IV.items()))
def test_table_iv_cycle_counts(model, target):
    """Cycle-accurate simulator within 3% of the paper's board cycles
    (the paper's own simulator is within 1% of its board; our constants are
    calibrated, see EXPERIMENTS.md §Repro)."""
    g = get_graph(model)
    sim = simulate_single_core(g, P128_9, BoardModel())
    assert abs(sim.cycles - target) / target < 0.03


def test_analytic_matches_simulator():
    """Eq.7 analytic total vs instruction-level simulation: < 2%."""
    b = BoardModel()
    for model in TABLE_IV:
        g = get_graph(model)
        _, analytic, _ = graph_latency_report(g.topological_order(),
                                              P128_9, b)
        sim = simulate_single_core(g, P128_9, b).cycles
        assert abs(analytic - sim) / sim < 0.02


def test_fig1_zigzag_dw_vs_conv():
    """Fig.1: depthwise layers run at much lower PE efficiency than the
    regular convolutions around them (the paper's motivation)."""
    b = BoardModel()
    g = get_graph("mobilenet_v1")
    rows, _, _ = graph_latency_report(g.topological_order(), P128_9, b)
    dw = [r.pe_efficiency(P128_9) for r in rows if r.layer.startswith("dw")]
    pw = [r.pe_efficiency(P128_9) for r in rows if r.layer.startswith("pw")]
    assert sum(dw) / len(dw) < 0.5 * (sum(pw) / len(pw))


def test_model_average_efficiency_band():
    """Fig.1 model averages: 59% / 41% / 62% on P(128,9).  Our calibrated
    model lands in-band for the weighted average (+-20pp tolerance: the
    paper's is an unweighted layer mean from unpublished traces)."""
    b = BoardModel()
    paper = {"mobilenet_v1": 0.59, "mobilenet_v2": 0.41, "squeezenet": 0.62}
    for m, eff_p in paper.items():
        g = get_graph(m)
        _, _, eff = graph_latency_report(g.topological_order(), P128_9, b)
        assert abs(eff - eff_p) < 0.20, (m, eff, eff_p)
