"""Scheduler + search invariants and paper Table V/VI/VII trend anchors."""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (ALLOCATION_SCHEMES, BoardModel, LayerSpec,
                        P128_9, DUAL_BASELINE,
                        DUAL_MBV1, DUAL_MBV2, DUAL_SQZ, DUAL_MULTI,
                        ResourceBudget, best_schedule, build_schedule,
                        chain_graph, evaluate_config, harmonic_mean,
                        load_balance, simulate_dual_core,
                        simulate_single_core, search)
from repro.models.zoo import get_graph

B = BoardModel()


def _random_graph(layer_params):
    layers = []
    h, w, c = 64, 64, 8
    for i, (op_dw, c_out_mult, k, s) in enumerate(layer_params):
        if op_dw:
            layers.append(LayerSpec(f"l{i}", "dwconv", h, w, c, c, 3, 3, s,
                                    pad=1))
        else:
            c_out = max(8, c * c_out_mult)
            layers.append(LayerSpec(f"l{i}", "conv", h, w, c, c_out, k, k, s,
                                    pad=k // 2))
            c = c_out
        h, w = max(1, -(-h // s)), max(1, -(-w // s))
    return chain_graph("rand", layers)


layer_strategy = st.lists(
    st.tuples(st.booleans(), st.sampled_from([1, 2]),
              st.sampled_from([1, 3]), st.sampled_from([1, 2])),
    min_size=2, max_size=10)


# --------------------------------------------------------------------------
# Structural invariants (property-based)
# --------------------------------------------------------------------------
@settings(max_examples=25, deadline=None)
@given(layer_strategy)
def test_schedule_invariants(params):
    g = _random_graph(params)
    for scheme in ALLOCATION_SCHEMES:
        s = build_schedule(g, DUAL_BASELINE, B, scheme)
        # groups alternate cores and cover every layer exactly once
        assert s.validate_alternating()
        names = [l.name for gr in s.groups for l in gr.layers]
        assert names == [l.name for l in g.topological_order()]
        # makespan is at least the per-stream critical path
        assert s.t_b2() >= max(s.group_latencies)


@settings(max_examples=15, deadline=None)
@given(layer_strategy)
def test_load_balance_never_worse(params):
    g = _random_graph(params)
    s = build_schedule(g, DUAL_BASELINE, B, "round_robin")
    lb = load_balance(s)
    assert lb.t_b2() <= s.t_b2()
    # layer splitting conserves every layer (possibly as .a/.b parts)
    orig = {l.name for l in g.topological_order()}
    seen = {l.name.split(".")[0].rstrip("ab").rstrip(".")
            for gr in lb.groups for l in gr.layers}
    base = {n.split(".")[0] for n in seen}
    assert {n.split(".")[0] for n in orig} <= base | orig


@settings(max_examples=15, deadline=None)
@given(layer_strategy)
def test_makespan_physical_lower_bound(params):
    """No schedule may beat the aggregate MAC throughput of both cores."""
    g = _random_graph(params)
    s = best_schedule(g, DUAL_BASELINE, B)
    peak = DUAL_BASELINE.c.n_mult + DUAL_BASELINE.p.n_mult
    lb_cycles = 2 * g.total_macs / peak       # 2 images, perfect overlap
    assert s.t_b2() >= lb_cycles


@settings(max_examples=10, deadline=None)
@given(layer_strategy)
def test_simulator_vs_analytic_dual(params):
    """Instruction-level simulation tracks the Eq.7/Eq.9 analytic makespan
    up to pipeline fill/drain (L_dram + L_post per slot boundary)."""
    g = _random_graph(params)
    s = best_schedule(g, DUAL_BASELINE, B)
    sim = simulate_dual_core(s)
    slack = 0.05 * s.t_b2() + (B.l_dram + 2 * B.l_post) * (len(s.groups) + 2)
    assert abs(sim.cycles_two_images - s.t_b2()) <= slack


# --------------------------------------------------------------------------
# Paper trend anchors
# --------------------------------------------------------------------------
def test_table_v_load_balance_improves():
    """Table V: load-balance-heuristic beats the basic schemes (~10% avg)."""
    gains = []
    for model in ("mobilenet_v1", "mobilenet_v2", "squeezenet"):
        g = get_graph(model)
        basic = max(build_schedule(g, DUAL_BASELINE, B, s).throughput_fps()
                    for s in ALLOCATION_SCHEMES)
        lb = best_schedule(g, DUAL_BASELINE, B,
                           paper_faithful=True).throughput_fps()
        assert lb >= basic
        gains.append(lb / basic - 1)
    assert sum(gains) / len(gains) > 0.04      # avg improvement visible


@pytest.mark.parametrize("model,cfg,paper_fps", [
    ("mobilenet_v1", DUAL_MBV1, 358.4),
    ("mobilenet_v2", DUAL_MBV2, 438.4),
    ("squeezenet", DUAL_SQZ, 534.7),
])
def test_table_vi_dual_beats_single(model, cfg, paper_fps):
    """Table VI: the per-CNN dual config beats same-area P(128,9) and lands
    within 25% of the paper's absolute fps (model calibration tolerance;
    see EXPERIMENTS.md for the exact deltas)."""
    g = get_graph(model)
    base = B.fps(simulate_single_core(g, P128_9, B).cycles)
    dual = best_schedule(g, cfg, B, paper_faithful=True).throughput_fps()
    assert dual > base * 1.1                  # >= +10% (paper: +20..+40%)
    assert abs(dual - paper_fps) / paper_fps < 0.25


@pytest.mark.slow
def test_table_vii_multi_cnn_tradeoff():
    """Table VII: the multi-CNN config C(128,10)+P(32,12) has a higher
    harmonic-mean fps than at least two of the single-CNN-optimal configs,
    and each single-CNN config wins on its own model vs the multi config
    for at least one model."""
    graphs = [get_graph(m) for m in
              ("mobilenet_v1", "mobilenet_v2", "squeezenet")]
    obj_multi, fps_multi, _ = evaluate_config(DUAL_MULTI, graphs, B)
    beaten = 0
    for cfg in (DUAL_MBV1, DUAL_MBV2, DUAL_SQZ):
        obj, _, _ = evaluate_config(cfg, graphs, B)
        if obj_multi >= obj * 0.98:
            beaten += 1
    assert beaten >= 2


@pytest.mark.slow
def test_search_finds_feasible_config():
    g = get_graph("mobilenet_v1")
    res = search([g], B, max_evals=6)
    budget = ResourceBudget()
    from repro.core import dual_core_area
    a = dual_core_area(res.config)
    assert budget.fits(a.dsp, a.bram18k, a.lut, a.ff)
    assert res.objective > 0
    # dual search result should beat the single-core baseline
    base = B.fps(simulate_single_core(g, P128_9, B).cycles)
    assert res.objective > base


def test_harmonic_mean():
    assert harmonic_mean([2, 2]) == pytest.approx(2)
    assert harmonic_mean([1, 3]) == pytest.approx(1.5)
    assert harmonic_mean([]) == 0.0
