"""Tile-sizing invariants (Eq.2-4) + ISA/simulator units."""

from hypothesis import given, settings, strategies as st

from repro.core import (BoardModel, CoreConfig, LayerSpec, P128_9,
                        compute_cycles, layer_latency, load_cycles,
                        tile_layer)
from repro.core.isa import compile_layer
from repro.core.simulator import run_stream

B = BoardModel()


def layers_strategy():
    return st.builds(
        lambda h, ci, co, k, s, dw: LayerSpec(
            "l", "dwconv" if dw else "conv", h, h,
            ci if not dw else ci, ci if dw else co,
            k, k, s, pad=k // 2),
        st.sampled_from([7, 14, 28, 56, 112, 224]),
        st.sampled_from([3, 16, 32, 64, 128, 256, 512, 1024]),
        st.sampled_from([16, 32, 64, 128, 256, 512, 1000, 1024]),
        st.sampled_from([1, 3, 5]),
        st.sampled_from([1, 2]),
        st.booleans())


def cores_strategy():
    return st.builds(
        lambda kind, n, v: CoreConfig(kind, n, v),
        st.sampled_from(["c", "p"]),
        st.sampled_from([8, 32, 64, 128, 180]),
        st.sampled_from([8, 9, 10, 12, 16]))


@settings(max_examples=80, deadline=None)
@given(layers_strategy(), cores_strategy())
def test_tiling_invariants(layer, core):
    """Eq.2: the live multiplier count never exceeds the array; tiles never
    exceed the layer dims; c-core never uses a window tile."""
    t = tile_layer(layer, core)
    assert 1 <= t.T_kh <= layer.K_h and 1 <= t.T_kw <= layer.K_w
    assert 1 <= t.T_ci <= max(layer.C_i, 1)
    assert 1 <= t.T_co <= max(layer.C_o, core.n)
    if not core.has_line_buffer and not t.fold:
        assert t.T_kh == t.T_kw == 1
    assert t.utilization(core) <= 1.0 + 1e-9
    # Eq.4: spatial block fits the buffer
    assert t.T_h * t.T_w <= core.buffer_depth


@settings(max_examples=60, deadline=None)
@given(layers_strategy(), cores_strategy())
def test_compute_cycles_lower_bounded_by_macs(layer, core):
    """No tiling may beat the MAC-rate bound (Eq.11 is a true bound)."""
    cycles, _ = compute_cycles(layer, core, B)
    assert cycles * core.n_mult >= layer.macs * 0.999


@settings(max_examples=40, deadline=None)
@given(layers_strategy(), cores_strategy())
def test_load_cycles_model(layer, core):
    assert load_cycles(layer, B) >= layer.load_elems // B.bw_dram


def test_dwconv_prefers_pcore():
    """The paper's motivation: depthwise conv runs far better on the
    line-buffered p-core than on the c-core at equal area."""
    dw = LayerSpec("dw", "dwconv", 14, 14, 512, 512, 3, 3, 1, pad=1)
    c = layer_latency(dw, CoreConfig("c", 128, 9), B)
    p = layer_latency(dw, CoreConfig("p", 128, 9), B)
    assert p.t_compute * 3 < c.t_compute


def test_pointwise_prefers_ccore_at_equal_area():
    pw = LayerSpec("pw", "conv", 14, 14, 512, 512, 1, 1, 1)
    c = layer_latency(pw, CoreConfig("c", 128, 8), B)
    p = layer_latency(pw, CoreConfig("p", 64, 9), B)   # ~same equiv area
    assert c.t_compute < p.t_compute


# --------------------------------------------------------------------------
# ISA + simulator
# --------------------------------------------------------------------------
def test_compile_layer_structure():
    l = LayerSpec("x", "conv", 56, 56, 64, 128, 3, 3, 1, pad=1)
    instrs = compile_layer(l, P128_9, B)
    ops = [i.op for i in instrs]
    assert ops[0] == "LOAD" and ops[-1] == "STORE"
    assert ops.count("LOAD") == ops.count("COMPUTE")
    # blocked loads alternate ping/pong banks
    banks = [i.bank for i in instrs if i.op == "LOAD"]
    assert all(b in (0, 1) for b in banks)


def test_simulator_matches_analytic_per_layer():
    l = LayerSpec("x", "conv", 56, 56, 64, 128, 3, 3, 1, pad=1)
    instrs = compile_layer(l, P128_9, B)
    tr = run_stream(instrs, B)
    analytic = layer_latency(l, P128_9, B).t_layer
    assert abs(tr.cycles - analytic) <= 0.05 * analytic + B.l_dram \
        + 2 * B.l_post


def test_simulator_overlaps_load_and_compute():
    """Ping-pong banks must overlap: total < sum of busy times when both
    engines have work."""
    l = LayerSpec("x", "conv", 112, 112, 64, 64, 3, 3, 1, pad=1)
    instrs = compile_layer(l, P128_9, B)
    tr = run_stream(instrs, B)
    assert tr.cycles < tr.busy_cycles["load"] + tr.busy_cycles["compute"]
